//! Profiling probe: one Table-1-scale job (Qwen3-32B, batch 256, TP2)
//! under `concur` (default) or `sglang` (argv[1]) — the workload used for
//! the EXPERIMENTS.md §Perf iterations.
//!
//! ```sh
//! perf record -F 999 ./target/release/examples/perf_probe concur
//! perf report --stdio --no-children
//! ```

use concur::config::{presets, AimdParams, EngineConfig, JobConfig, SchedulerKind, TopologyConfig};
use concur::driver::run_job;
fn main() {
    let sched = match std::env::args().nth(1).as_deref() {
        Some("sglang") => SchedulerKind::Uncontrolled,
        _ => SchedulerKind::Concur(AimdParams::default()),
    };
    let job = JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: presets::qwen3_workload(256),
        scheduler: sched,
        topology: TopologyConfig::default(),
    };
    let t = std::time::Instant::now();
    let r = run_job(&job).unwrap();
    println!("done: sim {} in wall {:?}, steps={}", r.total_time, t.elapsed(), r.engine_steps);
}
