//! Fault drill: kill a replica mid-run, revive it later, and watch the
//! rebalancing router re-home cold agents onto the survivors.
//!
//! A 4-replica Qwen3-TP2 fleet serves 64 agents under CONCUR admission.
//! A healthy probe run anchors the fault instants (kill at 40% of its
//! makespan, revive at 70%), then the same job is re-run under the
//! scripted disruption for each router so the recovery behavior is
//! directly comparable.  For the full sweep (plus `BENCH_faults.json`)
//! use `concur repro cluster_faults`; for the JSON-config route, run
//! `concur sim --config examples/configs/faulty_cluster.json`.
//!
//! ```sh
//! cargo run --release --example fault_drill
//! ```

use concur::config::RouterKind;
use concur::driver::run_job;
use concur::repro::faults::{base_job, plan_for};

fn main() -> concur::core::Result<()> {
    let routers =
        [RouterKind::LeastLoaded, RouterKind::CacheAffinity, RouterKind::Rebalance];

    // Healthy probe: anchors the fault instants so the kill is mid-run
    // (same kill/revive fractions as the repro study, via plan_for).
    let healthy = run_job(&base_job(RouterKind::CacheAffinity, 64))?;
    let plan = plan_for("kill-revive", healthy.total_time, 0);
    println!(
        "healthy makespan {} -> kill replica 0 at {}, revive at {}\n",
        healthy.total_time,
        plan.events()[0].at,
        plan.events()[1].at
    );

    for router in routers {
        let mut job = base_job(router, 64);
        job.topology.fault_plan = plan.clone();
        let r = run_job(&job)?;
        println!("{}", r.summary());
        println!(
            "  {:<14} requeued={} migrations={} kills={} revives={} \
             admissible replicas at end={}",
            router.name(),
            r.faults.requeued_agents,
            r.faults.migrations,
            r.faults.kills,
            r.faults.revives,
            r.alive_series.points().last().map(|p| p.1).unwrap_or(0.0),
        );
    }
    println!(
        "\n(rebalance keeps surviving replicas' pins and migrates cold \
         agents first; least-loaded scatters every step — compare the \
         hit columns above)"
    );
    Ok(())
}
