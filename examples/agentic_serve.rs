//! End-to-end driver: load the REAL AOT-compiled model and serve a batched
//! agentic workload through the full stack — L1 Pallas attention kernels →
//! L2 JAX graphs → HLO text → PJRT executables → rust serving loop under
//! the CONCUR admission controller.  Reports latency and throughput.
//!
//! Requires `make artifacts` (it is a Makefile prerequisite of `build`).
//!
//! ```sh
//! cargo run --release --example agentic_serve
//! ```
//!
//! The workload mimics the ReAct pattern at tiny-model scale: each "agent"
//! issues several generation steps whose prompts accumulate the previous
//! output plus a tool observation.

use std::time::Instant;

use concur::coordinator::concur_default;
use concur::core::ConcurError;
use concur::runtime::ModelRuntime;
use concur::server::{RealServer, Sampling, ServeRequest, tokenizer};

const AGENTS: usize = 6;
const STEPS: usize = 3;
const GEN_PER_STEP: usize = 24;
const BATCH: usize = 4;

fn main() -> concur::core::Result<()> {
    let t0 = Instant::now();
    let rt = ModelRuntime::load_default()
        .map_err(|e| ConcurError::runtime(format!("{e}\nhint: run `make artifacts` first")))?;
    let g = rt.geometry().clone();
    println!(
        "loaded {} compiled graphs in {:.1}s ({} params, vocab {}, max_seq {})",
        rt.manifest.artifacts.len(),
        t0.elapsed().as_secs_f64(),
        g.n_params,
        g.vocab,
        g.max_seq
    );

    // Agent histories evolve across rounds; the server is re-driven per
    // ReAct round (batched within a round, like an RL rollout worker).
    let mut histories: Vec<String> = (0..AGENTS)
        .map(|i| format!("agent {i} plan: explore, observe, act. state:"))
        .collect();

    let mut server = RealServer::new(rt, BATCH, concur_default())?;
    let mut total_gen = 0usize;
    let mut total_wall = 0.0f64;
    let serve_start = Instant::now();
    for round in 0..STEPS {
        for (i, h) in histories.iter().enumerate() {
            // Keep prompts inside the tiny model's max_seq budget.
            let prompt: String = h.chars().rev().take(180).collect::<String>()
                .chars().rev().collect();
            server.submit(ServeRequest {
                id: i as u64,
                prompt,
                max_new: GEN_PER_STEP,
                sampling: Sampling::Temperature(0.9),
            });
        }
        let (results, stats) = server.run_to_completion()?;
        total_gen += stats.total_gen_tokens;
        total_wall += stats.wall.as_secs_f64();
        println!(
            "round {round}: {} requests in {:.2}s — {:.1} tok/s, {} decode steps, \
             ttft p50 {}",
            stats.completed,
            stats.wall.as_secs_f64(),
            stats.tokens_per_sec,
            stats.decode_steps,
            stats.ttft.percentile(50.0),
        );
        // ReAct append: generated text + synthetic tool observation.
        for r in results {
            let obs = format!(" [tool#{round}:ok]");
            histories[r.id as usize].push_str(&r.text);
            histories[r.id as usize].push_str(&obs);
            let _ = tokenizer::encode(&histories[r.id as usize]);
        }
    }

    println!(
        "\nE2E: {AGENTS} agents x {STEPS} ReAct steps = {} generated tokens in \
         {:.2}s serving wall time ({:.1} tok/s overall, {:.2}s incl. setup)",
        total_gen,
        total_wall,
        total_gen as f64 / total_wall,
        serve_start.elapsed().as_secs_f64()
    );
    println!("sample trajectory (agent 0): {:?}...", &histories[0][..histories[0].len().min(160)]);
    Ok(())
}
