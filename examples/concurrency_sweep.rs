//! Concurrency sweep: the "adding more agents reduces throughput" paradox.
//!
//! Sweeps the *offered* batch size under the uncontrolled baseline and
//! under CONCUR on a fixed 2-GPU Qwen3-class replica.  The baseline's
//! throughput collapses past the memory knee (the paper's §3 observation:
//! during the middle phase, more concurrency = less throughput); CONCUR's
//! stays flat because admission is decoupled from the offered load.
//!
//! All (batch × scheduler) cells are independent simulations, so the whole
//! sweep fans out across cores via `run_jobs_parallel` — results are
//! bit-identical to running the cells one by one.
//!
//! ```sh
//! cargo run --release --example concurrency_sweep
//! ```

use concur::config::{presets, AimdParams, EngineConfig, JobConfig, SchedulerKind, TopologyConfig};
use concur::driver::run_jobs_parallel;

const BATCHES: [usize; 5] = [16, 32, 64, 128, 256];

fn main() -> concur::core::Result<()> {
    let jobs: Vec<JobConfig> = BATCHES
        .iter()
        .flat_map(|&batch| {
            [
                SchedulerKind::Uncontrolled,
                SchedulerKind::Concur(AimdParams::default()),
            ]
            .into_iter()
            .map(move |sched| JobConfig {
                cluster: presets::qwen3_cluster(2),
                engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
                workload: presets::qwen3_workload(batch),
                scheduler: sched,
                topology: TopologyConfig::default(),
            })
        })
        .collect();

    let wall = std::time::Instant::now();
    let results = run_jobs_parallel(&jobs)
        .into_iter()
        .collect::<concur::core::Result<Vec<_>>>()?;

    println!("offered-batch sweep on Qwen3-32B TP2 (tokens/s; higher is better)\n");
    println!("{:>8}  {:>12}  {:>12}  {:>10}", "batch", "sglang", "concur", "ratio");
    for (pair, batch) in results.chunks(2).zip(BATCHES) {
        let (sglang, concur) = (pair[0].throughput_tps, pair[1].throughput_tps);
        println!(
            "{:>8}  {:>12.0}  {:>12.0}  {:>9.2}x",
            batch,
            sglang,
            concur,
            concur / sglang
        );
    }
    println!(
        "\n({} simulations in {:.1}s wall time, parallel across cores)",
        results.len(),
        wall.elapsed().as_secs_f64()
    );
    Ok(())
}
