//! Concurrency sweep: the "adding more agents reduces throughput" paradox.
//!
//! Sweeps the *offered* batch size under the uncontrolled baseline and
//! under CONCUR on a fixed 2-GPU Qwen3-class replica.  The baseline's
//! throughput collapses past the memory knee (the paper's §3 observation:
//! during the middle phase, more concurrency = less throughput); CONCUR's
//! stays flat because admission is decoupled from the offered load.
//!
//! ```sh
//! cargo run --release --example concurrency_sweep
//! ```

use concur::config::{presets, AimdParams, EngineConfig, JobConfig, SchedulerKind};
use concur::driver::run_job;

fn main() -> anyhow::Result<()> {
    println!("offered-batch sweep on Qwen3-32B TP2 (tokens/s; higher is better)\n");
    println!("{:>8}  {:>12}  {:>12}  {:>10}", "batch", "sglang", "concur", "ratio");
    for batch in [16usize, 32, 64, 128, 256] {
        let mut tput = Vec::new();
        for sched in [
            SchedulerKind::Uncontrolled,
            SchedulerKind::Concur(AimdParams::default()),
        ] {
            let job = JobConfig {
                cluster: presets::qwen3_cluster(2),
                engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
                workload: presets::qwen3_workload(batch),
                scheduler: sched,
            };
            let r = run_job(&job).map_err(|e| anyhow::anyhow!(e.to_string()))?;
            tput.push(r.throughput_tps);
        }
        println!(
            "{:>8}  {:>12.0}  {:>12.0}  {:>9.2}x",
            batch,
            tput[0],
            tput[1],
            tput[1] / tput[0]
        );
    }
    Ok(())
}
