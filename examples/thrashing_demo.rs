//! Figure 2 as runnable code: six agents, a KV pool sized for three.
//!
//! (a) Uncontrolled: all six run concurrently; whenever one pauses for a
//!     tool call its cache loses recency and gets evicted by the others —
//!     every resume recomputes (middle-phase thrashing in miniature).
//! (b) Agent-level admission (cap 3): at most three agents hold slots; the
//!     rest wait; resident caches survive and recompute collapses.
//!
//! ```sh
//! cargo run --release --example thrashing_demo
//! ```

use concur::agent::{Agent, StepPlan};
use concur::config::{EngineConfig, SchedulerKind};
use concur::coordinator::make_controller;
use concur::core::{AgentId, Micros};
use concur::costmodel::{ClusterSpec, CostModel, GpuSpec, ModelSpec};
use concur::driver::run_with;
use concur::engine::SimEngine;
use concur::metrics::Phase;

/// Six deterministic agents, each: 2k-token context, 4 ReAct steps of
/// 200 generated + 300 tool tokens, 1 s tool calls.
fn fleet() -> Vec<Agent> {
    (0..6u32)
        .map(|i| {
            let base = 1_000_000 * (i + 1);
            let ctx: Vec<u32> = (base..base + 2_000).collect();
            let plan = (0..4u32)
                .map(|k| StepPlan {
                    gen: (base + 10_000 * (k + 1)..base + 10_000 * (k + 1) + 200)
                        .collect(),
                    tool_tokens: (base + 20_000 * (k + 1)
                        ..base + 20_000 * (k + 1) + 300)
                        .collect(),
                    tool_latency: Micros(1_000_000),
                })
                .collect();
            Agent::new(AgentId(i as u64), ctx, plan)
        })
        .collect()
}

/// Engine whose pool fits roughly three of the six agents.
fn tiny_engine() -> SimEngine {
    let cluster = ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), 8, 8);
    let mut engine = SimEngine::new(
        EngineConfig { hit_window: 4, ..EngineConfig::default() },
        CostModel::new(cluster),
    );
    engine.shrink_pool_for_tests(12_000); // ~3 agents x ~4k final context
    engine
}

fn main() -> concur::core::Result<()> {
    println!("Fig 2 demo: 6 agents, KV pool sized for 3\n");
    for scheduler in [SchedulerKind::Uncontrolled, SchedulerKind::AgentCap(3)] {
        let mut engine = tiny_engine();
        let r = run_with(&mut engine, fleet(), make_controller(&scheduler))?;
        println!("--- {}", r.scheduler);
        println!("  batch latency    : {}", r.total_time);
        println!("  cache hit rate   : {:.1}%", r.hit_rate * 100.0);
        println!("  evicted tokens   : {}", r.counters.evicted_tokens);
        println!("  recompute tokens : {}", r.counters.recompute_tokens);
        println!(
            "  recompute share  : {:.1}% of engine time",
            r.breakdown.fraction(Phase::Recompute) * 100.0
        );
        println!();
    }
    println!(
        "Uncontrolled: paused agents' prefixes get evicted -> repeated\n\
         recomputation.  Agent-level admission bounds the resident set ->\n\
         eviction-induced recompute collapses, exactly Fig. 2(b)."
    );
    Ok(())
}
