//! Ablation: which control-loop mechanisms actually matter?
//!
//! Runs the Table-1 Qwen3-TP2 cell with each of the implementation's
//! stability mechanisms disabled in turn (see DESIGN.md §7 /
//! EXPERIMENTS.md §Documented-deviations):
//!
//! * full        — CONCUR as shipped
//! * -band-probe — pure Eq. 1 growth (no congestion-avoidance probing)
//! * -cooldown   — cuts may fire every control interval
//! * slow-H      — coarse hit-rate window (64 requests instead of 8)
//!
//! The variants are independent, so they fan out across cores via
//! `run_jobs_parallel` (bit-identical results to a serial run).
//!
//! ```sh
//! cargo run --release --example ablation
//! ```

use concur::config::{presets, AimdParams, EngineConfig, JobConfig, SchedulerKind, TopologyConfig};
use concur::driver::run_jobs_parallel;

fn main() -> concur::core::Result<()> {
    let variants: Vec<(&str, AimdParams, usize)> = vec![
        ("full", AimdParams::default(), 8),
        (
            "-band-probe",
            AimdParams { band_probe_every: 0, ..AimdParams::default() },
            8,
        ),
        (
            "-cut-cooldown",
            AimdParams { cut_cooldown: 0, ..AimdParams::default() },
            8,
        ),
        ("slow-H (window 64)", AimdParams::default(), 64),
    ];

    let jobs: Vec<JobConfig> = variants
        .iter()
        .map(|(_, params, hit_window)| JobConfig {
            cluster: presets::qwen3_cluster(2),
            engine: EngineConfig { hit_window: *hit_window, ..EngineConfig::default() },
            workload: presets::qwen3_workload(256),
            scheduler: SchedulerKind::Concur(*params),
            topology: TopologyConfig::default(),
        })
        .collect();
    let results = run_jobs_parallel(&jobs)
        .into_iter()
        .collect::<concur::core::Result<Vec<_>>>()?;

    println!("ablation on Qwen3-32B, batch 256, TP2 (lower latency is better)\n");
    println!(
        "{:<22} {:>12} {:>8} {:>11} {:>8}",
        "variant", "latency (s)", "hit", "recompute", "pauses"
    );
    let mut base = None;
    for ((name, _, _), r) in variants.iter().zip(&results) {
        let lat = r.total_time.as_secs_f64();
        let delta = base
            .map(|b: f64| format!(" ({:+.0}%)", (lat / b - 1.0) * 100.0))
            .unwrap_or_default();
        if base.is_none() {
            base = Some(lat);
        }
        println!(
            "{:<22} {:>12} {:>7.1}% {:>10.1}% {:>8}{delta}",
            name,
            format!("{lat:.0}"),
            r.hit_rate * 100.0,
            r.breakdown.fraction(concur::metrics::Phase::Recompute) * 100.0,
            r.pauses,
        );
    }
    println!(
        "\nRemoving band probing strands capacity after the first congestion\n\
         epoch (+15% here); a coarse hit window reacts too slowly to the\n\
         eviction storm (+8%).  The cut cooldown is neutral at this config —\n\
         the drain gate (one cut until active <= W) already subsumes it; it\n\
         matters when tool latencies are long relative to control intervals."
    );
    Ok(())
}
