//! Replica sweep: data-parallel cluster scaling across router policies.
//!
//! Runs the `cluster_scaling` grid — a fixed offered load of 128 agents
//! on 1/2/4/8 Qwen3-TP2 engine replicas under round-robin, least-loaded
//! and cache-affinity routing — prints the scaling table, and writes
//! `BENCH_cluster.json` (override the path with `BENCH_JSON_PATH`) so the
//! nightly CI job can archive the fleet-scaling trajectory next to
//! `BENCH_hotpath.json`.
//!
//! ```sh
//! cargo run --release --example replica_sweep
//! ```

use concur::repro::cluster_scaling;

fn main() -> concur::core::Result<()> {
    let wall = std::time::Instant::now();
    let cells = cluster_scaling::run_sweep()?;
    println!("{}", cluster_scaling::output_from(&cells).render());

    let json_path = std::env::var("BENCH_JSON_PATH")
        .unwrap_or_else(|_| "BENCH_cluster.json".to_string());
    let json_path = std::path::PathBuf::from(json_path);
    std::fs::write(
        &json_path,
        format!("{}\n", cluster_scaling::bench_json(&cells).to_string_pretty()),
    )?;
    println!(
        "({} simulations in {:.1}s wall time; machine-readable results \
         written to {})",
        cells.len(),
        wall.elapsed().as_secs_f64(),
        json_path.display()
    );
    Ok(())
}
