//! Quickstart: run one simulated agentic batch job under CONCUR and print
//! what the controller did.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use concur::config::{presets, AimdParams, EngineConfig, JobConfig, SchedulerKind, TopologyConfig};
use concur::driver::run_job;

fn main() -> concur::core::Result<()> {
    // 64 ReAct agents against a Qwen3-32B-class replica on 2 GPUs — a
    // memory-constrained setup where admission control matters.
    let job = JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: presets::qwen3_workload(64),
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig::default(),
    };

    let r = run_job(&job)?;

    println!("scheduler        : {}", r.scheduler);
    println!("agents finished  : {}/{}", r.agents_finished, r.agents_total);
    println!("batch latency    : {}", r.total_time);
    println!("throughput       : {:.0} generated tokens/s", r.throughput_tps);
    println!("cache hit rate   : {:.1}%", r.hit_rate * 100.0);
    println!("pauses / resumes : {} / {}", r.pauses, r.resumes);
    println!("\nwhere the time went:\n{}", r.breakdown.report());
    println!("controller window over time:");
    print!("{}", r.window_series.ascii_plot(64, 8));
    Ok(())
}
