//! `concur` — CLI for the CONCUR reproduction.
//!
//! ```text
//! concur repro <exp|all> [--csv DIR]     regenerate paper tables/figures
//!                                        (+ cluster / cluster_faults /
//!                                         prefix_sharing / transport
//!                                         studies)
//! concur sim --config FILE               run a custom simulated job
//! concur serve [--batch N] [--prompt S] [--max-new N] [--requests N]
//!                                        serve the real tiny model (PJRT)
//! concur trace --out FILE [--agents N] [--seed S]
//!                                        dump a deterministic workload trace
//! concur bench gate --bench FILE --thresholds FILE --profile NAME
//!                                        perf-gate a BENCH json (exit 0 pass,
//!                                        1 breach, 2 config/IO error)
//! concur bench summary FILE...           one-line digests for CI summaries
//! concur info                            print presets + pool arithmetic
//! ```
//!
//! (The vendored crate set has no clap; this is a small hand-rolled parser.)

use std::path::PathBuf;
use std::process::ExitCode;

use concur::agent::{trace, WorkloadGenerator};
use concur::config::{presets, JobConfig, WorkloadConfig};
use concur::coordinator::concur_default;
use concur::core::Result;
use concur::driver::run_job;
use concur::repro;
use concur::runtime::ModelRuntime;
use concur::server::{RealServer, Sampling, ServeRequest};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // `bench gate` owns its exit codes (0 pass / 1 breach / 2 config
    // error) so CI can tell a regression from a wiring bug; every other
    // command keeps the plain ok/err mapping.
    if args.first().map(String::as_str) == Some("bench") {
        return cmd_bench(&args[1..]);
    }
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Pull `--flag value` out of the arg list.
fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("repro") => cmd_repro(&args[1..]),
        Some("sim") => cmd_sim(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("info") => cmd_info(),
        Some("--help" | "-h" | "help") | None => {
            print!("{}", usage());
            Ok(())
        }
        Some(other) => {
            eprint!("unknown command '{other}'\n\n{}", usage());
            Err(concur::core::ConcurError::config("unknown command"))
        }
    }
}

/// Usage text; the `repro` experiment list is generated from the same
/// table (`repro::EXPERIMENTS`) that drives dispatch and its
/// unknown-name error, so the three can never drift apart.
fn usage() -> String {
    format!(
        "\
concur — congestion-based agent-level admission control (paper reproduction)

USAGE:
  concur repro <{}> [--csv DIR]
  concur sim --config FILE
  concur serve [--batch N] [--requests N] [--max-new N] [--prompt TEXT]
               [--artifacts DIR] [--temperature T]
  concur trace --out FILE [--agents N] [--seed S]
  concur bench gate --bench FILE --thresholds FILE --profile NAME
  concur bench summary FILE...
  concur info
",
        repro::cli_name_list()
    )
}

/// `concur bench <gate|summary>` — the CI perf-gate surface.  Returns the
/// process exit code directly: the gate distinguishes "perf regression"
/// (1) from "the gate itself is misconfigured" (2).
fn cmd_bench(args: &[String]) -> ExitCode {
    match args.first().map(String::as_str) {
        Some("gate") => {
            let (Some(bench), Some(thresholds), Some(profile)) = (
                flag(args, "--bench"),
                flag(args, "--thresholds"),
                flag(args, "--profile"),
            ) else {
                eprintln!(
                    "error: bench gate requires --bench FILE --thresholds FILE --profile NAME"
                );
                return ExitCode::from(2);
            };
            match concur::gate::run_gate_files(
                std::path::Path::new(&bench),
                std::path::Path::new(&thresholds),
                &profile,
            ) {
                Ok(report) => {
                    print!("{}", report.render());
                    if report.passed() {
                        ExitCode::SUCCESS
                    } else {
                        ExitCode::from(1)
                    }
                }
                Err(e) => {
                    eprintln!("error: {e}");
                    ExitCode::from(2)
                }
            }
        }
        Some("summary") => {
            let files: Vec<&String> = args[1..].iter().filter(|a| !a.starts_with("--")).collect();
            if files.is_empty() {
                eprintln!("error: bench summary requires at least one BENCH json file");
                return ExitCode::from(2);
            }
            for f in files {
                let line = std::fs::read_to_string(f)
                    .map_err(|e| concur::core::ConcurError::config(format!("{f}: {e}")))
                    .and_then(|text| concur::core::json::Value::parse(&text))
                    .map(|v| concur::gate::summarize_bench(f, &v));
                match line {
                    Ok(line) => println!("{line}"),
                    Err(e) => {
                        eprintln!("error: {e}");
                        return ExitCode::from(2);
                    }
                }
            }
            ExitCode::SUCCESS
        }
        _ => {
            eprintln!("error: bench expects a 'gate' or 'summary' subcommand\n\n{}", usage());
            ExitCode::from(2)
        }
    }
}

fn cmd_repro(args: &[String]) -> Result<()> {
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let outputs = repro::run(&name)?;
    let csv_dir = flag(args, "--csv").map(PathBuf::from);
    for o in &outputs {
        println!("{}", o.render());
        if let Some(dir) = &csv_dir {
            let p = o.write_csv(dir)?;
            println!("(csv written to {})\n", p.display());
        }
    }
    Ok(())
}

fn cmd_sim(args: &[String]) -> Result<()> {
    let path = flag(args, "--config").ok_or_else(|| {
        concur::core::ConcurError::config("sim requires --config FILE")
    })?;
    let job = JobConfig::from_json_file(std::path::Path::new(&path))?;
    let r = run_job(&job)?;
    println!("{}", r.summary());
    println!("\nbreakdown:\n{}", r.breakdown.report());
    println!("agent latency: {}", r.agent_latency.summary());
    println!(
        "engine: steps={} preemptions={} evictions={} (evicted {} tokens)",
        r.engine_steps,
        r.counters.preemptions,
        r.counters.evictions,
        r.counters.evicted_tokens
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let dir = flag(args, "--artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(concur::runtime::artifacts::default_dir);
    let batch: usize = flag(args, "--batch")
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let n_requests: usize = flag(args, "--requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let max_new: usize = flag(args, "--max-new")
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    let sampling = match flag(args, "--temperature").and_then(|s| s.parse::<f64>().ok())
    {
        Some(t) if t > 0.0 => Sampling::Temperature(t),
        _ => Sampling::Greedy,
    };

    eprintln!("loading artifacts from {} ...", dir.display());
    let rt = ModelRuntime::load(&dir)?;
    eprintln!(
        "model: {} params, vocab {}, max_seq {}",
        rt.geometry().n_params,
        rt.geometry().vocab,
        rt.geometry().max_seq
    );
    let mut server = RealServer::new(rt, batch, concur_default())?;

    let prompts: Vec<String> = if let Some(p) = flag(args, "--prompt") {
        vec![p]
    } else {
        (0..n_requests)
            .map(|i| format!("Agent {i} reporting observations: step"))
            .collect()
    };
    for (i, p) in prompts.iter().enumerate() {
        server.submit(ServeRequest {
            id: i as u64,
            prompt: p.clone(),
            max_new,
            sampling,
        });
    }
    let (results, stats) = server.run_to_completion()?;
    for r in &results {
        println!(
            "[req {}] {} prompt tokens -> {} generated, ttft {:.1} ms, e2e {:.1} ms",
            r.id,
            r.prompt_tokens,
            r.gen_tokens,
            r.ttft.as_secs_f64() * 1e3,
            r.e2e.as_secs_f64() * 1e3
        );
    }
    println!(
        "\ncompleted {} requests in {:.2}s — {:.1} tok/s, {} decode steps, {} extend calls",
        stats.completed,
        stats.wall.as_secs_f64(),
        stats.tokens_per_sec,
        stats.decode_steps,
        stats.extend_calls
    );
    println!("{}", stats.ttft.summary());
    println!("{}", stats.e2e.summary());
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let out = flag(args, "--out").ok_or_else(|| {
        concur::core::ConcurError::config("trace requires --out FILE")
    })?;
    let mut wl = WorkloadConfig::default();
    if let Some(n) = flag(args, "--agents").and_then(|s| s.parse().ok()) {
        wl.n_agents = n;
    }
    if let Some(s) = flag(args, "--seed").and_then(|s| s.parse().ok()) {
        wl.seed = s;
    }
    let agents = WorkloadGenerator::new(wl).generate();
    trace::write_trace(std::path::Path::new(&out), &agents)?;
    let summary = trace::read_trace_summary(std::path::Path::new(&out))?;
    println!(
        "wrote {} agents / {} steps / {} gen tokens to {out}",
        summary.n_agents, summary.total_steps, summary.total_gen_tokens
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("cluster presets (H100-80GB, usable 90%):\n");
    for (label, cluster) in [
        ("Qwen3-32B TP8", presets::qwen3_cluster(8)),
        ("Qwen3-32B TP4", presets::qwen3_cluster(4)),
        ("Qwen3-32B TP2", presets::qwen3_cluster(2)),
        ("DeepSeek-V3 TP16", presets::dsv3_cluster(16)),
    ] {
        println!(
            "  {label:<18} kv/token={:>8}B  pool={:>8.1}GB = {:>9} token slots",
            cluster.model.kv_bytes_per_token(),
            cluster.kv_pool_bytes().as_gb(),
            cluster.kv_pool_tokens()
        );
    }
    println!("\nAIMD defaults (paper §5): alpha=2 beta=0.5 U=[0.2,0.5] H=0.2");
    Ok(())
}
