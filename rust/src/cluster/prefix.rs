//! Cross-replica shared-prefix broadcast tier.
//!
//! Sharding (cluster/) splits cross-agent prefix reuse: every replica
//! re-prefills the same family system prompt once, so the aggregate hit
//! rate `H_t` the admission controller feeds on is structurally depressed
//! at N>1 (the "lost shared-prefix hits" ROADMAP item).  [`SharedPrefixTier`]
//! recovers those hits the KVFlow way — by *shipping* hot shared prefixes
//! instead of re-computing them:
//!
//! 1. **Detect.**  Every submitted prompt is [`observe`]d.  Prompt heads
//!    are tracked as candidates; when two prompts overlap by at least
//!    `min_prefix_tokens`, the candidate shrinks to their exact common
//!    prefix (the LCP), so candidates converge onto true shared prefixes
//!    (family system prompts and anything beyond).  Reuse is counted per
//!    *distinct* agent — an agent extending its own history is not
//!    sharing.
//! 2. **Promote.**  A candidate reused by `hot_after` distinct agents is
//!    promoted to the broadcast tier, within a token budget; promotion
//!    past the budget demotes the stalest hot prefix first.
//! 3. **Ship.**  Once some alive replica holds the full prefix
//!    GPU-resident (the source — broadcasts move KV, they do not invent
//!    it), the tier installs it on every alive replica that lacks it:
//!    [`SimEngine::install_broadcast_prefix`] materialises the tokens,
//!    charges the simulated interconnect transfer, and **broadcast-pins**
//!    the radix path so per-replica LRU eviction can never drop it while
//!    it stays hot.  Replicas wiped by a kill or a drain-refill are
//!    re-shipped when they rejoin ([`on_replica_wiped`] clears the
//!    install, the next maintenance pass restores it).  With the cluster
//!    transport on, the install is a real [`Transfer`] over the shared
//!    fabric: per-target delta sizing (`delta_ship`) and — under
//!    `delayed_visibility` — a reserve/commit pair, where the pending
//!    install matches zero tokens and feeds no routing hint until its
//!    transfer's completion pops ([`on_transfer_done`]).
//! 4. **Demote.**  A hot prefix not reused for `cool_after` is demoted on
//!    every replica: the KV stays cached but becomes ordinary evictable
//!    state.
//!
//! **Content-hash detection** (`cfg.content_hash`, off by default) adds
//! a second candidate index over *non-head* chunks: every W-aligned
//! `hash_chunk_tokens` window past a prompt's head (and past its
//! hot-covered prefix) is hashed into a bounded table.  LCP detection is
//! structurally blind to mid-prompt sharing — two prompts embedding the
//! same intermediate context at *different offsets* (a workflow planner's
//! generated context vs. its workers' prompts, see
//! [`crate::agent::workflow_fleet`]) never converge head-first.  A chunk
//! seen by `hot_after` distinct agents promotes its **head-extended run**
//! — `prompt[..off + W]` from the smallest-offset sighting — which is a
//! true prefix of every prompt carrying the chunk at that offset, so it
//! rides the ordinary promote/ship machinery unchanged (broadcast pins
//! nest, so a run extending an already-hot family head is safe).
//!
//! Everything is deterministic — candidate order, promotion order and
//! install order follow insertion and replica index — and the whole tier
//! is inert unless `TopologyConfig::prefix_tier.enabled` is set: the
//! tier-off cluster path is differential-tested bit-identical to the
//! pre-tier loop.
//!
//! [`observe`]: SharedPrefixTier::observe
//! [`on_replica_wiped`]: SharedPrefixTier::on_replica_wiped
//! [`on_transfer_done`]: SharedPrefixTier::on_transfer_done
//! [`SimEngine::install_broadcast_prefix`]: crate::engine::SimEngine::install_broadcast_prefix

use crate::cluster::transport::{Transfer, TransferKind, Transport};
use crate::config::PrefixTierConfig;
use crate::core::{simd, AgentId, Micros, Token};
use crate::engine::radix::NodeId;
use crate::engine::SimEngine;

/// Detection cap: a candidate registers at most this many tokens of a
/// prompt head; the true shared prefix is recovered by LCP shrinking, so
/// the cap only bounds detection memory, not what can be shared.
const MAX_CANDIDATE_TOKENS: usize = 4096;

/// Bound on simultaneously tracked candidates (≈ distinct prompt
/// families in flight); a new head arriving at a full table replaces
/// the stalest candidate, so detection keeps adapting.
const MAX_CANDIDATES: usize = 64;

/// Bound on simultaneously tracked content-hash chunk candidates, with
/// the same stalest-replacement policy as `MAX_CANDIDATES` but wider —
/// every prompt contributes several non-head chunks (up to
/// `MAX_CANDIDATE_TOKENS / hash_chunk_tokens`), so a table sized like
/// the head index would churn out genuinely shared chunks between
/// sightings.  One-off chunks (unique agent history) still churn
/// through; shared chunks are re-sighted every step and stay fresh.
const MAX_CHUNK_CANDIDATES: usize = 256;

/// FNV-1a over a token run — the deterministic, dependency-free chunk
/// fingerprint of the content-hash index.  Matches are confirmed
/// byte-for-byte before they count, so collisions cost a lookup, never
/// a wrong promotion.
fn chunk_hash(tokens: &[Token]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Tier telemetry for one run (all zero with the tier disabled).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixTierStats {
    /// Shared prefixes promoted to the broadcast tier.
    pub hot_prefixes: u64,
    /// First-time installs of a hot prefix onto a replica.
    pub ships: u64,
    /// Re-installs onto a replica whose copy died (kill / drain-refill).
    pub reships: u64,
    /// Tokens actually moved over the interconnect by installs.
    pub shipped_tokens: u64,
    /// Hot prefixes demoted (cooled off, or displaced by the budget).
    pub demotions: u64,
    /// Installs skipped because a replica could not free enough pool.
    pub skipped_installs: u64,
    /// Hot prefixes that entered through the content-hash chunk index
    /// (a subset of `hot_prefixes`; zero with `content_hash` off).
    pub hash_promotions: u64,
}

/// A tracked prompt head that may converge onto a shared prefix.
struct Candidate {
    tokens: Vec<Token>,
    /// Distinct agents that have presented this prefix (capped at
    /// `hot_after` — beyond that the candidate is already ripe).
    seen: Vec<AgentId>,
    /// Last observation instant (aging: when the table is full, the
    /// stalest candidate is replaced, so one-off prompt heads cannot
    /// permanently lock out future detection).
    last_seen: Micros,
}

/// A tracked non-head chunk that may surface mid-prompt sharing (the
/// content-hash index; see the module docs).
struct ChunkCandidate {
    /// FNV-1a fingerprint of the W-token chunk (confirmed against the
    /// tail of `run` before a sighting counts).
    hash: u64,
    /// Head-extended run `prompt[..off + W]` from the smallest-offset
    /// sighting so far — what promotion ships.  Its last W tokens are
    /// the chunk itself.
    run: Vec<Token>,
    /// Distinct agents that have presented this chunk (capped at
    /// `hot_after`, like `Candidate::seen`).
    seen: Vec<AgentId>,
    last_seen: Micros,
    /// Already promoted: the entry stays as a tombstone (refreshed, never
    /// re-counted) so ongoing sightings cannot re-register the chunk and
    /// promote a duplicate run.
    promoted: bool,
}

/// Per-replica install state of a hot prefix.
#[derive(Debug)]
enum InstallState {
    /// The install's transfer is in flight (transport delayed
    /// visibility): pool capacity is reserved on the replica, but the
    /// prefix matches zero tokens and feeds no routing hint until the
    /// transfer with this id completes.
    Pending { transfer: u64, reserved: u64 },
    /// Broadcast-pinned radix path (the tier's demotion handle).
    Ready(Vec<NodeId>),
}

/// A promoted (hot) prefix and its per-replica install state.
struct HotPrefix {
    tokens: Vec<Token>,
    last_reuse: Micros,
    /// Install state per replica (`None` = not installed — never shipped
    /// yet, or the replica's state was wiped since).
    installed: Vec<Option<InstallState>>,
    /// Replicas that ever held this prefix (distinguishes re-ships).
    ever_installed: Vec<bool>,
}

fn lcp(a: &[Token], b: &[Token]) -> usize {
    simd::common_prefix_len(a, b)
}

/// Is `h` installed — transfer landed, pin live — on every replica that
/// was alive at the last maintenance pass?  Dead replicas are excused —
/// requiring an install on a killed, never-revived replica would disable
/// the routing hint fleet-wide for the rest of the run.  Pending
/// installs do **not** count: the free-mover premise is "the prefix is
/// resident wherever I land", and an in-flight transfer is not resident.
fn fully_installed(alive: &[bool], h: &HotPrefix) -> bool {
    h.installed
        .iter()
        .zip(alive)
        .all(|(slot, &a)| !a || matches!(slot, Some(InstallState::Ready(_))))
}

/// The cluster-owned broadcast tier (see the module docs).
pub struct SharedPrefixTier {
    cfg: PrefixTierConfig,
    replicas: usize,
    candidates: Vec<Candidate>,
    /// Content-hash chunk index (empty with `cfg.content_hash` off).
    chunks: Vec<ChunkCandidate>,
    hot: Vec<HotPrefix>,
    /// Σ tokens of hot prefixes (per-replica pinned budget).
    budget_used: u64,
    /// Alive view from the last maintenance pass (all-true before the
    /// first); scopes the install-everywhere gate of the routing hint.
    last_alive: Vec<bool>,
    stats: PrefixTierStats,
}

impl SharedPrefixTier {
    pub fn new(cfg: PrefixTierConfig, replicas: usize) -> SharedPrefixTier {
        debug_assert!(cfg.enabled, "tier constructed while disabled");
        SharedPrefixTier {
            cfg,
            replicas,
            candidates: Vec::new(),
            chunks: Vec::new(),
            hot: Vec::new(),
            budget_used: 0,
            last_alive: vec![true; replicas],
            stats: PrefixTierStats::default(),
        }
    }

    pub fn stats(&self) -> PrefixTierStats {
        self.stats
    }

    /// Tokens of `prompt` covered by a hot prefix that is currently
    /// **installed on every alive replica** (0 = none).  Feeds the
    /// routers' prefix-awareness — the free-mover premise is "the prefix
    /// is resident wherever I land", so a merely-promoted prefix with no
    /// installs yet (or with installs lost to a kill/refill and not yet
    /// re-shipped) must not loosen routing.  Dead replicas don't count
    /// against the gate (they can't receive work), and the alive view is
    /// the last maintenance pass's — at most one fleet instant stale, on
    /// the conservative side.
    pub fn broadcast_prefix_len(&self, prompt: &[Token]) -> u64 {
        self.hot
            .iter()
            .filter(|h| fully_installed(&self.last_alive, h) && prompt.starts_with(&h.tokens))
            .map(|h| h.tokens.len() as u64)
            .max()
            .unwrap_or(0)
    }

    /// Observe one submitted prompt: refresh hot-prefix reuse stamps and
    /// advance shared-prefix detection.  Pure bookkeeping — never touches
    /// an engine, so the disabled tier (which is simply never observed)
    /// and an enabled-but-idle tier leave replicas byte-identical.
    ///
    /// Returns the routing hint for this prompt — the same value as
    /// [`broadcast_prefix_len`](SharedPrefixTier::broadcast_prefix_len),
    /// computed in the pass this method already makes over the hot set
    /// so the per-request path scans it once, not twice.
    pub fn observe(&mut self, agent: AgentId, prompt: &[Token], now: Micros) -> u64 {
        // Any hot coverage (installed or not) stops candidate tracking —
        // re-registering an already-promoted prefix would duplicate it —
        // but only everywhere-installed coverage feeds the routing hint.
        let mut covered_by_hot = false;
        let mut hot_cov = 0usize;
        let mut hint = 0u64;
        for h in &mut self.hot {
            if prompt.starts_with(&h.tokens) {
                h.last_reuse = now;
                covered_by_hot = true;
                hot_cov = hot_cov.max(h.tokens.len());
                if fully_installed(&self.last_alive, h) {
                    hint = hint.max(h.tokens.len() as u64);
                }
            }
        }
        // Content-hash chunk detection runs even on hot-covered prompts —
        // a hot family head must not blind the tier to shared context
        // sitting *past* it — but skips the chunks the hot head already
        // covers (they cannot extend coverage, only re-register it).
        if self.cfg.content_hash {
            self.observe_chunks(agent, prompt, hot_cov, now);
        }
        let minp = (self.cfg.min_prefix_tokens as usize).max(1);
        if prompt.len() < minp || covered_by_hot {
            return hint;
        }
        // Longest-overlap candidate wins (ties → lowest index).
        let mut best: Option<(usize, usize)> = None;
        for (i, c) in self.candidates.iter().enumerate() {
            let l = lcp(&c.tokens, prompt);
            if l >= minp && best.is_none_or(|(_, bl)| l > bl) {
                best = Some((i, l));
            }
        }
        match best {
            Some((i, l)) => {
                let c = &mut self.candidates[i];
                if l < c.tokens.len() {
                    // The prompts diverge inside the candidate: the true
                    // shared prefix is exactly their common part.
                    c.tokens.truncate(l);
                }
                // Genuinely distinct-agent counting (the hot_after knob's
                // documented meaning); capped at hot_after — beyond that
                // the candidate is already ripe.
                if c.seen.len() < self.cfg.hot_after as usize && !c.seen.contains(&agent) {
                    c.seen.push(agent);
                }
                c.last_seen = now;
            }
            None => {
                let cap = prompt.len().min(MAX_CANDIDATE_TOKENS);
                let cand = Candidate {
                    tokens: prompt[..cap].to_vec(),
                    seen: vec![agent],
                    last_seen: now,
                };
                if self.candidates.len() < MAX_CANDIDATES {
                    self.candidates.push(cand);
                } else if let Some(victim) = (0..self.candidates.len())
                    .min_by_key(|&i| (self.candidates[i].last_seen, i))
                {
                    // Table full: replace the stalest candidate so a
                    // burst of one-off prompt heads cannot permanently
                    // lock out future shared-prefix detection.
                    self.candidates[victim] = cand;
                }
            }
        }
        0 // not covered by any hot prefix, so no routing hint either
    }

    /// Advance content-hash detection over one prompt: hash every
    /// W-aligned non-overlapping chunk past the head (offset 0 belongs to
    /// LCP detection) and past `hot_cov` (already-hot coverage), matching
    /// against the bounded chunk table.  A match from a smaller offset
    /// re-anchors the candidate's head-extended run there — the smallest
    /// sighting offset yields the run shared by the widest audience (a
    /// workflow's workers embed the shared context right after their
    /// family head; the planner carries it deep in its history).
    fn observe_chunks(
        &mut self,
        agent: AgentId,
        prompt: &[Token],
        hot_cov: usize,
        now: Micros,
    ) {
        let w = self.cfg.hash_chunk_tokens as usize;
        if w == 0 || prompt.len() < 2 * w {
            return;
        }
        // Same detection-memory bound as head candidates: chunks past
        // MAX_CANDIDATE_TOKENS are not tracked.
        let scan = prompt.len().min(MAX_CANDIDATE_TOKENS);
        let mut off = w.max(hot_cov.next_multiple_of(w));
        while off + w <= scan {
            let chunk = &prompt[off..off + w];
            let hash = chunk_hash(chunk);
            off += w;
            match self.chunks.iter_mut().find(|c| c.hash == hash) {
                Some(c) => {
                    if simd::common_prefix_len(&c.run[c.run.len() - w..], chunk) != w {
                        continue; // hash collision: not the same content
                    }
                    c.last_seen = now;
                    if c.promoted {
                        continue; // tombstone: refreshed, never re-counted
                    }
                    let o = off - w;
                    if o + w < c.run.len() {
                        c.run = prompt[..o + w].to_vec();
                    }
                    if c.seen.len() < self.cfg.hot_after as usize
                        && !c.seen.contains(&agent)
                    {
                        c.seen.push(agent);
                    }
                }
                None => {
                    let cand = ChunkCandidate {
                        hash,
                        run: prompt[..off].to_vec(),
                        seen: vec![agent],
                        last_seen: now,
                        promoted: false,
                    };
                    if self.chunks.len() < MAX_CHUNK_CANDIDATES {
                        self.chunks.push(cand);
                    } else if let Some(victim) = (0..self.chunks.len())
                        .min_by_key(|&i| (self.chunks[i].last_seen, i))
                    {
                        // Stalest replacement, exactly like the head
                        // candidate table: unique-history chunks churn
                        // through without locking out detection.
                        self.chunks[victim] = cand;
                    }
                }
            }
        }
    }

    /// A replica's serving state was wiped (kill, or drain-refill): its
    /// installs — landed pins and in-flight reservations alike — are
    /// gone with the pool and radix tree (the caller cancels the
    /// in-flight transfers themselves via `Transport::cancel_dst`).  The
    /// next [`maintain`] pass re-ships everything hot once the replica
    /// is admissible again.
    ///
    /// [`maintain`]: SharedPrefixTier::maintain
    pub fn on_replica_wiped(&mut self, replica: usize) {
        for h in &mut self.hot {
            h.installed[replica] = None;
        }
    }

    /// One tier maintenance pass: demote cooled prefixes, promote ripe
    /// candidates (displacing the stalest hot prefix when the budget
    /// overflows), and install hot prefixes on alive replicas lacking
    /// them — gated on a live source replica holding the full prefix
    /// GPU-resident, because broadcasts move KV rather than invent it.
    ///
    /// With no `transport` the install is the legacy teleport (charged on
    /// the target's host link, usable the same instant).  With one, the
    /// install becomes a [`Transfer`] over the shared fabric: committed
    /// at issue when visibility is instantaneous, or reserved now
    /// (`SimEngine::reserve_broadcast_prefix`) and committed when the
    /// transfer's completion pops ([`on_transfer_done`]) under delayed
    /// visibility.  `delta_ship` sizes the wire by the target's missing
    /// suffix instead of the full prefix.
    ///
    /// Returns `(tokens shipped and visible now, summed transfer
    /// latency accounted now)` — delayed installs report both at their
    /// completion instead.
    ///
    /// [`on_transfer_done`]: SharedPrefixTier::on_transfer_done
    pub fn maintain(
        &mut self,
        engines: &mut [SimEngine],
        alive: &[bool],
        now: Micros,
        mut transport: Option<&mut Transport>,
    ) -> (u64, Micros) {
        debug_assert_eq!(engines.len(), self.replicas);
        debug_assert_eq!(alive.len(), self.replicas);
        self.last_alive.clear();
        self.last_alive.extend_from_slice(alive);
        let mut shipped = 0u64;
        let mut transfer = Micros::ZERO;

        // 1. Cool-down demotions.
        let mut i = 0;
        while i < self.hot.len() {
            if now.saturating_sub(self.hot[i].last_reuse) >= self.cfg.cool_after {
                self.demote_at(i, engines);
            } else {
                i += 1;
            }
        }

        // 2. Promote ripe candidates (in registration order).
        let mut c = 0;
        while c < self.candidates.len() {
            if self.candidates[c].seen.len() >= self.cfg.hot_after as usize {
                let cand = self.candidates.remove(c);
                self.promote(cand, engines, now);
            } else {
                c += 1;
            }
        }

        // 2b. Promote ripe content-hash chunk candidates (in registration
        // order).  The head-extended run rides the ordinary promote/ship
        // machinery; a run an existing hot prefix already covers adds
        // nothing and is dropped, but a run *extending* a hot head (the
        // family prompt went hot first, the shared context sits past it)
        // promotes on top of it — broadcast pins nest per node, so the
        // overlap is safe and only the budget counts it twice.
        if self.cfg.content_hash {
            for i in 0..self.chunks.len() {
                if self.chunks[i].promoted
                    || self.chunks[i].seen.len() < self.cfg.hot_after as usize
                {
                    continue;
                }
                self.chunks[i].promoted = true;
                let run = self.chunks[i].run.clone();
                if self.hot.iter().any(|h| h.tokens.starts_with(&run)) {
                    continue; // fully covered: nothing new to ship
                }
                let cand = Candidate {
                    tokens: run,
                    seen: self.chunks[i].seen.clone(),
                    last_seen: self.chunks[i].last_seen,
                };
                self.stats.hash_promotions += 1;
                self.promote(cand, engines, now);
            }
        }

        // 3. Install hot prefixes where they are missing.  (Indexed
        // loops: the body splits borrows between `self.hot`, `self.stats`
        // and `engines`, which an iterator over `self.hot` cannot.)
        #[allow(clippy::needless_range_loop)]
        for h_idx in 0..self.hot.len() {
            let full = self.hot[h_idx].tokens.len() as u64;
            let missing_any =
                (0..self.replicas).any(|r| alive[r] && self.hot[h_idx].installed[r].is_none());
            if !missing_any {
                continue;
            }
            // The source replica: a landed install, or organic coverage.
            let src = (0..self.replicas).find(|&r| {
                alive[r]
                    && (matches!(self.hot[h_idx].installed[r], Some(InstallState::Ready(_)))
                        || engines[r].tree().peek_prefix(&self.hot[h_idx].tokens).0 >= full)
            });
            let Some(src) = src else { continue };
            for r in 0..self.replicas {
                if !alive[r] || self.hot[h_idx].installed[r].is_some() {
                    continue;
                }
                match transport.as_deref_mut() {
                    None => {
                        // Legacy teleport: charged and usable this instant.
                        let Some(out) =
                            engines[r].install_broadcast_prefix(&self.hot[h_idx].tokens, now)
                        else {
                            self.stats.skipped_installs += 1;
                            continue;
                        };
                        shipped += self.record_install(h_idx, r, &out);
                        transfer += out.transfer_done.saturating_sub(now);
                    }
                    Some(tp) if !tp.cfg.delayed_visibility => {
                        // Fabric modeled, visibility still instantaneous.
                        let Some(out) =
                            engines[r].install_broadcast_prefix(&self.hot[h_idx].tokens, now)
                        else {
                            self.stats.skipped_installs += 1;
                            continue;
                        };
                        // The source pins its own copy without a transfer;
                        // delta targets receive only what was resident
                        // nowhere on their node — sized from what the
                        // install actually materialised from remote KV
                        // (`installed_tokens` excludes local CPU-tier
                        // reloads, and is exact even when freeing room
                        // evicted part of the previously-cached coverage
                        // a pre-install peek would have counted).
                        let wire = if r == src {
                            0
                        } else if tp.cfg.delta_ship {
                            out.installed_tokens
                        } else {
                            full
                        };
                        let done = if wire > 0 {
                            // The source pays the read-out leg of every
                            // outbound copy on its own host link.
                            let src_done = engines[src].charge_link_transfer(wire, now);
                            let host = out.transfer_done.max(src_done);
                            tp.ship_instant(TransferKind::Broadcast, src, r, wire, host, now)
                        } else {
                            out.transfer_done // pure pin: nothing crossed the fabric
                        };
                        shipped += self.record_install(h_idx, r, &out);
                        transfer += done.saturating_sub(now);
                    }
                    Some(tp) => {
                        // Delayed visibility: reserve now, commit at the
                        // transfer's completion.
                        let Some(res) =
                            engines[r].reserve_broadcast_prefix(&self.hot[h_idx].tokens, now)
                        else {
                            self.stats.skipped_installs += 1;
                            continue;
                        };
                        // The source pins its own copy without a transfer;
                        // delta targets receive only what is resident
                        // nowhere on their node (CPU-tier parts reload
                        // locally, they never cross the fabric).
                        let wire = if r == src {
                            0
                        } else if tp.cfg.delta_ship {
                            res.uncached
                        } else {
                            full
                        };
                        if wire == 0 {
                            // Nothing crosses the fabric (source self-pin,
                            // or a delta target whose missing part sits in
                            // its own CPU tier): the install lands this
                            // instant, paying only its host-link leg —
                            // accounted here, exactly as the instant and
                            // legacy branches account theirs.
                            let committed = engines[r].commit_broadcast_prefix(
                                &self.hot[h_idx].tokens,
                                res.reserved,
                                now,
                            );
                            match committed {
                                Some(out) => shipped += self.record_install(h_idx, r, &out),
                                None => self.stats.skipped_installs += 1,
                            }
                            transfer += res.host_done.saturating_sub(now);
                            continue;
                        }
                        // The source pays the read-out leg of every
                        // outbound copy on its own host link.
                        let src_done = engines[src].charge_link_transfer(wire, now);
                        let host_done = res.host_done.max(src_done);
                        let (id, _done) = tp.ship_broadcast(src, r, wire, host_done, now);
                        self.hot[h_idx].installed[r] = Some(InstallState::Pending {
                            transfer: id,
                            reserved: res.reserved,
                        });
                    }
                }
            }
        }
        (shipped, transfer)
    }

    /// Mark an install landed on `r` and fold its stats in; returns the
    /// tokens it moved (the `broadcast_series` contribution).
    fn record_install(
        &mut self,
        h_idx: usize,
        r: usize,
        out: &crate::engine::BroadcastInstall,
    ) -> u64 {
        let moved = out.installed_tokens + out.reloaded_tokens;
        self.stats.shipped_tokens += moved;
        if self.hot[h_idx].ever_installed[r] {
            self.stats.reships += 1;
        } else {
            self.stats.ships += 1;
            self.hot[h_idx].ever_installed[r] = true;
        }
        self.hot[h_idx].installed[r] = Some(InstallState::Ready(out.path.clone()));
        moved
    }

    /// A broadcast transfer completed: commit the reserved install it
    /// was carrying.  Returns the tokens materialised (the
    /// `broadcast_series` contribution at this instant) — 0 when the
    /// completion is stale (the prefix was demoted or the replica wiped
    /// since; the reservation was already released at that point) or the
    /// commit no longer fits (reservation released, install retried on a
    /// later maintenance pass).
    pub fn on_transfer_done(
        &mut self,
        xfer: &Transfer,
        engines: &mut [SimEngine],
        now: Micros,
    ) -> u64 {
        debug_assert_eq!(xfer.kind(), TransferKind::Broadcast);
        let dst = xfer.dst;
        // Indexed loop: the body splits borrows between `self.hot`,
        // `self.stats` and `engines` (same shape as `maintain`).
        #[allow(clippy::needless_range_loop)]
        for h_idx in 0..self.hot.len() {
            let (transfer, reserved) = match &self.hot[h_idx].installed[dst] {
                Some(InstallState::Pending { transfer, reserved }) => (*transfer, *reserved),
                _ => continue,
            };
            if transfer != xfer.id {
                continue;
            }
            let committed =
                engines[dst].commit_broadcast_prefix(&self.hot[h_idx].tokens, reserved, now);
            match committed {
                Some(out) => return self.record_install(h_idx, dst, &out),
                None => {
                    self.hot[h_idx].installed[dst] = None;
                    self.stats.skipped_installs += 1;
                    return 0;
                }
            }
        }
        0 // stale: demoted or wiped while the transfer was in flight
    }

    fn promote(&mut self, mut cand: Candidate, engines: &mut [SimEngine], now: Micros) {
        // A shared prefix longer than the whole budget is truncated, not
        // dropped: a budget-length head is still a valid shared prefix,
        // and dropping would let the candidate re-register and churn
        // through detect/drop forever (validation guarantees
        // budget_tokens >= min_prefix_tokens).
        if cand.tokens.len() as u64 > self.cfg.budget_tokens {
            cand.tokens.truncate(self.cfg.budget_tokens as usize);
        }
        let len = cand.tokens.len() as u64;
        while self.budget_used + len > self.cfg.budget_tokens {
            // Displace the stalest hot prefix (ties → oldest promotion).
            let Some(victim) = (0..self.hot.len()).min_by_key(|&i| (self.hot[i].last_reuse, i))
            else {
                break;
            };
            self.demote_at(victim, engines);
        }
        debug_assert!(self.budget_used + len <= self.cfg.budget_tokens);
        self.budget_used += len;
        self.stats.hot_prefixes += 1;
        self.hot.push(HotPrefix {
            tokens: cand.tokens,
            last_reuse: now,
            installed: vec![None; self.replicas],
            ever_installed: vec![false; self.replicas],
        });
    }

    fn demote_at(&mut self, i: usize, engines: &mut [SimEngine]) {
        let h = self.hot.remove(i);
        for (r, slot) in h.installed.into_iter().enumerate() {
            match slot {
                Some(InstallState::Ready(path)) => engines[r].demote_broadcast_prefix(&path),
                // In-flight install of a now-demoted prefix: release the
                // reservation; the orphaned transfer still completes (the
                // wire time was spent) but its commit finds no pending
                // state and lands as a no-op.
                Some(InstallState::Pending { reserved, .. }) => {
                    engines[r].abort_broadcast_reserve(reserved)
                }
                None => {}
            }
        }
        self.budget_used -= h.tokens.len() as u64;
        self.stats.demotions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::core::RequestId;
    use crate::costmodel::CostModel;
    use crate::engine::Request;

    fn tier(replicas: usize) -> SharedPrefixTier {
        SharedPrefixTier::new(PrefixTierConfig::on(), replicas)
    }

    fn engines(n: usize) -> Vec<SimEngine> {
        (0..n)
            .map(|_| {
                let mut e = SimEngine::new(
                    EngineConfig::default(),
                    CostModel::new(crate::config::presets::qwen3_cluster(2)),
                );
                e.shrink_pool_for_tests(100_000);
                e
            })
            .collect()
    }

    fn prompt(family: u32, agent: u32) -> Vec<Token> {
        let mut p: Vec<Token> = (family * 512..family * 512 + 512).collect();
        p.extend(1_000_000 + agent * 10_000..1_000_000 + agent * 10_000 + 400);
        p
    }

    /// Serve one request so `prompt` lands in the replica's radix cache
    /// through the normal finish path (pool accounting included) — the
    /// replica becomes a legitimate broadcast source.
    fn seed(e: &mut SimEngine, prompt: Vec<Token>) {
        e.submit(Request {
            id: RequestId(9_999),
            agent: AgentId(9_999),
            prompt,
            gen: vec![42_000_000],
            prev_ctx: 0,
            submitted_at: Micros::ZERO,
        });
        let mut now = Micros::ZERO;
        for _ in 0..200 {
            if !e.has_work() {
                break;
            }
            let out = e.step(now);
            now += out.duration + Micros(1);
        }
        assert!(!e.has_work(), "seed request did not finish");
        e.check_invariants().unwrap();
    }

    #[test]
    fn candidates_converge_on_the_shared_prefix() {
        let mut t = tier(2);
        t.observe(AgentId(0), &prompt(0, 0), Micros(1));
        t.observe(AgentId(1), &prompt(0, 1), Micros(2));
        // Two observers sharing 512 tokens: one candidate, shrunk to the LCP.
        assert_eq!(t.candidates.len(), 1);
        assert_eq!(t.candidates[0].tokens.len(), 512);
        assert_eq!(t.candidates[0].seen.len(), 2);
        // A different family registers its own candidate.
        t.observe(AgentId(2), &prompt(3, 2), Micros(3));
        assert_eq!(t.candidates.len(), 2);
        // The same agent re-observing does not count as sharing...
        t.observe(AgentId(2), &prompt(3, 2), Micros(4));
        assert_eq!(t.candidates[1].seen.len(), 1);
        // ...and neither does alternation: A,B,A is two distinct reusers,
        // not three (the hot_after knob's documented meaning).
        t.observe(AgentId(0), &prompt(0, 0), Micros(5));
        assert_eq!(t.candidates[0].seen.len(), 2);
    }

    #[test]
    fn hot_prefix_ships_only_once_a_source_exists() {
        let mut t = tier(2);
        let mut eng = engines(2);
        let alive = vec![true, true];
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        // Hot, but no replica holds the prefix yet: nothing ships.
        let (shipped, _) = t.maintain(&mut eng, &alive, Micros(10), None);
        assert_eq!(shipped, 0);
        assert_eq!(t.stats().ships, 0);
        assert_eq!(t.stats().hot_prefixes, 1);
        // Replica 0 serves family traffic: its cache becomes the source.
        seed(&mut eng[0], prompt(0, 9));
        let (shipped, transfer) = t.maintain(&mut eng, &alive, Micros(12), None);
        assert_eq!(shipped, 512, "only replica 1 lacked the 512-token prefix");
        assert!(transfer > Micros::ZERO);
        assert_eq!(t.stats().ships, 2, "pin on the source + install on the peer");
        assert_eq!(eng[1].tree().broadcast_tokens(), 512);
        assert_eq!(eng[0].tree().broadcast_tokens(), 512, "source copy is pinned too");
        for e in &eng {
            e.check_invariants().unwrap();
        }
        // Steady state: nothing further to do.
        assert_eq!(t.maintain(&mut eng, &alive, Micros(13), None).0, 0);
    }

    #[test]
    fn wiped_replicas_are_reshipped() {
        let mut t = tier(2);
        let mut eng = engines(2);
        let alive = vec![true, true];
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        seed(&mut eng[0], prompt(0, 9));
        t.maintain(&mut eng, &alive, Micros(6), None);
        assert_eq!(t.stats().ships, 2);
        // Replica 1 dies and rejoins empty.
        eng[1].clear_state();
        t.on_replica_wiped(1);
        // While replica 1 is down, the routing hint must survive on the
        // alive remainder: a dead replica's missing install is excused.
        t.maintain(&mut eng, &[true, false], Micros(7), None);
        assert_eq!(t.broadcast_prefix_len(&prompt(0, 7)), 512, "dead replica excused");
        // Revive: the wiped install is restored (a re-ship, not a ship).
        let (shipped, _) = t.maintain(&mut eng, &alive, Micros(8), None);
        assert_eq!(shipped, 512);
        assert_eq!(t.stats().reships, 1, "rejoin must restore the tier");
        assert_eq!(eng[1].tree().broadcast_tokens(), 512);
        assert_eq!(t.broadcast_prefix_len(&prompt(0, 7)), 512);
    }

    #[test]
    fn cooled_prefixes_are_demoted_everywhere() {
        let mut cfg = PrefixTierConfig::on();
        cfg.cool_after = Micros(100);
        let mut t = SharedPrefixTier::new(cfg, 2);
        let mut eng = engines(2);
        let alive = vec![true, true];
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        seed(&mut eng[0], prompt(0, 9));
        t.maintain(&mut eng, &alive, Micros(6), None);
        assert_eq!(eng[1].tree().broadcast_tokens(), 512);
        // No reuse for >= cool_after: demoted on both replicas.
        t.maintain(&mut eng, &alive, Micros(200), None);
        assert_eq!(t.stats().demotions, 1);
        assert_eq!(eng[0].tree().broadcast_tokens(), 0);
        assert_eq!(eng[1].tree().broadcast_tokens(), 0);
        for e in &eng {
            e.check_invariants().unwrap();
        }
    }

    #[test]
    fn budget_displaces_the_stalest_prefix() {
        let mut cfg = PrefixTierConfig::on();
        cfg.budget_tokens = 800; // fits one 512-token prefix, not two
        let mut t = SharedPrefixTier::new(cfg, 1);
        let mut eng = engines(1);
        let alive = vec![true];
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        seed(&mut eng[0], prompt(0, 9));
        t.maintain(&mut eng, &alive, Micros(6), None);
        assert_eq!(t.stats().hot_prefixes, 1);
        // A second family goes hot: the budget displaces the first.
        for a in 10..13u32 {
            t.observe(AgentId(a as u64), &prompt(1, a), Micros(a as u64 + 10));
        }
        seed(&mut eng[0], prompt(1, 9));
        t.maintain(&mut eng, &alive, Micros(31), None);
        assert_eq!(t.stats().hot_prefixes, 2);
        assert_eq!(t.stats().demotions, 1, "budget must displace the stalest");
        assert_eq!(t.hot.len(), 1);
        assert!(prompt(1, 0).starts_with(&t.hot[0].tokens));
        eng[0].check_invariants().unwrap();
    }

    fn delayed_transport(eng: &[SimEngine]) -> Transport {
        let mut cfg = crate::config::TransportConfig::on();
        cfg.delayed_visibility = true;
        Transport::new(cfg, eng[0].cost.cluster.model.kv_bytes_per_token())
    }

    #[test]
    fn delayed_install_is_invisible_until_its_transfer_lands() {
        let mut t = tier(2);
        let mut eng = engines(2);
        let alive = vec![true, true];
        let mut tp = delayed_transport(&eng);
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        seed(&mut eng[0], prompt(0, 9));
        let (shipped, _) = t.maintain(&mut eng, &alive, Micros(10), Some(&mut tp));
        // The source pins its own copy instantly (nothing crosses the
        // fabric); the peer's install is reserved but in flight.
        assert_eq!(shipped, 0, "nothing is visible-shipped yet");
        assert_eq!(eng[0].tree().broadcast_tokens(), 512, "source pin is immediate");
        assert_eq!(eng[1].tree().broadcast_tokens(), 0, "peer install is pending");
        assert_eq!(eng[1].tree().peek_prefix(&prompt(0, 7)).0, 0, "matches zero tokens");
        assert_eq!(eng[1].pool().used(), 512, "capacity is reserved at issue");
        assert_eq!(t.stats().ships, 1, "only the source pin landed");
        assert_eq!(t.broadcast_prefix_len(&prompt(0, 7)), 0, "no routing hint while pending");
        // A second maintenance pass must not double-ship the pending slot.
        t.maintain(&mut eng, &alive, Micros(11), Some(&mut tp));
        assert_eq!(tp.stats().broadcast_transfers, 1);
        // The transfer lands: commit makes the prefix matchable + hinted.
        let done = tp.next_completion().expect("one transfer in flight");
        let due = tp.pop_due(done);
        assert_eq!(due.len(), 1);
        let committed = t.on_transfer_done(&due[0], &mut eng, done);
        assert_eq!(committed, 512);
        assert_eq!(eng[1].tree().broadcast_tokens(), 512);
        assert_eq!(t.stats().ships, 2);
        assert_eq!(t.broadcast_prefix_len(&prompt(0, 7)), 512);
        for e in &eng {
            e.check_invariants().unwrap();
        }
    }

    #[test]
    fn wiped_pending_install_is_reshipped_cleanly() {
        let mut t = tier(2);
        let mut eng = engines(2);
        let alive = vec![true, true];
        let mut tp = delayed_transport(&eng);
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        seed(&mut eng[0], prompt(0, 9));
        // Round 1: the peer install lands normally.
        t.maintain(&mut eng, &alive, Micros(10), Some(&mut tp));
        let done = tp.next_completion().expect("install in flight");
        let due = tp.pop_due(done);
        assert_eq!(t.on_transfer_done(&due[0], &mut eng, done), 512);
        assert_eq!(t.stats().ships, 2, "source pin + first peer install");
        // The peer dies; a re-ship goes out, and the peer dies AGAIN with
        // that re-ship still in flight — the transfer is voided.
        eng[1].clear_state();
        t.on_replica_wiped(1);
        tp.cancel_dst(1);
        assert_eq!(tp.stats().cancelled, 0, "nothing was in flight at the first wipe");
        t.maintain(&mut eng, &alive, Micros(20), Some(&mut tp));
        eng[1].clear_state();
        t.on_replica_wiped(1);
        tp.cancel_dst(1);
        assert_eq!(tp.stats().cancelled, 1, "in-flight re-ship voided by the wipe");
        assert_eq!(tp.next_completion(), None);
        // Final rejoin: the next attempt lands and counts as the re-ship.
        t.maintain(&mut eng, &alive, Micros(30), Some(&mut tp));
        let done = tp.next_completion().expect("re-ship in flight");
        let due = tp.pop_due(done);
        assert_eq!(t.on_transfer_done(&due[0], &mut eng, done), 512);
        assert_eq!(t.stats().ships, 2, "landed first installs are not recounted");
        assert_eq!(t.stats().reships, 1, "rejoin restores the tier");
        eng[1].check_invariants().unwrap();
    }

    #[test]
    fn demoted_pending_install_releases_its_reservation() {
        let mut cfg = PrefixTierConfig::on();
        cfg.cool_after = Micros(5);
        let mut t = SharedPrefixTier::new(cfg, 2);
        let mut eng = engines(2);
        let alive = vec![true, true];
        let mut tp = delayed_transport(&eng);
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        seed(&mut eng[0], prompt(0, 9));
        t.maintain(&mut eng, &alive, Micros(4), Some(&mut tp));
        assert_eq!(eng[1].pool().used(), 512, "reservation held");
        // The prefix cools before the transfer lands: demotion aborts the
        // reservation; the orphaned completion commits nothing.
        t.maintain(&mut eng, &alive, Micros(1_000), Some(&mut tp));
        assert_eq!(t.stats().demotions, 1);
        assert_eq!(eng[1].pool().used(), 0, "reservation released at demotion");
        let done = tp.next_completion().expect("orphan still in flight");
        let due = tp.pop_due(done);
        assert_eq!(t.on_transfer_done(&due[0], &mut eng, done), 0, "stale commit is a no-op");
        for e in &eng {
            e.check_invariants().unwrap();
        }
    }

    #[test]
    fn delta_shipping_moves_only_the_missing_suffix() {
        let mut t = tier(2);
        let mut eng = engines(2);
        let alive = vec![true, true];
        let mut cfg = crate::config::TransportConfig::on();
        cfg.delayed_visibility = true;
        cfg.delta_ship = true;
        let mut tp = Transport::new(cfg, eng[0].cost.cluster.model.kv_bytes_per_token());
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        // Both replicas served family traffic; replica 1 holds a partial
        // head (first 256 tokens) from a shorter organic request.
        seed(&mut eng[0], prompt(0, 9));
        seed(&mut eng[1], prompt(0, 8)[..256].to_vec());
        t.maintain(&mut eng, &alive, Micros(10), Some(&mut tp));
        // Delta: only the 256 missing tokens cross the fabric.
        assert_eq!(tp.stats().wire_tokens, 256);
        let done = tp.next_completion().expect("delta transfer in flight");
        let due = tp.pop_due(done);
        assert_eq!(t.on_transfer_done(&due[0], &mut eng, done), 256);
        assert_eq!(eng[1].tree().broadcast_tokens(), 512, "whole prefix ends pinned");
        eng[1].check_invariants().unwrap();
    }

    fn hashed_tier(replicas: usize) -> SharedPrefixTier {
        let mut cfg = PrefixTierConfig::on();
        cfg.content_hash = true;
        cfg.hash_chunk_tokens = 128;
        SharedPrefixTier::new(cfg, replicas)
    }

    /// Mid-prompt sharing fixture: every agent embeds the same 128-token
    /// shared context at a 128-aligned offset, but prompt heads are
    /// unique, so LCP detection can never converge on the shared part.
    /// `deep` carries it at offset 384 (a planner's history); otherwise
    /// at offset 128 (a worker's prompt).
    fn mid_prompt(agent: u32, deep: bool) -> Vec<Token> {
        let head = if deep { 384 } else { 128 };
        let base = 50_000_000 + agent * 100_000;
        let mut p: Vec<Token> = (base..base + head).collect();
        p.extend(40_000_000..40_000_128); // shared context, verbatim
        p.extend(base + 10_000..base + 10_192); // unique tail
        p
    }

    #[test]
    fn content_hash_promotes_mid_prompt_shared_context() {
        let mut t = hashed_tier(2);
        let mut eng = engines(2);
        let alive = vec![true, true];
        // Planner first: the chunk candidate anchors at its deep offset;
        // the workers then re-anchor the run to their shallow one.
        t.observe(AgentId(0), &mid_prompt(0, true), Micros(1));
        t.observe(AgentId(1), &mid_prompt(1, false), Micros(2));
        t.observe(AgentId(2), &mid_prompt(2, false), Micros(3));
        seed(&mut eng[0], mid_prompt(1, false));
        let (shipped, _) = t.maintain(&mut eng, &alive, Micros(10), None);
        // Three unrelated heads: only the chunk index converged.
        assert_eq!(t.stats().hash_promotions, 1);
        assert_eq!(t.stats().hot_prefixes, 1);
        // The promoted run is the smallest-offset sighting's head + S.
        assert_eq!(t.hot[0].tokens, mid_prompt(1, false)[..256].to_vec());
        assert_eq!(shipped, 256, "the peer replica receives the run");
        assert_eq!(t.broadcast_prefix_len(&mid_prompt(1, false)), 256);
        // Tombstone: continued sightings never re-promote the chunk.
        t.observe(AgentId(3), &mid_prompt(3, false), Micros(11));
        t.observe(AgentId(4), &mid_prompt(4, false), Micros(12));
        t.maintain(&mut eng, &alive, Micros(13), None);
        assert_eq!(t.stats().hash_promotions, 1);
        for e in &eng {
            e.check_invariants().unwrap();
        }
    }

    #[test]
    fn content_hash_off_tracks_no_chunks() {
        let mut t = tier(2);
        let mut eng = engines(2);
        t.observe(AgentId(0), &mid_prompt(0, true), Micros(1));
        t.observe(AgentId(1), &mid_prompt(1, false), Micros(2));
        t.observe(AgentId(2), &mid_prompt(2, false), Micros(3));
        assert!(t.chunks.is_empty(), "disabled index must stay empty");
        t.maintain(&mut eng, &[true, true], Micros(4), None);
        assert_eq!(t.stats().hash_promotions, 0);
        assert_eq!(t.stats().hot_prefixes, 0, "LCP is blind to mid-prompt sharing");
    }

    #[test]
    fn chunks_past_a_hot_head_extend_it() {
        let mut t = hashed_tier(1);
        let mut eng = engines(1);
        let family: Vec<Token> = (60_000_000..60_000_512).collect();
        let uniq = |a: u32| -> Vec<Token> {
            (70_000_000 + a * 100_000..70_000_000 + a * 100_000 + 256).collect()
        };
        // The family head goes hot through plain LCP traffic first.  The
        // family-interior chunks also ripen, but their runs are prefixes
        // of the hot head — fully covered, dropped without promotion.
        for a in 0..3u32 {
            let mut p = family.clone();
            p.extend(uniq(a));
            t.observe(AgentId(a as u64), &p, Micros(a as u64 + 1));
        }
        t.maintain(&mut eng, &[true], Micros(4), None);
        assert_eq!(t.stats().hot_prefixes, 1);
        assert_eq!(t.stats().hash_promotions, 0, "covered runs must not double-ship");
        // A later cohort embeds shared context right past the hot head:
        // their prompts are hot-covered, but the chunk index keeps
        // looking past the covered 512 tokens and promotes the extended
        // run on top (broadcast pins nest).
        let shared: Vec<Token> = (40_000_000..40_000_128).collect();
        for a in 10..13u32 {
            let mut p = family.clone();
            p.extend_from_slice(&shared);
            p.extend(uniq(a));
            t.observe(AgentId(a as u64), &p, Micros(a as u64 + 10));
        }
        t.maintain(&mut eng, &[true], Micros(30), None);
        assert_eq!(t.stats().hash_promotions, 1);
        assert_eq!(t.stats().hot_prefixes, 2);
        let ext = t.hot.iter().find(|h| h.tokens.len() == 640).expect("extended run hot");
        assert!(ext.tokens.starts_with(&family));
        assert_eq!(&ext.tokens[512..], &shared[..]);
    }

    #[test]
    fn broadcast_prefix_len_reports_only_installed_coverage() {
        let mut t = tier(1);
        let mut eng = engines(1);
        for a in 0..3u32 {
            t.observe(AgentId(a as u64), &prompt(0, a), Micros(a as u64 + 1));
        }
        assert_eq!(t.broadcast_prefix_len(&prompt(0, 7)), 0, "not hot yet");
        // Promoted but unshipped (no source): still no routing hint —
        // the free-mover premise needs the prefix resident everywhere.
        t.maintain(&mut eng, &[true], Micros(4), None);
        assert_eq!(t.stats().hot_prefixes, 1);
        assert_eq!(t.broadcast_prefix_len(&prompt(0, 7)), 0, "hot-but-unshipped");
        assert_eq!(t.observe(AgentId(9), &prompt(0, 9), Micros(5)), 0);
        seed(&mut eng[0], prompt(0, 9));
        t.maintain(&mut eng, &[true], Micros(6), None);
        assert_eq!(t.broadcast_prefix_len(&prompt(0, 7)), 512);
        assert_eq!(t.observe(AgentId(9), &prompt(0, 9), Micros(7)), 512);
        assert_eq!(t.broadcast_prefix_len(&prompt(2, 7)), 0);
    }
}
