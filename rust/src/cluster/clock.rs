//! Event-heap clock-stop index for the fleet loop.
//!
//! Step 5 of `run_sharded_with_workers` advances the simulated clock to
//! the earliest pending event.  The candidates — per-replica iteration
//! boundaries, the scripted fault cursor, the stochastic fault sampler,
//! the arrival cursor, and the transport — used to be rebuilt by linear
//! scans at every stop (`O(replicas)` per stop, dominated by the
//! `inflight.iter().min()` boundary scan).  [`ClockStops`] replaces the
//! scans with a lazy-deletion [`BinaryHeap`]: each candidate *slot* pushes
//! a heap entry when its instant changes, stale entries are dropped on
//! pop, and the earliest stop is an `O(log n)` peek.
//!
//! Entries are keyed `(Micros, source-rank, generation)`.  The rank
//! orders ties fault-source-first, then the fixed candidate order the old
//! array literal had — tie order among equal instants can never change
//! the *minimum value*, so the heap's answer is bit-identical to the
//! replaced `[..].into_iter().flatten().min()`; the rank exists so the
//! heap's internal ordering (and therefore its behaviour under the
//! differential fuzz test below) is fully deterministic.
//!
//! Slot layout: rank 0 = scripted faults, 1 = stochastic sampler,
//! 2 = arrivals, 3 = transport, `4 + r` = replica `r`'s iteration
//! boundary.  Singleton slots are re-synced once per stop (`set` no-ops
//! when the instant is unchanged); boundary slots are maintained at their
//! three mutation sites (iteration start, landing, replica kill).

use crate::core::Micros;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Fixed singleton slots (see module docs for the full layout).
pub const SLOT_FAULT: usize = 0;
pub const SLOT_SAMPLER: usize = 1;
pub const SLOT_ARRIVAL: usize = 2;
pub const SLOT_TRANSPORT: usize = 3;
const SINGLETON_SLOTS: usize = 4;

#[derive(Clone, Copy, Default)]
struct Slot {
    at: Option<Micros>,
    /// Bumped on every change; heap entries carrying an older generation
    /// (or a cleared slot's instant) are stale and dropped on pop.
    gen: u64,
}

/// Lazy-deletion min-heap over clock-stop candidate slots.
pub struct ClockStops {
    heap: BinaryHeap<Reverse<(Micros, usize, u64)>>,
    slots: Vec<Slot>,
    /// Boundary slots currently set — `O(1)` "is the fleet idle?".
    live_boundaries: usize,
}

impl ClockStops {
    /// Index for `replicas` boundary slots plus the four singletons.
    pub fn new(replicas: usize) -> ClockStops {
        ClockStops {
            heap: BinaryHeap::with_capacity(SINGLETON_SLOTS + replicas),
            slots: vec![Slot::default(); SINGLETON_SLOTS + replicas],
            live_boundaries: 0,
        }
    }

    /// Set or clear a singleton slot (`SLOT_FAULT` … `SLOT_TRANSPORT`).
    /// No-ops when the instant is unchanged, so per-stop re-syncs of slow-
    /// moving sources cost one compare.
    pub fn set(&mut self, slot: usize, at: Option<Micros>) {
        debug_assert!(slot < SINGLETON_SLOTS, "boundary slots use set_boundary");
        self.update(slot, at);
    }

    /// Set replica `r`'s iteration boundary.
    pub fn set_boundary(&mut self, r: usize, at: Micros) {
        let slot = SINGLETON_SLOTS + r;
        if self.slots[slot].at.is_none() {
            self.live_boundaries += 1;
        }
        self.update(slot, Some(at));
    }

    /// Clear replica `r`'s iteration boundary (landing or kill).  No-ops
    /// when already clear (a kill of an idle replica).
    pub fn clear_boundary(&mut self, r: usize) {
        let slot = SINGLETON_SLOTS + r;
        if self.slots[slot].at.is_some() {
            self.live_boundaries -= 1;
            self.update(slot, None);
        }
    }

    /// Any replica iteration in flight?  (The old loop's
    /// `inflight.iter().flatten().min().is_none()` idleness test.)
    pub fn has_boundary(&self) -> bool {
        self.live_boundaries > 0
    }

    fn update(&mut self, slot: usize, at: Option<Micros>) {
        let s = &mut self.slots[slot];
        if s.at == at {
            return;
        }
        s.at = at;
        s.gen += 1;
        if let Some(t) = at {
            self.heap.push(Reverse((t, slot, s.gen)));
        }
    }

    /// Earliest live candidate instant, or `None` when every slot is
    /// clear.  Amortised `O(log n)`: each pushed entry is popped at most
    /// once, lazily, when it has gone stale.
    pub fn earliest(&mut self) -> Option<Micros> {
        while let Some(&Reverse((at, slot, gen))) = self.heap.peek() {
            let s = self.slots[slot];
            if s.gen == gen && s.at == Some(at) {
                return Some(at);
            }
            self.heap.pop();
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Rng;

    #[test]
    fn empty_has_no_stop() {
        let mut c = ClockStops::new(4);
        assert_eq!(c.earliest(), None);
        assert!(!c.has_boundary());
    }

    #[test]
    fn singleton_set_update_clear() {
        let mut c = ClockStops::new(0);
        c.set(SLOT_FAULT, Some(Micros(50)));
        c.set(SLOT_ARRIVAL, Some(Micros(30)));
        assert_eq!(c.earliest(), Some(Micros(30)));
        // Move the arrival cursor later: the stale entry must not win.
        c.set(SLOT_ARRIVAL, Some(Micros(90)));
        assert_eq!(c.earliest(), Some(Micros(50)));
        c.set(SLOT_FAULT, None);
        assert_eq!(c.earliest(), Some(Micros(90)));
        c.set(SLOT_ARRIVAL, None);
        assert_eq!(c.earliest(), None);
    }

    #[test]
    fn unchanged_set_is_a_noop() {
        let mut c = ClockStops::new(0);
        c.set(SLOT_TRANSPORT, Some(Micros(7)));
        let gen_before = c.slots[SLOT_TRANSPORT].gen;
        for _ in 0..100 {
            c.set(SLOT_TRANSPORT, Some(Micros(7)));
        }
        assert_eq!(c.slots[SLOT_TRANSPORT].gen, gen_before);
        assert_eq!(c.heap.len(), 1);
    }

    #[test]
    fn boundaries_track_idleness() {
        let mut c = ClockStops::new(3);
        assert!(!c.has_boundary());
        c.set_boundary(1, Micros(100));
        c.set_boundary(2, Micros(40));
        assert!(c.has_boundary());
        assert_eq!(c.earliest(), Some(Micros(40)));
        c.clear_boundary(2);
        assert_eq!(c.earliest(), Some(Micros(100)));
        // Kill of an already-idle replica: clearing twice is safe.
        c.clear_boundary(2);
        c.clear_boundary(1);
        assert!(!c.has_boundary());
        assert_eq!(c.earliest(), None);
    }

    #[test]
    fn rescheduling_same_slot_repeatedly() {
        let mut c = ClockStops::new(1);
        for t in (1..=200u64).rev() {
            c.set_boundary(0, Micros(t));
        }
        assert_eq!(c.earliest(), Some(Micros(1)));
        c.set_boundary(0, Micros(500));
        assert_eq!(c.earliest(), Some(Micros(500)));
    }

    /// Differential fuzz: random set/clear traffic against a naive
    /// min-over-slots model, checking `earliest`/`has_boundary` after
    /// every op.  Seeded via the crate RNG — deterministic in CI.
    #[test]
    fn differential_fuzz_vs_naive_min() {
        let replicas = 6;
        let slots = SINGLETON_SLOTS + replicas;
        for seed in 0..8u64 {
            let mut rng = Rng::new(0xc10c + seed);
            let mut c = ClockStops::new(replicas);
            let mut model: Vec<Option<Micros>> = vec![None; slots];
            for _ in 0..4000 {
                let slot = rng.gen_range(0, slots as u64) as usize;
                let clear = rng.gen_range(0, 4) == 0;
                let at = if clear { None } else { Some(Micros(rng.gen_range(0, 1000))) };
                if slot < SINGLETON_SLOTS {
                    c.set(slot, at);
                } else {
                    match at {
                        Some(t) => c.set_boundary(slot - SINGLETON_SLOTS, t),
                        None => c.clear_boundary(slot - SINGLETON_SLOTS),
                    }
                }
                model[slot] = at;
                assert_eq!(c.earliest(), model.iter().flatten().min().copied());
                assert_eq!(
                    c.has_boundary(),
                    model[SINGLETON_SLOTS..].iter().any(|s| s.is_some())
                );
            }
        }
    }
}
