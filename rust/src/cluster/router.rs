//! Replica routing policies for the data-parallel cluster.
//!
//! Routing decides *where an agent's next generation step lands relative
//! to its warm prefix* — which dominates multi-agent throughput far more
//! than raw load spread (cf. KVFlow / Continuum in PAPERS.md).  Four
//! policies span the trade-off space:
//!
//! * [`RoundRobinRouter`] — per-request cycling.  Perfectly even request
//!   spread, but an agent revisits a given replica only every N steps, so
//!   each admission misses its last N-1 steps of context (recompute).
//! * [`LeastLoadedRouter`] — per-request argmin over active KV working
//!   sets.  Best instantaneous memory balance, but agents migrate whenever
//!   another replica dips below their current one, abandoning warm
//!   prefixes mid-trajectory.
//! * [`CacheAffinityRouter`] — each agent is pinned to an id-hashed home
//!   replica; every step of the trajectory extends the same radix path,
//!   so hit rate matches the single-replica driver at 1/N the load.  Load
//!   imbalance is tolerated until it is *sustained* — observed overloaded
//!   at several distinct simulation instants in a row — then individual
//!   steps spill to the least-loaded replica without re-homing the agent.
//! * [`RebalanceRouter`] — cache-affinity homes that can be *re-assigned*:
//!   under sustained imbalance or replica loss it migrates **cold agents
//!   first** (ranked by the engine's per-agent cache-heat signal — time
//!   since the agent last completed a decode on its current replica).
//!   A cold agent's radix path is the most likely to have been LRU-evicted
//!   already, so moving it forfeits the least warm state; hot agents keep
//!   their pins.  This replaces the load-only spill, which migrates
//!   whichever agent happens to request next, warm or not.
//!
//! All policies are deterministic: ties break toward the lowest replica
//! index and every input comes from the simulation state.  Replicas that
//! are dead or draining are offered with [`ReplicaLoad::admissible`] set
//! to `false`, and every policy must route around them.

use crate::config::RouterKind;
use crate::core::{AgentId, FxHashMap, Micros};

/// Per-replica load snapshot offered to routing decisions.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaLoad {
    /// Σ context tokens of slot-holding agents currently assigned here
    /// (the same agent-level working set the controller's U_t watches).
    pub active_footprint: u64,
    /// KV pool capacity in tokens.
    pub capacity: u64,
    /// May this replica receive new work?  `false` while the replica is
    /// dead or draining; routers must never return a non-admissible
    /// index (the fleet loop asserts it).
    pub admissible: bool,
}

/// Everything a routing decision may consult about the requesting agent.
#[derive(Debug, Clone, Copy)]
pub struct RouteCtx {
    /// Agent issuing its next generation step.
    pub agent: AgentId,
    /// The agent's current context length in tokens.
    pub ctx_tokens: u64,
    /// Replica its working set sits on right now (`None` before first
    /// admission, or after that replica was killed).
    pub current: Option<usize>,
    /// Simulation time of the decision.
    pub now: Micros,
    /// Cache heat: when the agent last completed a generation step on
    /// `current` (`None` = never decoded there, or the state died with
    /// its replica).  Staleness correlates with LRU eviction depth, so
    /// time-since-last-decode ranks agents coldest-first for migration.
    pub heat: Option<Micros>,
    /// Tokens of the agent's prompt covered by a cluster-wide broadcast
    /// prefix (0 = none, or the shared-prefix tier is off).  A covered
    /// agent whose private suffix has gone cold loses almost nothing by
    /// moving — the broadcast prefix is resident on every replica — so
    /// prefix-aware policies may migrate it more eagerly.
    pub broadcast_prefix: u64,
}

/// An agent whose remaining reuse is only the broadcast prefix is *free
/// to move*: the prefix is pinned on every admissible replica, and the
/// private suffix on its current replica is cold enough (no decode there
/// within `cold_after`, or none ever) to have been LRU-evicted already.
fn broadcast_free(ctx: &RouteCtx, cold_after: Micros) -> bool {
    ctx.broadcast_prefix > 0
        && match ctx.heat {
            None => true,
            Some(last) => ctx.now.saturating_sub(last) >= cold_after,
        }
}

/// A routing policy: picks the replica for one agent's next request.
pub trait Router {
    /// Stable policy name (reported in [`RunResult`]s and bench JSON).
    ///
    /// [`RunResult`]: crate::driver::RunResult
    fn name(&self) -> String;

    /// Choose a replica index in `0..replicas.len()` for the agent
    /// described by `ctx`.
    ///
    /// Contract: the returned index must satisfy
    /// `replicas[index].admissible`; the caller guarantees at least one
    /// admissible replica exists (enforced by `FaultPlan` validation)
    /// and asserts the contract after every decision.
    fn route(&mut self, ctx: &RouteCtx, replicas: &[ReplicaLoad]) -> usize;
}

/// Admissible replica with the smallest active working set (ties → lowest
/// index).  Callers guarantee at least one admissible replica.
fn least_loaded(replicas: &[ReplicaLoad]) -> usize {
    let mut best: Option<usize> = None;
    for (i, r) in replicas.iter().enumerate() {
        if !r.admissible {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => r.active_footprint < replicas[b].active_footprint,
        };
        if better {
            best = Some(i);
        }
    }
    best.expect("no admissible replica offered to router")
}

/// Cache-oblivious per-request cycling (skipping non-admissible replicas).
#[derive(Debug, Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn name(&self) -> String {
        "round-robin".into()
    }

    fn route(&mut self, _ctx: &RouteCtx, replicas: &[ReplicaLoad]) -> usize {
        let n = replicas.len();
        for _ in 0..n {
            let r = self.next % n;
            self.next = self.next.wrapping_add(1);
            if replicas[r].admissible {
                return r;
            }
        }
        unreachable!("no admissible replica offered to router")
    }
}

/// Per-request argmin over active KV working sets.
#[derive(Debug, Default)]
pub struct LeastLoadedRouter;

impl Router for LeastLoadedRouter {
    fn name(&self) -> String {
        "least-loaded".into()
    }

    fn route(&mut self, _ctx: &RouteCtx, replicas: &[ReplicaLoad]) -> usize {
        least_loaded(replicas)
    }
}

/// Shared sustained-imbalance detector: per-replica streaks of distinct
/// simulation instants at which the replica was over both the imbalance
/// and the pressure bar.  Streaks advance at most once per instant
/// (streaks only move while requests flow; with no routing activity
/// there is nothing to move), and non-admissible replicas always read as
/// streak 0.
#[derive(Debug, Default)]
struct OverloadStreaks {
    streaks: Vec<u32>,
    last_advance: Option<Micros>,
}

impl OverloadStreaks {
    /// Advance the streaks for instant `now` (no-op if already advanced
    /// at this instant) and return the streak table.
    fn advance(&mut self, now: Micros, replicas: &[ReplicaLoad], imbalance: f64, pressure: f64) {
        let n = replicas.len();
        if self.streaks.len() != n {
            self.streaks = vec![0; n];
            self.last_advance = None;
        }
        if self.last_advance == Some(now) {
            return;
        }
        self.last_advance = Some(now);
        let admissible = replicas.iter().filter(|r| r.admissible).count().max(1);
        let mean = replicas
            .iter()
            .filter(|r| r.admissible)
            .map(|r| r.active_footprint)
            .sum::<u64>() as f64
            / admissible as f64;
        for (i, r) in replicas.iter().enumerate() {
            let fp = r.active_footprint as f64;
            let overloaded =
                r.admissible && fp > imbalance * mean && fp > pressure * r.capacity as f64;
            if overloaded {
                self.streaks[i] = self.streaks[i].saturating_add(1);
            } else {
                self.streaks[i] = 0;
            }
        }
    }

    fn get(&self, i: usize) -> u32 {
        self.streaks[i]
    }
}

/// Home-replica pinning with sustained-imbalance spill.
#[derive(Debug)]
pub struct CacheAffinityRouter {
    /// Spill only after the home replica has been over the imbalance bar
    /// at this many consecutive *distinct simulation instants* (transient
    /// skew from a few long-context agents is cheaper to ride out than a
    /// cold prefix; a burst of same-instant routing decisions counts
    /// once).
    pub spill_after: u32,
    /// Overload bar: footprint > `imbalance` × fleet-mean footprint.
    pub imbalance: f64,
    /// ... and footprint > `pressure` × pool capacity (an imbalanced but
    /// mostly-empty fleet has no reason to give up cache locality).
    pub pressure: f64,
    /// Prefix-awareness (shared-prefix tier): an agent whose prompt is
    /// covered by a broadcast prefix and whose last decode on its current
    /// replica is at least this stale is a *free mover* — it spills on
    /// the first overloaded instant instead of waiting out `spill_after`
    /// (its private suffix is likely evicted, the shared prefix is
    /// resident everywhere, so the spill costs no warm state).  Inert
    /// while the tier is off (`broadcast_prefix` is then always 0).
    pub free_move_cold_after: Micros,
    streaks: OverloadStreaks,
    /// Requests routed away from their home (telemetry).
    pub spills: u64,
}

impl Default for CacheAffinityRouter {
    fn default() -> CacheAffinityRouter {
        CacheAffinityRouter {
            spill_after: 8,
            imbalance: 1.5,
            pressure: 0.75,
            free_move_cold_after: Micros(3_000_000),
            streaks: OverloadStreaks::default(),
            spills: 0,
        }
    }
}

impl Router for CacheAffinityRouter {
    fn name(&self) -> String {
        "cache-affinity".into()
    }

    fn route(&mut self, ctx: &RouteCtx, replicas: &[ReplicaLoad]) -> usize {
        let n = replicas.len();
        self.streaks.advance(ctx.now, replicas, self.imbalance, self.pressure);
        let home = ctx.agent.0 as usize % n;
        if !replicas[home].admissible {
            // Home down (dead or draining): re-hash the displaced cohort
            // evenly over the admissible replicas.  Stable while the
            // admissible set is stable, so displaced agents still build
            // affinity on their fallback replica.  Counting scan — the
            // routing path stays allocation-free.
            let admissible = replicas.iter().filter(|r| r.admissible).count();
            let mut rank = ctx.agent.0 as usize % admissible.max(1);
            for (i, r) in replicas.iter().enumerate() {
                if !r.admissible {
                    continue;
                }
                if rank == 0 {
                    return i;
                }
                rank -= 1;
            }
            unreachable!("no admissible replica offered to router");
        }
        let spill_after =
            if broadcast_free(ctx, self.free_move_cold_after) { 1 } else { self.spill_after };
        if self.streaks.get(home) >= spill_after {
            let target = least_loaded(replicas);
            if target != home {
                self.spills += 1;
                return target;
            }
        }
        home
    }
}

/// Re-homing router: cache-affinity pins that migrate **cold agents
/// first** under sustained imbalance or replica loss.
///
/// Each agent starts on the id-hashed home; unlike
/// [`CacheAffinityRouter`], the pin is stored and can move.  When the
/// agent's home has been overloaded for `spill_after` distinct instants
/// *and* the agent is cold (no decode completed on its current replica
/// within `cold_after`), it is re-homed to the least-loaded admissible
/// replica — warm agents keep their radix paths, cold agents (whose
/// paths are the most likely to be LRU-evicted already) carry the
/// rebalancing.  Agents whose home is dead or draining re-home
/// immediately: their pin is cleared and re-established wherever load is
/// lowest, which is how a refilled (drained or revived) replica fills
/// back up.
#[derive(Debug)]
pub struct RebalanceRouter {
    /// Re-home only after this many consecutive distinct overload
    /// instants (same role as [`CacheAffinityRouter::spill_after`]).
    pub spill_after: u32,
    /// Overload bar: footprint > `imbalance` × fleet-mean footprint.
    pub imbalance: f64,
    /// ... and footprint > `pressure` × pool capacity.  Lower than the
    /// affinity default: re-homing is permanent, so it is worth doing a
    /// little earlier than one-off spills.
    pub pressure: f64,
    /// An agent is cold when its last decode on its current replica is
    /// at least this long ago (or unknown).  Calibrated against the
    /// workload's second-scale tool latencies: the lognormal tail —
    /// agents parked in long tool calls, whose cache has aged the most —
    /// clears this bar; agents bouncing straight back do not.
    pub cold_after: Micros,
    homes: FxHashMap<u64, usize>,
    streaks: OverloadStreaks,
    /// Agents re-homed to another replica (telemetry).
    pub rehomes: u64,
}

impl Default for RebalanceRouter {
    fn default() -> RebalanceRouter {
        RebalanceRouter {
            spill_after: 8,
            imbalance: 1.5,
            pressure: 0.5,
            cold_after: Micros(3_000_000),
            homes: FxHashMap::default(),
            streaks: OverloadStreaks::default(),
            rehomes: 0,
        }
    }
}

impl RebalanceRouter {
    fn is_cold(&self, ctx: &RouteCtx) -> bool {
        match ctx.heat {
            None => true,
            Some(last) => ctx.now.saturating_sub(last) >= self.cold_after,
        }
    }
}

impl Router for RebalanceRouter {
    fn name(&self) -> String {
        "rebalance".into()
    }

    fn route(&mut self, ctx: &RouteCtx, replicas: &[ReplicaLoad]) -> usize {
        let n = replicas.len();
        self.streaks.advance(ctx.now, replicas, self.imbalance, self.pressure);
        let home = self.homes.get(&ctx.agent.0).copied().unwrap_or(ctx.agent.0 as usize % n);
        if !replicas[home].admissible {
            // Pin cleared by replica loss: re-establish it by load.
            let target = least_loaded(replicas);
            self.homes.insert(ctx.agent.0, target);
            self.rehomes += 1;
            return target;
        }
        // Prefix-awareness: a cold agent covered by a broadcast prefix
        // only has migratable state left (the shared prefix is resident
        // everywhere), so it re-homes on the first overloaded instant
        // instead of waiting out the full streak.  Inert with the tier
        // off (`broadcast_prefix` is then always 0).
        let spill_after = if ctx.broadcast_prefix > 0 { 1 } else { self.spill_after };
        if self.streaks.get(home) >= spill_after && self.is_cold(ctx) {
            let target = least_loaded(replicas);
            if target != home {
                self.homes.insert(ctx.agent.0, target);
                self.rehomes += 1;
                return target;
            }
        }
        home
    }
}

/// Instantiate a router from configuration.
pub fn make_router(kind: RouterKind) -> Box<dyn Router> {
    match kind {
        RouterKind::RoundRobin => Box::new(RoundRobinRouter::default()),
        RouterKind::LeastLoaded => Box::new(LeastLoadedRouter),
        RouterKind::CacheAffinity => Box::new(CacheAffinityRouter::default()),
        RouterKind::Rebalance => Box::new(RebalanceRouter::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(footprints: &[u64], capacity: u64) -> Vec<ReplicaLoad> {
        footprints
            .iter()
            .map(|&f| ReplicaLoad { active_footprint: f, capacity, admissible: true })
            .collect()
    }

    fn ctx(agent: u64, current: Option<usize>, t: u64) -> RouteCtx {
        RouteCtx {
            agent: AgentId(agent),
            ctx_tokens: 10,
            current,
            now: Micros(t),
            heat: None,
            broadcast_prefix: 0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobinRouter::default();
        let l = loads(&[0, 0, 0], 100);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&ctx(i, None, i), &l)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_non_admissible() {
        let mut r = RoundRobinRouter::default();
        let mut l = loads(&[0, 0, 0], 100);
        l[1].admissible = false;
        let picks: Vec<usize> = (0..4).map(|i| r.route(&ctx(i, None, i), &l)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn least_loaded_picks_argmin_with_index_ties() {
        let mut r = LeastLoadedRouter;
        let c = ctx(9, None, 1);
        assert_eq!(r.route(&c, &loads(&[50, 20, 30], 100)), 1);
        assert_eq!(r.route(&c, &loads(&[20, 20, 30], 100)), 0);
        // The argmin never lands on a non-admissible replica.
        let mut l = loads(&[50, 20, 30], 100);
        l[1].admissible = false;
        assert_eq!(r.route(&c, &l), 2);
    }

    #[test]
    fn affinity_pins_agents_to_home() {
        let mut r = CacheAffinityRouter::default();
        let l = loads(&[10, 10, 10, 10], 1_000);
        let mut t = 0u64;
        for agent in 0..8u64 {
            let home = (agent % 4) as usize;
            for _ in 0..3 {
                t += 1;
                assert_eq!(r.route(&ctx(agent, Some(home), t), &l), home);
            }
        }
        assert_eq!(r.spills, 0);
    }

    #[test]
    fn affinity_spills_only_under_sustained_pressure() {
        let mut r = CacheAffinityRouter::default();
        // Replica 0 over both bars (>1.5x mean, >0.75 capacity).
        let hot = loads(&[95, 10, 10, 10], 100);
        // A short burst does not spill...
        let mut t = 0u64;
        for _ in 0..(r.spill_after - 1) {
            t += 1;
            assert_eq!(r.route(&ctx(0, Some(0), t), &hot), 0);
        }
        // ...the sustained streak does, to the least-loaded replica.
        t += 1;
        assert_eq!(r.route(&ctx(0, Some(0), t), &hot), 1);
        assert_eq!(r.spills, 1);
        // Agents homed elsewhere are unaffected.
        assert_eq!(r.route(&ctx(2, Some(2), t), &hot), 2);
        // Once the pressure clears the streak resets and home is restored.
        assert_eq!(r.route(&ctx(0, Some(1), t + 1), &loads(&[10; 4], 100)), 0);
        for k in 0..3u64 {
            assert_eq!(r.route(&ctx(0, Some(0), t + 2 + k), &hot), 0);
        }
    }

    #[test]
    fn affinity_streak_advances_once_per_instant() {
        let mut r = CacheAffinityRouter::default();
        let hot = loads(&[95, 10, 10, 10], 100);
        // 100 same-instant decisions: one streak advance, no spill.
        for _ in 0..100 {
            assert_eq!(r.route(&ctx(0, Some(0), 7), &hot), 0);
        }
        assert_eq!(r.spills, 0);
    }

    #[test]
    fn affinity_ignores_imbalance_in_an_empty_fleet() {
        let mut r = CacheAffinityRouter::default();
        // 40 vs 1: heavily imbalanced but far below the pressure bar.
        let l = loads(&[40, 1, 1, 1], 1_000);
        for t in 0..20u64 {
            assert_eq!(r.route(&ctx(4, Some(0), t), &l), 0);
        }
        assert_eq!(r.spills, 0);
    }

    #[test]
    fn affinity_rehashes_cohort_of_a_down_home() {
        let mut r = CacheAffinityRouter::default();
        let mut l = loads(&[10, 10, 10, 10], 1_000);
        l[1].admissible = false;
        // Agents homed on replica 1 spread over {0, 2, 3} and stick there.
        let fallback_a = r.route(&ctx(1, Some(1), 1), &l);
        let fallback_b = r.route(&ctx(5, Some(1), 2), &l);
        assert_ne!(fallback_a, 1);
        assert_ne!(fallback_b, 1);
        assert_ne!(fallback_a, fallback_b, "cohort must not pile onto one replica");
        assert_eq!(r.route(&ctx(1, Some(fallback_a), 3), &l), fallback_a, "fallback is stable");
        // Other homes are untouched.
        assert_eq!(r.route(&ctx(2, Some(2), 4), &l), 2);
    }

    #[test]
    fn affinity_free_movers_spill_on_first_overloaded_instant() {
        let mut r = CacheAffinityRouter::default();
        let hot = loads(&[95, 10, 10, 10], 100);
        // Broadcast-covered agent with no private heat on its home: one
        // overloaded instant suffices (no spill_after streak).
        let free = RouteCtx { broadcast_prefix: 512, ..ctx(0, Some(0), 1) };
        assert_eq!(r.route(&free, &hot), 1);
        assert_eq!(r.spills, 1);
        // A *warm* covered agent is not a free mover: it still rides out
        // the imbalance like any pinned agent.
        let warm = RouteCtx { broadcast_prefix: 512, heat: Some(Micros(2)), ..ctx(4, Some(0), 2) };
        assert_eq!(r.route(&warm, &hot), 0);
        // Without broadcast coverage nothing changed (tier-off parity).
        let plain = ctx(8, Some(0), 3);
        assert_eq!(r.route(&plain, &hot), 0);
        assert_eq!(r.spills, 1);
    }

    #[test]
    fn rebalance_free_movers_rehome_without_the_full_streak() {
        const SEC: u64 = 1_000_000;
        let mut r = RebalanceRouter::default();
        let hot = loads(&[95, 10, 10, 10], 100);
        // One overloaded instant: a cold, broadcast-covered agent moves...
        let cold = RouteCtx { broadcast_prefix: 512, ..ctx(0, Some(0), SEC) };
        assert_eq!(r.route(&cold, &hot), 1);
        assert_eq!(r.rehomes, 1);
        // ...a cold but *uncovered* agent still waits out spill_after.
        let plain = ctx(4, Some(0), 2 * SEC);
        assert_eq!(r.route(&plain, &hot), 0);
        // ...and a covered but *hot* agent stays (cold gate still applies).
        let fresh = Some(Micros(3 * SEC));
        let warm = RouteCtx { broadcast_prefix: 512, heat: fresh, ..ctx(8, Some(0), 3 * SEC) };
        assert_eq!(r.route(&warm, &hot), 0);
        assert_eq!(r.rehomes, 1);
    }

    #[test]
    fn rebalance_pins_until_sustained_overload() {
        let mut r = RebalanceRouter::default();
        let l = loads(&[10, 10, 10, 10], 1_000);
        for t in 1..20u64 {
            assert_eq!(r.route(&ctx(3, Some(3), t), &l), 3);
        }
        assert_eq!(r.rehomes, 0);
    }

    #[test]
    fn rebalance_migrates_cold_agents_first() {
        const SEC: u64 = 1_000_000;
        let mut r = RebalanceRouter::default();
        let hot = loads(&[95, 10, 10, 10], 100);
        // Build the sustained-overload streak on replica 0, one distinct
        // second-scale instant per decision.
        let mut t = 0u64;
        for _ in 0..r.spill_after {
            t += SEC;
            // A *hot* agent (decoded just now) keeps its pin throughout.
            let c = RouteCtx { heat: Some(Micros(t)), ..ctx(0, Some(0), t) };
            assert_eq!(r.route(&c, &hot), 0);
        }
        assert_eq!(r.rehomes, 0, "hot agent must not migrate");
        // A cold agent (no decode for >= cold_after) is re-homed...
        t += SEC;
        let stale = Micros(t).saturating_sub(r.cold_after);
        let cold = RouteCtx { heat: Some(stale), ..ctx(4, Some(0), t) };
        assert_eq!(r.route(&cold, &hot), 1);
        assert_eq!(r.rehomes, 1);
        // ...while a freshly-decoded agent at the same instant stays put.
        let warm = RouteCtx { heat: Some(Micros(t)), ..ctx(0, Some(0), t) };
        assert_eq!(r.route(&warm, &hot), 0);
        // The new pin is sticky even after pressure clears.
        let calm = loads(&[10; 4], 100);
        assert_eq!(r.route(&ctx(4, Some(1), t + SEC), &calm), 1);
        assert_eq!(r.rehomes, 1);
    }

    #[test]
    fn rebalance_clears_pins_of_a_dead_home() {
        let mut r = RebalanceRouter::default();
        let mut l = loads(&[10, 30, 20, 40], 1_000);
        l[0].admissible = false;
        // Agent homed on dead replica 0 lands on the least-loaded (2).
        assert_eq!(r.route(&ctx(0, None, 1), &l), 2);
        assert_eq!(r.rehomes, 1);
        // The new pin holds once the old home revives: pin was cleared.
        l[0].admissible = true;
        assert_eq!(r.route(&ctx(0, Some(2), 2), &l), 2);
        assert_eq!(r.rehomes, 1);
    }

    #[test]
    fn factory_dispatches() {
        assert_eq!(make_router(RouterKind::RoundRobin).name(), "round-robin");
        assert_eq!(make_router(RouterKind::LeastLoaded).name(), "least-loaded");
        assert_eq!(make_router(RouterKind::CacheAffinity).name(), "cache-affinity");
        assert_eq!(make_router(RouterKind::Rebalance).name(), "rebalance");
    }
}
