//! Replica routing policies for the data-parallel cluster.
//!
//! Routing decides *where an agent's next generation step lands relative
//! to its warm prefix* — which dominates multi-agent throughput far more
//! than raw load spread (cf. KVFlow / Continuum in PAPERS.md).  Three
//! policies span the trade-off space:
//!
//! * [`RoundRobinRouter`] — per-request cycling.  Perfectly even request
//!   spread, but an agent revisits a given replica only every N steps, so
//!   each admission misses its last N-1 steps of context (recompute).
//! * [`LeastLoadedRouter`] — per-request argmin over active KV working
//!   sets.  Best instantaneous memory balance, but agents migrate whenever
//!   another replica dips below their current one, abandoning warm
//!   prefixes mid-trajectory.
//! * [`CacheAffinityRouter`] — each agent is pinned to an id-hashed home
//!   replica; every step of the trajectory extends the same radix path,
//!   so hit rate matches the single-replica driver at 1/N the load.  Load
//!   imbalance is tolerated until it is *sustained* — observed overloaded
//!   at several distinct simulation instants in a row — then individual
//!   steps spill to the least-loaded replica without re-homing the agent.
//!
//! All policies are deterministic: ties break toward the lowest replica
//! index and every input comes from the simulation state.

use crate::config::RouterKind;
use crate::core::{AgentId, Micros};

/// Per-replica load snapshot offered to routing decisions.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaLoad {
    /// Σ context tokens of slot-holding agents currently assigned here
    /// (the same agent-level working set the controller's U_t watches).
    pub active_footprint: u64,
    /// KV pool capacity in tokens.
    pub capacity: u64,
}

/// A routing policy: picks the replica for one agent's next request.
pub trait Router {
    fn name(&self) -> String;

    /// Choose a replica index in `0..replicas.len()` for `agent`'s next
    /// generation step at simulation time `now`.  `ctx_tokens` is the
    /// agent's current context length; `current` is the replica its
    /// working set sits on right now (`None` before first admission).
    fn route(
        &mut self,
        agent: AgentId,
        ctx_tokens: u64,
        current: Option<usize>,
        now: Micros,
        replicas: &[ReplicaLoad],
    ) -> usize;
}

/// Replica with the smallest active working set (ties → lowest index).
fn least_loaded(replicas: &[ReplicaLoad]) -> usize {
    let mut best = 0;
    for (i, r) in replicas.iter().enumerate().skip(1) {
        if r.active_footprint < replicas[best].active_footprint {
            best = i;
        }
    }
    best
}

/// Cache-oblivious per-request cycling.
#[derive(Debug, Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn name(&self) -> String {
        "round-robin".into()
    }

    fn route(
        &mut self,
        _agent: AgentId,
        _ctx_tokens: u64,
        _current: Option<usize>,
        _now: Micros,
        replicas: &[ReplicaLoad],
    ) -> usize {
        let r = self.next % replicas.len();
        self.next = self.next.wrapping_add(1);
        r
    }
}

/// Per-request argmin over active KV working sets.
#[derive(Debug, Default)]
pub struct LeastLoadedRouter;

impl Router for LeastLoadedRouter {
    fn name(&self) -> String {
        "least-loaded".into()
    }

    fn route(
        &mut self,
        _agent: AgentId,
        _ctx_tokens: u64,
        _current: Option<usize>,
        _now: Micros,
        replicas: &[ReplicaLoad],
    ) -> usize {
        least_loaded(replicas)
    }
}

/// Home-replica pinning with sustained-imbalance spill.
#[derive(Debug)]
pub struct CacheAffinityRouter {
    /// Spill only after the home replica has been over the imbalance bar
    /// at this many consecutive *distinct simulation instants* (transient
    /// skew from a few long-context agents is cheaper to ride out than a
    /// cold prefix; a burst of same-instant routing decisions counts
    /// once).
    pub spill_after: u32,
    /// Overload bar: footprint > `imbalance` × fleet-mean footprint.
    pub imbalance: f64,
    /// ... and footprint > `pressure` × pool capacity (an imbalanced but
    /// mostly-empty fleet has no reason to give up cache locality).
    pub pressure: f64,
    /// Per-replica consecutive-overload streak, advanced at most once per
    /// distinct `now` (streaks only move while requests flow; with no
    /// routing activity there is nothing to spill anyway).
    streaks: Vec<u32>,
    last_advance: Option<Micros>,
    /// Requests routed away from their home (telemetry).
    pub spills: u64,
}

impl Default for CacheAffinityRouter {
    fn default() -> CacheAffinityRouter {
        CacheAffinityRouter {
            spill_after: 8,
            imbalance: 1.5,
            pressure: 0.75,
            streaks: Vec::new(),
            last_advance: None,
            spills: 0,
        }
    }
}

impl Router for CacheAffinityRouter {
    fn name(&self) -> String {
        "cache-affinity".into()
    }

    fn route(
        &mut self,
        agent: AgentId,
        _ctx_tokens: u64,
        _current: Option<usize>,
        now: Micros,
        replicas: &[ReplicaLoad],
    ) -> usize {
        let n = replicas.len();
        if self.streaks.len() != n {
            self.streaks = vec![0; n];
            self.last_advance = None;
        }
        if self.last_advance != Some(now) {
            self.last_advance = Some(now);
            let mean = replicas.iter().map(|r| r.active_footprint).sum::<u64>() as f64 / n as f64;
            for (i, r) in replicas.iter().enumerate() {
                let fp = r.active_footprint as f64;
                let overloaded =
                    fp > self.imbalance * mean && fp > self.pressure * r.capacity as f64;
                if overloaded {
                    self.streaks[i] = self.streaks[i].saturating_add(1);
                } else {
                    self.streaks[i] = 0;
                }
            }
        }
        let home = agent.0 as usize % n;
        if self.streaks[home] >= self.spill_after {
            let target = least_loaded(replicas);
            if target != home {
                self.spills += 1;
                return target;
            }
        }
        home
    }
}

/// Instantiate a router from configuration.
pub fn make_router(kind: RouterKind) -> Box<dyn Router> {
    match kind {
        RouterKind::RoundRobin => Box::new(RoundRobinRouter::default()),
        RouterKind::LeastLoaded => Box::new(LeastLoadedRouter),
        RouterKind::CacheAffinity => Box::new(CacheAffinityRouter::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(footprints: &[u64], capacity: u64) -> Vec<ReplicaLoad> {
        footprints
            .iter()
            .map(|&f| ReplicaLoad { active_footprint: f, capacity })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobinRouter::default();
        let l = loads(&[0, 0, 0], 100);
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(AgentId(i), 10, None, Micros(i), &l)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_argmin_with_index_ties() {
        let mut r = LeastLoadedRouter;
        let t = Micros(1);
        assert_eq!(r.route(AgentId(9), 10, None, t, &loads(&[50, 20, 30], 100)), 1);
        assert_eq!(r.route(AgentId(9), 10, None, t, &loads(&[20, 20, 30], 100)), 0);
    }

    #[test]
    fn affinity_pins_agents_to_home() {
        let mut r = CacheAffinityRouter::default();
        let l = loads(&[10, 10, 10, 10], 1_000);
        let mut t = 0u64;
        for agent in 0..8u64 {
            let home = (agent % 4) as usize;
            for _ in 0..3 {
                t += 1;
                assert_eq!(r.route(AgentId(agent), 10, Some(home), Micros(t), &l), home);
            }
        }
        assert_eq!(r.spills, 0);
    }

    #[test]
    fn affinity_spills_only_under_sustained_pressure() {
        let mut r = CacheAffinityRouter::default();
        // Replica 0 over both bars (>1.5x mean, >0.75 capacity).
        let hot = loads(&[95, 10, 10, 10], 100);
        // A short burst does not spill...
        let mut t = 0u64;
        for _ in 0..(r.spill_after - 1) {
            t += 1;
            assert_eq!(r.route(AgentId(0), 10, Some(0), Micros(t), &hot), 0);
        }
        // ...the sustained streak does, to the least-loaded replica.
        t += 1;
        assert_eq!(r.route(AgentId(0), 10, Some(0), Micros(t), &hot), 1);
        assert_eq!(r.spills, 1);
        // Agents homed elsewhere are unaffected.
        assert_eq!(r.route(AgentId(2), 10, Some(2), Micros(t), &hot), 2);
        // Once the pressure clears the streak resets and home is restored.
        assert_eq!(r.route(AgentId(0), 10, Some(1), Micros(t + 1), &loads(&[10; 4], 100)), 0);
        for k in 0..3u64 {
            assert_eq!(r.route(AgentId(0), 10, Some(0), Micros(t + 2 + k), &hot), 0);
        }
    }

    #[test]
    fn affinity_streak_advances_once_per_instant() {
        let mut r = CacheAffinityRouter::default();
        let hot = loads(&[95, 10, 10, 10], 100);
        // 100 same-instant decisions: one streak advance, no spill.
        for _ in 0..100 {
            assert_eq!(r.route(AgentId(0), 10, Some(0), Micros(7), &hot), 0);
        }
        assert_eq!(r.spills, 0);
    }

    #[test]
    fn affinity_ignores_imbalance_in_an_empty_fleet() {
        let mut r = CacheAffinityRouter::default();
        // 40 vs 1: heavily imbalanced but far below the pressure bar.
        let l = loads(&[40, 1, 1, 1], 1_000);
        for t in 0..20u64 {
            assert_eq!(r.route(AgentId(4), 10, Some(0), Micros(t), &l), 0);
        }
        assert_eq!(r.spills, 0);
    }

    #[test]
    fn factory_dispatches() {
        assert_eq!(make_router(RouterKind::RoundRobin).name(), "round-robin");
        assert_eq!(make_router(RouterKind::LeastLoaded).name(), "least-loaded");
        assert_eq!(make_router(RouterKind::CacheAffinity).name(), "cache-affinity");
    }
}
