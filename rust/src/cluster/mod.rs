//! Data-parallel serving cluster: N engine replicas behind one controller.
//!
//! A production deployment runs N data-parallel `SimEngine` replicas —
//! each with its own KV pool and radix cache — behind a single admission
//! coordinator.  This module owns that topology:
//!
//! * [`router`] decides which replica an agent's next generation step
//!   lands on (round-robin / least-loaded / cache-affinity);
//! * [`run_sharded`] is the fleet event loop: per-replica iteration
//!   timelines, one global [`Controller`] regulating admission for the
//!   whole fleet through aggregated signals — `U_t` as the max over
//!   replica working-set usages (the fleet is as congested as its worst
//!   shard), `H_t` as the admission-weighted mean hit rate;
//! * [`ClusterCoordinator`] packages both behind `driver::run_job`.
//!
//! ## Timing semantics (and the N=1 contract)
//!
//! The cluster clock stops at replica iteration boundaries, and at tool
//! completions only when the whole fleet is idle — exactly the
//! event-boundary semantics of the pre-cluster single-engine driver,
//! which the N=1 path must reproduce **bit-for-bit** (differential-tested
//! in `tests/cluster_integration.rs`).  The cost of keeping that contract
//! at N>1 is that an idle replica can receive work up to one
//! (busiest-replica) iteration late; iterations are milliseconds against
//! second-scale tool latencies, so the distortion is negligible and —
//! more importantly — identical across router policies under comparison.
//!
//! Replicas are advanced in index order and every event queue tie-breaks
//! by insertion order, so cluster runs are deterministic for any N.

pub mod router;

pub use router::{make_router, CacheAffinityRouter, ReplicaLoad, Router};

use crate::agent::Agent;
use crate::config::JobConfig;
use crate::coordinator::{slots::BoundaryDecision, ControlInputs, Controller};
use crate::core::{AgentId, ConcurError, Micros, RequestId, Result};
use crate::costmodel::CostModel;
use crate::driver::RunResult;
use crate::engine::{EngineCounters, EngineSignals, FinishedReq, SimEngine};
use crate::metrics::{Breakdown, Histogram, LifetimeRatio, Phase, TimeSeries};
use crate::sim::{EventQueue, SimClock};

/// Owns the replica fleet and its router for one job.
pub struct ClusterCoordinator {
    engines: Vec<SimEngine>,
    router: Box<dyn Router>,
}

impl ClusterCoordinator {
    /// Build `job.topology.replicas` independent engine replicas, each
    /// with its own KV pool, radix cache and host link.
    pub fn new(job: &JobConfig) -> ClusterCoordinator {
        let n = job.topology.replicas.max(1);
        let engines = (0..n)
            .map(|_| SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone())))
            .collect();
        ClusterCoordinator { engines, router: make_router(job.topology.router) }
    }

    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// Run one batch job over the fleet to completion.
    pub fn run(
        mut self,
        agents: Vec<Agent>,
        controller: Box<dyn Controller>,
    ) -> Result<RunResult> {
        run_sharded(&mut self.engines, self.router.as_mut(), agents, controller)
    }
}

/// A replica iteration in flight: effects land when the clock reaches
/// `done_at` (the single-engine driver's "step, then advance" made
/// concurrent).
struct InFlight {
    done_at: Micros,
    finished: Vec<FinishedReq>,
}

/// Fleet-level engine signals for the controller and telemetry series.
/// With one replica this returns its signals verbatim (the bit-exact
/// single-engine path); otherwise `U`-style signals take the max over
/// replicas and `H_t` is the admission-weighted mean, weighted by each
/// replica's *windowed* observation count — recent admissions — so a
/// long-idle replica's frozen window cannot outvote the replicas
/// actively serving traffic.  Single pass, no intermediate allocation.
fn aggregate_signals(engines: &[SimEngine]) -> EngineSignals {
    if engines.len() == 1 {
        return engines[0].signals();
    }
    let mut agg =
        EngineSignals { kv_usage: 0.0, pool_usage: 0.0, hit_rate: 0.0, running: 0, waiting: 0 };
    let (mut num, mut den, mut hit_sum) = (0.0, 0.0, 0.0);
    for e in engines {
        let s = e.signals();
        agg.kv_usage = agg.kv_usage.max(s.kv_usage);
        agg.pool_usage = agg.pool_usage.max(s.pool_usage);
        agg.running += s.running;
        agg.waiting += s.waiting;
        let w = e.hit_observations() as f64;
        num += w * s.hit_rate;
        den += w;
        hit_sum += s.hit_rate;
    }
    agg.hit_rate = if den > 0.0 { num / den } else { hit_sum / engines.len() as f64 };
    agg
}

/// The controller's `U_t` numerator/denominator: footprint and capacity
/// of the most-loaded replica, so `ControlInputs::usage()` yields the
/// max-over-replicas usage without floating-point detours (compared by
/// cross-multiplication; exact for N=1 by construction).
fn fleet_usage(footprint: &[u64], engines: &[SimEngine]) -> (u64, u64) {
    let mut best = (footprint[0], engines[0].pool().capacity());
    for (fp, e) in footprint.iter().zip(engines).skip(1) {
        let cand = (*fp, e.pool().capacity());
        if (cand.0 as u128) * (best.1 as u128) > (best.0 as u128) * (cand.1 as u128) {
            best = cand;
        }
    }
    best
}

/// Ask the router for a replica, giving it the live load snapshot (built
/// into the caller's reused scratch buffer — no per-request allocation).
/// The caller moves the agent's footprint ledger entry if the choice
/// migrates it.  Single-replica fleets skip the router entirely (the N=1
/// path carries zero routing overhead).
// Private twice-used helper: the arg list IS the routing context; a
// one-off params struct would only rename it.
#[allow(clippy::too_many_arguments)]
fn route_to(
    router: &mut dyn Router,
    engines: &[SimEngine],
    footprint: &[u64],
    loads: &mut Vec<ReplicaLoad>,
    current: Option<usize>,
    aid: AgentId,
    ctx: u64,
    now: Micros,
) -> usize {
    if engines.len() == 1 {
        return 0;
    }
    loads.clear();
    loads.extend(engines.iter().zip(footprint).map(|(e, &fp)| ReplicaLoad {
        active_footprint: fp,
        capacity: e.pool().capacity(),
    }));
    let r = router.route(aid, ctx, current, now, loads);
    assert!(r < engines.len(), "router returned out-of-range replica {r}");
    r
}

/// Run a complete batch job over an explicit replica slice.  This is the
/// one driver loop in the crate: `driver::run_with` calls it with a
/// single-element slice and `driver::run_job` with the configured fleet.
pub fn run_sharded(
    engines: &mut [SimEngine],
    router: &mut dyn Router,
    agents: Vec<Agent>,
    mut controller: Box<dyn Controller>,
) -> Result<RunResult> {
    assert!(!engines.is_empty(), "cluster needs at least one replica");
    let n = engines.len();
    if let Some(cap) = controller.engine_request_cap() {
        for e in engines.iter_mut() {
            e.cfg.max_running = cap;
        }
    }

    let mut slots = crate::coordinator::SlotManager::new();
    let total_gen: u64 = agents.iter().map(|a| a.total_gen_tokens()).sum();
    let agents_total = agents.len();
    // Agent ids from the workload generator are dense 0..n — index by id
    // for O(1) access on the hot path.
    let mut fleet: Vec<Agent> = agents;
    fleet.sort_by_key(|a| a.id.0);
    for (i, a) in fleet.iter().enumerate() {
        assert_eq!(a.id.0 as usize, i, "driver requires dense agent ids");
        slots.register(a.id);
    }
    fn agent(fleet: &mut [Agent], id: AgentId) -> &mut Agent {
        &mut fleet[id.0 as usize]
    }
    // Replica each agent's working set currently sits on (None before
    // first admission) and the per-replica slot-holder footprints — the
    // numerators of each replica's U_t, maintained incrementally.
    let mut assignment: Vec<Option<usize>> = vec![None; agents_total];
    let mut footprint: Vec<u64> = vec![0; n];

    let mut clock = SimClock::new();
    let mut events: EventQueue<AgentId> = EventQueue::new();
    let mut next_req: u64 = 0;
    let mut toolwait = Micros::ZERO;

    let mut usage_series = TimeSeries::new("kv_usage");
    let mut hit_series = TimeSeries::new("hit_rate");
    let mut active_series = TimeSeries::new("active_agents");
    let mut window_series = TimeSeries::new("window");
    let mut agent_latency = Histogram::new("agent_e2e_latency");

    let mut finished_agents = 0usize;
    let mut engine_steps = 0u64;
    let mut stagnant: Vec<u32> = vec![0; n];
    let mut inflight: Vec<Option<InFlight>> = (0..n).map(|_| None).collect();
    // Scratch for per-decision load snapshots (reused, never reallocated).
    let mut loads: Vec<ReplicaLoad> = Vec::with_capacity(n);

    loop {
        let now = clock.now();

        // 1. Land replica iterations completing now: apply finished
        //    requests, then give the controller one observation per
        //    completed iteration.
        for slot in inflight.iter_mut() {
            if !slot.as_ref().is_some_and(|f| f.done_at <= now) {
                continue;
            }
            let fin = slot.take().expect("checked above");
            debug_assert_eq!(fin.done_at, now, "completion skipped by the clock");
            for f in fin.finished {
                let a = agent(&mut fleet, f.agent);
                let before = a.context_len() as u64;
                let ar = assignment[f.agent.0 as usize].expect("agent never assigned");
                match a.on_step_finished(&f.output, now) {
                    Some(tool_latency) => {
                        // Still active: account its context growth.
                        footprint[ar] += a.context_len() as u64 - before;
                        events.push(now + tool_latency, f.agent);
                    }
                    None => {
                        footprint[ar] -= before; // slot released
                        slots.release(f.agent);
                        finished_agents += 1;
                        let start = a.started_at.unwrap_or(Micros::ZERO);
                        agent_latency.record(now.saturating_sub(start));
                    }
                }
            }
            #[cfg(debug_assertions)]
            for (rep, fp) in footprint.iter().enumerate() {
                let expect: u64 = slots
                    .active_ids()
                    .filter(|aid| assignment[aid.0 as usize] == Some(rep))
                    .map(|aid| fleet[aid.0 as usize].context_len() as u64)
                    .sum();
                debug_assert_eq!(expect, *fp, "replica {rep} footprint drifted");
            }
            let sig = aggregate_signals(engines);
            let (fp, cap) = fleet_usage(&footprint, engines);
            controller.on_signals(&ControlInputs {
                engine: sig,
                active_agents: slots.active_count(),
                active_footprint: fp,
                capacity: cap,
            });
            usage_series.record(now, sig.pool_usage);
            hit_series.record(now, sig.hit_rate);
            active_series.record(now, slots.active_count() as f64);
            let w = controller.window();
            window_series.record(now, if w == usize::MAX { f64::NAN } else { w as f64 });
        }

        // 2. Deliver due tool completions; paused agents wait for slots.
        while let Some((_, aid)) = events.pop_due(now) {
            let a = agent(&mut fleet, aid);
            a.on_tool_done();
            if slots.on_step_boundary(aid, controller.window()) == BoundaryDecision::Continue {
                let ctx = a.context_len() as u64;
                let req = a.make_request(RequestId(next_req), now);
                next_req += 1;
                let cur = assignment[aid.0 as usize];
                let tgt = route_to(router, engines, &footprint, &mut loads, cur, aid, ctx, now);
                let old = cur.expect("active agent was never assigned");
                if old != tgt {
                    // Migration: the working set follows the agent.
                    footprint[old] -= ctx;
                    footprint[tgt] += ctx;
                    assignment[aid.0 as usize] = Some(tgt);
                }
                engines[tgt].submit(req);
            } else {
                let ar = assignment[aid.0 as usize].expect("paused before admission");
                footprint[ar] -= a.context_len() as u64; // paused
            }
        }

        // 3. Grant freed slots (resume paused LIFO, admit fresh FIFO).
        for aid in slots.grant_up_to(controller.window()) {
            let a = agent(&mut fleet, aid);
            let ctx = a.context_len() as u64;
            let req = a.make_request(RequestId(next_req), now);
            next_req += 1;
            let cur = assignment[aid.0 as usize];
            let tgt = route_to(router, engines, &footprint, &mut loads, cur, aid, ctx, now);
            assignment[aid.0 as usize] = Some(tgt);
            footprint[tgt] += ctx;
            engines[tgt].submit(req);
        }

        // 4. Start an iteration on every idle replica with queued work.
        for (r, e) in engines.iter_mut().enumerate() {
            if inflight[r].is_some() || !e.has_work() {
                continue;
            }
            let out = e.step(now);
            engine_steps += 1;
            let progressed = !out.work.is_empty() || !out.finished.is_empty();
            if progressed {
                stagnant[r] = 0;
            } else {
                stagnant[r] += 1;
                if stagnant[r] > 10_000 {
                    let sig = e.signals();
                    return Err(ConcurError::engine(format!(
                        "livelock: replica {r} made no progress for 10k \
                         iterations (running={} waiting={} pool_usage={:.3} \
                         working_usage={:.3} free={} evictable={})",
                        sig.running,
                        sig.waiting,
                        sig.pool_usage,
                        sig.kv_usage,
                        e.pool().free(),
                        e.tree().evictable_gpu_tokens(),
                    )));
                }
            }
            inflight[r] = Some(InFlight {
                done_at: now + Micros(out.duration.0.max(1)),
                finished: out.finished,
            });
        }

        // 5. Advance: to the earliest iteration boundary, else (fleet
        //    fully idle) jump to the next tool completion.
        if let Some(t) = inflight.iter().flatten().map(|f| f.done_at).min() {
            clock.advance_to(t);
        } else if let Some(t) = events.peek_time() {
            toolwait += t.saturating_sub(now);
            clock.advance_to(t);
        } else {
            break; // no work in flight, no future events → done
        }
    }

    if finished_agents != agents_total {
        return Err(ConcurError::engine(format!(
            "run ended with {finished_agents}/{agents_total} agents finished"
        )));
    }

    let total_time = clock.now();
    let mut breakdown = Breakdown::new();
    for e in engines.iter_mut() {
        breakdown.merge(&std::mem::take(&mut e.breakdown));
    }
    breakdown.add(Phase::ToolWait, toolwait);
    let mut counters = EngineCounters::default();
    let mut hits = LifetimeRatio::default();
    for e in engines.iter() {
        counters.merge(&e.counters);
        hits.record(e.lifetime_hits.num, e.lifetime_hits.den);
    }
    let throughput_tps = if total_time.0 > 0 {
        total_gen as f64 / total_time.as_secs_f64()
    } else {
        0.0
    };

    Ok(RunResult {
        scheduler: controller.name(),
        total_time,
        breakdown,
        hit_rate: hits.ratio(),
        counters,
        usage_series,
        hit_series,
        active_series,
        window_series,
        agents_total,
        agents_finished: finished_agents,
        total_gen_tokens: total_gen,
        throughput_tps,
        agent_latency,
        engine_steps,
        pauses: slots.pauses,
        resumes: slots.resumes,
        replicas: n,
        router: if n == 1 { "single".into() } else { router.name() },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::WorkloadGenerator;
    use crate::config::presets;
    use crate::config::{
        AimdParams, EngineConfig, JobConfig, RouterKind, SchedulerKind,
        TopologyConfig, WorkloadConfig,
    };
    use crate::coordinator::make_controller;

    fn cluster_job(replicas: usize, router: RouterKind) -> JobConfig {
        JobConfig {
            cluster: presets::qwen3_cluster(8),
            engine: EngineConfig::default(),
            workload: WorkloadConfig {
                n_agents: 12,
                steps_min: 2,
                steps_max: 4,
                ..WorkloadConfig::default()
            },
            scheduler: SchedulerKind::Concur(AimdParams::default()),
            topology: TopologyConfig { replicas, router },
        }
    }

    fn run(job: &JobConfig) -> RunResult {
        let agents = WorkloadGenerator::new(job.workload.clone()).generate();
        let controller = make_controller(&job.scheduler);
        ClusterCoordinator::new(job).run(agents, controller).unwrap()
    }

    #[test]
    fn coordinator_builds_the_configured_fleet() {
        let c = ClusterCoordinator::new(&cluster_job(4, RouterKind::RoundRobin));
        assert_eq!(c.replicas(), 4);
    }

    #[test]
    fn multi_replica_job_completes_under_every_router() {
        for router in [
            RouterKind::RoundRobin,
            RouterKind::LeastLoaded,
            RouterKind::CacheAffinity,
        ] {
            let r = run(&cluster_job(3, router));
            assert_eq!(r.agents_finished, 12, "{router:?} lost agents");
            assert_eq!(r.replicas, 3);
            assert_eq!(r.router, router.name());
            assert!(r.total_time.0 > 0);
        }
    }

    #[test]
    fn single_replica_reports_the_single_path() {
        let r = run(&cluster_job(1, RouterKind::LeastLoaded));
        assert_eq!(r.replicas, 1);
        assert_eq!(r.router, "single");
        assert_eq!(r.agents_finished, 12);
    }

    #[test]
    fn fleet_usage_picks_the_most_loaded_replica() {
        let job = cluster_job(2, RouterKind::RoundRobin);
        let engines: Vec<SimEngine> = (0..2)
            .map(|_| SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone())))
            .collect();
        let cap = engines[0].pool().capacity();
        assert_eq!(fleet_usage(&[10, 50], &engines), (50, cap));
        assert_eq!(fleet_usage(&[70, 50], &engines), (70, cap));
    }

    #[test]
    fn aggregate_signals_sums_queue_depths() {
        let job = cluster_job(2, RouterKind::RoundRobin);
        let mut engines: Vec<SimEngine> = (0..2)
            .map(|_| SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone())))
            .collect();
        engines[0].submit(crate::engine::Request {
            id: RequestId(0),
            agent: AgentId(0),
            prompt: (0..64).collect(),
            gen: (1000..1010).collect(),
            prev_ctx: 0,
            submitted_at: Micros::ZERO,
        });
        let sig = aggregate_signals(&engines);
        assert_eq!(sig.waiting, 1);
        assert_eq!(sig.running, 0);
        // Fresh engines report the optimistic hit-rate default.
        assert_eq!(sig.hit_rate, 1.0);
    }
}
