//! Data-parallel serving cluster: N engine replicas behind one controller.
//!
//! A production deployment runs N data-parallel `SimEngine` replicas —
//! each with its own KV pool and radix cache — behind a single admission
//! coordinator.  This module owns that topology:
//!
//! * [`router`] decides which replica an agent's next generation step
//!   lands on (round-robin / least-loaded / cache-affinity / rebalance);
//! * [`prefix`] is the optional cross-replica shared-prefix broadcast
//!   tier: hot shared prompt prefixes are shipped to every admissible
//!   replica and pinned read-only, recovering the cross-agent hits that
//!   sharding splits (off by default and inert when off);
//! * [`transport`] is the optional asynchronous interconnect: all
//!   cross-replica KV movement becomes link-occupying transfers with
//!   completion-time visibility (delayed broadcast installs, per-target
//!   delta shipping, KV handoff on planned drains — off by default and
//!   inert when off: shipping then teleports exactly as before);
//! * [`run_sharded`] is the fleet event loop: per-replica iteration
//!   timelines, one global [`Controller`] regulating admission for the
//!   whole fleet, and the scripted [`FaultPlan`] lifecycle (kill /
//!   drain-and-refill / revive);
//! * **open-loop traffic** (`TopologyConfig::open_loop`, off by
//!   default): sessions *arrive* over a seeded Poisson process instead
//!   of all being present at t=0, idle between turns, carry a tenant
//!   priority class, abandon when a turn out-waits their patience, and
//!   can be shed at the door by a hysteretic overload governor — with
//!   TTFT / per-turn latency percentiles and goodput-under-SLO
//!   accounting (see [`OpenLoopStats`]);
//! * **stochastic faults** (`TopologyConfig::fault_rates`, off by
//!   default): a seeded per-replica MTBF/MTTR process injects
//!   kill+revive and drain events beside (or instead of) the scripted
//!   plan, deterministically from its seed — fixed seed, bit-identical
//!   replay;
//! * [`ClusterCoordinator`] packages both behind `driver::run_job`.
//!
//! ## Signal flow (paper §4.2-§4.3)
//!
//! After every completed replica iteration the controller observes one
//! `ControlInputs`: `U_t` — the aggregate context of slot-holding agents
//! over pool capacity, taken as the **max over live replicas** (the
//! fleet is as congested as its worst shard) — and `H_t`, the
//! admission-weighted mean of per-replica windowed hit rates.  The AIMD
//! law (paper Eq. 1) then adjusts the active-agent window that
//! [`run_sharded`] enforces at step boundaries via `SlotManager`.
//! **Dead replicas are excluded from both aggregates**: a max over a
//! dead replica would freeze `U_t` on its stale working set and hold the
//! window down for capacity that no longer exists (DESIGN.md §Faults).
//!
//! ## Fault semantics
//!
//! * **kill** — the replica's pool/cache/queues are wiped; agents with a
//!   step in flight there lose it, drop their admission slot and re-enter
//!   the admission queue (FIFO, behind never-admitted agents — their
//!   cache died, so they have no warm-resume priority); tool-waiting
//!   agents keep their slot but their replica pin is cleared.  Ties with
//!   an iteration completing at the same instant resolve fault-first.
//! * **drain** — the replica stops receiving admissions (routers see it
//!   as non-admissible), finishes the requests it holds, then wipes its
//!   cache and rejoins ("refill").  Unlike kill, agents keep their slots
//!   and simply route elsewhere at their next step boundary.
//! * **revive** — a killed replica rejoins the admissible fleet, empty.
//!   With the broadcast tier enabled, hot shared prefixes are re-shipped
//!   to revived and refilled replicas at the same instant they rejoin.
//!
//! Stochastic (MTBF/MTTR-sampled) events apply the **same transitions
//! through the same code path** as scripted ones.  A sampled fault that
//! would leave the fleet unroutable (fewer than one admissible replica)
//! or that lands on a replica already down or draining is *suppressed* —
//! counted in `FaultStats::stochastic_suppressed`, never applied — and
//! the replica's stream simply redraws its next instant, so the process
//! stays deterministic whatever the fleet state.
//!
//! ## Timing semantics (and the N=1 contract)
//!
//! The cluster clock stops at replica iteration boundaries, at scripted
//! fault instants, and at tool completions only when the whole fleet is
//! idle — exactly the event-boundary semantics of the pre-cluster
//! single-engine driver, which the N=1 no-fault path must reproduce
//! **bit-for-bit** (differential-tested in
//! `tests/cluster_integration.rs`, including `FaultPlan::none()` and
//! identity tool skew).  The cost of keeping that contract at N>1 is
//! that an idle replica can receive work up to one (busiest-replica)
//! iteration late; iterations are milliseconds against second-scale tool
//! latencies, so the distortion is negligible and — more importantly —
//! identical across router policies under comparison.
//!
//! Replicas are advanced in index order and every event queue tie-breaks
//! by insertion order, so cluster runs are deterministic for any N, any
//! fault plan and any skew vector.
//!
//! ## Parallel stepping (the deterministic event-clock merge)
//!
//! Between those cluster-level clock stops, replicas are independent:
//! `SimEngine::step` touches nothing outside its own replica.
//! [`run_sharded`] therefore fans the per-instant step loop out over a
//! `parallel::StepPool` worker pool (`CONCUR_WORKERS`, the same knob
//! as the sweep driver) and re-serializes determinism at the merge
//! points: outcomes are *applied* in replica-index order, and the clock
//! advance takes the minimum over per-replica next-event times with the
//! same `(time, replica)` tie order as the sequential loop.  Results are
//! **bit-identical at any worker count** — pinned by the workers-{1,2,4}
//! full-stack determinism test and the CI determinism job.  N=1 fleets
//! never spawn a pool, so the single-engine bit-identity contract above
//! is untouched.  [`run_sharded_with_workers`] takes the worker count
//! explicitly (tests use it to avoid racing on the environment).

pub mod clock;
pub mod prefix;
pub mod router;
pub mod transport;

mod parallel;

pub use prefix::{PrefixTierStats, SharedPrefixTier};
pub use router::{
    make_router, CacheAffinityRouter, RebalanceRouter, ReplicaLoad, RouteCtx, Router,
};
pub use transport::{Transfer, TransferKind, TransferPayload, Transport, TransportStats};

use crate::agent::{Agent, AgentPhase, Priority, WorkflowGraph};
use crate::config::{
    FaultKind, FaultPlan, FaultRateConfig, JobConfig, OpenLoopConfig, PrefixTierConfig,
    TransportConfig,
};
use crate::coordinator::{
    slots::BoundaryDecision, ControlInputs, Controller, OverloadGovernor, SlotManager,
};
use crate::core::{AgentId, ConcurError, Micros, RequestId, Result, Rng};
use crate::costmodel::CostModel;
use crate::driver::{AgentOutcome, RunResult};
use crate::engine::{EngineCounters, EngineSignals, FinishedReq, KvLifetimePolicy, SimEngine};
use crate::metrics::{profiler, Breakdown, Histogram, LifetimeRatio, Phase, TimeSeries};
use crate::sim::{EventQueue, SimClock};

/// Fault/drain/migration telemetry for one run (all zero when the fleet
/// stays healthy).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Replica kills applied from the fault plan.
    pub kills: u64,
    /// Drains initiated from the fault plan.
    pub drains: u64,
    /// Killed replicas revived from the fault plan.
    pub revives: u64,
    /// Drained replicas that emptied, wiped their cache and rejoined.
    pub refills: u64,
    /// Agents whose in-flight step died with a replica and re-entered
    /// the admission queue.
    pub requeued_agents: u64,
    /// Step-boundary migrations: an agent's next step was routed to a
    /// different replica than the one its state sat on.
    pub migrations: u64,
    /// Agents whose warm context a draining replica checkpointed through
    /// the transport to their re-homed replica (zero with the
    /// transport's `drain_handoff` off).
    pub handoff_agents: u64,
    /// Σ tokens those handoffs moved over the interconnect (heads
    /// already resident at the destination — e.g. its broadcast-pinned
    /// copy of a shared prefix — are excluded: they never travel).
    pub handoff_tokens: u64,
    /// Stochastic (MTBF/MTTR-sampled) fault events actually applied;
    /// these are included in the kill/drain/revive counts above.
    pub stochastic_injected: u64,
    /// Stochastic events suppressed instead of applied: the draw landed
    /// on a replica that was already down or draining, or applying it
    /// would have left the fleet without an admissible replica.
    pub stochastic_suppressed: u64,
}

/// Open-loop traffic telemetry for one run (all zero for closed-batch
/// runs, where every agent is present at t=0 and none is ever shed).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpenLoopStats {
    /// Sessions that arrived (equals `agents_total` once the arrival
    /// schedule has drained).
    pub arrived: u64,
    /// Low-priority sessions rejected by the overload governor — at the
    /// door on arrival, or swept out of the queue when it trips.
    pub shed: u64,
    /// Sessions that gave up after a turn out-waited their patience.
    pub abandoned: u64,
    /// Turns whose latency exceeded the applicable SLO bound (TTFT for
    /// a session's first turn, the per-step bound afterwards).
    pub turn_violations: u64,
    /// Times the governor tripped into the shedding state.
    pub governor_trips: u64,
    /// Σ generated tokens of high-priority sessions that completed with
    /// every turn inside SLO — goodput-under-SLO, the paper-style
    /// "useful" throughput that shedding is meant to protect.
    pub goodput_high: u64,
    /// Goodput-under-SLO of low-priority sessions.
    pub goodput_low: u64,
    /// High-priority sessions that ran to completion.
    pub finished_high: u64,
    /// Low-priority sessions that ran to completion.
    pub finished_low: u64,
}

/// Replica lifecycle state inside one `run_sharded` invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReplicaState {
    Alive,
    Draining,
    Dead,
}

fn admissible_count(state: &[ReplicaState]) -> usize {
    state.iter().filter(|s| **s == ReplicaState::Alive).count()
}

/// Exponential draw in microseconds with the given mean (seconds),
/// clamped to ≥ 1µs so consecutive events never collapse onto one
/// instant.
fn exp_micros(rng: &mut Rng, mean_s: f64) -> Micros {
    // 1 - u ∈ (0, 1], so the log is finite and non-positive.
    let secs = -mean_s * (1.0 - rng.next_f64()).ln();
    Micros((secs * 1e6).round().max(1.0) as u64)
}

/// Seeded per-replica MTBF/MTTR fault process.  Each replica owns an
/// independent forked RNG stream and a single pending instant: while up,
/// the next failure (kill with probability `1 - drain_share`, else a
/// drain) lands one Exp(MTBF) gap out; a kill holds the replica down for
/// Exp(MTTR) before its revive.  Draw counts per event are fixed, so a
/// given seed yields one immutable event tape — bit-identical replay —
/// and suppression only redraws the *next* gap, never rewinds a stream.
struct FaultSampler {
    mtbf_s: f64,
    mttr_s: f64,
    drain_share: f64,
    per: Vec<SampledReplica>,
    /// Cached `min` over the per-replica pending instants, rebuilt lazily
    /// after [`next_due`](FaultSampler::next_due) advances any stream —
    /// [`next_event_at`](FaultSampler::next_event_at) sits on the
    /// clock-stop hot path and must not rescan every stream per stop.
    earliest: Option<Micros>,
    dirty: bool,
}

struct SampledReplica {
    rng: Rng,
    next_at: Micros,
    /// Set while this sampler holds the replica killed (revive pending).
    down: bool,
}

impl FaultSampler {
    fn new(cfg: &FaultRateConfig, n: usize) -> FaultSampler {
        let mut root = Rng::new(cfg.seed);
        let per = (0..n)
            .map(|r| {
                let mut rng = root.fork(r as u64 + 1);
                let next_at = exp_micros(&mut rng, cfg.mtbf_s);
                SampledReplica { rng, next_at, down: false }
            })
            .collect();
        FaultSampler {
            mtbf_s: cfg.mtbf_s,
            mttr_s: cfg.mttr_s,
            drain_share: cfg.drain_share,
            per,
            earliest: None,
            dirty: true,
        }
    }

    /// Earliest pending instant across all replica streams (for the
    /// clock-advance candidates).  O(1) unless a stream advanced since
    /// the last call.
    fn next_event_at(&mut self) -> Option<Micros> {
        if self.dirty {
            self.earliest = self.per.iter().map(|p| p.next_at).min();
            self.dirty = false;
        }
        self.earliest
    }

    /// Pop replica `r`'s next applicable event at or before `now`, or
    /// `None` once its stream is past `now`.  Suppressed draws (counted
    /// in `fstats`) are skipped internally, so the caller applies every
    /// returned event.
    fn next_due(
        &mut self,
        r: usize,
        now: Micros,
        state: &[ReplicaState],
        fstats: &mut FaultStats,
    ) -> Option<FaultKind> {
        if self.per[r].next_at > now {
            return None;
        }
        // Every path below advances this stream's pending instant.
        self.dirty = true;
        loop {
            let p = &mut self.per[r];
            if p.next_at > now {
                return None;
            }
            if p.down {
                // MTTR elapsed: the held-down replica comes back.
                p.down = false;
                p.next_at = p.next_at + exp_micros(&mut p.rng, self.mtbf_s);
                if state[r] == ReplicaState::Dead {
                    fstats.stochastic_injected += 1;
                    return Some(FaultKind::Revive);
                }
                // A scripted event already revived it out from under us.
                fstats.stochastic_suppressed += 1;
                continue;
            }
            let drain = p.rng.chance(self.drain_share);
            let survivable = state[r] == ReplicaState::Alive && admissible_count(state) >= 2;
            if !survivable {
                fstats.stochastic_suppressed += 1;
                p.next_at = p.next_at + exp_micros(&mut p.rng, self.mtbf_s);
                continue;
            }
            fstats.stochastic_injected += 1;
            return if drain {
                p.next_at = p.next_at + exp_micros(&mut p.rng, self.mtbf_s);
                Some(FaultKind::Drain)
            } else {
                p.down = true;
                p.next_at = p.next_at + exp_micros(&mut p.rng, self.mttr_s);
                Some(FaultKind::Kill)
            };
        }
    }
}

/// Owns the replica fleet, its router and its fault script for one job.
pub struct ClusterCoordinator {
    engines: Vec<SimEngine>,
    router: Box<dyn Router>,
    faults: FaultPlan,
    tool_skew: Vec<f64>,
    prefix_tier: PrefixTierConfig,
    transport: TransportConfig,
    open_loop: OpenLoopConfig,
    fault_rates: FaultRateConfig,
}

impl ClusterCoordinator {
    /// Build `job.topology.replicas` independent engine replicas, each
    /// with its own KV pool, radix cache and host link.
    pub fn new(job: &JobConfig) -> ClusterCoordinator {
        let n = job.topology.replicas.max(1);
        let engines = (0..n)
            .map(|_| SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone())))
            .collect();
        ClusterCoordinator {
            engines,
            router: make_router(job.topology.router),
            faults: job.topology.fault_plan.clone(),
            tool_skew: job.topology.tool_skew.clone(),
            prefix_tier: job.topology.prefix_tier,
            transport: job.topology.transport,
            open_loop: job.topology.open_loop,
            fault_rates: job.topology.fault_rates,
        }
    }

    /// Number of replicas in the fleet.
    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// Run one batch job over the fleet to completion.
    pub fn run(
        self,
        agents: Vec<Agent>,
        controller: Box<dyn Controller>,
    ) -> Result<RunResult> {
        self.run_workflow(agents, None, controller)
    }

    /// [`Self::run`] with a workflow dependency graph: only indegree-0
    /// nodes are admissible at t=0, and each node's completion releases
    /// its ready children through the normal slot path.  `None` is the
    /// plain closed batch (everyone present at t=0), bit-identical to
    /// [`Self::run`].
    pub fn run_workflow(
        mut self,
        agents: Vec<Agent>,
        workflow: Option<WorkflowGraph>,
        controller: Box<dyn Controller>,
    ) -> Result<RunResult> {
        run_sharded(
            &mut self.engines,
            self.router.as_mut(),
            agents,
            workflow,
            controller,
            &self.faults,
            &self.tool_skew,
            &self.prefix_tier,
            &self.transport,
            &self.open_loop,
            &self.fault_rates,
        )
    }
}

/// A replica iteration in flight: effects land when the clock reaches
/// `done_at` (the single-engine driver's "step, then advance" made
/// concurrent).
struct InFlight {
    done_at: Micros,
    finished: Vec<FinishedReq>,
}

/// Fleet-level engine signals for the controller and telemetry series.
/// With one replica this returns its signals verbatim (the bit-exact
/// single-engine path); otherwise `U`-style signals take the max over
/// live replicas and `H_t` is the admission-weighted mean, weighted by
/// each replica's *windowed* observation count — recent admissions — so
/// a long-idle replica's frozen window cannot outvote the replicas
/// actively serving traffic.  Dead replicas are excluded entirely: their
/// signals describe state that no longer exists.  Single pass, no
/// intermediate allocation.
fn aggregate_signals(engines: &[SimEngine], state: &[ReplicaState]) -> EngineSignals {
    if engines.len() == 1 {
        return engines[0].signals();
    }
    let mut agg =
        EngineSignals { kv_usage: 0.0, pool_usage: 0.0, hit_rate: 0.0, running: 0, waiting: 0 };
    let (mut num, mut den, mut hit_sum) = (0.0, 0.0, 0.0);
    let mut live = 0usize;
    for (e, st) in engines.iter().zip(state) {
        if *st == ReplicaState::Dead {
            continue;
        }
        live += 1;
        let s = e.signals();
        agg.kv_usage = agg.kv_usage.max(s.kv_usage);
        agg.pool_usage = agg.pool_usage.max(s.pool_usage);
        agg.running += s.running;
        agg.waiting += s.waiting;
        let w = e.hit_observations() as f64;
        num += w * s.hit_rate;
        den += w;
        hit_sum += s.hit_rate;
    }
    agg.hit_rate = if den > 0.0 { num / den } else { hit_sum / live.max(1) as f64 };
    agg
}

/// The controller's `U_t` numerator/denominator: footprint and capacity
/// of the most-loaded **live** replica, so `ControlInputs::usage()`
/// yields the max-over-replicas usage without floating-point detours
/// (compared by cross-multiplication; exact for N=1 by construction).
/// Dead replicas are skipped — their footprint ledger is zeroed at the
/// kill, but excluding them here keeps the invariant independent of that
/// bookkeeping.
fn fleet_usage(footprint: &[u64], engines: &[SimEngine], state: &[ReplicaState]) -> (u64, u64) {
    let mut best: Option<(u64, u64)> = None;
    for ((fp, e), st) in footprint.iter().zip(engines).zip(state) {
        if *st == ReplicaState::Dead {
            continue;
        }
        let cand = (*fp, e.pool().capacity());
        best = Some(match best {
            None => cand,
            Some(b) => {
                if (cand.0 as u128) * (b.1 as u128) > (b.0 as u128) * (cand.1 as u128) {
                    cand
                } else {
                    b
                }
            }
        });
    }
    // FaultPlan validation guarantees a live replica; the fallback keeps
    // the arithmetic total anyway.
    best.unwrap_or((0, 1))
}

/// Ask the router for a replica, giving it the live load snapshot (built
/// into the caller's reused scratch buffer — no per-request allocation)
/// and the agent's cache heat on its current replica.  The caller moves
/// the agent's footprint ledger entry if the choice migrates it.
/// `incoming` is an optional per-replica load bias (empty = none): the
/// drain handoff folds the tokens it has already shipped this drain into
/// what the router sees, so a burst of same-instant decisions spreads
/// instead of herding onto one snapshot's least-loaded replica.
/// Single-replica fleets skip the router entirely (the N=1 path carries
/// zero routing overhead).
// Private thrice-used helper: the arg list IS the routing context; a
// one-off params struct would only rename it.
#[allow(clippy::too_many_arguments)]
fn route_to(
    router: &mut dyn Router,
    engines: &[SimEngine],
    state: &[ReplicaState],
    footprint: &[u64],
    incoming: &[u64],
    loads: &mut Vec<ReplicaLoad>,
    current: Option<usize>,
    aid: AgentId,
    ctx: u64,
    broadcast_prefix: u64,
    now: Micros,
) -> usize {
    if engines.len() == 1 {
        return 0;
    }
    loads.clear();
    loads.extend(engines.iter().zip(footprint).zip(state).enumerate().map(
        |(i, ((e, &fp), &st))| ReplicaLoad {
            active_footprint: fp + incoming.get(i).copied().unwrap_or(0),
            capacity: e.pool().capacity(),
            admissible: st == ReplicaState::Alive,
        },
    ));
    let heat = current.and_then(|r| engines[r].agent_heat(aid));
    let rctx = RouteCtx { agent: aid, ctx_tokens: ctx, current, now, heat, broadcast_prefix };
    let r = router.route(&rctx, loads);
    assert!(r < engines.len(), "router returned out-of-range replica {r}");
    assert!(state[r] == ReplicaState::Alive, "router chose non-admissible replica {r}");
    r
}

/// Scale a tool latency by a replica's skew multiplier.  The identity
/// multiplier short-circuits so unskewed runs avoid the float round-trip
/// entirely (bit-identity of the no-skew path).
fn scale_latency(lat: Micros, skew: f64) -> Micros {
    if skew == 1.0 {
        lat
    } else {
        Micros((lat.0 as f64 * skew).round() as u64)
    }
}

/// Apply one fault transition to replica `r` — the single code path
/// shared by the scripted [`FaultPlan`] and the stochastic
/// [`FaultSampler`], so both produce identical kill / drain / revive
/// semantics (see the module docs).  The caller records the
/// admissible-replica series after each application.
// Private twice-used helper: the arg list IS the fleet state; a one-off
// params struct would only rename it.
#[allow(clippy::too_many_arguments)]
fn apply_fault_event(
    kind: FaultKind,
    r: usize,
    now: Micros,
    engines: &mut [SimEngine],
    router: &mut dyn Router,
    state: &mut [ReplicaState],
    fleet: &mut [Agent],
    assignment: &mut [Option<usize>],
    footprint: &mut [u64],
    slots: &mut SlotManager,
    inflight: &mut [Option<InFlight>],
    stops: &mut clock::ClockStops,
    stagnant: &mut [u32],
    tier: &mut Option<SharedPrefixTier>,
    transport: &mut Option<Transport>,
    loads: &mut Vec<ReplicaLoad>,
    fstats: &mut FaultStats,
    handoff_time: &mut Micros,
) {
    match kind {
        FaultKind::Kill => {
            // The iteration in flight dies with the replica.
            inflight[r] = None;
            stops.clear_boundary(r);
            stagnant[r] = 0;
            for (i, slot) in assignment.iter_mut().enumerate() {
                if *slot != Some(r) {
                    continue;
                }
                // Replica pin cleared for everyone who lived here.
                *slot = None;
                let a = &mut fleet[i];
                if a.phase == AgentPhase::Generating {
                    // Step in flight lost: back to Ready, slot
                    // revoked, re-enter the admission queue cold.
                    a.on_replica_failed();
                    slots.requeue(a.id);
                    fstats.requeued_agents += 1;
                }
            }
            footprint[r] = 0;
            engines[r].clear_state();
            if let Some(t) = tier.as_mut() {
                // The broadcast pins died with the radix tree; a
                // revive re-ships on the next maintenance pass.
                t.on_replica_wiped(r);
            }
            if let Some(tp) = transport.as_mut() {
                // In-flight transfers to the dead replica have
                // nowhere to land...
                tp.cancel_dst(r);
                // ...and a replica killed mid-drain also severs the
                // handoff checkpoints it was still streaming out: the
                // source died with the payloads.  The agents involved
                // were requeued cold above, so nothing is lost — they
                // just re-prefill wherever admission lands them next.
                tp.cancel_src_handoffs(r);
            }
            state[r] = ReplicaState::Dead;
            fstats.kills += 1;
        }
        FaultKind::Drain => {
            state[r] = ReplicaState::Draining;
            fstats.drains += 1;
            // KV handoff: before the drain's eventual refill wipes
            // this replica, checkpoint its hottest agents' warm
            // contexts through the transport to the replica each
            // agent is re-homed to, so they resume warm instead of
            // re-prefilling from scratch (heat-ranked, budget- and
            // agent-capped).  Routing the handoff *now* both picks
            // and — for stateful routers — pins the destination,
            // so the agent's next step boundary follows its KV.
            if transport.as_ref().is_some_and(|tp| tp.cfg.drain_handoff) {
                let n = engines.len();
                let tp = transport.as_mut().expect("checked above");
                let mut cands: Vec<(AgentId, Micros, u64)> = Vec::new();
                for (i, slot) in assignment.iter().enumerate() {
                    if *slot != Some(r) || fleet[i].is_done() {
                        continue;
                    }
                    let (gpu, cpu) = engines[r].tree().peek_prefix(fleet[i].context());
                    let warm = gpu + cpu;
                    if warm > 0 {
                        let heat = engines[r].agent_heat(fleet[i].id);
                        cands.push((fleet[i].id, heat.unwrap_or(Micros::ZERO), warm));
                    }
                }
                // Hottest first (most recently decoded = most KV
                // still worth moving); ties break on agent id.
                cands.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                let mut budget = tp.cfg.handoff_budget_tokens;
                let mut agents_left = tp.cfg.handoff_max_agents;
                // Tokens already shipped per destination this
                // drain: folded into the loads the router sees, so
                // one drain does not herd its whole cohort onto
                // the replica that was least loaded at the first
                // decision (the normal step-boundary path gets
                // this for free from footprint updates).
                let mut incoming: Vec<u64> = vec![0; n];
                for (aid, _, warm) in cands {
                    if agents_left == 0 || budget == 0 {
                        break;
                    }
                    if warm > budget {
                        continue; // a smaller context may still fit
                    }
                    let a = &fleet[aid.0 as usize];
                    let context = a.context()[..warm as usize].to_vec();
                    let bp = tier.as_ref().map_or(0, |t| t.broadcast_prefix_len(&context));
                    let ctx_len = a.context_len() as u64;
                    let dst = route_to(
                        router, engines, state, footprint, &incoming, loads, Some(r), aid,
                        ctx_len, bp, now,
                    );
                    // Only what the destination lacks entirely
                    // crosses the wire: its broadcast-pinned copy
                    // of the shared prefix (and any other resident
                    // head) stays put, exactly like delta
                    // shipping.  Its CPU-tier coverage reloads
                    // locally — off the fabric, but the write-in
                    // leg below still pays for the promotion
                    // (nothing about a handoff is free).
                    let (dgpu, dcpu) = engines[dst].tree().peek_prefix(&context);
                    let wire = warm.saturating_sub(dgpu + dcpu);
                    // Host-link legs at issue: the drainer reads
                    // out what leaves it; the target writes in
                    // everything it must materialise (wire + its
                    // own CPU-tier promotions).  Fabric inside
                    // `ship_*`.
                    let src_done = engines[r].charge_link_transfer(wire, now);
                    let dst_write = warm.saturating_sub(dgpu);
                    let dst_done = engines[dst].charge_link_transfer(dst_write, now);
                    let host_done = src_done.max(dst_done);
                    budget -= warm;
                    agents_left -= 1;
                    incoming[dst] += warm;
                    fstats.handoff_agents += 1;
                    fstats.handoff_tokens += wire;
                    if wire > 0 && tp.cfg.delayed_visibility {
                        tp.ship_handoff(r, dst, wire, host_done, now, aid, context);
                    } else {
                        // Instantaneous visibility — or nothing to
                        // move over the fabric at all (the state
                        // is already node-local at the target):
                        // the landing happens now, the link time
                        // above is still paid.
                        if wire > 0 {
                            let k = TransferKind::Handoff;
                            let done = tp.ship_instant(k, r, dst, wire, host_done, now);
                            *handoff_time += done.saturating_sub(now);
                        } else {
                            *handoff_time += host_done.saturating_sub(now);
                        }
                        engines[dst].install_handoff_context(aid, &context, now);
                    }
                }
            }
        }
        FaultKind::Revive => {
            // State was wiped at the kill; just rejoin.
            state[r] = ReplicaState::Alive;
            fstats.revives += 1;
        }
    }
}

/// KV lifetime hint for the step `a` is about to run on `engine` (see
/// `SimEngine::set_lifetime_hint`).  Under `StepsToExecution` it is the
/// remaining trajectory length — floored at 1 on the final step while
/// the workflow graph still holds children of this node, whose prompts
/// re-read its shared context the instant it finishes.  Under `ToolTtl`
/// it is the upcoming tool latency in microseconds (0 on the final
/// step: no tool return to pin for).
fn lifetime_hint(engine: &SimEngine, a: &Agent, graph: Option<&WorkflowGraph>) -> u64 {
    match engine.lifetime_policy() {
        KvLifetimePolicy::Lru => 0,
        KvLifetimePolicy::StepsToExecution => {
            let steps = a.remaining_steps() as u64;
            if steps == 0 && graph.is_some_and(|g| !g.children_of(a.id).is_empty()) {
                1
            } else {
                steps
            }
        }
        KvLifetimePolicy::ToolTtl => a.next_tool_latency().map_or(0, |l| l.0),
    }
}

/// Run a complete batch job over an explicit replica slice.  This is the
/// one driver loop in the crate: `driver::run_with` calls it with a
/// single-element slice, no faults and no skew; `driver::run_job` with
/// the configured fleet and the job's `TopologyConfig`.
///
/// `faults` scripts replica kills / drains / revivals (see the module
/// docs for semantics) and must validate against `engines.len()`;
/// `tool_skew` is either empty (uniform 1.0) or one positive multiplier
/// per replica, applied to the tool latency of every step served there;
/// `prefix_tier` configures the cross-replica shared-prefix broadcast
/// tier (see [`prefix`] — disabled by default, and **inert** when
/// disabled: the tier-off path is bit-identical to the pre-tier loop);
/// `transport_cfg` configures the asynchronous cross-replica KV
/// [`transport`] (also disabled by default and equally inert: shipping
/// then keeps the legacy instantaneous semantics and drains drop their
/// cache); `open_loop` switches the fleet from closed-batch (everyone
/// present at t=0) to open-loop session traffic with SLO accounting, and
/// `fault_rates` adds the stochastic MTBF/MTTR fault process — both off
/// by default and **inert** when off (differential-tested bit-identical
/// in `tests/cluster_integration.rs`).
///
/// `workflow` optionally imposes a dependency DAG on a closed batch:
/// only indegree-0 nodes are admissible at t=0, and finishing a node
/// releases its ready children through the same slot path (topo-ordered
/// release — see [`crate::agent::workflow_fleet`]).  `None` keeps the
/// everyone-at-t=0 closed batch bit-exactly, and is required with
/// `open_loop` (a DAG node's release time is its dependency edge, not a
/// Poisson arrival).
///
/// # Examples
///
/// Drive a tiny two-replica fleet to completion with a healthy fault
/// plan and uniform tool latencies:
///
/// ```
/// use concur::agent::WorkloadGenerator;
/// use concur::cluster::{make_router, run_sharded};
/// use concur::config::{presets, EngineConfig, FaultPlan, FaultRateConfig, OpenLoopConfig,
///                      PrefixTierConfig, RouterKind, TransportConfig, WorkloadConfig};
/// use concur::coordinator::concur_default;
/// use concur::costmodel::CostModel;
/// use concur::engine::SimEngine;
///
/// let workload =
///     WorkloadConfig { n_agents: 4, steps_min: 2, steps_max: 2, ..WorkloadConfig::default() };
/// let agents = WorkloadGenerator::new(workload).generate();
/// let mut engines: Vec<SimEngine> = (0..2)
///     .map(|_| SimEngine::new(EngineConfig::default(), CostModel::new(presets::qwen3_cluster(2))))
///     .collect();
/// let mut router = make_router(RouterKind::CacheAffinity);
/// let result = run_sharded(
///     &mut engines,
///     router.as_mut(),
///     agents,
///     None, // no workflow DAG: plain closed batch
///     concur_default(),
///     &FaultPlan::none(),
///     &[],
///     &PrefixTierConfig::default(),
///     &TransportConfig::default(),
///     &OpenLoopConfig::default(),
///     &FaultRateConfig::default(),
/// )
/// .unwrap();
/// assert_eq!(result.agents_finished, 4);
/// assert_eq!(result.faults.kills, 0);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn run_sharded(
    engines: &mut [SimEngine],
    router: &mut dyn Router,
    agents: Vec<Agent>,
    workflow: Option<WorkflowGraph>,
    controller: Box<dyn Controller>,
    faults: &FaultPlan,
    tool_skew: &[f64],
    prefix_tier: &PrefixTierConfig,
    transport_cfg: &TransportConfig,
    open_loop: &OpenLoopConfig,
    fault_rates: &FaultRateConfig,
) -> Result<RunResult> {
    // Resolve the step-worker count from the same `CONCUR_WORKERS` knob
    // the sweep driver honors, silently (the sweep path already warns on
    // bad overrides; double-warning every nested run would spam stderr).
    let available = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (workers, _) = crate::driver::resolve_workers_explain(
        std::env::var("CONCUR_WORKERS").ok().as_deref(),
        available,
    );
    run_sharded_with_workers(
        engines, router, agents, workflow, controller, faults, tool_skew, prefix_tier,
        transport_cfg, open_loop, fault_rates, workers,
    )
}

/// [`run_sharded`] with an explicit step-worker count instead of the
/// `CONCUR_WORKERS` environment lookup (`0`/`1` ⇒ sequential stepping).
/// The count only changes *how* ready replicas are stepped, never the
/// result: outputs are bit-identical at any value (see the module docs on
/// the deterministic event-clock merge).  The pool is capped at the
/// replica count; single-replica fleets never spawn one.
#[allow(clippy::too_many_arguments)]
pub fn run_sharded_with_workers(
    engines: &mut [SimEngine],
    router: &mut dyn Router,
    agents: Vec<Agent>,
    workflow: Option<WorkflowGraph>,
    mut controller: Box<dyn Controller>,
    faults: &FaultPlan,
    tool_skew: &[f64],
    prefix_tier: &PrefixTierConfig,
    transport_cfg: &TransportConfig,
    open_loop: &OpenLoopConfig,
    fault_rates: &FaultRateConfig,
    step_workers: usize,
) -> Result<RunResult> {
    assert!(!engines.is_empty(), "cluster needs at least one replica");
    // Baseline for the run's profile delta (all-zero while the profiler
    // is disabled, so the subtraction is free in the common case).
    let prof_start = profiler::snapshot();
    let n = engines.len();
    faults.validate(n)?;
    open_loop.validate()?;
    fault_rates.validate()?;
    assert!(
        tool_skew.is_empty() || tool_skew.len() == n,
        "tool_skew must be empty or one multiplier per replica"
    );
    assert!(
        tool_skew.iter().all(|s| s.is_finite() && *s > 0.0),
        "tool_skew multipliers must be finite and > 0"
    );
    let skew_of = |r: usize| if tool_skew.is_empty() { 1.0 } else { tool_skew[r] };
    if let Some(cap) = controller.engine_request_cap() {
        for e in engines.iter_mut() {
            e.cfg.max_running = cap;
        }
    }

    let mut slots = SlotManager::new();
    let total_gen: u64 = agents.iter().map(|a| a.total_gen_tokens()).sum();
    let agents_total = agents.len();
    let ol = open_loop.enabled;
    // Workflow DAG release state (mutated as nodes finish).  `None` is
    // the plain closed batch and must stay bit-identical to the
    // pre-workflow loop.
    let mut graph: Option<WorkflowGraph> = workflow;
    if let Some(g) = &graph {
        assert!(!ol, "workflow DAGs and open-loop traffic are mutually exclusive");
        assert_eq!(g.len(), agents_total, "workflow graph must cover the fleet exactly");
    }
    // Agent ids from the workload generator are dense 0..n — index by id
    // for O(1) access on the hot path.
    let mut fleet: Vec<Agent> = agents;
    fleet.sort_by_key(|a| a.id.0);
    for (i, a) in fleet.iter().enumerate() {
        assert_eq!(a.id.0 as usize, i, "driver requires dense agent ids");
        if !ol && graph.as_ref().map_or(true, |g| g.is_ready(a.id)) {
            // Closed batch: the whole fleet is present at t=0 — minus
            // workflow nodes with unmet dependencies, which register
            // when their last dependency finishes.  Open loop registers
            // each session at its arrival instant.
            slots.register(a.id);
        }
    }
    // Open-loop arrival schedule: (instant, id), chronological.
    let arrivals: Vec<(Micros, AgentId)> = if ol {
        let mut v: Vec<(Micros, AgentId)> = fleet.iter().map(|a| (a.arrival_at, a.id)).collect();
        v.sort_unstable_by_key(|&(t, id)| (t, id.0));
        v
    } else {
        Vec::new()
    };
    let mut next_arrival = 0usize;
    // Per-session instant its current turn became ready (its arrival, or
    // the latest tool completion): the base of TTFT / per-turn latency
    // and of the patience clock.  A kill-requeue deliberately leaves it
    // alone — the lost step's wait counts against the SLO.
    let mut turn_ready: Vec<Micros> = vec![Micros::ZERO; agents_total];
    let mut in_slo: Vec<bool> = vec![true; agents_total];
    let mut olstats = OpenLoopStats::default();
    // Sessions that left without finishing (shed + abandoned).
    let mut terminated_early = 0usize;
    let slo_ttft = Micros::from_secs_f64(open_loop.slo_ttft_s);
    let slo_step = Micros::from_secs_f64(open_loop.slo_step_s);
    let mut governor: Option<OverloadGovernor> = if ol && open_loop.shed {
        Some(OverloadGovernor::new(open_loop.shed_on_ratio, open_loop.shed_off_ratio))
    } else {
        None
    };
    // Latency shards are recorded per serving replica and merged at
    // assembly (`Histogram::merge` keeps percentiles exact because every
    // histogram shares one bucket layout).
    let mut ttft_shards: Vec<Histogram> =
        if ol { (0..n).map(|_| Histogram::new("ttft")).collect() } else { Vec::new() };
    let mut step_shards: Vec<Histogram> =
        if ol { (0..n).map(|_| Histogram::new("step_latency")).collect() } else { Vec::new() };
    let mut sampler: Option<FaultSampler> =
        if fault_rates.enabled { Some(FaultSampler::new(fault_rates, n)) } else { None };
    fn agent(fleet: &mut [Agent], id: AgentId) -> &mut Agent {
        &mut fleet[id.0 as usize]
    }
    // Replica each agent's working set currently sits on (None before
    // first admission or after its replica died) and the per-replica
    // slot-holder footprints — the numerators of each replica's U_t,
    // maintained incrementally.
    let mut assignment: Vec<Option<usize>> = vec![None; agents_total];
    let mut footprint: Vec<u64> = vec![0; n];

    let mut clock = SimClock::new();
    let mut events: EventQueue<AgentId> = EventQueue::new();
    let mut next_req: u64 = 0;
    let mut toolwait = Micros::ZERO;

    let mut usage_series = TimeSeries::new("kv_usage");
    let mut hit_series = TimeSeries::new("hit_rate");
    let mut active_series = TimeSeries::new("active_agents");
    let mut window_series = TimeSeries::new("window");
    let mut agent_latency = Histogram::new("agent_e2e_latency");
    let mut alive_series = TimeSeries::new("admissible_replicas");
    alive_series.record(Micros::ZERO, n as f64);
    let mut per_agent: Vec<AgentOutcome> = Vec::with_capacity(agents_total);

    let mut finished_agents = 0usize;
    let mut engine_steps = 0u64;
    let mut stagnant: Vec<u32> = vec![0; n];
    let mut inflight: Vec<Option<InFlight>> = (0..n).map(|_| None).collect();
    // Event-heap index over the clock-stop candidates (see `clock`):
    // boundary slots maintained at the three inflight mutation sites
    // below, singleton slots re-synced once per stop in step 5.
    let mut stops = clock::ClockStops::new(n);
    // Scratch for per-decision load snapshots (reused, never reallocated).
    let mut loads: Vec<ReplicaLoad> = Vec::with_capacity(n);

    let mut state: Vec<ReplicaState> = vec![ReplicaState::Alive; n];
    let mut fstats = FaultStats::default();
    let mut next_fault = 0usize;

    // Shared-prefix broadcast tier: absent unless configured, so the
    // tier-off path carries zero extra work (bit-identity differential).
    prefix_tier.validate()?;
    let mut tier: Option<SharedPrefixTier> =
        if prefix_tier.enabled { Some(SharedPrefixTier::new(*prefix_tier, n)) } else { None };
    let mut broadcast_series = TimeSeries::new("broadcast_shipped_tokens");
    let mut broadcast_time = Micros::ZERO;
    // Scratch for the tier's alive-replica view (reused, never reallocated).
    let mut alive_scratch: Vec<bool> = Vec::with_capacity(n);

    // Asynchronous KV transport: absent unless configured, so the
    // transport-off path keeps the legacy teleport semantics bit-exactly.
    transport_cfg.validate()?;
    let mut transport: Option<Transport> = if transport_cfg.enabled {
        Some(Transport::new(*transport_cfg, engines[0].cost.cluster.model.kv_bytes_per_token()))
    } else {
        None
    };
    let mut handoff_time = Micros::ZERO;

    // Parallel stepping: a scoped worker pool for phase 4, capped at the
    // replica count.  `None` means "step inline" — single-replica fleets
    // and `CONCUR_WORKERS=1` never pay thread spawn or channel traffic.
    let step_pool = if step_workers > 1 && n > 1 {
        Some(parallel::StepPool::new(step_workers.min(n)))
    } else {
        None
    };
    let mut ready: Vec<usize> = Vec::with_capacity(n);
    let mut stepped: Vec<crate::engine::StepOutcome> = Vec::with_capacity(n);

    loop {
        let now = clock.now();

        // 0a. Open-loop arrivals due now join the admission queue — or,
        //     for low-priority sessions while the governor is shedding,
        //     are rejected at the door.
        while let Some(&(at, aid)) = arrivals.get(next_arrival).filter(|e| e.0 <= now) {
            next_arrival += 1;
            olstats.arrived += 1;
            let i = aid.0 as usize;
            turn_ready[i] = at;
            let low = fleet[i].priority == Priority::Low;
            if low && governor.as_ref().is_some_and(|g| g.is_shedding()) {
                olstats.shed += 1;
                terminated_early += 1;
            } else if low && open_loop.priority_admission {
                slots.register_low(aid);
            } else {
                slots.register(aid);
            }
        }

        // 0. Apply scripted fault transitions due now.  Ties with an
        //    iteration completing at this instant resolve fault-first: a
        //    replica that dies at t loses an iteration finishing at t.
        while let Some(ev) = faults.events().get(next_fault).filter(|e| e.at <= now) {
            let ev = *ev;
            next_fault += 1;
            apply_fault_event(
                ev.kind, ev.replica, now, engines, router, &mut state, &mut fleet,
                &mut assignment, &mut footprint, &mut slots, &mut inflight, &mut stops,
                &mut stagnant, &mut tier, &mut transport, &mut loads, &mut fstats,
                &mut handoff_time,
            );
            alive_series.record(now, admissible_count(&state) as f64);
        }

        // 0b. Stochastic faults due now, replicas in index order (after
        //     the script: scripted events win same-instant ties, and the
        //     sampler's viability check sees their outcome).  Gated on the
        //     cached earliest instant: when nothing is due, every
        //     `next_due` call would be a pure no-op, so the whole
        //     per-replica sweep is skipped — replica order is only
        //     load-bearing among *due* events, which still process in
        //     index order.
        if let Some(fs) = sampler.as_mut() {
            if fs.next_event_at().is_some_and(|t| t <= now) {
                for r in 0..n {
                    while let Some(kind) = fs.next_due(r, now, &state, &mut fstats) {
                        apply_fault_event(
                            kind, r, now, engines, router, &mut state, &mut fleet,
                            &mut assignment, &mut footprint, &mut slots, &mut inflight,
                            &mut stops, &mut stagnant, &mut tier, &mut transport, &mut loads,
                            &mut fstats, &mut handoff_time,
                        );
                        alive_series.record(now, admissible_count(&state) as f64);
                    }
                }
            }
        }

        // 1. Land replica iterations completing now: apply finished
        //    requests, then give the controller one observation per
        //    completed iteration.
        for (r, slot) in inflight.iter_mut().enumerate() {
            if !slot.as_ref().is_some_and(|f| f.done_at <= now) {
                continue;
            }
            let fin = slot.take().expect("checked above");
            stops.clear_boundary(r);
            debug_assert_eq!(fin.done_at, now, "completion skipped by the clock");
            for f in fin.finished {
                let i = f.agent.0 as usize;
                let a = agent(&mut fleet, f.agent);
                let before = a.context_len() as u64;
                let ar = assignment[i].expect("agent never assigned");
                if ol {
                    // Turn latency: ready (arrival / tool return) to the
                    // step's completion — queueing, recompute and decode
                    // all count against the SLO.  A session's first turn
                    // is its TTFT; later turns meet the per-step bound.
                    let lat = now.saturating_sub(turn_ready[i]);
                    let first_turn = a.steps_done() == 0;
                    let bound = if first_turn { slo_ttft } else { slo_step };
                    if first_turn {
                        ttft_shards[ar].record(lat);
                    } else {
                        step_shards[ar].record(lat);
                    }
                    if lat > bound {
                        in_slo[i] = false;
                        olstats.turn_violations += 1;
                    }
                }
                match a.on_step_finished(&f.output, now) {
                    Some(tool_latency) => {
                        // Still active: account its context growth.
                        footprint[ar] += a.context_len() as u64 - before;
                        events.push(now + scale_latency(tool_latency, skew_of(ar)), f.agent);
                    }
                    None => {
                        footprint[ar] -= before; // slot released
                        slots.release(f.agent);
                        finished_agents += 1;
                        let start = a.started_at.unwrap_or(Micros::ZERO);
                        agent_latency.record(now.saturating_sub(start));
                        per_agent.push(AgentOutcome {
                            agent: f.agent,
                            gen_tokens: a.total_gen_tokens(),
                            finished_at: now,
                        });
                        // Workflow release: this node's completion may
                        // free downstream consumers.  Only a *true*
                        // finish releases — a kill-requeue re-runs the
                        // same step without ever reaching this branch,
                        // so no child is lost or double-released.
                        if let Some(g) = graph.as_mut() {
                            for ready_id in g.on_finished(f.agent) {
                                slots.register(ready_id);
                            }
                        }
                        if ol {
                            // Goodput-under-SLO: a completed session
                            // counts only if every turn met its bound.
                            let tokens = a.total_gen_tokens();
                            match a.priority {
                                Priority::High => {
                                    olstats.finished_high += 1;
                                    if in_slo[i] {
                                        olstats.goodput_high += tokens;
                                    }
                                }
                                Priority::Low => {
                                    olstats.finished_low += 1;
                                    if in_slo[i] {
                                        olstats.goodput_low += tokens;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            #[cfg(debug_assertions)]
            for (rep, fp) in footprint.iter().enumerate() {
                let expect: u64 = slots
                    .active_ids()
                    .filter(|aid| assignment[aid.0 as usize] == Some(rep))
                    .map(|aid| fleet[aid.0 as usize].context_len() as u64)
                    .sum();
                debug_assert_eq!(expect, *fp, "replica {rep} footprint drifted");
            }
            let sig = aggregate_signals(engines, &state);
            let (fp, cap) = fleet_usage(&footprint, engines, &state);
            controller.on_signals(&ControlInputs {
                engine: sig,
                active_agents: slots.active_count(),
                active_footprint: fp,
                capacity: cap,
            });
            usage_series.record(now, sig.pool_usage);
            hit_series.record(now, sig.hit_rate);
            active_series.record(now, slots.active_count() as f64);
            let w = controller.window();
            window_series.record(now, if w == usize::MAX { f64::NAN } else { w as f64 });
        }

        // 1b. Drain-and-refill: a draining replica that has emptied (no
        //     iteration in flight, no running or queued requests) wipes
        //     its cache and rejoins the admissible fleet.
        for r in 0..n {
            if state[r] == ReplicaState::Draining
                && inflight[r].is_none()
                && !engines[r].has_work()
            {
                engines[r].clear_state();
                if let Some(t) = tier.as_mut() {
                    t.on_replica_wiped(r); // re-shipped below, same instant
                }
                if let Some(tp) = transport.as_mut() {
                    tp.cancel_dst(r); // in-flight payloads died with the wipe
                }
                state[r] = ReplicaState::Alive;
                fstats.refills += 1;
                alive_series.record(now, admissible_count(&state) as f64);
            }
        }

        // 1c. Land transport completions due now: commit delayed
        //     broadcast installs (the prefix becomes matchable and
        //     routing-visible from this instant) and deliver drained
        //     replicas' KV handoffs.  Pop order is (done, id) —
        //     deterministic for any schedule.
        if let Some(tp) = transport.as_mut() {
            for xfer in tp.pop_due(now) {
                match &xfer.payload {
                    TransferPayload::Broadcast => {
                        if let Some(t) = tier.as_mut() {
                            let committed = t.on_transfer_done(&xfer, engines, now);
                            if committed > 0 {
                                broadcast_series.record(now, committed as f64);
                            }
                        }
                        broadcast_time += xfer.done.saturating_sub(xfer.issued);
                    }
                    TransferPayload::Handoff { agent, context } => {
                        engines[xfer.dst].install_handoff_context(*agent, context, now);
                        handoff_time += xfer.done.saturating_sub(xfer.issued);
                    }
                }
            }
        }

        // 2. Deliver due tool completions; paused agents wait for slots.
        while let Some((_, aid)) = events.pop_due(now) {
            if ol {
                // The session's next turn is ready from this instant:
                // TTFT/step-latency and patience clocks restart here.
                turn_ready[aid.0 as usize] = now;
            }
            let a = agent(&mut fleet, aid);
            a.on_tool_done();
            if slots.on_step_boundary(aid, controller.window()) == BoundaryDecision::Continue {
                let ctx = a.context_len() as u64;
                let req = a.make_request(RequestId(next_req), now);
                next_req += 1;
                let bp = tier.as_mut().map_or(0, |t| t.observe(aid, &req.prompt, now));
                let cur = assignment[aid.0 as usize];
                let tgt = route_to(
                    router, engines, &state, &footprint, &[], &mut loads, cur, aid, ctx, bp, now,
                );
                match cur {
                    Some(old) if old == tgt => {}
                    Some(old) => {
                        // Migration: the working set follows the agent.
                        footprint[old] -= ctx;
                        footprint[tgt] += ctx;
                        assignment[aid.0 as usize] = Some(tgt);
                        fstats.migrations += 1;
                    }
                    None => {
                        // Working set died with its replica: lands fresh.
                        footprint[tgt] += ctx;
                        assignment[aid.0 as usize] = Some(tgt);
                    }
                }
                if engines[tgt].wants_lifetime_hint() {
                    let hint = lifetime_hint(&engines[tgt], a, graph.as_ref());
                    engines[tgt].set_lifetime_hint(aid, hint);
                }
                engines[tgt].submit(req);
            } else if let Some(ar) = assignment[aid.0 as usize] {
                footprint[ar] -= a.context_len() as u64; // paused
            }
            // (Paused with no assignment: its ledger entry already went
            // down with the killed replica.)
        }

        // 2b. Open-loop patience: a waiting session whose current turn
        //     has out-waited its patience abandons.  Only waiters can
        //     expire — an in-flight step always completes (and its
        //     latency is still recorded against the SLO above).
        if ol {
            let expired = slots.take_expired(|aid| {
                let i = aid.0 as usize;
                fleet[i].patience.is_some_and(|p| now > turn_ready[i] + p)
            });
            olstats.abandoned += expired.len() as u64;
            terminated_early += expired.len();
        }

        // 2c. Overload governor: observe the admission backlog against
        //     the window; on the trip into shedding, reject the queued
        //     low-priority sessions wholesale (arrivals are then shed at
        //     the door until it recovers — hysteresis in the governor).
        if let Some(g) = governor.as_mut() {
            let was_shedding = g.is_shedding();
            if g.observe(slots.pending_count(), controller.window()) && !was_shedding {
                olstats.governor_trips += 1;
                let shed = slots.shed_low_fresh();
                olstats.shed += shed.len() as u64;
                terminated_early += shed.len();
            }
        }

        // 3. Grant freed slots (resume paused LIFO, admit fresh FIFO).
        for aid in slots.grant_up_to(controller.window()) {
            let a = agent(&mut fleet, aid);
            let ctx = a.context_len() as u64;
            let req = a.make_request(RequestId(next_req), now);
            next_req += 1;
            let bp = tier.as_mut().map_or(0, |t| t.observe(aid, &req.prompt, now));
            let cur = assignment[aid.0 as usize];
            let tgt = route_to(
                router, engines, &state, &footprint, &[], &mut loads, cur, aid, ctx, bp, now,
            );
            if cur.is_some_and(|old| old != tgt) {
                fstats.migrations += 1;
            }
            assignment[aid.0 as usize] = Some(tgt);
            footprint[tgt] += ctx;
            if engines[tgt].wants_lifetime_hint() {
                let hint = lifetime_hint(&engines[tgt], a, graph.as_ref());
                engines[tgt].set_lifetime_hint(aid, hint);
            }
            engines[tgt].submit(req);
        }

        // 3b. Shared-prefix tier maintenance: promote ripe candidates,
        //     demote cooled prefixes, and install hot prefixes on alive
        //     replicas lacking them (covers freshly refilled/revived
        //     replicas at this same instant, before their next iteration).
        if let Some(t) = tier.as_mut() {
            alive_scratch.clear();
            alive_scratch.extend(state.iter().map(|s| *s == ReplicaState::Alive));
            let (shipped, transfer) = t.maintain(engines, &alive_scratch, now, transport.as_mut());
            if shipped > 0 {
                broadcast_series.record(now, shipped as f64);
            }
            broadcast_time += transfer;
        }

        // 4. Start an iteration on every idle live replica with queued
        //    work (a draining replica keeps iterating to finish what it
        //    holds; a dead one is skipped).  The ready set is stepped
        //    either inline or on the pool — replicas share no state
        //    between clock stops, so the outcomes are identical — and
        //    then applied strictly in replica-index order, which keeps
        //    every downstream observation (stagnation counters, livelock
        //    error attribution, inflight boundaries) bit-identical at any
        //    worker count.
        ready.clear();
        ready.extend((0..n).filter(|&r| {
            state[r] != ReplicaState::Dead && inflight[r].is_none() && engines[r].has_work()
        }));
        stepped.clear();
        match &step_pool {
            Some(pool) if ready.len() > 1 => pool.step_batch(engines, &ready, now, &mut stepped),
            _ => stepped.extend(ready.iter().map(|&r| engines[r].step(now))),
        }
        for (&r, out) in ready.iter().zip(stepped.drain(..)) {
            engine_steps += 1;
            // Mirror committed storage-tier reads onto the shared-fabric
            // accounting (instant: the engine already landed the KV; the
            // fabric carries the bytes of a disaggregated storage pool).
            if let Some(tp) = transport.as_mut() {
                for &(tokens, engine_done) in &out.storage_transfers {
                    tp.ship_instant(TransferKind::StorageReload, r, r, tokens, engine_done, now);
                }
            }
            let progressed = !out.work.is_empty() || !out.finished.is_empty();
            if progressed {
                stagnant[r] = 0;
            } else {
                stagnant[r] += 1;
                if stagnant[r] > 10_000 {
                    // Applied in index order, so the livelock error names
                    // the lowest stagnant replica exactly as the
                    // sequential loop did; outcomes from higher replicas
                    // stepped in the same batch are discarded with the
                    // aborted run and thus invisible.
                    let e = &engines[r];
                    let sig = e.signals();
                    return Err(ConcurError::engine(format!(
                        "livelock: replica {r} made no progress for 10k \
                         iterations (running={} waiting={} pool_usage={:.3} \
                         working_usage={:.3} free={} evictable={})",
                        sig.running,
                        sig.waiting,
                        sig.pool_usage,
                        sig.kv_usage,
                        e.pool().free(),
                        e.tree().evictable_gpu_tokens(),
                    )));
                }
            }
            let done_at = now + Micros(out.duration.0.max(1));
            inflight[r] = Some(InFlight { done_at, finished: out.finished });
            stops.set_boundary(r, done_at);
        }

        // 5. Advance to the earliest of: an iteration boundary, a
        //    scripted or sampled fault instant, an open-loop arrival, a
        //    transport completion, or (when the whole fleet is idle) the
        //    next tool completion.  Idle gaps count as tool wait.
        if finished_agents + terminated_early == agents_total {
            break; // done; trailing fault events and transfers are moot
        }
        // Boundary slots are already current (maintained at their
        // mutation sites); re-sync the four slow-moving singleton
        // candidates — each an O(1) compare that no-ops while its cursor
        // has not moved — then pop the earliest stop off the heap.  The
        // heap's answer equals the old candidate-array `min` exactly: tie
        // order among equal instants never changes the minimum value.
        let _prof = profiler::scope(profiler::Section::ClockAdvance);
        stops.set(clock::SLOT_FAULT, faults.events().get(next_fault).map(|e| e.at));
        stops.set(clock::SLOT_SAMPLER, sampler.as_mut().and_then(|s| s.next_event_at()));
        stops.set(clock::SLOT_ARRIVAL, arrivals.get(next_arrival).map(|e| e.0));
        stops.set(clock::SLOT_TRANSPORT, transport.as_ref().and_then(|t| t.next_completion()));
        let idle = !stops.has_boundary();
        let mut target = stops.earliest();
        if idle {
            if let Some(t) = events.peek_time() {
                target = Some(target.map_or(t, |x| x.min(t)));
            }
        }
        match target {
            Some(t) => {
                if idle {
                    toolwait += t.saturating_sub(now);
                }
                clock.advance_to(t);
            }
            None => break, // no work in flight, no future events → done
        }
    }

    if finished_agents + terminated_early != agents_total {
        return Err(ConcurError::engine(format!(
            "run ended with {finished_agents}/{agents_total} agents finished \
             ({} shed, {} abandoned)",
            olstats.shed, olstats.abandoned,
        )));
    }
    // Open-loop throughput counts what was actually generated: shed and
    // abandoned sessions contribute the steps they completed, nothing
    // more.  Closed batch keeps the exact upfront plan total.
    let total_gen: u64 =
        if ol { fleet.iter().map(|a| a.gen_tokens_done()).sum() } else { total_gen };
    let mut ttft = Histogram::new("ttft");
    let mut step_latency = Histogram::new("step_latency");
    for h in &ttft_shards {
        ttft.merge(h);
    }
    for h in &step_shards {
        step_latency.merge(h);
    }

    let total_time = clock.now();
    let mut breakdown = Breakdown::new();
    for e in engines.iter_mut() {
        breakdown.merge(&std::mem::take(&mut e.breakdown));
    }
    breakdown.add(Phase::ToolWait, toolwait);
    breakdown.add(Phase::Broadcast, broadcast_time);
    breakdown.add(Phase::Handoff, handoff_time);
    let mut counters = EngineCounters::default();
    let mut hits = LifetimeRatio::default();
    for e in engines.iter() {
        counters.merge(&e.counters);
        hits.record(e.lifetime_hits.num, e.lifetime_hits.den);
    }
    let throughput_tps = if total_time.0 > 0 {
        total_gen as f64 / total_time.as_secs_f64()
    } else {
        0.0
    };

    Ok(RunResult {
        scheduler: controller.name(),
        total_time,
        breakdown,
        hit_rate: hits.ratio(),
        counters,
        usage_series,
        hit_series,
        active_series,
        window_series,
        agents_total,
        agents_finished: finished_agents,
        total_gen_tokens: total_gen,
        throughput_tps,
        agent_latency,
        engine_steps,
        pauses: slots.pauses,
        resumes: slots.resumes,
        replicas: n,
        router: if n == 1 { "single".into() } else { router.name() },
        faults: fstats,
        alive_series,
        per_agent,
        prefix_tier: tier.as_ref().map(|t| t.stats()).unwrap_or_default(),
        broadcast_series,
        transport: transport.as_ref().map(|t| t.stats()).unwrap_or_default(),
        ttft,
        step_latency,
        open_loop: olstats,
        profile: profiler::snapshot().since(&prof_start),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::WorkloadGenerator;
    use crate::config::presets;
    use crate::config::{
        AimdParams, EngineConfig, FaultEvent, JobConfig, RouterKind, SchedulerKind,
        TopologyConfig, WorkloadConfig,
    };
    use crate::coordinator::make_controller;

    fn cluster_job(replicas: usize, router: RouterKind) -> JobConfig {
        JobConfig {
            cluster: presets::qwen3_cluster(8),
            engine: EngineConfig::default(),
            workload: WorkloadConfig {
                n_agents: 12,
                steps_min: 2,
                steps_max: 4,
                ..WorkloadConfig::default()
            },
            scheduler: SchedulerKind::Concur(AimdParams::default()),
            topology: TopologyConfig { replicas, router, ..TopologyConfig::default() },
        }
    }

    fn run(job: &JobConfig) -> RunResult {
        let agents = WorkloadGenerator::new(job.workload.clone()).generate();
        let controller = make_controller(&job.scheduler);
        ClusterCoordinator::new(job).run(agents, controller).unwrap()
    }

    #[test]
    fn coordinator_builds_the_configured_fleet() {
        let c = ClusterCoordinator::new(&cluster_job(4, RouterKind::RoundRobin));
        assert_eq!(c.replicas(), 4);
    }

    #[test]
    fn multi_replica_job_completes_under_every_router() {
        for router in [
            RouterKind::RoundRobin,
            RouterKind::LeastLoaded,
            RouterKind::CacheAffinity,
            RouterKind::Rebalance,
        ] {
            let r = run(&cluster_job(3, router));
            assert_eq!(r.agents_finished, 12, "{router:?} lost agents");
            assert_eq!(r.replicas, 3);
            assert_eq!(r.router, router.name());
            assert!(r.total_time.0 > 0);
            let want = FaultStats { migrations: r.faults.migrations, ..Default::default() };
            assert_eq!(r.faults, want);
            assert_eq!(r.per_agent.len(), 12);
        }
    }

    #[test]
    fn single_replica_reports_the_single_path() {
        let r = run(&cluster_job(1, RouterKind::LeastLoaded));
        assert_eq!(r.replicas, 1);
        assert_eq!(r.router, "single");
        assert_eq!(r.agents_finished, 12);
        // Healthy N=1: one admissible-replicas point, no fault telemetry.
        assert_eq!(r.alive_series.len(), 1);
        assert_eq!(r.faults, FaultStats::default());
    }

    #[test]
    fn fleet_usage_picks_the_most_loaded_live_replica() {
        let job = cluster_job(2, RouterKind::RoundRobin);
        let engines: Vec<SimEngine> = (0..2)
            .map(|_| SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone())))
            .collect();
        let cap = engines[0].pool().capacity();
        let alive = vec![ReplicaState::Alive; 2];
        assert_eq!(fleet_usage(&[10, 50], &engines, &alive), (50, cap));
        assert_eq!(fleet_usage(&[70, 50], &engines, &alive), (70, cap));
        // A dead replica cannot be the fleet maximum, whatever its ledger
        // says (exclusion is what un-freezes U_t after a kill).
        let half_dead = vec![ReplicaState::Dead, ReplicaState::Alive];
        assert_eq!(fleet_usage(&[70, 50], &engines, &half_dead), (50, cap));
    }

    #[test]
    fn aggregate_signals_sums_queue_depths_of_live_replicas() {
        let job = cluster_job(2, RouterKind::RoundRobin);
        let mut engines: Vec<SimEngine> = (0..2)
            .map(|_| SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone())))
            .collect();
        engines[0].submit(crate::engine::Request {
            id: RequestId(0),
            agent: AgentId(0),
            prompt: (0..64).collect(),
            gen: (1000..1010).collect(),
            prev_ctx: 0,
            submitted_at: Micros::ZERO,
        });
        let alive = vec![ReplicaState::Alive; 2];
        let sig = aggregate_signals(&engines, &alive);
        assert_eq!(sig.waiting, 1);
        assert_eq!(sig.running, 0);
        // Fresh engines report the optimistic hit-rate default.
        assert_eq!(sig.hit_rate, 1.0);
        // Dead replicas drop out of the aggregate entirely.
        let dead0 = vec![ReplicaState::Dead, ReplicaState::Alive];
        assert_eq!(aggregate_signals(&engines, &dead0).waiting, 0);
    }

    #[test]
    fn scale_latency_identity_is_exact() {
        let lat = Micros(1_234_567);
        assert_eq!(scale_latency(lat, 1.0), lat);
        assert_eq!(scale_latency(lat, 2.0), Micros(2_469_134));
        assert_eq!(scale_latency(Micros(1_000), 0.5), Micros(500));
    }

    #[test]
    fn tier_enabled_fleet_ships_and_finishes() {
        use crate::config::PrefixTierConfig;
        let mut job = cluster_job(3, RouterKind::CacheAffinity);
        job.workload.n_agents = 18;
        job.workload.task_families = 5; // coprime with 3: every family splits
        job.topology.prefix_tier = PrefixTierConfig::on();
        let r = run(&job);
        assert_eq!(r.agents_finished, 18);
        assert!(r.prefix_tier.hot_prefixes > 0, "family prefixes must go hot");
        assert!(r.prefix_tier.ships > 0, "hot prefixes must ship");
        assert!(r.counters.broadcast_hit_tokens > 0, "shipped prefixes must be hit");
        assert_eq!(r.prefix_tier.reships, 0, "healthy fleets never re-ship");
        // Disabled tier reports all-zero telemetry.
        let off = run(&cluster_job(3, RouterKind::CacheAffinity));
        assert_eq!(off.prefix_tier, PrefixTierStats::default());
        assert!(off.broadcast_series.is_empty());
    }

    #[test]
    fn open_loop_run_serves_arrivals_and_reports_slo_stats() {
        use crate::config::OpenLoopConfig;
        let mut job = cluster_job(2, RouterKind::CacheAffinity);
        job.topology.open_loop =
            OpenLoopConfig { arrival_rate_per_s: 2.0, ..OpenLoopConfig::on() };
        let agents =
            crate::agent::open_loop_fleet(&job.workload, &job.topology.open_loop);
        let controller = make_controller(&job.scheduler);
        let r = ClusterCoordinator::new(&job).run(agents, controller).unwrap();
        assert_eq!(r.open_loop.arrived, 12);
        let gone = (r.open_loop.shed + r.open_loop.abandoned) as usize;
        assert_eq!(r.agents_finished + gone, 12);
        assert_eq!(
            r.open_loop.finished_high + r.open_loop.finished_low,
            r.agents_finished as u64
        );
        // Every finished session has exactly one TTFT sample (abandoned
        // ones have one only if their first turn ever landed); later
        // turns land in the step-latency histogram.
        assert!(r.ttft.count() >= r.agents_finished as u64);
        assert!(r.ttft.count() <= 12);
        assert!(r.step_latency.count() > 0);
        // The batch no longer starts whole: the first arrival is after
        // t=0, so the makespan includes arrival spread.
        assert!(r.total_time > Micros::ZERO);
        // Closed-batch runs report the feature fully dormant.
        let closed = run(&cluster_job(2, RouterKind::CacheAffinity));
        assert_eq!(closed.open_loop, OpenLoopStats::default());
        assert_eq!(closed.ttft.count(), 0);
        assert_eq!(closed.step_latency.count(), 0);
    }

    #[test]
    fn stochastic_faults_inject_and_replay_bit_identically() {
        use crate::config::FaultRateConfig;
        let mut job = cluster_job(3, RouterKind::Rebalance);
        job.topology.fault_rates =
            FaultRateConfig { mtbf_s: 3.0, mttr_s: 1.5, ..FaultRateConfig::on() };
        let a = run(&job);
        let b = run(&job);
        assert_eq!(a.agents_finished, 12);
        // MTBF far below the makespan: the sampler must have acted.
        assert!(
            a.faults.stochastic_injected + a.faults.stochastic_suppressed > 0,
            "sampler never fired: {:?}",
            a.faults
        );
        assert_eq!(a.faults.kills + a.faults.drains + a.faults.revives,
                   a.faults.stochastic_injected);
        // Fixed seed ⇒ bit-identical replay.
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.engine_steps, b.engine_steps);
        assert!(a.hit_rate.to_bits() == b.hit_rate.to_bits());
        // A different fault seed yields a different fault tape.
        let mut job2 = job.clone();
        job2.topology.fault_rates.seed = 777;
        let c = run(&job2);
        assert_eq!(c.agents_finished, 12);
        assert_ne!(
            (a.faults.kills, a.faults.drains, a.total_time),
            (c.faults.kills, c.faults.drains, c.total_time),
            "different fault seeds should perturb the run"
        );
    }

    #[test]
    fn killed_replica_fleet_still_finishes() {
        // Anchor the kill at half the healthy makespan: both runs are
        // identical up to that instant, and the healthy run still has
        // unfinished agents there, so the kill is guaranteed mid-run.
        let healthy = run(&cluster_job(3, RouterKind::Rebalance));
        let mut job = cluster_job(3, RouterKind::Rebalance);
        job.topology.fault_plan =
            FaultPlan::new(vec![FaultEvent::kill(0, Micros(healthy.total_time.0 / 2))]);
        let r = run(&job);
        assert_eq!(r.agents_finished, 12);
        assert_eq!(r.faults.kills, 1);
        // The admissible-replica series recorded the drop.
        assert_eq!(r.alive_series.points().last().unwrap().1, 2.0);
    }
}
