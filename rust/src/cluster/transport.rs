//! Asynchronous cross-replica KV transport.
//!
//! The paper's whole argument is that KV movement is never free — the
//! Fig. 1c offload collapse is a *bandwidth* pathology — yet cross-replica
//! features naturally grow "teleport" semantics: a broadcast prefix
//! install that charges a link but is usable the same instant, a drain
//! that drops a replica's warm cache because migrating it would need a
//! transfer model.  [`Transport`] is that model: every cross-replica KV
//! movement becomes a [`Transfer`] record with an issue instant and a
//! completion instant, scheduled over one shared inter-replica fabric
//! link ([`PcieLink`] semantics: FIFO serialization plus queue-depth
//! congestion) *in addition to* the endpoint host links the engine
//! already charges.  `cluster::run_sharded` drains completions on its
//! event clock, so effects land at deterministic instants:
//!
//! * **Broadcast installs** (shared-prefix tier) reserve pool capacity on
//!   the target at issue and **commit** — materialise, pin, become
//!   matchable and routing-visible — only at `done` when
//!   `delayed_visibility` is on (`SimEngine::reserve_broadcast_prefix` /
//!   `commit_broadcast_prefix`).  With `delta_ship` the fabric carries
//!   only the per-target un-cached suffix; otherwise the source blasts
//!   the full prefix to every target.
//! * **Drain handoffs** snapshot a draining replica's hottest agents'
//!   warm contexts at the drain instant and install them on the replica
//!   each agent is re-homed to, so drain-and-refill no longer re-enters
//!   those agents cold.
//!
//! Transfers whose destination is wiped (kill, drain-refill) are
//! [cancelled](Transport::cancel_dst) — the payload has nowhere to land.
//! A *broadcast* whose source dies mid-flight still completes (the
//! immutable prefix was read out at issue), but a **handoff** whose
//! source is killed mid-drain is
//! [cancelled](Transport::cancel_src_handoffs): the checkpoint dies with
//! the replica and the displaced agent re-enters admission cold through
//! the ordinary kill path.  Completions pop in `(done, id)` order, so
//! runs are deterministic for any schedule.

use crate::config::TransportConfig;
use crate::core::{AgentId, Bytes, Micros, Token};
use crate::costmodel::PcieLink;

/// What a completed transfer delivers.
#[derive(Debug, Clone)]
pub enum TransferPayload {
    /// A broadcast-prefix install; the shared-prefix tier resolves the
    /// pending reservation by transfer id.
    Broadcast,
    /// A drained replica's agent context; the destination engine inserts
    /// it as ordinary (evictable) warm cache.
    Handoff { agent: AgentId, context: Vec<Token> },
}

/// Transfer kind (telemetry / dispatch label for [`TransferPayload`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    Broadcast,
    Handoff,
    /// A storage-tier (NVMe) extent read mirrored onto the fabric
    /// accounting.  Always instantaneous — the engine lands the KV
    /// itself; the fabric only carries the bytes — so it never appears
    /// as an in-flight [`Transfer`].
    StorageReload,
}

impl TransferPayload {
    fn kind(&self) -> TransferKind {
        match self {
            TransferPayload::Broadcast => TransferKind::Broadcast,
            TransferPayload::Handoff { .. } => TransferKind::Handoff,
        }
    }
}

/// One cross-replica KV movement in flight.
#[derive(Debug, Clone)]
pub struct Transfer {
    /// Unique, monotonically increasing (the completion tie-breaker).
    pub id: u64,
    /// Source replica (where the KV was read out).
    pub src: usize,
    /// Destination replica (where the payload lands at `done`).
    pub dst: usize,
    /// Tokens carried over the shared fabric link.
    pub tokens: u64,
    /// Issue instant.
    pub issued: Micros,
    /// Completion instant: `max` of the endpoint host-link completions
    /// and the fabric completion.  Effects land here.
    pub done: Micros,
    /// What lands at `done`.
    pub payload: TransferPayload,
}

impl Transfer {
    pub fn kind(&self) -> TransferKind {
        self.payload.kind()
    }
}

/// Transport telemetry for one run (all zero with the transport off).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Transfers issued (instantaneous-mode transfers included).
    pub transfers: u64,
    /// Broadcast-install transfers issued.
    pub broadcast_transfers: u64,
    /// Drain-handoff transfers issued.
    pub handoff_transfers: u64,
    /// Storage-tier reload reads mirrored onto the fabric.
    pub storage_reload_transfers: u64,
    /// Σ tokens carried over the fabric.
    pub wire_tokens: u64,
    /// Σ bytes carried over the fabric.
    pub wire_bytes: u64,
    /// Σ transfer latency (`done − issued`) over all issued transfers.
    pub wire_time: Micros,
    /// In-flight transfers voided because their destination was wiped.
    pub cancelled: u64,
}

/// The cluster's asynchronous interconnect (see the module docs).
pub struct Transport {
    pub cfg: TransportConfig,
    fabric: PcieLink,
    kv_bytes_per_token: u64,
    /// In-flight delayed transfers, in issue order (ids ascend).
    inflight: Vec<Transfer>,
    /// Cached `min` over `inflight[..].done` — `next_completion` sits on
    /// the cluster clock-stop hot path and must not rescan per stop.
    /// Min-updated on issue, recomputed after removals.
    earliest_done: Option<Micros>,
    next_id: u64,
    stats: TransportStats,
}

impl Transport {
    pub fn new(cfg: TransportConfig, kv_bytes_per_token: u64) -> Transport {
        debug_assert!(cfg.enabled, "transport constructed while disabled");
        Transport {
            fabric: PcieLink::new(cfg.fabric_gbps),
            kv_bytes_per_token,
            inflight: Vec::new(),
            earliest_done: None,
            next_id: 0,
            stats: TransportStats::default(),
            cfg,
        }
    }

    pub fn stats(&self) -> TransportStats {
        self.stats
    }

    /// Bytes the shared fabric has actually carried (conservation check:
    /// must equal `stats().wire_bytes` at all times).
    pub fn fabric_bytes_moved(&self) -> u64 {
        self.fabric.bytes_moved
    }

    fn kv_bytes(&self, tokens: u64) -> Bytes {
        Bytes(tokens * self.kv_bytes_per_token)
    }

    /// Charge the fabric for `tokens` at `now` and fold the endpoint
    /// host-link completion in; returns the transfer's completion
    /// instant.  Zero-token transfers skip the fabric entirely.
    fn schedule(
        &mut self,
        kind: TransferKind,
        tokens: u64,
        host_done: Micros,
        now: Micros,
    ) -> Micros {
        let fabric_done =
            if tokens > 0 { self.fabric.transfer(now, self.kv_bytes(tokens)) } else { now };
        let done = host_done.max(fabric_done);
        self.stats.transfers += 1;
        match kind {
            TransferKind::Broadcast => self.stats.broadcast_transfers += 1,
            TransferKind::Handoff => self.stats.handoff_transfers += 1,
            TransferKind::StorageReload => self.stats.storage_reload_transfers += 1,
        }
        self.stats.wire_tokens += tokens;
        self.stats.wire_bytes += self.kv_bytes(tokens).0;
        self.stats.wire_time += done.saturating_sub(now);
        done
    }

    /// Record an *instantaneous* transfer: the fabric and stats are
    /// charged, but the effects landed at issue (legacy visibility).
    /// Returns the completion instant for the caller's accounting.
    pub fn ship_instant(
        &mut self,
        kind: TransferKind,
        _src: usize,
        _dst: usize,
        tokens: u64,
        host_done: Micros,
        now: Micros,
    ) -> Micros {
        self.schedule(kind, tokens, host_done, now)
    }

    /// Schedule a delayed broadcast-install transfer; the tier resolves
    /// the reservation by the returned id when the completion pops.
    pub fn ship_broadcast(
        &mut self,
        src: usize,
        dst: usize,
        tokens: u64,
        host_done: Micros,
        now: Micros,
    ) -> (u64, Micros) {
        self.ship_delayed(src, dst, tokens, host_done, now, TransferPayload::Broadcast)
    }

    /// Schedule a delayed drain-handoff transfer carrying `context`.
    /// `wire_tokens` is what actually crosses the fabric — the payload
    /// may be longer (the destination-resident head travels as metadata
    /// only, so the landing can re-walk the full radix path).
    #[allow(clippy::too_many_arguments)]
    pub fn ship_handoff(
        &mut self,
        src: usize,
        dst: usize,
        wire_tokens: u64,
        host_done: Micros,
        now: Micros,
        agent: AgentId,
        context: Vec<Token>,
    ) -> (u64, Micros) {
        debug_assert!(wire_tokens <= context.len() as u64);
        self.ship_delayed(src, dst, wire_tokens, host_done, now, TransferPayload::Handoff {
            agent,
            context,
        })
    }

    fn ship_delayed(
        &mut self,
        src: usize,
        dst: usize,
        tokens: u64,
        host_done: Micros,
        now: Micros,
        payload: TransferPayload,
    ) -> (u64, Micros) {
        debug_assert!(tokens > 0, "zero-token transfers must commit at issue");
        let done = self.schedule(payload.kind(), tokens, host_done, now);
        // `PcieLink::transfer` adds a positive sync overhead, so a
        // non-empty transfer always completes strictly after `now` — the
        // clock below never has to advance to its own instant.
        debug_assert!(done > now);
        let id = self.next_id;
        self.next_id += 1;
        self.inflight.push(Transfer { id, src, dst, tokens, issued: now, done, payload });
        self.earliest_done = Some(self.earliest_done.map_or(done, |e| e.min(done)));
        (id, done)
    }

    /// Earliest in-flight completion (the cluster clock's next transport
    /// stop), if any.  O(1) — maintained across issue/pop/cancel.
    pub fn next_completion(&self) -> Option<Micros> {
        self.earliest_done
    }

    fn recompute_earliest(&mut self) {
        self.earliest_done = self.inflight.iter().map(|t| t.done).min();
    }

    /// Remove and return every transfer due at `now`, in `(done, id)`
    /// order — the deterministic delivery order.
    pub fn pop_due(&mut self, now: Micros) -> Vec<Transfer> {
        if !self.earliest_done.is_some_and(|e| e <= now) {
            return Vec::new();
        }
        let mut due: Vec<Transfer> = Vec::new();
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].done <= now {
                due.push(self.inflight.remove(i));
            } else {
                i += 1;
            }
        }
        due.sort_by_key(|t| (t.done, t.id));
        self.recompute_earliest();
        due
    }

    /// Void every in-flight transfer destined for `replica` (its serving
    /// state was wiped — the payload has nowhere to land).  The wire time
    /// was genuinely spent; only the delivery is dropped.
    pub fn cancel_dst(&mut self, replica: usize) {
        let before = self.inflight.len();
        self.inflight.retain(|t| t.dst != replica);
        if self.inflight.len() != before {
            self.recompute_earliest();
        }
        self.stats.cancelled += (before - self.inflight.len()) as u64;
    }

    /// Void every in-flight **handoff** sourced from `replica`: a kill
    /// landing on a replica mid drain-handoff tears down its DMA engines,
    /// so a checkpoint still crossing the fabric never materialises at
    /// the destination (delivering it would resurrect state the kill is
    /// defined to destroy, and the displaced agent re-enters the
    /// admission queue cold via the normal kill path — exactly once).
    /// Broadcast installs are left alone: their payload is an immutable
    /// shared prefix fully read out at issue, valid wherever it lands.
    pub fn cancel_src_handoffs(&mut self, replica: usize) {
        let before = self.inflight.len();
        self.inflight.retain(|t| {
            !(t.src == replica && t.kind() == TransferKind::Handoff)
        });
        if self.inflight.len() != before {
            self.recompute_earliest();
        }
        self.stats.cancelled += (before - self.inflight.len()) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KVB: u64 = 100_000; // bytes/token: big enough for visible wire time

    fn transport() -> Transport {
        let mut cfg = TransportConfig::on();
        cfg.delayed_visibility = true;
        Transport::new(cfg, KVB)
    }

    #[test]
    fn fabric_serializes_and_completions_are_monotone() {
        let mut t = transport();
        let mut last = Micros::ZERO;
        for i in 0..5u64 {
            let (_, done) = t.ship_broadcast(0, 1, 4096, Micros::ZERO, Micros(i));
            assert!(done > last, "fabric completions must be non-decreasing");
            last = done;
        }
        assert_eq!(t.stats().transfers, 5);
        assert_eq!(t.stats().wire_tokens, 5 * 4096);
    }

    #[test]
    fn wire_bytes_are_conserved() {
        let mut t = transport();
        t.ship_broadcast(0, 1, 1000, Micros::ZERO, Micros::ZERO);
        t.ship_handoff(1, 0, 3, Micros::ZERO, Micros(5), AgentId(7), vec![1, 2, 3]);
        assert_eq!(t.stats().wire_bytes, t.fabric_bytes_moved());
        assert_eq!(t.stats().wire_bytes, (1000 + 3) * KVB);
    }

    #[test]
    fn completion_folds_in_the_host_link() {
        let mut t = transport();
        let far = Micros(1_000_000_000);
        let (_, done) = t.ship_broadcast(0, 1, 16, far, Micros::ZERO);
        assert_eq!(done, far, "a slow host link dominates the completion");
    }

    #[test]
    fn pop_due_delivers_in_done_id_order_and_only_when_due() {
        let mut t = transport();
        let (id_a, done_a) = t.ship_broadcast(0, 1, 1 << 20, Micros::ZERO, Micros::ZERO);
        let (id_b, done_b) = t.ship_broadcast(0, 2, 16, Micros::ZERO, Micros::ZERO);
        assert!(done_b > Micros::ZERO);
        assert_eq!(t.next_completion(), Some(done_a.min(done_b)));
        assert!(t.pop_due(Micros::ZERO).is_empty(), "nothing is due at issue");
        let all = t.pop_due(done_a.max(done_b));
        assert_eq!(all.len(), 2);
        assert!(all.windows(2).all(|w| (w[0].done, w[0].id) < (w[1].done, w[1].id)));
        assert_eq!(all[0].id.min(all[1].id), id_a.min(id_b));
        assert_eq!(t.next_completion(), None);
    }

    #[test]
    fn cancel_dst_voids_only_that_replica() {
        let mut t = transport();
        let (_, d1) = t.ship_broadcast(0, 1, 64, Micros::ZERO, Micros::ZERO);
        let (_, d2) =
            t.ship_handoff(0, 2, 64, Micros::ZERO, Micros::ZERO, AgentId(1), vec![9; 64]);
        t.cancel_dst(1);
        assert_eq!(t.stats().cancelled, 1);
        let due = t.pop_due(d1.max(d2));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].dst, 2);
        assert_eq!(due[0].kind(), TransferKind::Handoff);
    }

    #[test]
    fn cancel_src_handoffs_spares_broadcasts_and_other_sources() {
        let mut t = transport();
        let (_, d1) = t.ship_broadcast(0, 1, 64, Micros::ZERO, Micros::ZERO);
        let (_, d2) =
            t.ship_handoff(0, 2, 64, Micros::ZERO, Micros::ZERO, AgentId(1), vec![9; 64]);
        let (_, d3) =
            t.ship_handoff(1, 2, 64, Micros::ZERO, Micros::ZERO, AgentId(2), vec![8; 64]);
        t.cancel_src_handoffs(0);
        assert_eq!(t.stats().cancelled, 1, "only replica 0's handoff dies");
        let due = t.pop_due(d1.max(d2).max(d3));
        assert_eq!(due.len(), 2);
        assert!(due.iter().any(|x| x.kind() == TransferKind::Broadcast && x.src == 0));
        assert!(due.iter().any(|x| x.kind() == TransferKind::Handoff && x.src == 1));
    }

    #[test]
    fn storage_reloads_are_instant_and_separately_counted() {
        let mut t = transport();
        let engine_done = Micros(50_000);
        let done = t.ship_instant(
            TransferKind::StorageReload,
            1,
            1,
            2_048,
            engine_done,
            Micros::ZERO,
        );
        assert!(done >= engine_done, "fabric leg folds into the completion");
        assert_eq!(t.next_completion(), None, "mirrored reads never queue");
        assert_eq!(t.stats().storage_reload_transfers, 1);
        assert_eq!(t.stats().broadcast_transfers, 0);
        assert_eq!(t.stats().wire_bytes, 2_048 * KVB);
    }

    #[test]
    fn instant_transfers_never_queue() {
        let mut t = transport();
        let done =
            t.ship_instant(TransferKind::Broadcast, 0, 1, 512, Micros::ZERO, Micros::ZERO);
        assert!(done > Micros::ZERO);
        assert_eq!(t.next_completion(), None, "instant transfers are accounting-only");
        assert_eq!(t.stats().broadcast_transfers, 1);
        assert!(t.stats().wire_time >= done);
    }
}
