//! Scoped worker pool for parallel replica stepping.
//!
//! Between cluster-level events — fault instants, arrivals, transfer
//! completions, coordinator adjustments, all of which are clock stops in
//! [`run_sharded`](super::run_sharded) — replicas are fully independent:
//! `SimEngine::step` reads and writes nothing outside its own replica.
//! That makes the per-instant step fan-out embarrassingly parallel, and
//! this pool exploits it without giving up determinism:
//!
//! * the dispatching loop collects the ready replica set, ships one task
//!   per replica to the pool, and blocks until **all** results are back;
//! * outcomes are applied in replica-index order, and the clock advance
//!   merges per-replica next-event times with the same `(time, replica)`
//!   tie order as the sequential loop —
//!
//! so a run is bit-identical at any worker count (pinned by the
//! `workers {1,2,4}` determinism tests and the CI determinism job).
//!
//! Workers are plain `std::thread` spawns living for one `run_sharded`
//! invocation; tasks cross the channel as raw engine pointers because the
//! engines stay borrowed by the dispatching frame.  Soundness rests on
//! two invariants, both local to this file and `step_batch`'s caller
//! contract: task indices are distinct, and the dispatcher never touches
//! the engine slice while tasks are outstanding.

use crate::core::Micros;
use crate::engine::{SimEngine, StepOutcome};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

// Compile-time proof that engines may cross threads at all: the unsafe
// Send below only smuggles the *pointer*, the pointee type must be Send
// in its own right.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<SimEngine>();
};

/// A replica pointer crossing the channel to a worker.
struct EnginePtr(*mut SimEngine);

// SAFETY: an `EnginePtr` is dereferenced only by the worker that receives
// it, exclusively between task receipt and result send.  `step_batch`
// guarantees every outstanding task points at a *distinct* engine and
// that the dispatching thread does not access the engine slice until all
// results are collected, so no two threads ever alias one engine.
// `SimEngine` itself is `Send` (compile-checked above).
unsafe impl Send for EnginePtr {}

type StepTask = (usize, EnginePtr, Micros);
type StepResult = (usize, std::thread::Result<StepOutcome>);

/// Worker pool stepping disjoint replicas concurrently for one
/// `run_sharded` invocation.  Dropping the pool disconnects the task
/// channel and joins every worker.
pub(crate) struct StepPool {
    task_tx: Option<Sender<StepTask>>,
    result_rx: Receiver<StepResult>,
    workers: Vec<JoinHandle<()>>,
}

impl StepPool {
    pub(crate) fn new(workers: usize) -> StepPool {
        let (task_tx, task_rx) = channel::<StepTask>();
        let task_rx = Arc::new(Mutex::new(task_rx));
        let (result_tx, result_rx) = channel::<StepResult>();
        let workers = (0..workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&task_rx);
                let tx = result_tx.clone();
                std::thread::spawn(move || loop {
                    // Blocking recv under the mutex serializes task
                    // pickup, which is exactly what a shared queue is;
                    // idle workers would block on the empty channel
                    // anyway.
                    let task = rx.lock().expect("step pool lock poisoned").recv();
                    let Ok((r, ptr, now)) = task else {
                        break; // pool dropped: no more tasks will come
                    };
                    let outcome =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            // SAFETY: see `EnginePtr` — this worker has
                            // exclusive access to the pointed-to engine
                            // for the duration of the task.
                            let engine = unsafe { &mut *ptr.0 };
                            engine.step(now)
                        }));
                    if tx.send((r, outcome)).is_err() {
                        break; // pool dropped mid-flight
                    }
                })
            })
            .collect();
        StepPool { task_tx: Some(task_tx), result_rx, workers }
    }

    /// Step every replica in `ready` concurrently at instant `now`,
    /// appending one outcome per replica to `out` in `ready` order (the
    /// caller's replica-index order).  Blocks until all results are in;
    /// a panic inside any `step` is resumed on this thread, exactly as
    /// the sequential loop would have surfaced it.
    ///
    /// `ready` must hold strictly increasing (hence distinct) in-range
    /// indices — the aliasing contract behind `EnginePtr`.
    pub(crate) fn step_batch(
        &self,
        engines: &mut [SimEngine],
        ready: &[usize],
        now: Micros,
        out: &mut Vec<StepOutcome>,
    ) {
        debug_assert!(ready.windows(2).all(|w| w[0] < w[1]), "ready not sorted");
        debug_assert!(ready.last().is_none_or(|&r| r < engines.len()));
        let base = engines.as_mut_ptr();
        let tx = self.task_tx.as_ref().expect("pool already shut down");
        for &r in ready {
            // SAFETY: `r` is in range and the indices are distinct, so
            // each task carries a pointer to a different engine.  This
            // thread parks in the recv loop below until every task has
            // answered, so it never aliases an engine mid-step.
            let ptr = EnginePtr(unsafe { base.add(r) });
            tx.send((r, ptr, now)).expect("step worker pool died");
        }
        let start = out.len();
        out.resize_with(start + ready.len(), StepOutcome::default);
        for _ in 0..ready.len() {
            let (r, res) = self.result_rx.recv().expect("step worker pool died");
            let slot = ready.binary_search(&r).expect("result for unknown replica");
            match res {
                Ok(o) => out[start + slot] = o,
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
    }
}

impl Drop for StepPool {
    fn drop(&mut self) {
        // Disconnect the task channel so idle workers wake and exit, then
        // join them: after drop returns, nothing holds an engine pointer.
        self.task_tx.take();
        for h in self.workers.drain(..) {
            // A panicking worker already surfaced through `step_batch`
            // (or this drop is part of that unwind); don't double-panic.
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, EngineConfig};
    use crate::core::{AgentId, RequestId};
    use crate::costmodel::CostModel;
    use crate::engine::Request;

    fn engine_with_work(seed: u32) -> SimEngine {
        let mut e =
            SimEngine::new(EngineConfig::default(), CostModel::new(presets::qwen3_cluster(8)));
        e.submit(Request {
            id: RequestId(u64::from(seed)),
            agent: AgentId(u64::from(seed)),
            prompt: (seed * 1000..seed * 1000 + 64).collect(),
            gen: (900_000..900_010).collect(),
            prev_ctx: 0,
            submitted_at: Micros::ZERO,
        });
        e
    }

    #[test]
    fn pool_steps_match_sequential_steps() {
        let mut seq: Vec<SimEngine> = (1..=4).map(engine_with_work).collect();
        let mut par: Vec<SimEngine> = (1..=4).map(engine_with_work).collect();
        let seq_out: Vec<StepOutcome> =
            seq.iter_mut().map(|e| e.step(Micros(5))).collect();

        let pool = StepPool::new(3);
        let ready: Vec<usize> = (0..4).collect();
        let mut par_out = Vec::new();
        pool.step_batch(&mut par, &ready, Micros(5), &mut par_out);

        assert_eq!(par_out.len(), 4);
        for (s, p) in seq_out.iter().zip(&par_out) {
            assert_eq!(s.duration, p.duration);
            assert_eq!(s.finished.len(), p.finished.len());
            assert_eq!(s.admitted, p.admitted);
            assert_eq!(s.recompute_tokens, p.recompute_tokens);
        }
        for (s, p) in seq.iter().zip(&par) {
            assert_eq!(s.counters, p.counters);
            assert_eq!(s.pool().free(), p.pool().free());
            assert_eq!(s.tree().gpu_tokens(), p.tree().gpu_tokens());
        }
    }

    #[test]
    fn pool_steps_a_sparse_ready_set() {
        let mut engines: Vec<SimEngine> = (1..=5).map(engine_with_work).collect();
        let pool = StepPool::new(2);
        let ready = vec![0usize, 2, 4];
        let mut out = Vec::new();
        pool.step_batch(&mut engines, &ready, Micros(3), &mut out);
        assert_eq!(out.len(), 3);
        // Only the stepped replicas made progress (admitted their request).
        for (r, e) in engines.iter().enumerate() {
            let stepped = ready.contains(&r);
            assert_eq!(e.counters.admitted > 0, stepped, "replica {r}");
        }
    }

    #[test]
    fn dropping_an_idle_pool_joins_cleanly() {
        let pool = StepPool::new(4);
        drop(pool); // must not hang or panic
    }
}
