//! Experiment presets: the exact configurations evaluated in the paper.
//!
//! Each `Table 1` row / figure panel maps to one of these constructors so
//! the repro harnesses and the CLI share a single source of truth.

use crate::costmodel::{ClusterSpec, GpuSpec, ModelSpec};

use super::{
    AimdParams, EngineConfig, EvictionMode, JobConfig, RouterKind, SchedulerKind,
    TopologyConfig, WorkloadConfig,
};

/// Workload used for the Qwen3-32B rows (batch 256 agents).  Trajectories
/// run deeper than the Fig. 1a window (ReAct workloads span "dozens" of
/// steps — §2); contexts reach ~20-25k tokens by completion, which is what
/// makes even the TP8 pool thrash at batch 256 (paper Table 1).
pub fn qwen3_workload(n_agents: usize) -> WorkloadConfig {
    WorkloadConfig {
        n_agents,
        steps_min: 18,
        steps_max: 28,
        ..WorkloadConfig::default()
    }
}

/// Workload used for the DeepSeek-V3 rows.  DSV3 contexts in Fig. 1a grow
/// slightly faster (deeper reasoning traces), so the generation/tool spans
/// are a bit larger.
pub fn dsv3_workload(n_agents: usize) -> WorkloadConfig {
    WorkloadConfig {
        n_agents,
        steps_min: 10,
        steps_max: 16,
        gen_tokens_min: 400,
        gen_tokens_max: 900,
        tool_tokens_min: 250,
        tool_tokens_max: 700,
        ..WorkloadConfig::default()
    }
}

/// Qwen3-32B cluster at a given TP (paper always pairs #GPU = TP).
pub fn qwen3_cluster(tp: u32) -> ClusterSpec {
    ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), tp, tp)
}

/// DeepSeek-V3 cluster (TP16 across 16 GPUs in Table 1, TP8 in Table 2).
pub fn dsv3_cluster(tp: u32) -> ClusterSpec {
    ClusterSpec::new(GpuSpec::h100(), ModelSpec::deepseek_v3(), tp, tp)
}

/// One Table-1-style job: (cluster, batch) under a given scheduler.
pub fn job(
    cluster: ClusterSpec,
    workload: WorkloadConfig,
    scheduler: SchedulerKind,
) -> JobConfig {
    let engine = match &scheduler {
        // HiCache rows flip the eviction mode; everything else discards.
        _ => EngineConfig::default(),
    };
    JobConfig { cluster, engine, workload, scheduler, topology: TopologyConfig::default() }
}

/// A data-parallel job: `replicas` engine replicas (each a full `cluster`
/// with its own KV pool) fed through `router`.  The `cluster_scaling`
/// repro scenario and the `replica_sweep` example build their grids here.
pub fn replicated_job(
    cluster: ClusterSpec,
    workload: WorkloadConfig,
    scheduler: SchedulerKind,
    replicas: usize,
    router: RouterKind,
) -> JobConfig {
    let mut j = job(cluster, workload, scheduler);
    j.topology = TopologyConfig { replicas, router, ..TopologyConfig::default() };
    j
}

/// The four systems compared in Tables 1-2.  `request_cap` follows the
/// paper's fixed request-level cap; for HiCache the scheduler is
/// uncontrolled but eviction offloads instead of discarding.
pub fn baseline_systems(request_cap: usize) -> Vec<(&'static str, SchedulerKind, EvictionMode)> {
    vec![
        ("SGLang", SchedulerKind::Uncontrolled, EvictionMode::Discard),
        (
            "SGLang w/ Request Control",
            SchedulerKind::RequestCap(request_cap),
            EvictionMode::Discard,
        ),
        ("SGLang w/ HiCache", SchedulerKind::Uncontrolled, EvictionMode::Offload),
        (
            "CONCUR",
            SchedulerKind::Concur(AimdParams::default()),
            EvictionMode::Discard,
        ),
    ]
}

/// Fixed admission levels evaluated in Fig. 6.
pub const FIG6_FIXED_LEVELS: [usize; 4] = [30, 32, 64, 128];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for tp in [2u32, 4, 8] {
            job(
                qwen3_cluster(tp),
                qwen3_workload(256),
                SchedulerKind::Concur(AimdParams::default()),
            )
            .validate()
            .unwrap();
        }
        job(
            dsv3_cluster(16),
            dsv3_workload(40),
            SchedulerKind::Uncontrolled,
        )
        .validate()
        .unwrap();
    }

    #[test]
    fn replicated_job_sets_topology() {
        let j = replicated_job(
            qwen3_cluster(2),
            qwen3_workload(64),
            SchedulerKind::Uncontrolled,
            4,
            RouterKind::CacheAffinity,
        );
        j.validate().unwrap();
        assert_eq!(j.topology.replicas, 4);
        assert_eq!(j.topology.router, RouterKind::CacheAffinity);
    }

    #[test]
    fn baseline_systems_cover_paper() {
        let systems = baseline_systems(64);
        assert_eq!(systems.len(), 4);
        assert_eq!(systems[2].2, EvictionMode::Offload); // HiCache offloads
        assert!(matches!(systems[3].1, SchedulerKind::Concur(_)));
    }
}
