//! Scripted replica fault plans: kill, drain-and-refill, revive.
//!
//! A [`FaultPlan`] is part of [`TopologyConfig`](super::TopologyConfig):
//! a time-ordered script of replica lifecycle transitions that the
//! cluster loop (`cluster::run_sharded`) applies at exact simulation
//! instants.  Plans are configuration, not runtime state — the same
//! `JobConfig` always reproduces the same disruption, so fault-tolerance
//! comparisons across routers/schedulers are run on bit-identical
//! failure timelines.
//!
//! Semantics (details in DESIGN.md §Faults):
//!
//! * **kill** — the replica process dies at `at`: its KV pool, radix
//!   cache and queues vanish; agents with an in-flight step there lose
//!   the step and re-enter the admission queue; the controller stops
//!   aggregating the dead replica's signals.
//! * **drain** — the replica stops receiving admissions, finishes the
//!   requests it already holds, then wipes its cache and rejoins the
//!   admissible fleet ("refill") — the rolling-restart primitive.
//! * **revive** — a killed replica rejoins, empty.
//!
//! Validation is conservative: replaying the script must leave at least
//! one replica alive-and-not-draining at every step (a draining replica
//! is counted as unavailable until the run proves otherwise), so a plan
//! can never strand routing with zero admissible replicas.

use crate::core::json::Value;
use crate::core::{ConcurError, Micros, Result};

/// A replica lifecycle transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Replica dies: all KV state and queued work is lost instantly.
    Kill,
    /// Replica stops admissions, finishes its running work, rejoins empty.
    Drain,
    /// A previously killed replica rejoins the fleet, empty.
    Revive,
}

impl FaultKind {
    /// Stable lowercase name (JSON `kind` field and table labels).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Kill => "kill",
            FaultKind::Drain => "drain",
            FaultKind::Revive => "revive",
        }
    }
}

/// One scripted transition: `replica` undergoes `kind` at instant `at`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Simulation instant the transition fires (ties with an iteration
    /// completing at the same instant resolve fault-first).
    pub at: Micros,
    /// Target replica index in `0..topology.replicas`.
    pub replica: usize,
    /// Which transition.
    pub kind: FaultKind,
}

impl FaultEvent {
    /// Kill `replica` at `at`.
    pub fn kill(replica: usize, at: Micros) -> FaultEvent {
        FaultEvent { at, replica, kind: FaultKind::Kill }
    }

    /// Drain `replica` starting at `at` (refill is automatic once idle).
    pub fn drain(replica: usize, at: Micros) -> FaultEvent {
        FaultEvent { at, replica, kind: FaultKind::Drain }
    }

    /// Revive previously killed `replica` at `at`.
    pub fn revive(replica: usize, at: Micros) -> FaultEvent {
        FaultEvent { at, replica, kind: FaultKind::Revive }
    }
}

/// A time-ordered script of [`FaultEvent`]s (empty = healthy fleet).
///
/// Construction sorts stably by instant, so same-instant events apply in
/// the order listed.  `FaultPlan::none()` is the default and changes
/// nothing about a run — the N=1 no-fault path stays bit-identical to
/// the pre-fault driver (differential-tested).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The healthy fleet: no scripted faults.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Build a plan from events in any order (sorted stably by `at`).
    pub fn new(mut events: Vec<FaultEvent>) -> FaultPlan {
        events.sort_by_key(|e| e.at);
        FaultPlan { events }
    }

    /// No scripted faults?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events in firing order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Validate against a fleet of `replicas` by replaying the script:
    /// indices in range, transitions legal from each replica's prior
    /// state (kill from alive/draining, drain from alive, revive from
    /// dead), and at least one replica alive-and-not-draining after
    /// every event (drains count as unavailable here because validation
    /// cannot know when a drain refills).
    pub fn validate(&self, replicas: usize) -> Result<()> {
        #[derive(Clone, Copy, PartialEq)]
        enum S {
            Alive,
            Draining,
            Dead,
        }
        // Range-check every event up front, before replay: a bad index is
        // a config typo and must surface at load time as *that* event's
        // error — naming kind, replica and instant — not whatever replay
        // error the surrounding script happens to trip first.
        for e in &self.events {
            if e.replica >= replicas {
                return Err(ConcurError::config(format!(
                    "fault plan event '{} replica {} at {}' is out of range: \
                     topology has {replicas} replicas (valid indices \
                     0..{replicas})",
                    e.kind.name(),
                    e.replica,
                    e.at
                )));
            }
        }
        let mut state = vec![S::Alive; replicas];
        for e in &self.events {
            let s = &mut state[e.replica];
            *s = match (e.kind, *s) {
                (FaultKind::Kill, S::Alive | S::Draining) => S::Dead,
                (FaultKind::Drain, S::Alive) => S::Draining,
                (FaultKind::Revive, S::Dead) => S::Alive,
                (kind, _) => {
                    return Err(ConcurError::config(format!(
                        "fault plan: illegal '{}' of replica {} at {} (kill \
                         needs a live replica, drain an alive one, revive a \
                         dead one)",
                        kind.name(),
                        e.replica,
                        e.at
                    )))
                }
            };
            if !state.iter().any(|s| *s == S::Alive) {
                return Err(ConcurError::config(format!(
                    "fault plan leaves no admissible replica at {} (drains \
                     count as unavailable until they refill)",
                    e.at
                )));
            }
        }
        Ok(())
    }

    /// Parse the `topology.fault_plan` JSON array: each entry is
    /// `{"at_s": seconds, "replica": index, "kind": "kill|drain|revive"}`
    /// (see `docs/OPERATIONS.md` for worked configs).
    pub fn from_json_events(entries: &[Value]) -> Result<FaultPlan> {
        let mut events = Vec::with_capacity(entries.len());
        for e in entries {
            let at = Micros::from_secs_f64(e.req_f64("at_s")?);
            let replica = e.req_u64("replica")? as usize;
            let kind = match e.req_str("kind")? {
                "kill" => FaultKind::Kill,
                "drain" => FaultKind::Drain,
                "revive" => FaultKind::Revive,
                other => {
                    return Err(ConcurError::config(format!(
                        "unknown fault kind '{other}' (kill|drain|revive)"
                    )))
                }
            };
            events.push(FaultEvent { at, replica, kind });
        }
        Ok(FaultPlan::new(events))
    }
}

/// Stochastic replica fault injection: seeded per-replica MTBF/MTTR
/// rates beside the scripted [`FaultPlan`].  When enabled, each replica
/// draws its up-times and repair-times from its own forked stream of the
/// run's fault seed — exponential inter-event gaps, so the fleet fails at
/// a *rate* while traffic keeps flowing — and the cluster loop applies
/// the drawn kills, planned-maintenance drains and revives through the
/// same machinery as scripted events.  Draws are independent of system
/// state, so a fixed seed replays bit-identically; a drawn fault that
/// would strand routing with zero admissible replicas (or land on a
/// replica not currently alive) is suppressed and counted, never
/// applied.  Disabled by default and inert when disabled: the scripted
/// path stays bit-identical to the pre-stochastic loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRateConfig {
    pub enabled: bool,
    /// Mean up-time (seconds) a replica runs before its next drawn fault.
    pub mtbf_s: f64,
    /// Mean down-time (seconds) a killed replica stays dead before its
    /// drawn revive.
    pub mttr_s: f64,
    /// Probability a drawn fault is a planned-maintenance drain (which
    /// refills on its own, and hands KV off when the transport's
    /// `drain_handoff` is on) instead of a kill.
    pub drain_share: f64,
    /// Seed of the per-replica draw streams (independent of the workload
    /// seed, so fault timelines can be swept against a fixed workload).
    pub seed: u64,
}

impl Default for FaultRateConfig {
    fn default() -> FaultRateConfig {
        FaultRateConfig {
            enabled: false,
            mtbf_s: 600.0,
            mttr_s: 60.0,
            drain_share: 0.25,
            seed: 23,
        }
    }
}

impl FaultRateConfig {
    /// The default rate configuration with injection switched on.
    pub fn on() -> FaultRateConfig {
        FaultRateConfig { enabled: true, ..FaultRateConfig::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(()); // dormant knobs are valid, whatever they say
        }
        if !self.mtbf_s.is_finite() || self.mtbf_s <= 0.0 {
            return Err(ConcurError::config("fault_rates.mtbf_s must be finite and > 0"));
        }
        if !self.mttr_s.is_finite() || self.mttr_s <= 0.0 {
            return Err(ConcurError::config("fault_rates.mttr_s must be finite and > 0"));
        }
        if !(0.0..=1.0).contains(&self.drain_share) {
            return Err(ConcurError::config("fault_rates.drain_share must be in [0,1]"));
        }
        Ok(())
    }

    /// Parse the `topology.fault_rates` JSON object (all fields optional
    /// on top of the defaults).
    pub fn from_json(v: &Value) -> Result<FaultRateConfig> {
        let mut cfg = FaultRateConfig::default();
        if let Some(b) = v.get("enabled").as_bool() {
            cfg.enabled = b;
        }
        if let Some(x) = v.get("mtbf_s").as_f64() {
            cfg.mtbf_s = x;
        }
        if let Some(x) = v.get("mttr_s").as_f64() {
            cfg.mttr_s = x;
        }
        if let Some(x) = v.get("drain_share").as_f64() {
            cfg.drain_share = x;
        }
        if let Some(x) = v.get("seed").as_u64() {
            cfg.seed = x;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty_and_always_valid() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        for n in 1..4 {
            p.validate(n).unwrap();
        }
    }

    #[test]
    fn events_are_sorted_stably_by_instant() {
        let p = FaultPlan::new(vec![
            FaultEvent::revive(0, Micros(300)),
            FaultEvent::kill(0, Micros(100)),
            FaultEvent::drain(1, Micros(100)),
        ]);
        let kinds: Vec<FaultKind> = p.events().iter().map(|e| e.kind).collect();
        // Same-instant events keep listed order (kill before drain).
        assert_eq!(kinds, vec![FaultKind::Kill, FaultKind::Drain, FaultKind::Revive]);
    }

    #[test]
    fn validation_rejects_out_of_range_replica() {
        let p = FaultPlan::new(vec![FaultEvent::kill(3, Micros(1))]);
        let err = p.validate(2).unwrap_err().to_string();
        // The error names the offending event: kind, replica, instant.
        assert!(err.contains("kill replica 3"), "{err}");
        assert!(err.contains("topology has 2 replicas"), "{err}");
    }

    /// The range check runs before replay: even when an out-of-range
    /// event sorts *after* script entries that would trip a replay error
    /// themselves, the out-of-range event is the one reported.
    #[test]
    fn out_of_range_is_reported_before_replay_errors() {
        let p = FaultPlan::new(vec![
            // Replaying this alone would fail ("no admissible replica").
            FaultEvent::kill(0, Micros(1)),
            FaultEvent::kill(9, Micros(2)),
        ]);
        let err = p.validate(1).unwrap_err().to_string();
        assert!(err.contains("kill replica 9"), "{err}");
    }

    /// JSON round-trip of an out-of-range plan: parsing succeeds (range
    /// needs the topology), and load-time validation names the event.
    #[test]
    fn json_out_of_range_event_is_named_at_load_time() {
        let text = r#"[
            {"at_s": 10.0, "replica": 1, "kind": "drain"},
            {"at_s": 99.5, "replica": 7, "kind": "revive"}
        ]"#;
        let v = Value::parse(text).unwrap();
        let p = FaultPlan::from_json_events(v.as_array().unwrap()).unwrap();
        let err = p.validate(4).unwrap_err().to_string();
        assert!(err.contains("revive replica 7"), "{err}");
        assert!(err.contains("99.5"), "err must name the instant: {err}");
        // The same plan against a big enough fleet round-trips fine
        // (revive is illegal from alive, so only check the range pass).
        let ok = FaultPlan::new(vec![FaultEvent::drain(1, Micros(10_000_000))]);
        ok.validate(4).unwrap();
    }

    #[test]
    fn validation_rejects_illegal_transitions() {
        // Revive of a never-killed replica.
        assert!(FaultPlan::new(vec![FaultEvent::revive(1, Micros(1))]).validate(3).is_err());
        // Double kill.
        let p = FaultPlan::new(vec![
            FaultEvent::kill(1, Micros(1)),
            FaultEvent::kill(1, Micros(2)),
        ]);
        assert!(p.validate(3).is_err());
        // Drain of a dead replica.
        let p = FaultPlan::new(vec![
            FaultEvent::kill(1, Micros(1)),
            FaultEvent::drain(1, Micros(2)),
        ]);
        assert!(p.validate(3).is_err());
        // Kill of a draining replica is allowed.
        let p = FaultPlan::new(vec![
            FaultEvent::drain(1, Micros(1)),
            FaultEvent::kill(1, Micros(2)),
        ]);
        p.validate(3).unwrap();
    }

    #[test]
    fn validation_requires_a_surviving_replica() {
        // Killing the only replica is rejected...
        assert!(FaultPlan::new(vec![FaultEvent::kill(0, Micros(1))]).validate(1).is_err());
        // ...as is draining it (conservative: refill time is unknown).
        assert!(FaultPlan::new(vec![FaultEvent::drain(0, Micros(1))]).validate(1).is_err());
        // Kill + later revive of one of two replicas is fine.
        let p = FaultPlan::new(vec![
            FaultEvent::kill(0, Micros(1)),
            FaultEvent::revive(0, Micros(10)),
        ]);
        p.validate(2).unwrap();
        // Kill one, then the other (even after the revive of the first).
        let p = FaultPlan::new(vec![
            FaultEvent::kill(0, Micros(1)),
            FaultEvent::revive(0, Micros(10)),
            FaultEvent::kill(1, Micros(20)),
        ]);
        p.validate(2).unwrap();
    }

    #[test]
    fn fault_rates_default_off_and_validate() {
        let d = FaultRateConfig::default();
        assert!(!d.enabled, "stochastic injection must be opt-in");
        d.validate().unwrap();
        // Dormant nonsense knobs are valid while disabled...
        let weird = FaultRateConfig { mtbf_s: -1.0, drain_share: 7.0, ..d };
        weird.validate().unwrap();
        // ...and rejected once enabled.
        assert!(FaultRateConfig { enabled: true, ..weird }.validate().is_err());
        FaultRateConfig::on().validate().unwrap();
        let mut on = FaultRateConfig::on();
        on.mttr_s = 0.0;
        assert!(on.validate().is_err());
        let mut on = FaultRateConfig::on();
        on.drain_share = 1.5;
        assert!(on.validate().is_err());
    }

    #[test]
    fn fault_rates_json_overrides_defaults() {
        let v = Value::parse(
            r#"{"enabled": true, "mtbf_s": 120.5, "mttr_s": 9, "drain_share": 0.5, "seed": 99}"#,
        )
        .unwrap();
        let cfg = FaultRateConfig::from_json(&v).unwrap();
        assert!(cfg.enabled);
        assert_eq!(cfg.mtbf_s, 120.5);
        assert_eq!(cfg.mttr_s, 9.0);
        assert_eq!(cfg.drain_share, 0.5);
        assert_eq!(cfg.seed, 99);
        // Empty object keeps every default.
        let empty = FaultRateConfig::from_json(&Value::parse("{}").unwrap()).unwrap();
        assert_eq!(empty, FaultRateConfig::default());
    }

    #[test]
    fn json_roundtrip() {
        let text = r#"[
            {"at_s": 120.0, "replica": 0, "kind": "kill"},
            {"at_s": 300.0, "replica": 0, "kind": "revive"},
            {"at_s": 60.5, "replica": 1, "kind": "drain"}
        ]"#;
        let v = Value::parse(text).unwrap();
        let p = FaultPlan::from_json_events(v.as_array().unwrap()).unwrap();
        assert_eq!(p.events().len(), 3);
        // Sorted: drain at 60.5s first.
        assert_eq!(p.events()[0], FaultEvent::drain(1, Micros(60_500_000)));
        assert_eq!(p.events()[1], FaultEvent::kill(0, Micros(120_000_000)));
        p.validate(2).unwrap();

        let bad = Value::parse(r#"[{"at_s": 1, "replica": 0, "kind": "explode"}]"#).unwrap();
        assert!(FaultPlan::from_json_events(bad.as_array().unwrap()).is_err());
    }
}
