//! Scripted replica fault plans: kill, drain-and-refill, revive.
//!
//! A [`FaultPlan`] is part of [`TopologyConfig`](super::TopologyConfig):
//! a time-ordered script of replica lifecycle transitions that the
//! cluster loop (`cluster::run_sharded`) applies at exact simulation
//! instants.  Plans are configuration, not runtime state — the same
//! `JobConfig` always reproduces the same disruption, so fault-tolerance
//! comparisons across routers/schedulers are run on bit-identical
//! failure timelines.
//!
//! Semantics (details in DESIGN.md §Faults):
//!
//! * **kill** — the replica process dies at `at`: its KV pool, radix
//!   cache and queues vanish; agents with an in-flight step there lose
//!   the step and re-enter the admission queue; the controller stops
//!   aggregating the dead replica's signals.
//! * **drain** — the replica stops receiving admissions, finishes the
//!   requests it already holds, then wipes its cache and rejoins the
//!   admissible fleet ("refill") — the rolling-restart primitive.
//! * **revive** — a killed replica rejoins, empty.
//!
//! Validation is conservative: replaying the script must leave at least
//! one replica alive-and-not-draining at every step (a draining replica
//! is counted as unavailable until the run proves otherwise), so a plan
//! can never strand routing with zero admissible replicas.

use crate::core::json::Value;
use crate::core::{ConcurError, Micros, Result};

/// A replica lifecycle transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Replica dies: all KV state and queued work is lost instantly.
    Kill,
    /// Replica stops admissions, finishes its running work, rejoins empty.
    Drain,
    /// A previously killed replica rejoins the fleet, empty.
    Revive,
}

impl FaultKind {
    /// Stable lowercase name (JSON `kind` field and table labels).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Kill => "kill",
            FaultKind::Drain => "drain",
            FaultKind::Revive => "revive",
        }
    }
}

/// One scripted transition: `replica` undergoes `kind` at instant `at`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Simulation instant the transition fires (ties with an iteration
    /// completing at the same instant resolve fault-first).
    pub at: Micros,
    /// Target replica index in `0..topology.replicas`.
    pub replica: usize,
    /// Which transition.
    pub kind: FaultKind,
}

impl FaultEvent {
    /// Kill `replica` at `at`.
    pub fn kill(replica: usize, at: Micros) -> FaultEvent {
        FaultEvent { at, replica, kind: FaultKind::Kill }
    }

    /// Drain `replica` starting at `at` (refill is automatic once idle).
    pub fn drain(replica: usize, at: Micros) -> FaultEvent {
        FaultEvent { at, replica, kind: FaultKind::Drain }
    }

    /// Revive previously killed `replica` at `at`.
    pub fn revive(replica: usize, at: Micros) -> FaultEvent {
        FaultEvent { at, replica, kind: FaultKind::Revive }
    }
}

/// A time-ordered script of [`FaultEvent`]s (empty = healthy fleet).
///
/// Construction sorts stably by instant, so same-instant events apply in
/// the order listed.  `FaultPlan::none()` is the default and changes
/// nothing about a run — the N=1 no-fault path stays bit-identical to
/// the pre-fault driver (differential-tested).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The healthy fleet: no scripted faults.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Build a plan from events in any order (sorted stably by `at`).
    pub fn new(mut events: Vec<FaultEvent>) -> FaultPlan {
        events.sort_by_key(|e| e.at);
        FaultPlan { events }
    }

    /// No scripted faults?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events in firing order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Validate against a fleet of `replicas` by replaying the script:
    /// indices in range, transitions legal from each replica's prior
    /// state (kill from alive/draining, drain from alive, revive from
    /// dead), and at least one replica alive-and-not-draining after
    /// every event (drains count as unavailable here because validation
    /// cannot know when a drain refills).
    pub fn validate(&self, replicas: usize) -> Result<()> {
        #[derive(Clone, Copy, PartialEq)]
        enum S {
            Alive,
            Draining,
            Dead,
        }
        let mut state = vec![S::Alive; replicas];
        for e in &self.events {
            if e.replica >= replicas {
                return Err(ConcurError::config(format!(
                    "fault plan targets replica {} but topology has {replicas}",
                    e.replica
                )));
            }
            let s = &mut state[e.replica];
            *s = match (e.kind, *s) {
                (FaultKind::Kill, S::Alive | S::Draining) => S::Dead,
                (FaultKind::Drain, S::Alive) => S::Draining,
                (FaultKind::Revive, S::Dead) => S::Alive,
                (kind, _) => {
                    return Err(ConcurError::config(format!(
                        "fault plan: illegal '{}' of replica {} at {} (kill \
                         needs a live replica, drain an alive one, revive a \
                         dead one)",
                        kind.name(),
                        e.replica,
                        e.at
                    )))
                }
            };
            if !state.iter().any(|s| *s == S::Alive) {
                return Err(ConcurError::config(format!(
                    "fault plan leaves no admissible replica at {} (drains \
                     count as unavailable until they refill)",
                    e.at
                )));
            }
        }
        Ok(())
    }

    /// Parse the `topology.fault_plan` JSON array: each entry is
    /// `{"at_s": seconds, "replica": index, "kind": "kill|drain|revive"}`
    /// (see `docs/OPERATIONS.md` for worked configs).
    pub fn from_json_events(entries: &[Value]) -> Result<FaultPlan> {
        let mut events = Vec::with_capacity(entries.len());
        for e in entries {
            let at = Micros::from_secs_f64(e.req_f64("at_s")?);
            let replica = e.req_u64("replica")? as usize;
            let kind = match e.req_str("kind")? {
                "kill" => FaultKind::Kill,
                "drain" => FaultKind::Drain,
                "revive" => FaultKind::Revive,
                other => {
                    return Err(ConcurError::config(format!(
                        "unknown fault kind '{other}' (kill|drain|revive)"
                    )))
                }
            };
            events.push(FaultEvent { at, replica, kind });
        }
        Ok(FaultPlan::new(events))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty_and_always_valid() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        for n in 1..4 {
            p.validate(n).unwrap();
        }
    }

    #[test]
    fn events_are_sorted_stably_by_instant() {
        let p = FaultPlan::new(vec![
            FaultEvent::revive(0, Micros(300)),
            FaultEvent::kill(0, Micros(100)),
            FaultEvent::drain(1, Micros(100)),
        ]);
        let kinds: Vec<FaultKind> = p.events().iter().map(|e| e.kind).collect();
        // Same-instant events keep listed order (kill before drain).
        assert_eq!(kinds, vec![FaultKind::Kill, FaultKind::Drain, FaultKind::Revive]);
    }

    #[test]
    fn validation_rejects_out_of_range_replica() {
        let p = FaultPlan::new(vec![FaultEvent::kill(3, Micros(1))]);
        assert!(p.validate(2).is_err());
    }

    #[test]
    fn validation_rejects_illegal_transitions() {
        // Revive of a never-killed replica.
        assert!(FaultPlan::new(vec![FaultEvent::revive(1, Micros(1))]).validate(3).is_err());
        // Double kill.
        let p = FaultPlan::new(vec![
            FaultEvent::kill(1, Micros(1)),
            FaultEvent::kill(1, Micros(2)),
        ]);
        assert!(p.validate(3).is_err());
        // Drain of a dead replica.
        let p = FaultPlan::new(vec![
            FaultEvent::kill(1, Micros(1)),
            FaultEvent::drain(1, Micros(2)),
        ]);
        assert!(p.validate(3).is_err());
        // Kill of a draining replica is allowed.
        let p = FaultPlan::new(vec![
            FaultEvent::drain(1, Micros(1)),
            FaultEvent::kill(1, Micros(2)),
        ]);
        p.validate(3).unwrap();
    }

    #[test]
    fn validation_requires_a_surviving_replica() {
        // Killing the only replica is rejected...
        assert!(FaultPlan::new(vec![FaultEvent::kill(0, Micros(1))]).validate(1).is_err());
        // ...as is draining it (conservative: refill time is unknown).
        assert!(FaultPlan::new(vec![FaultEvent::drain(0, Micros(1))]).validate(1).is_err());
        // Kill + later revive of one of two replicas is fine.
        let p = FaultPlan::new(vec![
            FaultEvent::kill(0, Micros(1)),
            FaultEvent::revive(0, Micros(10)),
        ]);
        p.validate(2).unwrap();
        // Kill one, then the other (even after the revive of the first).
        let p = FaultPlan::new(vec![
            FaultEvent::kill(0, Micros(1)),
            FaultEvent::revive(0, Micros(10)),
            FaultEvent::kill(1, Micros(20)),
        ]);
        p.validate(2).unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let text = r#"[
            {"at_s": 120.0, "replica": 0, "kind": "kill"},
            {"at_s": 300.0, "replica": 0, "kind": "revive"},
            {"at_s": 60.5, "replica": 1, "kind": "drain"}
        ]"#;
        let v = Value::parse(text).unwrap();
        let p = FaultPlan::from_json_events(v.as_array().unwrap()).unwrap();
        assert_eq!(p.events().len(), 3);
        // Sorted: drain at 60.5s first.
        assert_eq!(p.events()[0], FaultEvent::drain(1, Micros(60_500_000)));
        assert_eq!(p.events()[1], FaultEvent::kill(0, Micros(120_000_000)));
        p.validate(2).unwrap();

        let bad = Value::parse(r#"[{"at_s": 1, "replica": 0, "kind": "explode"}]"#).unwrap();
        assert!(FaultPlan::from_json_events(bad.as_array().unwrap()).is_err());
    }
}
