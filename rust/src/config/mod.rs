//! Configuration system: typed configs with builders, JSON file loading and
//! the paper's experiment presets.
//!
//! Every experiment in `repro/` is expressed as a [`JobConfig`]; users can
//! also write a JSON config file and run it with `concur sim --config f.json`.

pub mod faults;
pub mod presets;

pub use faults::{FaultEvent, FaultKind, FaultPlan, FaultRateConfig};

use crate::core::json::Value;
use crate::core::{ConcurError, Micros, Result};
use crate::costmodel::{ClusterSpec, GpuSpec, ModelSpec};

/// Which admission scheduler fronts the engine (§6 of DESIGN.md).
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerKind {
    /// SGLang-like: admit everything, rely on LRU eviction.
    Uncontrolled,
    /// Fixed cap on in-flight *requests* (no agent affinity).
    RequestCap(usize),
    /// Fixed cap on concurrently *active agents*.
    AgentCap(usize),
    /// The paper's contribution: AIMD cache-aware agent admission.
    Concur(AimdParams),
}

impl SchedulerKind {
    pub fn name(&self) -> String {
        match self {
            SchedulerKind::Uncontrolled => "sglang".into(),
            SchedulerKind::RequestCap(n) => format!("request-cap({n})"),
            SchedulerKind::AgentCap(n) => format!("agent-cap({n})"),
            SchedulerKind::Concur(_) => "concur".into(),
        }
    }
}

/// How the cluster routes an agent's generation steps across data-parallel
/// engine replicas (see `cluster::router` for the policies' trade-offs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    /// Cycle through replicas per request; load-even, cache-oblivious.
    RoundRobin,
    /// Send each request to the replica with the smallest active KV
    /// working set; balances memory but migrates agents off their warm
    /// prefixes.
    LeastLoaded,
    /// Pin each agent to a home replica (id-hashed) and spill to the
    /// least-loaded replica only under sustained home overload.
    CacheAffinity,
    /// Cache-affinity homes that are *re-assigned* under sustained
    /// imbalance or replica loss, migrating cold agents first (ranked by
    /// the engine's per-agent cache-heat signal).
    Rebalance,
}

impl RouterKind {
    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::LeastLoaded => "least-loaded",
            RouterKind::CacheAffinity => "cache-affinity",
            RouterKind::Rebalance => "rebalance",
        }
    }
}

/// Cross-replica shared-prefix broadcast tier (`cluster::prefix`).  When
/// enabled, the cluster detects hot shared prompt prefixes (family system
/// prompts and beyond) from the request stream, ships them to every
/// admissible replica over the simulated interconnect, and pins them as
/// read-only radix paths so per-replica eviction never drops them while
/// they stay hot.  Disabled by default: the tier must be **invisible**
/// unless asked for (the tier-off path is differential-tested
/// bit-identical to the pre-tier cluster).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixTierConfig {
    pub enabled: bool,
    /// Distinct-agent reuse count at which a detected shared prefix is
    /// promoted to the broadcast tier ("hotness threshold").
    pub hot_after: u32,
    /// Total tokens the tier may keep broadcast-pinned per replica;
    /// promoting past the budget demotes the stalest prefix first.
    pub budget_tokens: u64,
    /// Shortest shared prefix worth tracking, in tokens (two prompts
    /// overlapping less than this are considered unrelated).
    pub min_prefix_tokens: u32,
    /// Demote a broadcast prefix that has not been reused for this long.
    pub cool_after: Micros,
    /// Content-hash candidate index over non-head prompt chunks: detects
    /// shared context sitting *mid-prompt* (workflow intermediate
    /// context), where LCP convergence is structurally blind because the
    /// prompt heads differ.  A detected chunk's candidate is the
    /// head-extended run through the chunk, so promotion still pins an
    /// installable radix prefix.  Off by default (pure LCP detection).
    pub content_hash: bool,
    /// Chunk width (tokens) of the content-hash index; chunks are
    /// non-overlapping and offset-aligned to this width.
    pub hash_chunk_tokens: u32,
}

impl Default for PrefixTierConfig {
    fn default() -> PrefixTierConfig {
        PrefixTierConfig {
            enabled: false,
            hot_after: 3,
            budget_tokens: 32_768,
            min_prefix_tokens: 64,
            cool_after: Micros(300_000_000), // 300 s of simulated cold
            content_hash: false,
            hash_chunk_tokens: 256,
        }
    }
}

impl PrefixTierConfig {
    /// The default configuration with the tier switched on.
    pub fn on() -> PrefixTierConfig {
        PrefixTierConfig { enabled: true, ..PrefixTierConfig::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if self.hot_after < 2 {
            return Err(ConcurError::config(
                "prefix_tier.hot_after must be >= 2 (a prefix shared by one \
                 agent is not shared)",
            ));
        }
        if self.min_prefix_tokens == 0 {
            return Err(ConcurError::config("prefix_tier.min_prefix_tokens must be > 0"));
        }
        if self.budget_tokens < self.min_prefix_tokens as u64 {
            return Err(ConcurError::config(
                "prefix_tier.budget_tokens cannot fit even one minimal prefix",
            ));
        }
        if self.cool_after == Micros::ZERO {
            return Err(ConcurError::config(
                "prefix_tier.cool_after must be > 0 (zero demotes every \
                 prefix the instant after it ships, churning the tier \
                 forever)",
            ));
        }
        if self.content_hash && self.hash_chunk_tokens == 0 {
            return Err(ConcurError::config(
                "prefix_tier.hash_chunk_tokens must be > 0 with \
                 content_hash on",
            ));
        }
        Ok(())
    }
}

/// Asynchronous cross-replica KV transport (`cluster::transport`).  All
/// cross-replica KV movement — broadcast prefix installs and drain
/// handoffs — is modeled as transfers over a shared inter-replica fabric
/// link plus the endpoints' host (PCIe) links.  Disabled by default:
/// shipping then behaves exactly as before this subsystem existed
/// (instantaneous visibility, no fabric modeled, drains drop their
/// cache), and the off path is differential-tested bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransportConfig {
    /// Model the interconnect explicitly.  Off = the legacy teleport:
    /// installs are usable the instant they are charged and drains drop
    /// warm state on the floor.
    pub enabled: bool,
    /// Broadcast installs (and handoffs) become visible only at their
    /// transfer's completion instant: the radix pin is reserved at issue,
    /// matches zero tokens and feeds no routing hint until the transfer
    /// lands.  Off = transfers are charged but commit at issue.
    pub delayed_visibility: bool,
    /// Ship only the per-target un-cached suffix over the fabric (the
    /// tier peeks each target's radix tree for the longest cached prefix
    /// of the candidate).  Off = the source blasts the full prefix to
    /// every target, target-oblivious.
    pub delta_ship: bool,
    /// On a planned drain, checkpoint the draining replica's hottest
    /// agents' contexts through the transport to the replica each agent
    /// will be re-homed to, instead of dropping the warm cache at refill.
    pub drain_handoff: bool,
    /// Shared inter-replica fabric bandwidth in GB/s (one link for the
    /// whole fleet — simultaneous transfers contend).
    pub fabric_gbps: f64,
    /// Max context tokens one drain may hand off (hottest agents first).
    pub handoff_budget_tokens: u64,
    /// Max agents one drain may hand off.
    pub handoff_max_agents: usize,
}

impl Default for TransportConfig {
    fn default() -> TransportConfig {
        TransportConfig {
            enabled: false,
            delayed_visibility: false,
            delta_ship: false,
            drain_handoff: false,
            fabric_gbps: 50.0,
            handoff_budget_tokens: 262_144,
            handoff_max_agents: 16,
        }
    }
}

impl TransportConfig {
    /// The default configuration with the transport switched on (fabric
    /// modeled; visibility still instantaneous, full-ship, drop-on-drain
    /// until the feature flags say otherwise).
    pub fn on() -> TransportConfig {
        TransportConfig { enabled: true, ..TransportConfig::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            if self.delayed_visibility || self.delta_ship || self.drain_handoff {
                return Err(ConcurError::config(
                    "transport features (delayed_visibility / delta_ship / \
                     drain_handoff) require transport.enabled — silently \
                     ignoring them would misreport the model being run",
                ));
            }
            return Ok(());
        }
        if !self.fabric_gbps.is_finite() || self.fabric_gbps <= 0.0 {
            return Err(ConcurError::config("transport.fabric_gbps must be finite and > 0"));
        }
        if self.drain_handoff {
            if self.handoff_budget_tokens == 0 {
                return Err(ConcurError::config(
                    "transport.handoff_budget_tokens must be > 0 with drain_handoff on",
                ));
            }
            if self.handoff_max_agents == 0 {
                return Err(ConcurError::config(
                    "transport.handoff_max_agents must be > 0 with drain_handoff on",
                ));
            }
        }
        Ok(())
    }
}

/// Open-loop production traffic (`agent::arrivals` + the cluster loop).
/// When enabled, the fleet of multi-turn sessions no longer starts as a
/// closed batch: sessions *arrive* on a seeded Poisson process with a
/// diurnal rate curve, idle a lognormal think time between turns (on top
/// of tool latency), carry a tenant priority class, and **abandon** when
/// a turn has waited longer than their patience.  Latency becomes
/// first-class: TTFT and per-turn latency land in log-bucketed
/// histograms, and sessions that finish with every turn inside the SLO
/// count as goodput.  Overload shedding (with hysteresis) and
/// priority-aware admission are governed here too.  Disabled by default
/// and differential-tested inert: the closed-batch path is bit-identical
/// to the pre-open-loop loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpenLoopConfig {
    pub enabled: bool,
    /// Mean session arrival rate λ (sessions per second of simulated
    /// time), before diurnal modulation.
    pub arrival_rate_per_s: f64,
    /// Diurnal modulation amplitude A in [0,1]: the instantaneous rate is
    /// `λ · (1 + A·sin(2πt/P))`.  0 = homogeneous Poisson.
    pub diurnal_amplitude: f64,
    /// Diurnal period P in seconds.
    pub diurnal_period_s: f64,
    /// Think time idled between a session's turns, lognormal(mu, sigma)
    /// seconds added to each turn's tool latency.
    pub think_mu: f64,
    pub think_sigma: f64,
    /// A session abandons when one of its turns has waited longer than
    /// this (seconds) without completing.  0 = infinitely patient.
    pub patience_s: f64,
    /// Fraction of sessions drawn into the High priority class.
    pub high_priority_share: f64,
    /// SLO on time-to-first-token (arrival → first turn complete), secs.
    pub slo_ttft_s: f64,
    /// SLO on every later turn's latency (turn ready → complete), secs.
    pub slo_step_s: f64,
    /// Class-aware admission: High-priority sessions are admitted ahead
    /// of Low-priority ones.  Off = plain FIFO arrival order (the
    /// baseline the acceptance test compares against).
    pub priority_admission: bool,
    /// Overload shedding: when the admission backlog exceeds
    /// `shed_on_ratio × window`, Low-priority sessions that have not yet
    /// started are rejected until the backlog falls below
    /// `shed_off_ratio × window` (hysteresis, so shedding does not flap
    /// across fault/revive boundaries).
    pub shed: bool,
    pub shed_on_ratio: f64,
    pub shed_off_ratio: f64,
    /// Seed of the arrival/class/think draws (independent of the
    /// workload seed, so traffic timing can be swept against a fixed
    /// session population).
    pub seed: u64,
}

impl Default for OpenLoopConfig {
    fn default() -> OpenLoopConfig {
        OpenLoopConfig {
            enabled: false,
            arrival_rate_per_s: 1.0,
            diurnal_amplitude: 0.5,
            diurnal_period_s: 120.0,
            think_mu: 0.5, // e^0.5 ≈ 1.6 s median think time
            think_sigma: 0.6,
            patience_s: 60.0,
            high_priority_share: 0.25,
            slo_ttft_s: 30.0,
            slo_step_s: 45.0,
            priority_admission: true,
            shed: true,
            shed_on_ratio: 2.0,
            shed_off_ratio: 1.0,
            seed: 11,
        }
    }
}

impl OpenLoopConfig {
    /// The default configuration with open-loop traffic switched on.
    pub fn on() -> OpenLoopConfig {
        OpenLoopConfig { enabled: true, ..OpenLoopConfig::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(()); // dormant knobs are valid, whatever they say
        }
        if !self.arrival_rate_per_s.is_finite() || self.arrival_rate_per_s <= 0.0 {
            return Err(ConcurError::config(
                "open_loop.arrival_rate_per_s must be finite and > 0",
            ));
        }
        if !(0.0..=1.0).contains(&self.diurnal_amplitude) {
            return Err(ConcurError::config("open_loop.diurnal_amplitude must be in [0,1]"));
        }
        if self.diurnal_amplitude > 0.0
            && (!self.diurnal_period_s.is_finite() || self.diurnal_period_s <= 0.0)
        {
            return Err(ConcurError::config(
                "open_loop.diurnal_period_s must be finite and > 0 when \
                 diurnal_amplitude > 0",
            ));
        }
        if !self.think_sigma.is_finite() || self.think_sigma < 0.0 {
            return Err(ConcurError::config("open_loop.think_sigma must be finite and >= 0"));
        }
        if !self.patience_s.is_finite() || self.patience_s < 0.0 {
            return Err(ConcurError::config(
                "open_loop.patience_s must be finite and >= 0 (0 = infinitely patient)",
            ));
        }
        if !(0.0..=1.0).contains(&self.high_priority_share) {
            return Err(ConcurError::config(
                "open_loop.high_priority_share must be in [0,1]",
            ));
        }
        for (name, v) in [("slo_ttft_s", self.slo_ttft_s), ("slo_step_s", self.slo_step_s)] {
            if !v.is_finite() || v <= 0.0 {
                return Err(ConcurError::config(format!(
                    "open_loop.{name} must be finite and > 0"
                )));
            }
        }
        if self.shed {
            if !self.shed_on_ratio.is_finite() || self.shed_on_ratio <= 0.0 {
                return Err(ConcurError::config(
                    "open_loop.shed_on_ratio must be finite and > 0",
                ));
            }
            if !self.shed_off_ratio.is_finite()
                || self.shed_off_ratio < 0.0
                || self.shed_off_ratio >= self.shed_on_ratio
            {
                return Err(ConcurError::config(
                    "open_loop.shed_off_ratio must satisfy 0 <= off < on \
                     (the gap is the hysteresis band)",
                ));
            }
        }
        Ok(())
    }
}

/// Data-parallel serving topology: how many engine replicas a job runs on
/// (each with its own KV pool and radix cache), how agents are routed
/// between them, which replica faults are scripted, and how tool latency
/// skews per replica.  The default — one healthy, unskewed replica —
/// reproduces the pre-cluster driver bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyConfig {
    pub replicas: usize,
    pub router: RouterKind,
    /// Scripted replica kills / drains / revivals (empty = healthy fleet).
    pub fault_plan: FaultPlan,
    /// Per-replica tool-latency multipliers, threaded into tool-call
    /// scheduling so routers face heterogeneous service times.  Empty
    /// means uniform 1.0; otherwise the length must equal `replicas` and
    /// every multiplier must be finite and positive.
    pub tool_skew: Vec<f64>,
    /// Cross-replica shared-prefix broadcast tier (off by default).
    pub prefix_tier: PrefixTierConfig,
    /// Asynchronous cross-replica KV transport (off by default = legacy
    /// instantaneous shipping and drop-on-drain).
    pub transport: TransportConfig,
    /// Open-loop arrival traffic with SLO/priority/shedding semantics
    /// (off by default = closed batch, all sessions present at t=0).
    pub open_loop: OpenLoopConfig,
    /// Stochastic MTBF/MTTR fault injection beside the scripted plan
    /// (off by default = only `fault_plan` events fire).
    pub fault_rates: FaultRateConfig,
}

impl Default for TopologyConfig {
    fn default() -> TopologyConfig {
        TopologyConfig {
            replicas: 1,
            router: RouterKind::CacheAffinity,
            fault_plan: FaultPlan::none(),
            tool_skew: Vec::new(),
            prefix_tier: PrefixTierConfig::default(),
            transport: TransportConfig::default(),
            open_loop: OpenLoopConfig::default(),
            fault_rates: FaultRateConfig::default(),
        }
    }
}

impl TopologyConfig {
    pub fn validate(&self) -> Result<()> {
        if self.replicas == 0 {
            return Err(ConcurError::config("replicas must be >= 1"));
        }
        self.fault_plan.validate(self.replicas)?;
        if !self.tool_skew.is_empty() {
            if self.tool_skew.len() != self.replicas {
                return Err(ConcurError::config(format!(
                    "tool_skew has {} entries for {} replicas (empty = \
                     uniform 1.0)",
                    self.tool_skew.len(),
                    self.replicas
                )));
            }
            if self.tool_skew.iter().any(|s| !s.is_finite() || *s <= 0.0) {
                return Err(ConcurError::config(
                    "tool_skew multipliers must be finite and > 0",
                ));
            }
        }
        self.prefix_tier.validate()?;
        self.transport.validate()?;
        self.open_loop.validate()?;
        self.fault_rates.validate()?;
        Ok(())
    }
}

/// AIMD control-law parameters (paper §4.3, defaults §5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AimdParams {
    /// Additive increase per control interval when `U_t < u_low`.
    pub alpha: f64,
    /// Multiplicative decrease when `U_t > u_high && H_t < h_thresh`.
    pub beta: f64,
    pub u_low: f64,
    pub u_high: f64,
    pub h_thresh: f64,
    /// Initial window (active-agent budget).
    pub w_init: f64,
    /// Window floor (never pause the whole fleet).
    pub w_min: f64,
    /// Window ceiling (engine/queue capacity).
    pub w_max: f64,
    /// Apply the control law every this many engine steps.
    pub control_interval: u32,
    /// After a multiplicative cut, suppress further cuts for this many
    /// control intervals while the hit-rate window refreshes (one cut per
    /// congestion epoch, as in TCP fast recovery).
    pub cut_cooldown: u32,
    /// Slow additive probe inside the [u_low, u_high] hold band: every
    /// `band_probe_every`-th control interval, if the window is saturated,
    /// the hit rate is at least `h_healthy` and no cut fired recently,
    /// probe +α.  This is congestion avoidance proper — without it the
    /// window can only ratchet downward after warmup and strands capacity
    /// when the post-cut equilibrium sits below the true fit.
    /// 0 disables band probing.
    pub band_probe_every: u32,
    /// Hit rate considered "healthy" for band probing.
    pub h_healthy: f64,
}

impl Default for AimdParams {
    fn default() -> AimdParams {
        AimdParams {
            alpha: 2.0,
            beta: 0.5,
            u_low: 0.2,
            u_high: 0.5,
            h_thresh: 0.2,
            w_init: 8.0,
            w_min: 1.0,
            w_max: 4096.0,
            control_interval: 4,
            cut_cooldown: 16,
            band_probe_every: 8,
            h_healthy: 0.8,
        }
    }
}

impl AimdParams {
    pub fn validate(&self) -> Result<()> {
        if !(0.0 < self.beta && self.beta < 1.0) {
            return Err(ConcurError::config("beta must be in (0,1)"));
        }
        if self.alpha <= 0.0 {
            return Err(ConcurError::config("alpha must be positive"));
        }
        if !(0.0 <= self.u_low && self.u_low < self.u_high && self.u_high <= 1.0) {
            return Err(ConcurError::config("need 0 <= u_low < u_high <= 1"));
        }
        if !(0.0..=1.0).contains(&self.h_thresh) {
            return Err(ConcurError::config("h_thresh must be in [0,1]"));
        }
        if !(0.0..=1.0).contains(&self.h_healthy) {
            return Err(ConcurError::config("h_healthy must be in [0,1]"));
        }
        if self.w_min < 1.0 || self.w_init < self.w_min || self.w_max < self.w_init {
            return Err(ConcurError::config("need 1 <= w_min <= w_init <= w_max"));
        }
        Ok(())
    }
}

/// How evicted KV is handled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionMode {
    /// Discard and recompute on next use (vanilla SGLang).
    Discard,
    /// Offload to CPU memory, reload over the host link (HiCache).
    Offload,
}

/// Which KV lifetime policy orders the radix tree's eviction queue
/// (mirrored into `engine::radix::KvLifetimePolicy`; the config layer
/// cannot depend on the engine).  `Lru` is the default and is
/// bit-identical to the pre-policy tree; the other two reorder *which*
/// KV is evicted first, never *whether* an admission fits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLifetimeMode {
    /// Recency only (the classic ordered-LRU index).
    Lru,
    /// KVFlow-style freshness: agents closest to their next execution
    /// (fewest remaining workflow steps) are evicted last; finished
    /// agents with no pending workflow consumers are evicted first.
    StepsToExecution,
    /// Continuum-style tool-TTL pinning: a finished step's KV is pinned
    /// until the issuing agent's expected tool latency elapses on the
    /// simulation clock (the agent is about to return for it), expiring
    /// lazily at eviction time.
    ToolTtl,
}

impl KvLifetimeMode {
    pub fn name(&self) -> &'static str {
        match self {
            KvLifetimeMode::Lru => "lru",
            KvLifetimeMode::StepsToExecution => "steps-to-execution",
            KvLifetimeMode::ToolTtl => "tool-ttl",
        }
    }
}

/// Third (NVMe-class) KV memory tier below the CPU tier.  When enabled,
/// `trim_cpu` demotes CPU-resident prefixes into a storage-resident
/// extent map instead of dropping them, and the admit path may read them
/// back over a contended [`StorageLink`](crate::costmodel::StorageLink)
/// (lower bandwidth, higher per-op latency than the host link).
/// Disabled by default and differential-tested inert: with the tier off
/// the engine is bit-identical to the two-tier hierarchy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StorageTierConfig {
    pub enabled: bool,
    /// Extent-map capacity in tokens; the stalest extents are dropped
    /// deterministically once exceeded.
    pub capacity_tokens: u64,
    /// Aggregate storage read/write bandwidth in GB/s (NVMe-class; the
    /// sweep axis of `concur repro storage`).
    pub bandwidth_gbps: f64,
    /// CPU-tier cap override in tokens; `0` derives the cap from the
    /// cluster spec (2 TB of host DRAM per node) as always.  Sim-scale
    /// workloads never fill terabytes of host memory, so sweeps that
    /// want demotion pressure shrink the middle tier through this knob.
    pub cpu_tier_tokens: u64,
}

impl Default for StorageTierConfig {
    fn default() -> StorageTierConfig {
        StorageTierConfig {
            enabled: false,
            capacity_tokens: 4_000_000,
            bandwidth_gbps: 6.0,
            cpu_tier_tokens: 0,
        }
    }
}

impl StorageTierConfig {
    /// The default configuration with the storage tier switched on.
    pub fn on() -> StorageTierConfig {
        StorageTierConfig { enabled: true, ..StorageTierConfig::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(()); // dormant knobs are valid, whatever they say
        }
        if self.capacity_tokens == 0 {
            return Err(ConcurError::config("storage_tier.capacity_tokens must be > 0"));
        }
        if self.bandwidth_gbps <= 0.0 {
            return Err(ConcurError::config("storage_tier.bandwidth_gbps must be > 0"));
        }
        Ok(())
    }
}

/// How the engine serves a prefix that is resident only in the storage
/// tier: read it back over the storage link, re-prefill it from scratch,
/// or let the per-request cost comparison decide (DualPath, PAPERS.md).
/// Dormant unless `storage_tier.enabled` — without a storage tier there
/// is nothing to reload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualPathMode {
    /// Always read storage-resident prefixes back (HiCache extended
    /// down-stack — collapses when the storage link congests).
    AlwaysReload,
    /// Never read storage — re-prefill the missing prefix (pays the
    /// quadratic attention term however idle the link is).
    AlwaysRecompute,
    /// Per-request argmin of modeled storage-read time vs modeled
    /// prefill-FLOPs time for the missing span.
    DualPath,
}

impl DualPathMode {
    pub fn name(&self) -> &'static str {
        match self {
            DualPathMode::AlwaysReload => "always-reload",
            DualPathMode::AlwaysRecompute => "always-recompute",
            DualPathMode::DualPath => "dual-path",
        }
    }
}

/// Serving-engine substrate parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Radix-tree / allocator page size in tokens (SGLang uses 16..64).
    pub page_size: u32,
    /// Max prompt tokens prefilled per sequence per iteration.
    pub prefill_chunk: u32,
    /// Engine-internal cap on concurrently running sequences (its batch
    /// capacity); admission control sits *in front* of this.
    pub max_running: usize,
    /// Hit-rate observation window (requests) for telemetry + `H_t`.
    pub hit_window: usize,
    pub eviction: EvictionMode,
    /// KV lifetime policy ordering the eviction queue (`Lru` = the
    /// pre-policy tree, bit-identical).
    pub kv_lifetime: KvLifetimeMode,
    /// Fraction of the pool decode steps must keep free to allocate new
    /// tokens (headroom before forced eviction).
    pub decode_headroom: f64,
    /// NVMe-class capacity tier below the CPU tier (off by default).
    pub storage_tier: StorageTierConfig,
    /// Reload-vs-recompute policy for storage-resident prefixes (dormant
    /// while the storage tier is off).
    pub dual_path: DualPathMode,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            page_size: 16,
            prefill_chunk: 4096,
            max_running: 1024,
            hit_window: 64,
            eviction: EvictionMode::Discard,
            kv_lifetime: KvLifetimeMode::Lru,
            decode_headroom: 0.02,
            storage_tier: StorageTierConfig::default(),
            dual_path: DualPathMode::AlwaysReload,
        }
    }
}

/// Workflow-graph workload shape (`agent::workload::workflow_fleet`).
/// When enabled, the fleet is no longer independent ReAct agents but a
/// set of seeded planner→worker DAGs: each graph has a planner whose
/// first step *produces* a shared intermediate context, fan-out workers
/// whose prompts embed that context byte-identically (mid-prompt, chunk
/// aligned), and — for the map-reduce share — a reducer that joins on
/// every worker.  Nodes are released in topological order through the
/// existing slot path: a worker becomes admissible only when its planner
/// finishes, a reducer only when all its workers have.  Disabled by
/// default and differential-tested inert: the closed-batch fleet is
/// bit-identical to the pre-workflow generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkflowConfig {
    pub enabled: bool,
    /// Number of independent workflow graphs in the fleet (the fleet
    /// size is derived: planner + fan-out + optional reducer per graph;
    /// `n_agents` is ignored in workflow mode).
    pub graphs: usize,
    /// Fan-out workers per planner: uniform in [min, max].
    pub fanout_min: u32,
    pub fanout_max: u32,
    /// Fraction of graphs shaped map-reduce (fan-out *and* fan-in
    /// through a reducer); the rest are plain planner→worker fan-outs.
    pub map_reduce_share: f64,
    /// Tokens of planner-produced shared context injected into every
    /// consumer prompt (byte-identical across the graph's consumers).
    pub shared_context_tokens: u32,
    /// The shared context is padded to start on a multiple of this many
    /// tokens in every prompt that embeds it, so content-hash chunking
    /// (`prefix_tier.hash_chunk_tokens`) sees identical aligned chunks.
    pub align_tokens: u32,
    /// Seed of the graph-shape draws (independent of the workload seed).
    pub seed: u64,
}

impl Default for WorkflowConfig {
    fn default() -> WorkflowConfig {
        WorkflowConfig {
            enabled: false,
            graphs: 8,
            fanout_min: 2,
            fanout_max: 4,
            map_reduce_share: 0.5,
            shared_context_tokens: 384,
            align_tokens: 256,
            seed: 13,
        }
    }
}

impl WorkflowConfig {
    /// The default configuration with workflow workloads switched on.
    pub fn on() -> WorkflowConfig {
        WorkflowConfig { enabled: true, ..WorkflowConfig::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(()); // dormant knobs are valid, whatever they say
        }
        if self.graphs == 0 {
            return Err(ConcurError::config("workflow.graphs must be > 0"));
        }
        if self.fanout_min == 0 || self.fanout_min > self.fanout_max {
            return Err(ConcurError::config(
                "need 1 <= workflow.fanout_min <= workflow.fanout_max",
            ));
        }
        if !(0.0..=1.0).contains(&self.map_reduce_share) {
            return Err(ConcurError::config(
                "workflow.map_reduce_share must be in [0,1]",
            ));
        }
        if self.shared_context_tokens == 0 {
            return Err(ConcurError::config(
                "workflow.shared_context_tokens must be > 0 (a workflow \
                 whose members share nothing is just the plain fleet)",
            ));
        }
        if self.align_tokens == 0 {
            return Err(ConcurError::config("workflow.align_tokens must be > 0"));
        }
        Ok(())
    }
}

/// ReAct workload shape (calibrated to Fig. 1a growth curves).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_agents: usize,
    /// ReAct steps per agent: uniform in [min, max].
    pub steps_min: u32,
    pub steps_max: u32,
    /// Shared system-prompt tokens (common radix prefix across agents of
    /// the same family).
    pub system_prompt_tokens: u32,
    /// Number of distinct task families (distinct system prompts).
    pub task_families: u32,
    /// Initial user-prompt tokens: uniform in [min, max].
    pub initial_prompt_min: u32,
    pub initial_prompt_max: u32,
    /// Generated tokens per ReAct step: lognormal-ish via uniform [min,max].
    pub gen_tokens_min: u32,
    pub gen_tokens_max: u32,
    /// Tool-observation tokens appended per step: uniform [min, max].
    pub tool_tokens_min: u32,
    pub tool_tokens_max: u32,
    /// Tool latency: lognormal(mu, sigma) seconds.
    pub tool_latency_mu: f64,
    pub tool_latency_sigma: f64,
    pub seed: u64,
    /// Workflow-graph mode (off by default = independent ReAct agents).
    pub workflow: WorkflowConfig,
}

impl Default for WorkloadConfig {
    fn default() -> WorkloadConfig {
        // Calibrated so context grows from ~1.2k to ~10-12k tokens over
        // 10 steps, matching Fig. 1a.
        WorkloadConfig {
            n_agents: 64,
            steps_min: 8,
            steps_max: 12,
            system_prompt_tokens: 512,
            task_families: 4,
            initial_prompt_min: 400,
            initial_prompt_max: 900,
            gen_tokens_min: 300,
            gen_tokens_max: 700,
            tool_tokens_min: 200,
            tool_tokens_max: 600,
            tool_latency_mu: 0.3,  // e^0.3 ≈ 1.35 s median
            tool_latency_sigma: 0.8,
            seed: 7,
            workflow: WorkflowConfig::default(),
        }
    }
}

impl WorkloadConfig {
    pub fn validate(&self) -> Result<()> {
        if self.n_agents == 0 {
            return Err(ConcurError::config("n_agents must be > 0"));
        }
        if self.steps_min == 0 || self.steps_min > self.steps_max {
            return Err(ConcurError::config("need 1 <= steps_min <= steps_max"));
        }
        if self.initial_prompt_min > self.initial_prompt_max
            || self.gen_tokens_min > self.gen_tokens_max
            || self.tool_tokens_min > self.tool_tokens_max
        {
            return Err(ConcurError::config("min must be <= max for token ranges"));
        }
        if self.gen_tokens_min == 0 {
            return Err(ConcurError::config("gen_tokens_min must be > 0"));
        }
        if self.task_families == 0 {
            return Err(ConcurError::config("task_families must be > 0"));
        }
        self.workflow.validate()?;
        Ok(())
    }
}

/// A complete simulated batch-inference job.
#[derive(Debug, Clone)]
pub struct JobConfig {
    pub cluster: ClusterSpec,
    pub engine: EngineConfig,
    pub workload: WorkloadConfig,
    pub scheduler: SchedulerKind,
    /// Replica count + routing policy (defaults to a single replica).
    pub topology: TopologyConfig,
}

impl JobConfig {
    pub fn validate(&self) -> Result<()> {
        self.workload.validate()?;
        self.topology.validate()?;
        if self.workload.workflow.enabled && self.topology.open_loop.enabled {
            return Err(ConcurError::config(
                "workflow workloads and open-loop traffic are mutually \
                 exclusive: a DAG node's release time is its dependency \
                 edge, not a Poisson arrival",
            ));
        }
        if let SchedulerKind::Concur(p) = &self.scheduler {
            p.validate()?;
        }
        if self.engine.page_size == 0 {
            return Err(ConcurError::config("page_size must be > 0"));
        }
        self.engine.storage_tier.validate()?;
        if self.engine.storage_tier.enabled
            && self.engine.eviction != EvictionMode::Offload
        {
            return Err(ConcurError::config(
                "storage_tier requires eviction = offload: the storage \
                 tier is fed by CPU-tier demotion, which only exists on \
                 the offload path",
            ));
        }
        if self.cluster.kv_pool_tokens() == 0 {
            return Err(ConcurError::config(
                "cluster has no KV pool (weights exceed usable HBM)",
            ));
        }
        Ok(())
    }

    /// Parse from a JSON config document (see `examples/configs/*.json`).
    pub fn from_json(v: &Value) -> Result<JobConfig> {
        let model = match v.get("model").as_str().unwrap_or("qwen3-32b") {
            "qwen3-32b" => ModelSpec::qwen3_32b(),
            "deepseek-v3" => ModelSpec::deepseek_v3(),
            "tiny" => ModelSpec::tiny(),
            other => {
                return Err(ConcurError::config(format!("unknown model '{other}'")))
            }
        };
        let tp = v.get("tp").as_u64().unwrap_or(8) as u32;
        let n_gpus = v.get("n_gpus").as_u64().unwrap_or(tp as u64) as u32;
        let cluster = ClusterSpec::new(GpuSpec::h100(), model, tp, n_gpus);

        let mut workload = WorkloadConfig::default();
        let w = v.get("workload");
        if let Some(n) = w.get("n_agents").as_usize() {
            workload.n_agents = n;
        }
        if let Some(s) = w.get("seed").as_u64() {
            workload.seed = s;
        }
        if let Some(s) = w.get("steps_min").as_u64() {
            workload.steps_min = s as u32;
        }
        if let Some(s) = w.get("steps_max").as_u64() {
            workload.steps_max = s as u32;
        }
        let wf = w.get("workflow");
        if let Some(b) = wf.get("enabled").as_bool() {
            workload.workflow.enabled = b;
        }
        if let Some(n) = wf.get("graphs").as_usize() {
            workload.workflow.graphs = n;
        }
        if let Some(x) = wf.get("fanout_min").as_u64() {
            workload.workflow.fanout_min = u32::try_from(x).map_err(|_| {
                ConcurError::config("workflow.fanout_min out of range (u32)")
            })?;
        }
        if let Some(x) = wf.get("fanout_max").as_u64() {
            workload.workflow.fanout_max = u32::try_from(x).map_err(|_| {
                ConcurError::config("workflow.fanout_max out of range (u32)")
            })?;
        }
        if let Some(x) = wf.get("map_reduce_share").as_f64() {
            workload.workflow.map_reduce_share = x;
        }
        if let Some(x) = wf.get("shared_context_tokens").as_u64() {
            workload.workflow.shared_context_tokens = u32::try_from(x).map_err(|_| {
                ConcurError::config("workflow.shared_context_tokens out of range (u32)")
            })?;
        }
        if let Some(x) = wf.get("align_tokens").as_u64() {
            workload.workflow.align_tokens = u32::try_from(x).map_err(|_| {
                ConcurError::config("workflow.align_tokens out of range (u32)")
            })?;
        }
        if let Some(s) = wf.get("seed").as_u64() {
            workload.workflow.seed = s;
        }

        let mut engine = EngineConfig::default();
        let e = v.get("engine");
        if let Some(p) = e.get("page_size").as_u64() {
            engine.page_size = p as u32;
        }
        if e.get("eviction").as_str() == Some("offload") {
            engine.eviction = EvictionMode::Offload;
        }
        if let Some(k) = e.get("kv_lifetime").as_str() {
            engine.kv_lifetime = match k {
                "lru" => KvLifetimeMode::Lru,
                "steps-to-execution" | "steps_to_execution" | "steps" => {
                    KvLifetimeMode::StepsToExecution
                }
                "tool-ttl" | "tool_ttl" => KvLifetimeMode::ToolTtl,
                other => {
                    return Err(ConcurError::config(format!(
                        "unknown kv_lifetime '{other}'"
                    )))
                }
            };
        }
        let st = e.get("storage_tier");
        if let Some(b) = st.get("enabled").as_bool() {
            engine.storage_tier.enabled = b;
        }
        if let Some(c) = st.get("capacity_tokens").as_u64() {
            engine.storage_tier.capacity_tokens = c;
        }
        if let Some(bw) = st.get("bandwidth_gbps").as_f64() {
            engine.storage_tier.bandwidth_gbps = bw;
        }
        if let Some(c) = st.get("cpu_tier_tokens").as_u64() {
            engine.storage_tier.cpu_tier_tokens = c;
        }
        if let Some(m) = e.get("dual_path").as_str() {
            engine.dual_path = match m {
                "always-reload" | "always_reload" => DualPathMode::AlwaysReload,
                "always-recompute" | "always_recompute" => DualPathMode::AlwaysRecompute,
                "dual-path" | "dual_path" => DualPathMode::DualPath,
                other => {
                    return Err(ConcurError::config(format!(
                        "unknown dual_path '{other}'"
                    )))
                }
            };
        }

        let mut topology = TopologyConfig::default();
        let t = v.get("topology");
        if let Some(n) = t.get("replicas").as_usize() {
            topology.replicas = n;
        }
        if let Some(r) = t.get("router").as_str() {
            topology.router = match r {
                "round-robin" => RouterKind::RoundRobin,
                "least-loaded" => RouterKind::LeastLoaded,
                "cache-affinity" => RouterKind::CacheAffinity,
                "rebalance" | "rebalancing" => RouterKind::Rebalance,
                other => {
                    return Err(ConcurError::config(format!(
                        "unknown router '{other}'"
                    )))
                }
            };
        }
        if let Some(skew) = t.get("tool_skew").as_array() {
            topology.tool_skew = skew
                .iter()
                .map(|v| {
                    v.as_f64().ok_or_else(|| {
                        ConcurError::config("tool_skew entries must be numbers")
                    })
                })
                .collect::<Result<Vec<f64>>>()?;
        }
        if let Some(plan) = t.get("fault_plan").as_array() {
            topology.fault_plan = FaultPlan::from_json_events(plan)?;
        }
        let pt = t.get("prefix_tier");
        if let Some(b) = pt.get("enabled").as_bool() {
            topology.prefix_tier.enabled = b;
        }
        if let Some(x) = pt.get("hot_after").as_u64() {
            topology.prefix_tier.hot_after = u32::try_from(x).map_err(|_| {
                ConcurError::config("prefix_tier.hot_after out of range (u32)")
            })?;
        }
        if let Some(x) = pt.get("budget_tokens").as_u64() {
            topology.prefix_tier.budget_tokens = x;
        }
        if let Some(x) = pt.get("min_prefix_tokens").as_u64() {
            topology.prefix_tier.min_prefix_tokens = u32::try_from(x).map_err(|_| {
                ConcurError::config("prefix_tier.min_prefix_tokens out of range (u32)")
            })?;
        }
        if let Some(x) = pt.get("cool_after_s").as_f64() {
            topology.prefix_tier.cool_after = Micros::from_secs_f64(x);
        }
        if let Some(b) = pt.get("content_hash").as_bool() {
            topology.prefix_tier.content_hash = b;
        }
        if let Some(x) = pt.get("hash_chunk_tokens").as_u64() {
            topology.prefix_tier.hash_chunk_tokens = u32::try_from(x).map_err(|_| {
                ConcurError::config("prefix_tier.hash_chunk_tokens out of range (u32)")
            })?;
        }
        let tr = t.get("transport");
        if let Some(b) = tr.get("enabled").as_bool() {
            topology.transport.enabled = b;
        }
        if let Some(b) = tr.get("delayed_visibility").as_bool() {
            topology.transport.delayed_visibility = b;
        }
        if let Some(b) = tr.get("delta_ship").as_bool() {
            topology.transport.delta_ship = b;
        }
        if let Some(b) = tr.get("drain_handoff").as_bool() {
            topology.transport.drain_handoff = b;
        }
        if let Some(x) = tr.get("fabric_gbps").as_f64() {
            topology.transport.fabric_gbps = x;
        }
        if let Some(x) = tr.get("handoff_budget_tokens").as_u64() {
            topology.transport.handoff_budget_tokens = x;
        }
        if let Some(x) = tr.get("handoff_max_agents").as_u64() {
            topology.transport.handoff_max_agents = usize::try_from(x).map_err(|_| {
                ConcurError::config("transport.handoff_max_agents out of range (usize)")
            })?;
        }
        let ol = t.get("open_loop");
        if let Some(b) = ol.get("enabled").as_bool() {
            topology.open_loop.enabled = b;
        }
        if let Some(x) = ol.get("arrival_rate_per_s").as_f64() {
            topology.open_loop.arrival_rate_per_s = x;
        }
        if let Some(x) = ol.get("diurnal_amplitude").as_f64() {
            topology.open_loop.diurnal_amplitude = x;
        }
        if let Some(x) = ol.get("diurnal_period_s").as_f64() {
            topology.open_loop.diurnal_period_s = x;
        }
        if let Some(x) = ol.get("think_mu").as_f64() {
            topology.open_loop.think_mu = x;
        }
        if let Some(x) = ol.get("think_sigma").as_f64() {
            topology.open_loop.think_sigma = x;
        }
        if let Some(x) = ol.get("patience_s").as_f64() {
            topology.open_loop.patience_s = x;
        }
        if let Some(x) = ol.get("high_priority_share").as_f64() {
            topology.open_loop.high_priority_share = x;
        }
        if let Some(x) = ol.get("slo_ttft_s").as_f64() {
            topology.open_loop.slo_ttft_s = x;
        }
        if let Some(x) = ol.get("slo_step_s").as_f64() {
            topology.open_loop.slo_step_s = x;
        }
        if let Some(b) = ol.get("priority_admission").as_bool() {
            topology.open_loop.priority_admission = b;
        }
        if let Some(b) = ol.get("shed").as_bool() {
            topology.open_loop.shed = b;
        }
        if let Some(x) = ol.get("shed_on_ratio").as_f64() {
            topology.open_loop.shed_on_ratio = x;
        }
        if let Some(x) = ol.get("shed_off_ratio").as_f64() {
            topology.open_loop.shed_off_ratio = x;
        }
        if let Some(x) = ol.get("seed").as_u64() {
            topology.open_loop.seed = x;
        }
        topology.fault_rates = FaultRateConfig::from_json(t.get("fault_rates"))?;

        let scheduler = match v.get("scheduler").as_str().unwrap_or("concur") {
            "sglang" | "uncontrolled" => SchedulerKind::Uncontrolled,
            "request-cap" => SchedulerKind::RequestCap(
                v.get("cap").as_usize().unwrap_or(64),
            ),
            "agent-cap" => {
                SchedulerKind::AgentCap(v.get("cap").as_usize().unwrap_or(64))
            }
            "concur" => {
                let mut p = AimdParams::default();
                let a = v.get("aimd");
                if let Some(x) = a.get("alpha").as_f64() {
                    p.alpha = x;
                }
                if let Some(x) = a.get("beta").as_f64() {
                    p.beta = x;
                }
                if let Some(x) = a.get("u_low").as_f64() {
                    p.u_low = x;
                }
                if let Some(x) = a.get("u_high").as_f64() {
                    p.u_high = x;
                }
                if let Some(x) = a.get("h_thresh").as_f64() {
                    p.h_thresh = x;
                }
                SchedulerKind::Concur(p)
            }
            other => {
                return Err(ConcurError::config(format!(
                    "unknown scheduler '{other}'"
                )))
            }
        };

        let job = JobConfig { cluster, engine, workload, scheduler, topology };
        job.validate()?;
        Ok(job)
    }

    pub fn from_json_file(path: &std::path::Path) -> Result<JobConfig> {
        let text = std::fs::read_to_string(path)?;
        JobConfig::from_json(&Value::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_aimd_matches_paper() {
        let p = AimdParams::default();
        assert_eq!(p.alpha, 2.0);
        assert_eq!(p.beta, 0.5);
        assert_eq!(p.u_low, 0.2);
        assert_eq!(p.u_high, 0.5);
        assert_eq!(p.h_thresh, 0.2);
        p.validate().unwrap();
    }

    #[test]
    fn aimd_validation_rejects_bad_params() {
        let mut p = AimdParams::default();
        p.beta = 1.5;
        assert!(p.validate().is_err());
        let mut p = AimdParams::default();
        p.u_low = 0.7; // > u_high
        assert!(p.validate().is_err());
        let mut p = AimdParams::default();
        p.w_init = 0.5;
        assert!(p.validate().is_err());
    }

    #[test]
    fn workload_validation() {
        let mut w = WorkloadConfig::default();
        w.validate().unwrap();
        w.n_agents = 0;
        assert!(w.validate().is_err());
        let mut w = WorkloadConfig::default();
        w.steps_min = 20;
        w.steps_max = 10;
        assert!(w.validate().is_err());
    }

    #[test]
    fn json_config_roundtrip() {
        let text = r#"{
            "model": "qwen3-32b", "tp": 2, "n_gpus": 2,
            "scheduler": "concur",
            "aimd": {"alpha": 4, "u_high": 0.6},
            "workload": {"n_agents": 128, "seed": 3},
            "engine": {"page_size": 32, "eviction": "offload"}
        }"#;
        let v = Value::parse(text).unwrap();
        let job = JobConfig::from_json(&v).unwrap();
        assert_eq!(job.cluster.tp, 2);
        assert_eq!(job.workload.n_agents, 128);
        assert_eq!(job.engine.page_size, 32);
        assert_eq!(job.engine.eviction, EvictionMode::Offload);
        match job.scheduler {
            SchedulerKind::Concur(p) => {
                assert_eq!(p.alpha, 4.0);
                assert_eq!(p.u_high, 0.6);
                assert_eq!(p.beta, 0.5); // default preserved
            }
            _ => panic!("wrong scheduler"),
        }
    }

    #[test]
    fn topology_defaults_to_single_replica() {
        let t = TopologyConfig::default();
        assert_eq!(t.replicas, 1);
        assert_eq!(t.router, RouterKind::CacheAffinity);
        t.validate().unwrap();
        assert!(TopologyConfig { replicas: 0, ..t }.validate().is_err());
    }

    #[test]
    fn json_config_parses_storage_tier() {
        let text = r#"{
            "model": "qwen3-32b", "tp": 2,
            "engine": {
                "eviction": "offload",
                "storage_tier": {
                    "enabled": true,
                    "capacity_tokens": 500000,
                    "bandwidth_gbps": 3.5,
                    "cpu_tier_tokens": 65536
                },
                "dual_path": "dual-path"
            }
        }"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        assert!(job.engine.storage_tier.enabled);
        assert_eq!(job.engine.storage_tier.capacity_tokens, 500_000);
        assert_eq!(job.engine.storage_tier.bandwidth_gbps, 3.5);
        assert_eq!(job.engine.storage_tier.cpu_tier_tokens, 65_536);
        assert_eq!(job.engine.dual_path, DualPathMode::DualPath);

        let bad = r#"{"model": "qwen3-32b", "engine": {"dual_path": "sometimes"}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());

        // The checked-in example stays loadable (and valid: offload
        // eviction, tier on, squeezed CPU cap).
        let example = std::path::Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/configs/storage_tier.json"
        ));
        let job = JobConfig::from_json_file(example).unwrap();
        assert!(job.engine.storage_tier.enabled);
        assert_eq!(job.engine.eviction, EvictionMode::Offload);
        assert_eq!(job.engine.storage_tier.cpu_tier_tokens, 48_000);
    }

    #[test]
    fn storage_tier_requires_offload_eviction() {
        let mut job = JobConfig {
            cluster: ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), 2, 2),
            engine: EngineConfig {
                storage_tier: StorageTierConfig::on(),
                ..EngineConfig::default()
            },
            workload: WorkloadConfig::default(),
            scheduler: SchedulerKind::Uncontrolled,
            topology: TopologyConfig::default(),
        };
        // Discard eviction never demotes to CPU, so there is nothing to
        // feed the storage tier from.
        assert!(job.validate().is_err());
        job.engine.eviction = EvictionMode::Offload;
        job.validate().unwrap();
        // Dormant knobs are valid whatever they say.
        job.engine.storage_tier = StorageTierConfig {
            enabled: false,
            capacity_tokens: 0,
            bandwidth_gbps: -1.0,
            cpu_tier_tokens: 0,
        };
        job.engine.eviction = EvictionMode::Discard;
        job.validate().unwrap();
        // Enabled knobs are range-checked.
        let mut bad = StorageTierConfig::on();
        bad.capacity_tokens = 0;
        assert!(bad.validate().is_err());
        bad = StorageTierConfig::on();
        bad.bandwidth_gbps = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn json_config_parses_topology() {
        let text = r#"{
            "model": "qwen3-32b", "tp": 2,
            "topology": {"replicas": 4, "router": "least-loaded"}
        }"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        assert_eq!(job.topology.replicas, 4);
        assert_eq!(job.topology.router, RouterKind::LeastLoaded);
        assert!(job.topology.fault_plan.is_empty());
        assert!(job.topology.tool_skew.is_empty());

        let bad = r#"{"topology": {"router": "sticky"}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn json_config_parses_faults_and_skew() {
        let text = r#"{
            "model": "qwen3-32b", "tp": 2,
            "topology": {
                "replicas": 3, "router": "rebalance",
                "tool_skew": [1.0, 1.5, 2.0],
                "fault_plan": [
                    {"at_s": 120, "replica": 0, "kind": "kill"},
                    {"at_s": 240, "replica": 0, "kind": "revive"}
                ]
            }
        }"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        assert_eq!(job.topology.router, RouterKind::Rebalance);
        assert_eq!(job.topology.tool_skew, vec![1.0, 1.5, 2.0]);
        assert_eq!(job.topology.fault_plan.events().len(), 2);
        assert_eq!(
            job.topology.fault_plan.events()[0],
            FaultEvent::kill(0, crate::core::Micros(120_000_000))
        );

        // Validation runs inside from_json: killing the whole fleet fails.
        let bad = r#"{
            "topology": {"replicas": 1,
                         "fault_plan": [{"at_s": 1, "replica": 0, "kind": "kill"}]}
        }"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn topology_validates_skew_shape() {
        let mut t = TopologyConfig { replicas: 2, ..TopologyConfig::default() };
        t.tool_skew = vec![1.0, 2.0];
        t.validate().unwrap();
        t.tool_skew = vec![1.0];
        assert!(t.validate().is_err(), "length mismatch must be rejected");
        t.tool_skew = vec![1.0, 0.0];
        assert!(t.validate().is_err(), "non-positive skew must be rejected");
        t.tool_skew = vec![1.0, f64::NAN];
        assert!(t.validate().is_err(), "non-finite skew must be rejected");
    }

    #[test]
    fn prefix_tier_defaults_off_and_validates() {
        let t = TopologyConfig::default();
        assert!(!t.prefix_tier.enabled, "the tier must be opt-in");
        t.validate().unwrap();
        // Disabled configs never fail validation, whatever the knobs say.
        let weird = TopologyConfig {
            prefix_tier: PrefixTierConfig {
                hot_after: 0,
                min_prefix_tokens: 0,
                ..PrefixTierConfig::default()
            },
            ..TopologyConfig::default()
        };
        weird.validate().unwrap();
        // Enabled configs are checked.
        let mut on =
            TopologyConfig { prefix_tier: PrefixTierConfig::on(), ..TopologyConfig::default() };
        on.validate().unwrap();
        on.prefix_tier.hot_after = 1;
        assert!(on.validate().is_err(), "hot_after < 2 must be rejected");
        on.prefix_tier = PrefixTierConfig { budget_tokens: 8, ..PrefixTierConfig::on() };
        assert!(on.validate().is_err(), "budget below one minimal prefix");
    }

    #[test]
    fn json_config_parses_prefix_tier() {
        let text = r#"{
            "model": "qwen3-32b", "tp": 2,
            "topology": {
                "replicas": 4,
                "prefix_tier": {"enabled": true, "hot_after": 5,
                                 "budget_tokens": 8192,
                                 "min_prefix_tokens": 128,
                                 "cool_after_s": 60}
            }
        }"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        let pt = job.topology.prefix_tier;
        assert!(pt.enabled);
        assert_eq!(pt.hot_after, 5);
        assert_eq!(pt.budget_tokens, 8192);
        assert_eq!(pt.min_prefix_tokens, 128);
        assert_eq!(pt.cool_after, Micros(60_000_000));

        // Validation runs inside from_json.
        let bad = r#"{"topology": {"prefix_tier": {"enabled": true, "hot_after": 1}}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
        // Out-of-range u32 knobs are rejected, not silently wrapped.
        let wrap = r#"{"topology": {"prefix_tier": {"hot_after": 4294967298}}}"#;
        assert!(JobConfig::from_json(&Value::parse(wrap).unwrap()).is_err());
        // A zero cool-down would churn the tier forever; rejected.
        let churn = r#"{"topology": {"prefix_tier": {"enabled": true, "cool_after_s": 0}}}"#;
        assert!(JobConfig::from_json(&Value::parse(churn).unwrap()).is_err());
    }

    #[test]
    fn transport_defaults_off_and_validates() {
        let t = TopologyConfig::default();
        assert!(!t.transport.enabled, "the transport must be opt-in");
        t.validate().unwrap();
        // Disabled transport with non-flag knobs changed is still valid
        // (the knobs are dormant, not contradictory)...
        let dormant = TopologyConfig {
            transport: TransportConfig {
                fabric_gbps: 1.0,
                handoff_budget_tokens: 7,
                handoff_max_agents: 1,
                ..TransportConfig::default()
            },
            ..TopologyConfig::default()
        };
        dormant.validate().unwrap();
        // ...but feature flags without `enabled` are rejected loudly.
        for bad in [
            TransportConfig { delayed_visibility: true, ..TransportConfig::default() },
            TransportConfig { delta_ship: true, ..TransportConfig::default() },
            TransportConfig { drain_handoff: true, ..TransportConfig::default() },
        ] {
            assert!(bad.validate().is_err(), "feature flag must require enabled");
        }
        // Enabled configs are checked.
        TransportConfig::on().validate().unwrap();
        let mut on = TransportConfig::on();
        on.fabric_gbps = 0.0;
        assert!(on.validate().is_err(), "zero fabric bandwidth must be rejected");
        let mut on = TransportConfig::on();
        on.drain_handoff = true;
        on.handoff_budget_tokens = 0;
        assert!(on.validate().is_err(), "handoff with zero budget must be rejected");
        let mut on = TransportConfig::on();
        on.drain_handoff = true;
        on.handoff_max_agents = 0;
        assert!(on.validate().is_err(), "handoff with zero agents must be rejected");
    }

    #[test]
    fn json_config_parses_transport() {
        let text = r#"{
            "model": "qwen3-32b", "tp": 2,
            "topology": {
                "replicas": 4,
                "transport": {"enabled": true, "delayed_visibility": true,
                               "delta_ship": true, "drain_handoff": true,
                               "fabric_gbps": 25.0,
                               "handoff_budget_tokens": 4096,
                               "handoff_max_agents": 3}
            }
        }"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        let tr = job.topology.transport;
        assert!(tr.enabled && tr.delayed_visibility && tr.delta_ship && tr.drain_handoff);
        assert_eq!(tr.fabric_gbps, 25.0);
        assert_eq!(tr.handoff_budget_tokens, 4096);
        assert_eq!(tr.handoff_max_agents, 3);

        // Validation runs inside from_json: features without `enabled`.
        let bad = r#"{"topology": {"transport": {"delta_ship": true}}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn open_loop_defaults_off_and_validates() {
        let t = TopologyConfig::default();
        assert!(!t.open_loop.enabled, "open-loop traffic must be opt-in");
        assert!(!t.fault_rates.enabled, "stochastic faults must be opt-in");
        t.validate().unwrap();
        // Dormant nonsense knobs are valid while disabled...
        let weird = TopologyConfig {
            open_loop: OpenLoopConfig {
                arrival_rate_per_s: -3.0,
                shed_on_ratio: 0.0,
                high_priority_share: 9.0,
                ..OpenLoopConfig::default()
            },
            ..TopologyConfig::default()
        };
        weird.validate().unwrap();
        // ...and rejected once enabled.
        let mut on = weird;
        on.open_loop.enabled = true;
        assert!(on.validate().is_err());
        OpenLoopConfig::on().validate().unwrap();
        let mut bad = OpenLoopConfig::on();
        bad.shed_off_ratio = bad.shed_on_ratio; // no hysteresis band
        assert!(bad.validate().is_err());
        let mut bad = OpenLoopConfig::on();
        bad.slo_ttft_s = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = OpenLoopConfig::on();
        bad.diurnal_amplitude = 1.5;
        assert!(bad.validate().is_err());
        // Patience 0 is legal: infinitely patient sessions never abandon.
        let mut ok = OpenLoopConfig::on();
        ok.patience_s = 0.0;
        ok.validate().unwrap();
    }

    #[test]
    fn json_config_parses_open_loop_and_fault_rates() {
        let text = r#"{
            "model": "qwen3-32b", "tp": 2,
            "topology": {
                "replicas": 3, "router": "rebalance",
                "open_loop": {"enabled": true, "arrival_rate_per_s": 2.5,
                               "diurnal_amplitude": 0.3, "diurnal_period_s": 90,
                               "patience_s": 40, "high_priority_share": 0.4,
                               "slo_ttft_s": 20, "slo_step_s": 35,
                               "priority_admission": false, "shed": false,
                               "seed": 77},
                "fault_rates": {"enabled": true, "mtbf_s": 200, "mttr_s": 30}
            }
        }"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        let ol = job.topology.open_loop;
        assert!(ol.enabled);
        assert_eq!(ol.arrival_rate_per_s, 2.5);
        assert_eq!(ol.diurnal_amplitude, 0.3);
        assert_eq!(ol.patience_s, 40.0);
        assert_eq!(ol.high_priority_share, 0.4);
        assert!(!ol.priority_admission && !ol.shed);
        assert_eq!(ol.seed, 77);
        assert_eq!(ol.think_mu, OpenLoopConfig::default().think_mu, "default preserved");
        let fr = job.topology.fault_rates;
        assert!(fr.enabled);
        assert_eq!(fr.mtbf_s, 200.0);
        assert_eq!(fr.mttr_s, 30.0);
        assert_eq!(fr.drain_share, FaultRateConfig::default().drain_share);

        // Validation runs inside from_json.
        let bad = r#"{"topology": {"open_loop": {"enabled": true, "arrival_rate_per_s": 0}}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
        let bad = r#"{"topology": {"fault_rates": {"enabled": true, "mtbf_s": -5}}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
    }

    /// The checked-in broken fixture fails at load time, and the error
    /// names the offending fault event (kind + replica + instant), not a
    /// downstream replay symptom.
    #[test]
    fn bad_fault_plan_fixture_fails_at_load_naming_the_event() {
        let path = std::path::Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/configs/bad_fault_plan.json"
        ));
        let err = JobConfig::from_json_file(path).unwrap_err().to_string();
        assert!(err.contains("drain replica 9"), "{err}");
        assert!(err.contains("topology has 4 replicas"), "{err}");
        // The good sibling fixture still loads cleanly.
        let good = std::path::Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/configs/faulty_cluster.json"
        ));
        JobConfig::from_json_file(good).unwrap();
    }

    #[test]
    fn workflow_defaults_off_and_validates() {
        let w = WorkloadConfig::default();
        assert!(!w.workflow.enabled, "workflow mode must be opt-in");
        w.validate().unwrap();
        // Dormant nonsense knobs are valid while disabled...
        let weird = WorkloadConfig {
            workflow: WorkflowConfig {
                graphs: 0,
                fanout_min: 9,
                fanout_max: 2,
                shared_context_tokens: 0,
                ..WorkflowConfig::default()
            },
            ..WorkloadConfig::default()
        };
        weird.validate().unwrap();
        // ...and rejected once enabled.
        let mut on = weird;
        on.workflow.enabled = true;
        assert!(on.validate().is_err());
        WorkflowConfig::on().validate().unwrap();
        let mut bad = WorkflowConfig::on();
        bad.fanout_min = 0;
        assert!(bad.validate().is_err());
        let mut bad = WorkflowConfig::on();
        bad.map_reduce_share = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = WorkflowConfig::on();
        bad.align_tokens = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn workflow_excludes_open_loop() {
        let mut job = JobConfig {
            cluster: ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), 2, 2),
            engine: EngineConfig::default(),
            workload: WorkloadConfig {
                workflow: WorkflowConfig::on(),
                ..WorkloadConfig::default()
            },
            scheduler: SchedulerKind::Uncontrolled,
            topology: TopologyConfig::default(),
        };
        job.validate().unwrap();
        job.topology.open_loop = OpenLoopConfig::on();
        assert!(job.validate().is_err(), "workflow + open_loop must be rejected");
    }

    #[test]
    fn kv_lifetime_defaults_to_lru_and_parses() {
        assert_eq!(EngineConfig::default().kv_lifetime, KvLifetimeMode::Lru);
        assert_eq!(KvLifetimeMode::Lru.name(), "lru");
        assert_eq!(KvLifetimeMode::StepsToExecution.name(), "steps-to-execution");
        assert_eq!(KvLifetimeMode::ToolTtl.name(), "tool-ttl");
        let text = r#"{
            "model": "qwen3-32b", "tp": 2,
            "engine": {"kv_lifetime": "steps-to-execution"}
        }"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        assert_eq!(job.engine.kv_lifetime, KvLifetimeMode::StepsToExecution);
        let text = r#"{"model": "tiny", "engine": {"kv_lifetime": "tool_ttl"}}"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        assert_eq!(job.engine.kv_lifetime, KvLifetimeMode::ToolTtl);
        let bad = r#"{"engine": {"kv_lifetime": "mru"}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn json_config_parses_workflow_and_content_hash() {
        let text = r#"{
            "model": "qwen3-32b", "tp": 2,
            "workload": {"workflow": {"enabled": true, "graphs": 5,
                                       "fanout_min": 3, "fanout_max": 6,
                                       "map_reduce_share": 0.25,
                                       "shared_context_tokens": 512,
                                       "align_tokens": 128, "seed": 21}},
            "topology": {"prefix_tier": {"enabled": true, "content_hash": true,
                                          "hash_chunk_tokens": 128}}
        }"#;
        let job = JobConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        let wf = job.workload.workflow;
        assert!(wf.enabled);
        assert_eq!(wf.graphs, 5);
        assert_eq!(wf.fanout_min, 3);
        assert_eq!(wf.fanout_max, 6);
        assert_eq!(wf.map_reduce_share, 0.25);
        assert_eq!(wf.shared_context_tokens, 512);
        assert_eq!(wf.align_tokens, 128);
        assert_eq!(wf.seed, 21);
        let pt = job.topology.prefix_tier;
        assert!(pt.content_hash);
        assert_eq!(pt.hash_chunk_tokens, 128);

        // Validation runs inside from_json.
        let bad = r#"{"workload": {"workflow": {"enabled": true, "graphs": 0}}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
        let bad = r#"{"topology": {"prefix_tier": {"enabled": true,
                       "content_hash": true, "hash_chunk_tokens": 0}}}"#;
        assert!(JobConfig::from_json(&Value::parse(bad).unwrap()).is_err());
        // Out-of-range u32 knobs are rejected, not silently wrapped.
        let wrap = r#"{"workload": {"workflow": {"fanout_max": 4294967298}}}"#;
        assert!(JobConfig::from_json(&Value::parse(wrap).unwrap()).is_err());
    }

    #[test]
    fn router_names() {
        assert_eq!(RouterKind::RoundRobin.name(), "round-robin");
        assert_eq!(RouterKind::LeastLoaded.name(), "least-loaded");
        assert_eq!(RouterKind::CacheAffinity.name(), "cache-affinity");
        assert_eq!(RouterKind::Rebalance.name(), "rebalance");
    }

    #[test]
    fn json_config_rejects_unknown_model() {
        let v = Value::parse(r#"{"model": "gpt-oss"}"#).unwrap();
        assert!(JobConfig::from_json(&v).is_err());
    }

    #[test]
    fn scheduler_names() {
        assert_eq!(SchedulerKind::Uncontrolled.name(), "sglang");
        assert_eq!(SchedulerKind::RequestCap(64).name(), "request-cap(64)");
        assert_eq!(
            SchedulerKind::Concur(AimdParams::default()).name(),
            "concur"
        );
    }
}
