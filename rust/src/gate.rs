//! CI perf gate: compare a `BENCH_*.json` dump against checked-in
//! per-metric thresholds and fail loudly on regression.
//!
//! Perf work without a gate silently rots: the nightly bench artifacts
//! record the trajectory, but nobody reads artifacts, so a 2× regression
//! lands and ages until it is archaeology.  The gate turns the dump into
//! a verdict: `concur bench gate --bench BENCH_hotpath.json --thresholds
//! ci/perf_thresholds.json --profile nightly` exits 0 when every metric
//! is within its allowance, 1 on any breach (printing a per-metric
//! table), and 2 when the inputs themselves are unreadable — so a CI
//! wiring bug is distinguishable from a real regression.
//!
//! Threshold schema (`ci/perf_thresholds.json`):
//!
//! ```json
//! {
//!   "schema": 1,
//!   "profiles": {
//!     "pr":      { "metric-name": { "kind": "ceiling", "baseline": 1000.0,
//!                                   "allowed_regression_pct": 100.0 } },
//!     "nightly": { "metric-name": { "kind": "ceiling", "baseline": 1000.0,
//!                                   "allowed_regression_pct": 35.0 } }
//!   }
//! }
//! ```
//!
//! `kind` is `"ceiling"` (lower is better — latencies; breach when value
//! exceeds `baseline × (1 + pct/100)`) or `"floor"` (higher is better —
//! throughputs; breach when value drops below `baseline × (1 − pct/100)`).
//! A metric listed in the profile but absent from the bench dump is a
//! breach (a silently dropped bench must not pass the gate); a bench
//! metric with no threshold is reported as uncovered but does not fail.
//! Re-baselining is an ordinary reviewed edit to the JSON — see
//! OPERATIONS.md.

use std::collections::BTreeMap;

use crate::core::json::Value;
use crate::core::{ConcurError, Result};

/// Direction of a metric's "better" axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThresholdKind {
    /// Higher is better (e.g. tokens/s); breach when value < limit.
    Floor,
    /// Lower is better (e.g. ns/op, p99 step time); breach when value > limit.
    Ceiling,
}

/// One metric's checked-in expectation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Threshold {
    pub kind: ThresholdKind,
    pub baseline: f64,
    pub allowed_regression_pct: f64,
}

impl Threshold {
    /// The worst acceptable value.
    pub fn limit(&self) -> f64 {
        match self.kind {
            ThresholdKind::Floor => self.baseline * (1.0 - self.allowed_regression_pct / 100.0),
            ThresholdKind::Ceiling => self.baseline * (1.0 + self.allowed_regression_pct / 100.0),
        }
    }

    fn breached_by(&self, value: f64) -> bool {
        match self.kind {
            ThresholdKind::Floor => value < self.limit(),
            ThresholdKind::Ceiling => value > self.limit(),
        }
    }
}

/// A named set of thresholds (`pr`, `nightly`, ...).
pub type Profile = BTreeMap<String, Threshold>;

/// Parse the thresholds file into its profiles.
pub fn parse_thresholds(v: &Value) -> Result<BTreeMap<String, Profile>> {
    if v.get("schema").as_u64() != Some(1) {
        return Err(ConcurError::config(
            "thresholds file: missing or unsupported 'schema' (expected 1)",
        ));
    }
    let profiles = v.get("profiles").as_object().ok_or_else(|| {
        ConcurError::config("thresholds file: missing 'profiles' object")
    })?;
    let mut out = BTreeMap::new();
    for (pname, pval) in profiles {
        let metrics = pval.as_object().ok_or_else(|| {
            ConcurError::config(format!("thresholds profile '{pname}' is not an object"))
        })?;
        let mut profile = Profile::new();
        for (metric, tval) in metrics {
            let kind = match tval.req_str("kind")? {
                "floor" => ThresholdKind::Floor,
                "ceiling" => ThresholdKind::Ceiling,
                other => {
                    return Err(ConcurError::config(format!(
                        "threshold '{pname}/{metric}': unknown kind {other:?} \
                         (expected \"floor\" or \"ceiling\")"
                    )))
                }
            };
            let baseline = tval.req_f64("baseline")?;
            let pct = tval.req_f64("allowed_regression_pct")?;
            if !(baseline.is_finite() && baseline > 0.0) {
                return Err(ConcurError::config(format!(
                    "threshold '{pname}/{metric}': baseline must be finite and positive"
                )));
            }
            if !(pct.is_finite() && pct >= 0.0) || (kind == ThresholdKind::Floor && pct >= 100.0) {
                return Err(ConcurError::config(format!(
                    "threshold '{pname}/{metric}': bad allowed_regression_pct"
                )));
            }
            profile.insert(
                metric.clone(),
                Threshold { kind, baseline, allowed_regression_pct: pct },
            );
        }
        out.insert(pname.clone(), profile);
    }
    Ok(out)
}

/// Parse a `BENCH_*.json` dump (flat `{name -> number}`; non-numeric
/// entries are ignored so future nested dumps don't break old gates).
pub fn parse_bench(v: &Value) -> Result<BTreeMap<String, f64>> {
    let obj = v
        .as_object()
        .ok_or_else(|| ConcurError::config("bench file: top level is not an object"))?;
    Ok(obj
        .iter()
        .filter_map(|(k, v)| v.as_f64().map(|n| (k.clone(), n)))
        .collect())
}

/// One metric's verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct GateRow {
    pub metric: String,
    pub threshold: Threshold,
    /// Measured value; `None` when the bench dump lacks the metric.
    pub value: Option<f64>,
    pub breached: bool,
}

/// Full gate outcome: one row per threshold plus the bench metrics no
/// threshold covers (informational).
#[derive(Debug, Clone, PartialEq)]
pub struct GateReport {
    pub profile: String,
    pub rows: Vec<GateRow>,
    pub uncovered: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.rows.iter().all(|r| !r.breached)
    }

    /// Human-readable per-metric table (stdout in CI).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "perf gate · profile '{}': {}\n\n",
            self.profile,
            if self.passed() { "PASS" } else { "BREACH" }
        ));
        out.push_str(&format!(
            "{:<44} {:>6} {:>14} {:>14} {:>14}  {}\n",
            "metric", "kind", "baseline", "limit", "value", "status"
        ));
        for r in &self.rows {
            let kind = match r.threshold.kind {
                ThresholdKind::Floor => "floor",
                ThresholdKind::Ceiling => "ceil",
            };
            let value = match r.value {
                Some(v) => format!("{v:.1}"),
                None => "missing".to_string(),
            };
            let status = if r.breached { "BREACH" } else { "ok" };
            out.push_str(&format!(
                "{:<44} {:>6} {:>14.1} {:>14.1} {:>14}  {}\n",
                r.metric,
                kind,
                r.threshold.baseline,
                r.threshold.limit(),
                value,
                status
            ));
        }
        for m in &self.uncovered {
            out.push_str(&format!("{m:<44} (no threshold — uncovered)\n"));
        }
        out
    }
}

/// Evaluate one profile against one bench dump.
pub fn evaluate(
    profile_name: &str,
    profile: &Profile,
    bench: &BTreeMap<String, f64>,
) -> GateReport {
    let rows = profile
        .iter()
        .map(|(metric, &threshold)| {
            let value = bench.get(metric).copied();
            // A metric the bench no longer emits is a breach: a dropped
            // bench must not read as "no regression".
            let breached = value.is_none_or(|v| threshold.breached_by(v));
            GateRow { metric: metric.clone(), threshold, value, breached }
        })
        .collect();
    let uncovered = bench
        .keys()
        .filter(|k| !profile.contains_key(*k))
        .cloned()
        .collect();
    GateReport { profile: profile_name.to_string(), rows, uncovered }
}

/// File-level driver for `concur bench gate`: load both JSONs, pick the
/// profile, evaluate.  Every error here is a *config/IO* failure (exit 2
/// in the CLI), never a perf verdict.
pub fn run_gate_files(
    bench_path: &std::path::Path,
    thresholds_path: &std::path::Path,
    profile: &str,
) -> Result<GateReport> {
    let read = |p: &std::path::Path| -> Result<Value> {
        let text = std::fs::read_to_string(p).map_err(|e| {
            ConcurError::config(format!("cannot read {}: {e}", p.display()))
        })?;
        Value::parse(&text)
    };
    let bench = parse_bench(&read(bench_path)?)?;
    let profiles = parse_thresholds(&read(thresholds_path)?)?;
    let prof = profiles.get(profile).ok_or_else(|| {
        ConcurError::config(format!(
            "thresholds file has no profile '{profile}' (have: {})",
            profiles.keys().cloned().collect::<Vec<_>>().join(", ")
        ))
    })?;
    Ok(evaluate(profile, prof, &bench))
}

/// One-line digest of a BENCH json for `$GITHUB_STEP_SUMMARY`:
/// `name: k=v k=v ...` for numeric entries, nested objects counted.
pub fn summarize_bench(name: &str, v: &Value) -> String {
    let mut parts: Vec<String> = Vec::new();
    if let Some(obj) = v.as_object() {
        for (k, val) in obj {
            match val {
                Value::Number(n) => parts.push(format!("{k}={n:.4}")),
                Value::Object(o) => parts.push(format!("{k}={{{} entries}}", o.len())),
                Value::Array(a) => parts.push(format!("{k}=[{} items]", a.len())),
                _ => {}
            }
        }
    }
    format!("{name}: {}", parts.join("  "))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn thresholds_fixture() -> BTreeMap<String, Profile> {
        let text = r#"{
            "schema": 1,
            "profiles": {
                "pr": {
                    "engine/iteration_ns": {
                        "kind": "ceiling", "baseline": 1000000.0,
                        "allowed_regression_pct": 100.0
                    },
                    "driver/full_job_tokens_per_s": {
                        "kind": "floor", "baseline": 50000.0,
                        "allowed_regression_pct": 50.0
                    }
                }
            }
        }"#;
        parse_thresholds(&Value::parse(text).unwrap()).unwrap()
    }

    fn bench(entries: &[(&str, f64)]) -> BTreeMap<String, f64> {
        entries.iter().map(|&(k, v)| (k.to_string(), v)).collect()
    }

    #[test]
    fn thresholds_parse_and_compute_limits() {
        let profiles = thresholds_fixture();
        let pr = &profiles["pr"];
        let ceil = pr["engine/iteration_ns"];
        assert_eq!(ceil.kind, ThresholdKind::Ceiling);
        assert!((ceil.limit() - 2_000_000.0).abs() < 1e-6);
        let floor = pr["driver/full_job_tokens_per_s"];
        assert_eq!(floor.kind, ThresholdKind::Floor);
        assert!((floor.limit() - 25_000.0).abs() < 1e-6);
    }

    #[test]
    fn in_allowance_values_pass() {
        let profiles = thresholds_fixture();
        let b = bench(&[
            ("engine/iteration_ns", 1_900_000.0),
            ("driver/full_job_tokens_per_s", 26_000.0),
        ]);
        let report = evaluate("pr", &profiles["pr"], &b);
        assert!(report.passed(), "{}", report.render());
        assert!(report.uncovered.is_empty());
    }

    /// The acceptance-criteria breach test: a synthetic regression past
    /// the allowance must fail the gate and name the metric.
    #[test]
    fn synthetic_breach_fails_the_gate() {
        let profiles = thresholds_fixture();
        // Ceiling blown 2.5×, floor undershot to 20% of baseline.
        let b = bench(&[
            ("engine/iteration_ns", 2_500_000.0),
            ("driver/full_job_tokens_per_s", 10_000.0),
        ]);
        let report = evaluate("pr", &profiles["pr"], &b);
        assert!(!report.passed());
        assert_eq!(report.rows.iter().filter(|r| r.breached).count(), 2);
        let rendered = report.render();
        assert!(rendered.contains("BREACH"), "{rendered}");
        assert!(rendered.contains("engine/iteration_ns"), "{rendered}");
    }

    #[test]
    fn boundary_values_pass_exactly_at_the_limit() {
        let profiles = thresholds_fixture();
        let b = bench(&[
            ("engine/iteration_ns", 2_000_000.0),
            ("driver/full_job_tokens_per_s", 25_000.0),
        ]);
        assert!(evaluate("pr", &profiles["pr"], &b).passed());
    }

    #[test]
    fn missing_metric_is_a_breach_extra_metric_is_not() {
        let profiles = thresholds_fixture();
        let b = bench(&[
            ("engine/iteration_ns", 1_000_000.0),
            ("radix/new_metric_ns", 5.0), // no threshold yet
        ]);
        let report = evaluate("pr", &profiles["pr"], &b);
        assert!(!report.passed()); // tokens_per_s missing from the dump
        let missing = report
            .rows
            .iter()
            .find(|r| r.metric == "driver/full_job_tokens_per_s")
            .unwrap();
        assert!(missing.breached && missing.value.is_none());
        assert_eq!(report.uncovered, vec!["radix/new_metric_ns".to_string()]);
        let rendered = report.render();
        assert!(rendered.contains("missing"), "{rendered}");
        assert!(rendered.contains("uncovered"), "{rendered}");
    }

    #[test]
    fn bad_threshold_files_are_config_errors() {
        for text in [
            r#"{"profiles": {}}"#,                       // no schema
            r#"{"schema": 2, "profiles": {}}"#,          // wrong schema
            r#"{"schema": 1}"#,                          // no profiles
            r#"{"schema": 1, "profiles": {"pr": {"m":
                {"kind": "sideways", "baseline": 1.0,
                 "allowed_regression_pct": 10.0}}}}"#,   // bad kind
            r#"{"schema": 1, "profiles": {"pr": {"m":
                {"kind": "floor", "baseline": 1.0,
                 "allowed_regression_pct": 100.0}}}}"#,  // floor pct >= 100
            r#"{"schema": 1, "profiles": {"pr": {"m":
                {"kind": "ceiling", "baseline": -3.0,
                 "allowed_regression_pct": 10.0}}}}"#,   // negative baseline
        ] {
            let v = Value::parse(text).unwrap();
            assert!(parse_thresholds(&v).is_err(), "{text}");
        }
    }

    #[test]
    fn bench_parsing_keeps_numbers_and_skips_the_rest() {
        let v = Value::parse(
            r#"{"a": 1.5, "b": "text", "c": {"nested": 1}, "d": 2}"#,
        )
        .unwrap();
        let b = parse_bench(&v).unwrap();
        assert_eq!(b, bench(&[("a", 1.5), ("d", 2.0)]));
        assert!(parse_bench(&Value::parse("[1,2]").unwrap()).is_err());
    }

    #[test]
    fn summary_line_digests_mixed_shapes() {
        let v = Value::parse(r#"{"tput": 123.456, "cells": {"a": 1, "b": 2}}"#).unwrap();
        let line = summarize_bench("BENCH_x.json", &v);
        assert!(line.starts_with("BENCH_x.json: "), "{line}");
        assert!(line.contains("tput=123.456"), "{line}");
        assert!(line.contains("cells={2 entries}"), "{line}");
    }

    #[test]
    fn file_driver_reports_missing_profile_and_files_as_errors() {
        let dir = std::env::temp_dir().join("concur_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bench_p = dir.join("bench.json");
        let thr_p = dir.join("thr.json");
        std::fs::write(&bench_p, r#"{"engine/iteration_ns": 1.0}"#).unwrap();
        std::fs::write(
            &thr_p,
            r#"{"schema": 1, "profiles": {"pr": {"engine/iteration_ns":
                {"kind": "ceiling", "baseline": 2.0,
                 "allowed_regression_pct": 10.0}}}}"#,
        )
        .unwrap();
        assert!(run_gate_files(&bench_p, &thr_p, "pr").unwrap().passed());
        assert!(run_gate_files(&bench_p, &thr_p, "nightly").is_err());
        assert!(run_gate_files(&dir.join("nope.json"), &thr_p, "pr").is_err());
    }
}
