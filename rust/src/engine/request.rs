//! Per-request (one ReAct generation step) state inside the engine.

use crate::core::{AgentId, Micros, RequestId, Token};
use crate::costmodel::StepWork;

use super::radix::NodeId;

/// Execution phase of a sequence in the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    /// Prefilling the uncached prompt suffix; `done` tokens processed so far
    /// (relative to the uncached part).
    Prefill,
    /// Generating tokens one per engine iteration.
    Decode,
    /// Completed (terminal).
    Finished,
}

/// A generation request: one agent's next ReAct step.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub agent: AgentId,
    /// Full accumulated context (system prompt + history + tool outputs).
    pub prompt: Vec<Token>,
    /// Predetermined tokens this step will generate (the workload fixes
    /// trajectories up front so runs are bit-reproducible across schedulers).
    pub gen: Vec<Token>,
    /// The agent's context length after its *previous* step — any prefilled
    /// position below this is recomputation of previously-computed state
    /// (the thrashing penalty); positions at/above it are genuinely new.
    pub prev_ctx: u64,
    /// Submission time (for queueing-latency accounting).
    pub submitted_at: Micros,
}

/// Engine-internal bookkeeping for a running request.
#[derive(Debug)]
pub struct RunningSeq {
    pub req: Request,
    pub phase: SeqPhase,
    /// Prompt tokens covered by the radix cache at admission (GPU-resident
    /// or reloaded); prefill starts after them.
    pub cached_len: u64,
    /// Prompt tokens prefilled so far (beyond `cached_len`).
    pub prefilled: u64,
    /// Tokens generated so far.
    pub generated: u64,
    /// Generated token values (synthetic stream, fed back into history).
    pub output: Vec<Token>,
    /// Radix path locked at admission (unlocked at finish/preemption).
    pub locked_path: Vec<NodeId>,
    /// Pool slots allocated directly to this request (uncached prompt
    /// suffix + generated tokens); handed to the tree at finish.
    pub private_tokens: u64,
    /// When the request was admitted into the running batch.
    pub admitted_at: Micros,
}

impl RunningSeq {
    pub fn new(req: Request, cached_len: u64, locked_path: Vec<NodeId>, now: Micros) -> RunningSeq {
        let phase = if cached_len >= req.prompt.len() as u64 {
            SeqPhase::Decode
        } else {
            SeqPhase::Prefill
        };
        RunningSeq {
            req,
            phase,
            cached_len,
            prefilled: 0,
            generated: 0,
            output: Vec::new(),
            locked_path,
            private_tokens: 0,
            admitted_at: now,
        }
    }

    #[inline]
    pub fn prompt_len(&self) -> u64 {
        self.req.prompt.len() as u64
    }

    /// In the decode phase (generating one token per iteration)?
    #[inline]
    pub fn is_decode(&self) -> bool {
        self.phase == SeqPhase::Decode
    }

    /// Still prefilling its uncached prompt suffix?
    #[inline]
    pub fn is_prefill(&self) -> bool {
        self.phase == SeqPhase::Prefill
    }

    /// Prompt tokens still to prefill.
    #[inline]
    pub fn prefill_remaining(&self) -> u64 {
        self.prompt_len() - self.cached_len - self.prefilled
    }

    /// Current total context length (cached + prefilled + generated).
    #[inline]
    pub fn context_len(&self) -> u64 {
        self.cached_len + self.prefilled + self.generated
    }

    /// Of the next `chunk` prefill tokens, how many are *recompute* (were
    /// part of the agent's context before this step but missed cache)?
    pub fn recompute_in_next(&self, chunk: u64) -> u64 {
        let start = self.cached_len + self.prefilled; // absolute position
        let end = start + chunk;
        let boundary = self.req.prev_ctx;
        if end <= boundary {
            chunk
        } else if start >= boundary {
            0
        } else {
            boundary - start
        }
    }

    /// Apply one decode step — consume the pool slot the caller already
    /// charged, emit the next token, and record the step's work.  The one
    /// place decode bookkeeping lives, shared by the engine's batched and
    /// memory-pressure paths so their accounting can never diverge.
    pub fn advance_decode(&mut self, work: &mut StepWork) {
        self.private_tokens += 1;
        let tok = self.next_gen_token();
        self.output.push(tok);
        self.generated += 1;
        work.decode_seqs += 1;
        work.decode_ctx_tokens += self.context_len();
        if self.decode_done() {
            self.phase = SeqPhase::Finished;
        }
    }

    #[inline]
    pub fn decode_done(&self) -> bool {
        self.generated >= self.req.gen.len() as u64
    }

    /// The token produced by the next decode step.
    #[inline]
    pub fn next_gen_token(&self) -> Token {
        self.req.gen[self.generated as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt_len: usize, prev_ctx: u64) -> Request {
        Request {
            id: RequestId(1),
            agent: AgentId(1),
            prompt: (0..prompt_len as u32).collect(),
            gen: (90_000..90_010).collect(),
            prev_ctx,
            submitted_at: Micros::ZERO,
        }
    }

    #[test]
    fn fresh_cache_hit_goes_straight_to_decode() {
        let r = RunningSeq::new(req(100, 0), 100, vec![], Micros::ZERO);
        assert_eq!(r.phase, SeqPhase::Decode);
        assert_eq!(r.prefill_remaining(), 0);
    }

    #[test]
    fn recompute_accounting_splits_at_prev_ctx() {
        // Prompt 1000 tokens, agent had 800 before this step, cache
        // matched only 100 → positions 100..800 are recompute, 800..1000
        // are new.
        let mut r = RunningSeq::new(req(1000, 800), 100, vec![], Micros::ZERO);
        assert_eq!(r.prefill_remaining(), 900);
        // First chunk of 500: all below 800 → 100% recompute? positions
        // 100..600, all < 800 → yes.
        assert_eq!(r.recompute_in_next(500), 500);
        r.prefilled += 500;
        // Next chunk 400 covers 600..1000: 200 recompute + 200 new.
        assert_eq!(r.recompute_in_next(400), 200);
        r.prefilled += 400;
        assert_eq!(r.prefill_remaining(), 0);
    }

    #[test]
    fn no_recompute_when_cache_covers_history() {
        // Cache matched the whole previous context: everything prefilled
        // is genuinely new.
        let r = RunningSeq::new(req(1000, 800), 800, vec![], Micros::ZERO);
        assert_eq!(r.recompute_in_next(200), 0);
    }

    #[test]
    fn context_len_tracks_progress() {
        let mut r = RunningSeq::new(req(100, 0), 40, vec![], Micros::ZERO);
        assert_eq!(r.context_len(), 40);
        r.prefilled = 60;
        r.generated = 5;
        assert_eq!(r.context_len(), 105);
    }
}
