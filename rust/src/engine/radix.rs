//! Radix-tree prefix cache with LRU eviction (SGLang-style).
//!
//! Cached token sequences are stored in a compressed trie: each node holds a
//! token-run edge label and the KV slots for those tokens.  A new request
//! matches its prompt from the root; matched prefixes reuse cached KV and
//! only the divergent suffix is prefetched.  Under memory pressure, LRU
//! *leaves* with no active references are evicted — either discarded
//! (vanilla) or demoted to a CPU tier (HiCache) that can be matched but must
//! be reloaded over the host link before use.
//!
//! This is exactly the structure whose recency-based eviction produces
//! middle-phase thrashing (paper §3): a paused agent's path loses recency
//! while it waits on a tool, gets evicted, and must be recomputed on resume.
//!
//! ## Hot-path representation (see DESIGN.md §Perf)
//!
//! * **Token arena with generational compaction.**  All edge labels live in
//!   one `Vec<Token>` slab; nodes store `(off, len)` ranges into it.
//!   `split()` is two range adjustments with zero copies, and
//!   `match_prefix` compares the probe against contiguous memory.
//!   Discarded leaves abandon their range in place; once dead ranges
//!   outweigh live tokens past a floor, `compact_arena` rebuilds the slab —
//!   tenured (pinned/parked) ranges first, LRU candidates behind them with
//!   the coldest at the tail, so the ranges most likely to die next cluster
//!   where the next compaction cheaply truncates.  Compaction rewrites only
//!   `off` fields: node identities, stamps, counters and the mutation epoch
//!   are untouched, so it is invisible to every caller (including the
//!   engine's epoch-guarded fast path) and to simulation results.
//! * **Ordered LRU index.**  Eviction candidates sit in a `BTreeSet` of
//!   `(last_access, version, id)` keys — the exact pop order of the lazy
//!   binary heap (and then the intrusive list) this replaced, so eviction
//!   decisions (and therefore every simulation result) are bit-identical.
//!   Touch/pop/insert are O(log n); crucially, *stale-stamp re-entry*
//!   (unlock after a long-held lock) is O(log n) too, where the intrusive
//!   list walked backward past every fresher candidate — the pause-heavy
//!   fleet pathology the ROADMAP item named.  Membership mirrors the old
//!   heap's "has a currently-valid entry" rule: a node touched after its
//!   last `push_candidate` is *not* evictable until the next push — that
//!   quirk is load-bearing for which caches survive, so it is preserved.
//! * **Incremental counters.**  `node_count` and the per-node GPU-child
//!   count (`is_gpu_leaf`) are maintained on every mutation instead of
//!   being recomputed by scans.

use crate::core::{simd, FxHashMap, Micros, Token};
use crate::metrics::profiler;
use std::collections::BTreeSet;

pub type NodeId = usize;

const ROOT: NodeId = 0;

/// Auto-compaction floor: slabs below this size are never compacted (the
/// copy would cost more than the memory it reclaims).
const COMPACT_MIN_ARENA: usize = 64 * 1024;
/// Auto-compaction slack: compact only once the slab exceeds this multiple
/// of the live token count, i.e. at least half the slab is garbage.
const COMPACT_SLACK: usize = 2;

/// Where a node's KV currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    Gpu,
    Cpu,
}

#[derive(Debug)]
struct Node {
    /// Edge label: `arena[off..off + len]`.
    off: usize,
    len: usize,
    children: FxHashMap<Token, NodeId>,
    parent: NodeId,
    ref_count: u32,
    /// Number of locked nodes in this node's subtree (including itself).
    /// A node with `pin_count > 0` lies on a root→locked path and cannot
    /// be reclaimed; maintained incrementally by lock/unlock walks.
    pin_count: u32,
    /// Broadcast registrations covering this node (cluster shared-prefix
    /// tier).  A node with `broadcast_pins > 0` is a read-only broadcast
    /// prefix: it never enters the LRU candidate list (so per-replica
    /// eviction can neither discard nor offload it) and `trim_cpu` skips
    /// it.  Maintained by `pin_broadcast`/`demote_broadcast` walks; edge
    /// splits inherit it so coverage stays contiguous root→deepest.
    broadcast_pins: u32,
    /// Children currently GPU-resident; 0 ⇒ this node is a *GPU leaf*
    /// (its subtree holds no other GPU memory) and may be evicted.
    gpu_children: u32,
    /// Lifetime class under `KvLifetimePolicy::StepsToExecution`: lower
    /// classes evict first (recency breaks ties within a class).  Stamped
    /// by the engine from per-agent remaining-steps hints; always 0 under
    /// the other policies, where it does not participate in the key.
    class: u64,
    /// Pin expiry instant under `KvLifetimePolicy::ToolTtl`: while
    /// `pin_until > now` the node sorts behind every unpinned candidate.
    /// `Micros::ZERO` = unpinned; elapsed pins are cleared lazily by
    /// `evict_at`.  Always ZERO under the other policies.
    pin_until: Micros,
    last_access: Micros,
    /// Bumped on every access; a node whose version moved past its last
    /// `push_candidate` is off the LRU list until re-pushed.
    version: u64,
    residency: Residency,
    alive: bool,
    /// Whether this node currently has an entry in the LRU index.  While
    /// set, `(last_access, version)` are frozen (every mutation removes the
    /// entry first), so the stored key is always recomputable.
    in_lru: bool,
}

impl Node {
    fn tokens(&self) -> u64 {
        self.len as u64
    }
}

/// A probe sequence presented as up to two back-to-back slices, so callers
/// can match/insert `prompt ⧺ output` without materialising the
/// concatenation (the `collect_finished` hot path).
#[derive(Clone, Copy)]
struct Probe<'a> {
    a: &'a [Token],
    b: &'a [Token],
}

impl<'a> Probe<'a> {
    fn len(&self) -> usize {
        self.a.len() + self.b.len()
    }

    #[inline]
    fn at(&self, pos: usize) -> Token {
        if pos < self.a.len() {
            self.a[pos]
        } else {
            self.b[pos - self.a.len()]
        }
    }

    /// Length of the common run between `key` and `self[pos..]`, capped at
    /// `key.len()`.  Word-wise comparison (`core::simd`) dominates on
    /// full-edge matches (agent-history reuse); at most two segment hops
    /// because the probe is two slices.
    fn common_with(&self, key: &[Token], pos: usize) -> usize {
        let maxcmp = key.len().min(self.len() - pos);
        let mut done = 0usize;
        while done < maxcmp {
            let p = pos + done;
            let (seg, seg_off) = if p < self.a.len() {
                (self.a, p)
            } else {
                (self.b, p - self.a.len())
            };
            let n = (seg.len() - seg_off).min(maxcmp - done);
            let c = simd::common_prefix_len(&key[done..done + n], &seg[seg_off..seg_off + n]);
            done += c;
            if c < n {
                break;
            }
        }
        done
    }

    /// Append `self[from..]` to the arena.
    fn extend_arena(&self, arena: &mut Vec<Token>, from: usize) {
        if from < self.a.len() {
            arena.extend_from_slice(&self.a[from..]);
            arena.extend_from_slice(self.b);
        } else {
            arena.extend_from_slice(&self.b[from - self.a.len()..]);
        }
    }
}

/// Result of matching a prompt against the tree.
#[derive(Debug, Clone, Default)]
pub struct MatchResult {
    /// Node path (root excluded) covering the matched prefix, in order.
    pub path: Vec<NodeId>,
    /// Matched tokens resident on GPU.
    pub gpu_tokens: u64,
    /// Matched tokens resident in the CPU tier (must be reloaded).
    pub cpu_tokens: u64,
    /// Matched tokens lying on broadcast-pinned nodes (a subset of the
    /// totals above) — the engine's broadcast-hit accounting.
    pub broadcast_tokens: u64,
}

impl MatchResult {
    pub fn total(&self) -> u64 {
        self.gpu_tokens + self.cpu_tokens
    }
}

/// Result of inserting a sequence.
#[derive(Debug, Clone, Default)]
pub struct InsertResult {
    /// Full node path (root excluded) covering the sequence.
    pub path: Vec<NodeId>,
    /// Tokens newly added to the GPU tier by this insert.
    pub new_gpu_tokens: u64,
    /// Matched CPU-tier tokens along the path (caller decides reload).
    pub cpu_tokens: u64,
}

/// Outcome of an eviction request.
#[derive(Debug, Clone, Default)]
pub struct EvictResult {
    /// GPU token slots freed.
    pub freed_gpu_tokens: u64,
    /// Tokens demoted to the CPU tier (Offload mode only).
    pub offloaded_tokens: u64,
    /// Tokens dropped entirely.
    pub discarded_tokens: u64,
    /// Number of nodes touched.
    pub nodes: usize,
}

/// Eviction behaviour (mirrors `config::EvictionMode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    Discard,
    OffloadToCpu,
}

/// KV lifetime policy: what orders the eviction queue (mirrors
/// `config::KvLifetimeMode`).  The policy decides *which* cached KV is
/// evicted first, never *whether* an eviction request can be satisfied —
/// candidate membership (and therefore `evictable_gpu_tokens` and every
/// admission-feasibility decision) is identical across policies.
///
/// Mechanically, each policy prepends one component to the LRU ordering
/// key `(last_access, version, id)`:
///
/// * [`Lru`](KvLifetimePolicy::Lru) — constant `0`: the 4-tuple orders
///   exactly as the classic 3-tuple, bit-identical to the pre-policy
///   tree.
/// * [`StepsToExecution`](KvLifetimePolicy::StepsToExecution) — the
///   node's *lifetime class*, stamped by the engine from each agent's
///   remaining-steps hint (KVFlow): low class = little future = evicted
///   first; recency breaks ties within a class.
/// * [`ToolTtl`](KvLifetimePolicy::ToolTtl) — the node's pin expiry
///   instant (Continuum): unpinned KV (`pin_until` 0) evicts first in
///   recency order; pinned KV is only reached once nothing unpinned
///   remains, and an *elapsed* pin lazily re-enters the unpinned order
///   at its preserved recency stamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLifetimePolicy {
    Lru,
    StepsToExecution,
    ToolTtl,
}

/// The prefix cache.
pub struct RadixTree {
    nodes: Vec<Node>,
    free_slots: Vec<NodeId>,
    /// Token slab backing every edge label.  Appended on insert; dead
    /// ranges are reclaimed by `compact_arena`.
    arena: Vec<Token>,
    gpu_tokens: u64,
    cpu_tokens: u64,
    /// GPU tokens pinned by locked paths (incremental; see `pin_count`).
    pinned_gpu_tokens: u64,
    /// Tokens covered by broadcast registrations (incremental; per-node,
    /// counted once however many registrations overlap a node).
    broadcast_tokens: u64,
    /// Live nodes excluding the root (incremental).
    live_nodes: usize,
    /// Bumped on every structural or content mutation (insert, split,
    /// evict, reload, trim).  An unchanged epoch guarantees a repeated
    /// match of the same probe returns the same totals over the same node
    /// path — what lets the engine skip redundant head-of-line re-matches
    /// and replay their recency touches from a cached path.
    epoch: u64,
    /// KV lifetime policy ordering this tree's eviction queue (fixed at
    /// construction; see [`KvLifetimePolicy`]).
    lifetime: KvLifetimePolicy,
    /// Ordered LRU index of eviction candidates, keyed by
    /// `(lifetime_component, last_access, version, id)` — the first
    /// element is the eviction victim.  The leading component is the
    /// policy's contribution (constant 0 under `Lru`, so the order is
    /// bit-identical to the classic `(last_access, version, id)` key).
    /// Keys are unique (id tie-break) and frozen while a node is a member
    /// (see `Node::in_lru`).
    lru: BTreeSet<(u64, Micros, u64, NodeId)>,
    /// Auto-compaction switch (on by default; tests that pin slab layout
    /// or diff against a non-compacting oracle turn it off).
    auto_compact: bool,
    /// Number of `compact_arena` runs (diagnostics).
    compactions: u64,
    /// Total dead tokens reclaimed by compaction (diagnostics).
    compacted_tokens: u64,
}

impl RadixTree {
    pub fn new() -> RadixTree {
        Self::with_policy(KvLifetimePolicy::Lru)
    }

    /// Build a tree whose eviction queue is ordered by `lifetime`.
    /// `with_policy(Lru)` is exactly `new()`.
    pub fn with_policy(lifetime: KvLifetimePolicy) -> RadixTree {
        let root = Node {
            off: 0,
            len: 0,
            children: FxHashMap::default(),
            parent: ROOT,
            ref_count: 1, // the root is never evictable
            pin_count: 0,
            broadcast_pins: 0,
            gpu_children: 0,
            class: 0,
            pin_until: Micros::ZERO,
            last_access: Micros::ZERO,
            version: 0,
            residency: Residency::Gpu,
            alive: true,
            in_lru: false,
        };
        RadixTree {
            nodes: vec![root],
            free_slots: Vec::new(),
            arena: Vec::new(),
            gpu_tokens: 0,
            cpu_tokens: 0,
            pinned_gpu_tokens: 0,
            broadcast_tokens: 0,
            live_nodes: 0,
            epoch: 0,
            lifetime,
            lru: BTreeSet::new(),
            auto_compact: true,
            compactions: 0,
            compacted_tokens: 0,
        }
    }

    /// The lifetime policy this tree was built with.
    pub fn lifetime_policy(&self) -> KvLifetimePolicy {
        self.lifetime
    }

    /// Tokens currently resident on GPU (must equal the pool's `used` minus
    /// per-request transient allocations).
    pub fn gpu_tokens(&self) -> u64 {
        self.gpu_tokens
    }

    /// Tokens parked in the CPU tier.
    pub fn cpu_tokens(&self) -> u64 {
        self.cpu_tokens
    }

    /// Number of live nodes (excluding the root).  O(1).
    pub fn node_count(&self) -> usize {
        self.live_nodes
    }

    /// Mutation epoch: unchanged epoch means a repeated `match_prefix` of
    /// the same probe returns the same totals (`gpu`/`cpu`/`broadcast`)
    /// over the same node path with no splits.  Every match-visible
    /// mutation bumps it — insert, split, evict, reload, CPU-tier trim,
    /// and broadcast pin 0↔1 transitions; recency touches and arena
    /// compaction do not.  The engine's admission memo keys on this.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Current token-slab length (diagnostics).  Live tokens plus
    /// not-yet-compacted dead ranges; bounded at roughly
    /// `COMPACT_SLACK ×` live tokens once auto-compaction kicks in.
    pub fn arena_len(&self) -> usize {
        self.arena.len()
    }

    /// Number of arena compactions performed so far (diagnostics).
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Total dead tokens reclaimed by arena compaction (diagnostics).
    pub fn compacted_tokens(&self) -> u64 {
        self.compacted_tokens
    }

    /// Enable or disable automatic arena compaction (on by default).
    /// Compaction never changes observable behaviour — only slab layout —
    /// so this exists for tests that pin layout or diff against a
    /// non-compacting oracle.
    pub fn set_auto_compaction(&mut self, on: bool) {
        self.auto_compact = on;
    }

    /// Tokens currently covered by broadcast registrations (each node
    /// counted once however many registrations overlap it).  O(1).
    pub fn broadcast_tokens(&self) -> u64 {
        self.broadcast_tokens
    }

    /// Read-only longest-prefix probe: how many of `tokens` are matchable
    /// right now, as `(gpu, cpu)` token counts — without touching
    /// recency, splitting edges or bumping the epoch.  The cluster's
    /// shared-prefix tier uses this to test replica residency before
    /// shipping a broadcast prefix (a mutating `match_prefix` would
    /// perturb LRU aging just by looking).
    pub fn peek_prefix(&self, tokens: &[Token]) -> (u64, u64) {
        let (mut gpu, mut cpu) = (0u64, 0u64);
        let mut cur = ROOT;
        let mut pos = 0usize;
        while pos < tokens.len() {
            let Some(&child) = self.nodes[cur].children.get(&tokens[pos]) else {
                break;
            };
            let n = &self.nodes[child];
            let key = &self.arena[n.off..n.off + n.len];
            let same = simd::common_prefix_len(key, &tokens[pos..]);
            if same == 0 {
                break;
            }
            match n.residency {
                Residency::Gpu => gpu += same as u64,
                Residency::Cpu => cpu += same as u64,
            }
            pos += same;
            cur = child;
            if same < key.len() {
                break; // diverged (or ended) inside the edge
            }
        }
        (gpu, cpu)
    }

    // -- allocation ---------------------------------------------------------

    fn alloc_node(&mut self, node: Node) -> NodeId {
        self.live_nodes += 1;
        if let Some(id) = self.free_slots.pop() {
            self.nodes[id] = node;
            id
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    fn touch(&mut self, id: NodeId, now: Micros) {
        if self.nodes[id].in_lru {
            // The old lazy heap never re-pushed on touch, so a touched
            // candidate stayed unevictable until the next push_candidate.
            // Dropping it from the list preserves that exactly.
            self.lru_remove(id);
        }
        let node = &mut self.nodes[id];
        node.last_access = now;
        node.version += 1;
    }

    /// True when `id` has no GPU-resident children.  In Offload mode a
    /// node's children may be demoted to the CPU tier without being
    /// removed; the node is then a *GPU leaf* and must stay evictable or
    /// GPU inner nodes leak unreclaimably.  O(1) via the incremental
    /// `gpu_children` counter.
    fn is_gpu_leaf(&self, id: NodeId) -> bool {
        self.nodes[id].gpu_children == 0
    }

    // -- ordered LRU index --------------------------------------------------

    /// The policy's leading key component for `n` (see the `lru` field
    /// doc).  Constant 0 under `Lru`, so the 4-tuple key orders exactly
    /// as the classic `(last_access, version, id)` 3-tuple.
    fn lifetime_component(&self, n: &Node) -> u64 {
        match self.lifetime {
            KvLifetimePolicy::Lru => 0,
            KvLifetimePolicy::StepsToExecution => n.class,
            KvLifetimePolicy::ToolTtl => n.pin_until.0,
        }
    }

    fn lru_key(&self, id: NodeId) -> (u64, Micros, u64, NodeId) {
        let n = &self.nodes[id];
        (self.lifetime_component(n), n.last_access, n.version, id)
    }

    fn lru_remove(&mut self, id: NodeId) {
        debug_assert!(self.nodes[id].in_lru);
        // Valid because every key input (class/pin_until, last_access,
        // version) is frozen while in_lru — all mutators remove the entry
        // first — so the key computed now is the key that was inserted.
        let removed = self.lru.remove(&self.lru_key(id));
        debug_assert!(removed, "lru entry missing for flagged node {id}");
        self.nodes[id].in_lru = false;
    }

    /// Insert `id` at its sorted position — O(log candidates) whether the
    /// stamp is fresh (new leaf, just-touched push) or stale (unlock after
    /// a long-held lock).  The stale case is the win over the intrusive
    /// list this replaced, which walked backward past every candidate that
    /// entered since the stamp; pop order is unchanged, so eviction
    /// decisions stay bit-identical (safety net:
    /// `lru_stale_reentry_matches_slow_path_order`).
    fn lru_insert(&mut self, id: NodeId) {
        debug_assert!(!self.nodes[id].in_lru);
        let inserted = self.lru.insert(self.lru_key(id));
        debug_assert!(inserted, "duplicate lru key for node {id}");
        self.nodes[id].in_lru = true;
    }

    /// Register `id` as an LRU candidate (no-op if already registered or
    /// ineligible) — the analogue of the old heap push.
    fn push_candidate(&mut self, id: NodeId) {
        if id == ROOT {
            return;
        }
        let n = &self.nodes[id];
        if n.alive
            && !n.in_lru
            && n.ref_count == 0
            && n.broadcast_pins == 0
            && n.residency == Residency::Gpu
            && n.gpu_children == 0
        {
            self.lru_insert(id);
        }
    }

    /// Split `id`'s edge so its first `at` tokens become a new parent node.
    /// Returns the new parent's id.  Zero-copy: both halves keep pointing
    /// into the shared arena.
    fn split(&mut self, id: NodeId, at: usize) -> NodeId {
        debug_assert!(at > 0 && at < self.nodes[id].len);
        let (off, parent, last_access, residency) = {
            let n = &self.nodes[id];
            (n.off, n.parent, n.last_access, n.residency)
        };
        // Locks live on the *deepest* node of a request's path only (see
        // `lock_path`), so the new upper node starts unreferenced: the
        // still-locked lower half protects it transitively via the child
        // link.  Copying the ref here would leak it when the locker later
        // unlocks the lower node.
        let lower_pins = self.nodes[id].pin_count;
        let lower_bcast = self.nodes[id].broadcast_pins;
        let lower_class = self.nodes[id].class;
        let lower_pin_until = self.nodes[id].pin_until;
        let upper = self.alloc_node(Node {
            off,
            len: at,
            children: FxHashMap::default(),
            parent,
            ref_count: 0,
            // The upper half sits on every root→locked path the lower half
            // is on; pinned-token totals are unchanged by the split.  The
            // same holds for broadcast coverage: the upper half carries
            // `at` of the lower's tokens, so the per-node token sum behind
            // `broadcast_tokens` is unchanged too.
            pin_count: lower_pins,
            broadcast_pins: lower_bcast,
            // The lower half is the upper's only child and shares its
            // residency.
            gpu_children: if residency == Residency::Gpu { 1 } else { 0 },
            // Lifetime stamps cover whole root→deepest paths, so both
            // halves of a split edge carry the same class/pin — coverage
            // stays contiguous exactly like broadcast pins.
            class: lower_class,
            pin_until: lower_pin_until,
            last_access,
            version: 0,
            residency,
            alive: true,
            in_lru: false,
        });
        {
            let n = &mut self.nodes[id];
            n.off = off + at;
            n.len -= at;
            n.parent = upper;
        }
        // `id` keeps its identity, (stamp, version) and therefore its LRU
        // position — only its token range shrank, exactly as the old heap
        // entry kept pointing at the shrunken node.
        let first_upper = self.arena[off];
        let first_lower = self.arena[off + at];
        self.nodes[upper].children.insert(first_lower, id);
        self.nodes[parent].children.insert(first_upper, upper);
        // A split leaves match totals unchanged but alters path structure;
        // bumping the epoch keeps cached paths (the engine's blocked-head
        // fast path) from straddling a node they no longer fully cover.
        self.epoch += 1;
        upper
    }

    // -- match / insert -------------------------------------------------------

    /// Match `tokens` against the tree, splitting edges so the matched
    /// prefix is covered by whole nodes.  Updates recency on the path.
    pub fn match_prefix(&mut self, tokens: &[Token], now: Micros) -> MatchResult {
        self.match_probe(Probe { a: tokens, b: &[] }, now)
    }

    fn match_probe(&mut self, p: Probe<'_>, now: Micros) -> MatchResult {
        let mut prof = profiler::scope(profiler::Section::RadixMatch);
        let mut result = MatchResult::default();
        let mut cur = ROOT;
        let mut pos = 0usize;
        let total = p.len();
        while pos < total {
            let Some(&child) = self.nodes[cur].children.get(&p.at(pos)) else {
                break;
            };
            let (off, klen) = {
                let n = &self.nodes[child];
                (n.off, n.len)
            };
            let same = p.common_with(&self.arena[off..off + klen], pos);
            if same == 0 {
                break;
            }
            let matched_node = if same < klen {
                // Partial edge: split so the matched half is its own node.
                self.split(child, same)
            } else {
                child
            };
            self.touch(matched_node, now);
            let n = &self.nodes[matched_node];
            match n.residency {
                Residency::Gpu => result.gpu_tokens += same as u64,
                Residency::Cpu => result.cpu_tokens += same as u64,
            }
            if n.broadcast_pins > 0 {
                result.broadcast_tokens += same as u64;
            }
            result.path.push(matched_node);
            pos += same;
            cur = matched_node;
            if same < klen {
                break; // diverged inside the edge
            }
        }
        prof.add_units(pos as u64);
        result
    }

    /// Re-touch `path` (recency refresh) without re-matching — used by the
    /// engine so a blocked head-of-line request's matched prefix ages
    /// exactly as the per-step re-match it replaces would have kept it
    /// fresh.  Callers must ensure the tree is structurally unchanged since
    /// the path was obtained (the engine's epoch/free/evictable guard
    /// does).
    pub fn touch_path(&mut self, path: &[NodeId], now: Micros) {
        for &id in path {
            debug_assert!(self.nodes[id].alive);
            self.touch(id, now);
        }
    }

    /// Insert `tokens`, reusing any matched prefix.  New tokens land on GPU.
    pub fn insert(&mut self, tokens: &[Token], now: Micros) -> InsertResult {
        self.insert_probe(Probe { a: tokens, b: &[] }, now)
    }

    /// Insert the logical concatenation `head ⧺ tail` without materialising
    /// it — identical tree mutations to `insert(&[head, tail].concat())`.
    pub fn insert_parts(
        &mut self,
        head: &[Token],
        tail: &[Token],
        now: Micros,
    ) -> InsertResult {
        self.insert_probe(Probe { a: head, b: tail }, now)
    }

    fn insert_probe(&mut self, p: Probe<'_>, now: Micros) -> InsertResult {
        let m = self.match_probe(p, now);
        let matched = m.total() as usize;
        let mut path = m.path;
        let cur = path.last().copied().unwrap_or(ROOT);
        let mut new_gpu = 0u64;
        if matched < p.len() {
            let off = self.arena.len();
            p.extend_arena(&mut self.arena, matched);
            let len = self.arena.len() - off;
            new_gpu = len as u64;
            let first = self.arena[off];
            let leaf = self.alloc_node(Node {
                off,
                len,
                children: FxHashMap::default(),
                parent: cur,
                ref_count: 0,
                pin_count: 0,
                broadcast_pins: 0,
                gpu_children: 0,
                class: 0,
                pin_until: Micros::ZERO,
                last_access: now,
                version: 0,
                residency: Residency::Gpu,
                alive: true,
                in_lru: false,
            });
            // `cur` gains a GPU child and stops being a GPU leaf.  (The
            // match already touched it off the LRU list unless it's the
            // root; this guard covers direct structural callers.)
            if self.nodes[cur].in_lru {
                self.lru_remove(cur);
            }
            self.nodes[cur].children.insert(first, leaf);
            self.nodes[cur].gpu_children += 1;
            self.gpu_tokens += new_gpu;
            self.epoch += 1;
            path.push(leaf);
            self.push_candidate(leaf);
        }
        InsertResult { path, new_gpu_tokens: new_gpu, cpu_tokens: m.cpu_tokens }
    }

    // -- locking ---------------------------------------------------------------

    /// Prevent every node on `path` from being evicted.
    ///
    /// Only the deepest node carries the reference: ancestors are protected
    /// transitively because eviction only ever removes childless nodes.
    /// This keeps locks stable across later edge splits.
    pub fn lock_path(&mut self, path: &[NodeId]) {
        if let Some(&last) = path.last() {
            debug_assert!(self.nodes[last].alive);
            if self.nodes[last].in_lru {
                self.lru_remove(last);
            }
            self.nodes[last].ref_count += 1;
            // Pin the root→last chain (O(depth), keeps the evictable
            // counter O(1) to read — the controller samples it every step).
            let mut id = last;
            while id != ROOT {
                let n = &mut self.nodes[id];
                n.pin_count += 1;
                if n.pin_count == 1 && n.residency == Residency::Gpu {
                    self.pinned_gpu_tokens += n.len as u64;
                }
                id = n.parent;
            }
        }
    }

    /// Release a previous `lock_path`; nodes become eviction candidates.
    pub fn unlock_path(&mut self, path: &[NodeId]) {
        if let Some(&last) = path.last() {
            debug_assert!(self.nodes[last].ref_count > 0, "unlock of unlocked node");
            self.nodes[last].ref_count -= 1;
            let mut id = last;
            while id != ROOT {
                let n = &mut self.nodes[id];
                debug_assert!(n.pin_count > 0);
                n.pin_count -= 1;
                if n.pin_count == 0 && n.residency == Residency::Gpu {
                    self.pinned_gpu_tokens -= n.len as u64;
                }
                id = n.parent;
            }
            self.push_candidate(last);
        }
    }

    // -- lifetime stamping ------------------------------------------------------

    /// Stamp every node on `path` with a lifetime `class` and `pin_until`
    /// expiry (the engine derives both from per-agent hints; see
    /// [`KvLifetimePolicy`]).  A no-op under `Lru`, where neither field
    /// participates in the eviction key.
    ///
    /// Stamping re-orders the eviction queue but never changes candidate
    /// membership, token counters, recency stamps or the mutation epoch —
    /// admission feasibility (and the engine's epoch-guarded head-of-line
    /// fast path) is untouched by construction.
    pub fn stamp_path_lifetime(&mut self, path: &[NodeId], class: u64, pin_until: Micros) {
        if self.lifetime == KvLifetimePolicy::Lru {
            return;
        }
        for &id in path {
            let n = &self.nodes[id];
            debug_assert!(n.alive);
            if n.class == class && n.pin_until == pin_until {
                continue;
            }
            let was_in_lru = n.in_lru;
            if was_in_lru {
                self.lru_remove(id);
            }
            let n = &mut self.nodes[id];
            n.class = class;
            n.pin_until = pin_until;
            if was_in_lru {
                self.lru_insert(id);
            }
        }
    }

    // -- broadcast pinning ------------------------------------------------------

    /// Register `path` (a full root→deepest node path, as returned by
    /// `insert`/`insert_parts`) as a **read-only broadcast prefix**: the
    /// covered nodes leave the LRU candidate list and can be neither
    /// discarded nor offloaded until a matching [`demote_broadcast`]
    /// releases them.  Internally this also takes a regular path lock, so
    /// `evictable_gpu_tokens` excludes the covered tokens exactly as it
    /// excludes request-locked paths.  Registrations nest: overlapping
    /// pins are counted per node and coverage survives later edge splits
    /// (the split upper half inherits the count).
    ///
    /// [`demote_broadcast`]: RadixTree::demote_broadcast
    pub fn pin_broadcast(&mut self, path: &[NodeId]) {
        self.lock_path(path);
        if let Some(&last) = path.last() {
            let mut id = last;
            let mut newly_pinned = false;
            while id != ROOT {
                if self.nodes[id].in_lru {
                    self.lru_remove(id);
                }
                let n = &mut self.nodes[id];
                n.broadcast_pins += 1;
                if n.broadcast_pins == 1 {
                    self.broadcast_tokens += n.len as u64;
                    newly_pinned = true;
                }
                id = n.parent;
            }
            // A 0→1 pin transition changes future matches'
            // `broadcast_tokens`, which is part of the epoch contract
            // ("unchanged epoch ⇒ identical match totals") that the
            // engine's admission memo relies on.
            if newly_pinned {
                self.epoch += 1;
            }
        }
    }

    /// Release a previous [`pin_broadcast`] registration.  The covered
    /// nodes become ordinary cache again; like an unlock, only the
    /// deepest node re-enters LRU candidacy immediately (ancestors re-arm
    /// on their next `push_candidate`, mirroring the heap-parity rule).
    ///
    /// [`pin_broadcast`]: RadixTree::pin_broadcast
    pub fn demote_broadcast(&mut self, path: &[NodeId]) {
        if let Some(&last) = path.last() {
            let mut id = last;
            let mut unpinned = false;
            while id != ROOT {
                let n = &mut self.nodes[id];
                debug_assert!(n.broadcast_pins > 0, "demote of non-broadcast node");
                n.broadcast_pins -= 1;
                if n.broadcast_pins == 0 {
                    self.broadcast_tokens -= n.len as u64;
                    unpinned = true;
                }
                id = n.parent;
            }
            // Mirror of `pin_broadcast`: a 1→0 transition changes match
            // `broadcast_tokens`, so cached matches must invalidate.
            if unpinned {
                self.epoch += 1;
            }
        }
        self.unlock_path(path);
    }

    // -- eviction ---------------------------------------------------------------

    /// GPU tokens that could be freed right now (unlocked subtrees).
    /// O(1): `gpu_tokens - pinned_gpu_tokens`, maintained incrementally.
    pub fn evictable_gpu_tokens(&self) -> u64 {
        self.gpu_tokens - self.pinned_gpu_tokens
    }

    /// Reference implementation of [`evictable_gpu_tokens`] — O(n) subtree
    /// walk, used by `check_invariants` and tests.
    pub fn evictable_gpu_tokens_slow(&self) -> u64 {
        // A node is evictable iff it and all its descendants are unlocked.
        // Compute by propagating "subtree locked" from leaves up; simpler:
        // sum over nodes that are unlocked and whose entire subtree is
        // unlocked.  We do a post-order accumulation.
        let mut locked_subtree = vec![false; self.nodes.len()];
        // Iterative post-order: process children before parents using a
        // stack of (node, visited) pairs.
        let mut stack = vec![(ROOT, false)];
        let mut total = 0u64;
        while let Some((id, visited)) = stack.pop() {
            if visited {
                let n = &self.nodes[id];
                let mut locked = n.ref_count > 0 && id != ROOT || id == ROOT;
                for (&_, &c) in &n.children {
                    locked |= locked_subtree[c];
                }
                locked_subtree[id] = locked;
                if id != ROOT && !locked && n.residency == Residency::Gpu {
                    total += n.tokens();
                }
            } else {
                stack.push((id, true));
                for (&_, &c) in &self.nodes[id].children {
                    if self.nodes[c].alive {
                        stack.push((c, false));
                    }
                }
            }
        }
        total
    }

    /// Evict LRU leaves until `want` GPU tokens are freed or nothing is
    /// evictable.  In `OffloadToCpu` mode evicted nodes stay matchable in
    /// the CPU tier.
    ///
    /// Clock-free wrapper around [`evict_at`](Self::evict_at) at
    /// `Micros::ZERO` — under `Lru` (where no pins exist) the two are
    /// identical; under `ToolTtl` this treats every pin as still active.
    pub fn evict(&mut self, want: u64, policy: EvictPolicy) -> EvictResult {
        self.evict_at(want, policy, Micros::ZERO)
    }

    /// Evict eviction-queue heads until `want` GPU tokens are freed or
    /// nothing is evictable, lazily expiring `ToolTtl` pins against the
    /// sim clock `now`: a queue head whose `pin_until` has elapsed is
    /// un-pinned and re-enters the unpinned order at its preserved
    /// recency stamp instead of being evicted.  A head pinned *into the
    /// future* is only reached once nothing unpinned remains (the key
    /// sorts all pins last) and is then evicted anyway — pinning shapes
    /// the order, never feasibility, so admission cannot deadlock on a
    /// fully-pinned cache.
    pub fn evict_at(&mut self, want: u64, policy: EvictPolicy, now: Micros) -> EvictResult {
        let _prof = profiler::scope(profiler::Section::Evict);
        let mut out = EvictResult::default();
        while out.freed_gpu_tokens < want {
            let Some(&(life, _, _, id)) = self.lru.first() else {
                break;
            };
            if life > 0
                && self.lifetime == KvLifetimePolicy::ToolTtl
                && self.nodes[id].pin_until <= now
            {
                // Elapsed pin: clear it and re-sort among the unpinned
                // (each node takes this branch at most once per pin, so
                // the loop terminates).
                self.lru_remove(id);
                self.nodes[id].pin_until = Micros::ZERO;
                self.lru_insert(id);
                continue;
            }
            // Index membership is maintained eagerly: the first entry is
            // always a currently-valid candidate.
            debug_assert!({
                let n = &self.nodes[id];
                n.alive && n.ref_count == 0 && n.broadcast_pins == 0
                    && n.residency == Residency::Gpu
            } && self.is_gpu_leaf(id));
            self.lru_remove(id);
            // Discard may only remove fully childless nodes; a GPU node
            // whose children live in the CPU tier (possible when policies
            // are mixed across calls) must stay to anchor them.  (Like the
            // old heap's discarded pop, it stays unevictable until the
            // next push_candidate revalidates it.)
            if policy == EvictPolicy::Discard && !self.nodes[id].children.is_empty() {
                continue;
            }
            let tokens = self.nodes[id].tokens();
            out.freed_gpu_tokens += tokens;
            out.nodes += 1;
            self.gpu_tokens -= tokens;
            match policy {
                EvictPolicy::Discard => {
                    out.discarded_tokens += tokens;
                    self.remove_leaf(id);
                }
                EvictPolicy::OffloadToCpu => {
                    out.offloaded_tokens += tokens;
                    self.cpu_tokens += tokens;
                    if self.nodes[id].pin_count > 0 {
                        // Pinned via a locked CPU descendant: it leaves the
                        // GPU tier, so it leaves the pinned-GPU total too.
                        self.pinned_gpu_tokens -= tokens;
                    }
                    let n = &mut self.nodes[id];
                    n.residency = Residency::Cpu;
                    n.version += 1;
                    // A CPU parent whose children are gone stays in the
                    // tree; GPU ancestors may now be leaves.
                    let parent = self.nodes[id].parent;
                    self.nodes[parent].gpu_children -= 1;
                    self.push_candidate(parent);
                }
            }
        }
        if out.nodes > 0 {
            self.epoch += 1;
            self.maybe_compact();
        }
        out
    }

    fn remove_leaf(&mut self, id: NodeId) {
        debug_assert!(self.nodes[id].children.is_empty());
        debug_assert_eq!(self.nodes[id].broadcast_pins, 0, "broadcast node removed");
        if self.nodes[id].in_lru {
            self.lru_remove(id);
        }
        let parent = self.nodes[id].parent;
        let first = self.arena[self.nodes[id].off];
        self.nodes[parent].children.remove(&first);
        if self.nodes[id].residency == Residency::Gpu {
            self.nodes[parent].gpu_children -= 1;
        }
        let n = &mut self.nodes[id];
        n.alive = false;
        n.len = 0; // arena range abandoned; reclaimed by the next compaction
        self.live_nodes -= 1;
        self.free_slots.push(id);
        // The parent may have become an eviction candidate.
        self.push_candidate(parent);
    }

    // -- arena compaction -------------------------------------------------------

    /// Rebuild the token slab with only live edge ranges, rewriting each
    /// node's `off`.  Generational copy order: tenured ranges (everything
    /// *not* on the LRU candidate index — pinned, broadcast, parked,
    /// CPU-tier and inner nodes) go first in node-id order, then the LRU
    /// candidates from newest to coldest, so the ranges most likely to die
    /// next sit at the slab tail where future compactions reclaim them as
    /// a cheap truncation.
    ///
    /// Observable behaviour is unchanged by construction: node identities,
    /// `(last_access, version)` stamps, all token counters and the
    /// mutation epoch stay exactly as they were — only `off` values and
    /// the slab move.  The engine's epoch-guarded head-of-line fast path
    /// therefore stays valid across a compaction, and simulation results
    /// are bit-identical with compaction on or off (pinned by the
    /// non-compacting-oracle differential test in `proptests.rs`).
    pub fn compact_arena(&mut self) {
        let _prof = profiler::scope(profiler::Section::Compact);
        let live_tokens = (self.gpu_tokens + self.cpu_tokens) as usize;
        let mut fresh: Vec<Token> = Vec::with_capacity(live_tokens);
        for id in 0..self.nodes.len() {
            let n = &self.nodes[id];
            if id == ROOT || !n.alive || n.in_lru {
                continue;
            }
            let off = fresh.len();
            fresh.extend_from_slice(&self.arena[n.off..n.off + n.len]);
            self.nodes[id].off = off;
        }
        let candidates: Vec<NodeId> =
            self.lru.iter().rev().map(|&(_, _, _, id)| id).collect();
        for id in candidates {
            let n = &self.nodes[id];
            let off = fresh.len();
            fresh.extend_from_slice(&self.arena[n.off..n.off + n.len]);
            self.nodes[id].off = off;
        }
        debug_assert_eq!(fresh.len(), live_tokens);
        self.compacted_tokens += (self.arena.len() - fresh.len()) as u64;
        self.compactions += 1;
        self.arena = fresh;
    }

    /// Auto-compaction trigger, run after bulk reclaim paths (`evict`,
    /// `trim_cpu`): compact once the slab is past the floor and more than
    /// half dead.  A deterministic function of tree state, so identical
    /// op sequences compact at identical points on every run.
    fn maybe_compact(&mut self) {
        let live = (self.gpu_tokens + self.cpu_tokens) as usize;
        if self.auto_compact
            && self.arena.len() > COMPACT_MIN_ARENA
            && self.arena.len() > COMPACT_SLACK * live
        {
            self.compact_arena();
        }
    }

    /// Drop LRU CPU-tier nodes until at most `limit` CPU tokens remain.
    /// Only childless CPU nodes can be dropped (structure preserved).
    pub fn trim_cpu(&mut self, limit: u64) -> u64 {
        self.trim_cpu_with(limit, None)
    }

    /// [`trim_cpu`](Self::trim_cpu) with an optional demotion sink: each
    /// dropped leaf is reported as `(context_prefix, edge_tokens)` — the
    /// root→parent token path the leaf extends, and the leaf's own edge —
    /// *before* removal, so the storage tier can capture what the CPU
    /// tier is about to forget.  The sink only observes; which leaves are
    /// dropped, and in what order, is identical with or without it.
    pub fn trim_cpu_with(
        &mut self,
        limit: u64,
        mut sink: Option<&mut dyn FnMut(Vec<Token>, Vec<Token>)>,
    ) -> u64 {
        if self.cpu_tokens <= limit {
            return 0;
        }
        let mut dropped = 0u64;
        // CPU nodes are not on the GPU LRU list; scan (rare path).
        let mut cpu_leaves: Vec<(Micros, NodeId)> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(id, n)| {
                *id != ROOT
                    && n.alive
                    && n.residency == Residency::Cpu
                    && n.children.is_empty()
                    && n.ref_count == 0
                    && n.broadcast_pins == 0
            })
            .map(|(id, n)| (n.last_access, id))
            .collect();
        cpu_leaves.sort_unstable();
        for (_, id) in cpu_leaves {
            if self.cpu_tokens <= limit {
                break;
            }
            let tokens = self.nodes[id].tokens();
            self.cpu_tokens -= tokens;
            dropped += tokens;
            if let Some(sink) = sink.as_deref_mut() {
                let prefix = self.context_prefix_of(id);
                let n = &self.nodes[id];
                let edge = self.arena[n.off..n.off + n.len].to_vec();
                sink(prefix, edge);
            }
            self.remove_leaf(id);
        }
        if dropped > 0 {
            self.epoch += 1;
            self.maybe_compact();
        }
        dropped
    }

    /// Tokens on the root→`id` path *excluding* `id`'s own edge — the
    /// context under which `id`'s tokens were produced.  The storage
    /// tier keys demoted extents by (a hash of) this prefix.
    pub fn context_prefix_of(&self, id: NodeId) -> Vec<Token> {
        let mut chain = Vec::new();
        let mut cur = self.nodes[id].parent;
        while cur != ROOT {
            chain.push(cur);
            cur = self.nodes[cur].parent;
        }
        let total: usize = chain.iter().map(|&nid| self.nodes[nid].len).sum();
        let mut out = Vec::with_capacity(total);
        for &nid in chain.iter().rev() {
            let n = &self.nodes[nid];
            out.extend_from_slice(&self.arena[n.off..n.off + n.len]);
        }
        out
    }

    /// Promote every CPU-resident node on `path` back to GPU (the engine
    /// charges the PCIe reload and pool allocation).  Returns promoted
    /// token count.
    pub fn reload_path(&mut self, path: &[NodeId], now: Micros) -> u64 {
        let mut promoted = 0u64;
        for &id in path {
            let n = &mut self.nodes[id];
            if n.alive && n.residency == Residency::Cpu {
                n.residency = Residency::Gpu;
                n.last_access = now;
                n.version += 1;
                promoted += n.len as u64;
                if n.pin_count > 0 {
                    self.pinned_gpu_tokens += n.len as u64;
                }
                // The parent regained a GPU child and stops being a GPU
                // leaf.  (Parents on the reload path were just touched by
                // the match, so they are off the list already; this guard
                // covers out-of-path parents.)
                let parent = self.nodes[id].parent;
                self.nodes[parent].gpu_children += 1;
                if self.nodes[parent].in_lru {
                    self.lru_remove(parent);
                }
            }
        }
        self.cpu_tokens -= promoted;
        self.gpu_tokens += promoted;
        if promoted > 0 {
            self.epoch += 1;
        }
        promoted
    }

    /// Debug invariant: recomputed counters match node contents, links and
    /// the LRU list are consistent.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let mut gpu = 0u64;
        let mut cpu = 0u64;
        let mut bcast = 0u64;
        let mut live = 0usize;
        for (id, n) in self.nodes.iter().enumerate() {
            if !n.alive || id == ROOT {
                continue;
            }
            live += 1;
            if n.broadcast_pins > 0 {
                bcast += n.tokens();
                if n.pin_count == 0 {
                    return Err(format!(
                        "broadcast node {id} lost its lock pin (pin_count 0)"
                    ));
                }
            }
            if n.off + n.len > self.arena.len() {
                return Err(format!("node {id} range escapes the arena"));
            }
            if n.len == 0 {
                return Err(format!("live node {id} has an empty edge"));
            }
            match n.residency {
                Residency::Gpu => gpu += n.tokens(),
                Residency::Cpu => cpu += n.tokens(),
            }
            let parent = &self.nodes[n.parent];
            if !parent.alive {
                return Err(format!("node {id} has dead parent {}", n.parent));
            }
            if parent.children.get(&self.arena[n.off]) != Some(&id) {
                return Err(format!("node {id} not linked from parent"));
            }
        }
        if gpu != self.gpu_tokens {
            return Err(format!("gpu tokens {gpu} != counter {}", self.gpu_tokens));
        }
        if cpu != self.cpu_tokens {
            return Err(format!("cpu tokens {cpu} != counter {}", self.cpu_tokens));
        }
        if live != self.live_nodes {
            return Err(format!("live nodes {live} != counter {}", self.live_nodes));
        }
        if bcast != self.broadcast_tokens {
            return Err(format!(
                "broadcast tokens {bcast} != counter {}",
                self.broadcast_tokens
            ));
        }
        // Incremental GPU-child counters vs reality.
        for (id, n) in self.nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            let actual = n
                .children
                .values()
                .filter(|&&c| self.nodes[c].residency == Residency::Gpu)
                .count() as u32;
            if actual != n.gpu_children {
                return Err(format!(
                    "node {id} gpu_children {} != actual {actual}",
                    n.gpu_children
                ));
            }
        }
        // Arena: live ranges already validated per node above; the slab
        // must be at least as large as the live token total (compaction
        // shrinks it to exactly that).
        if gpu + cpu > self.arena.len() as u64 {
            return Err(format!(
                "arena {} smaller than live tokens {}",
                self.arena.len(),
                gpu + cpu
            ));
        }
        // LRU index: flags consistent, keys current, members are valid
        // candidates.
        for &(life, stamp, version, id) in &self.lru {
            let Some(n) = self.nodes.get(id) else {
                return Err(format!("lru entry for out-of-range node {id}"));
            };
            if !n.in_lru {
                return Err(format!("lru node {id} not flagged in_lru"));
            }
            if (n.last_access, n.version) != (stamp, version) {
                return Err(format!("lru key for node {id} is stale"));
            }
            if life != self.lifetime_component(n) {
                return Err(format!(
                    "lru lifetime component for node {id} is stale \
                     ({life} != {})",
                    self.lifetime_component(n)
                ));
            }
            if !(n.alive
                && n.ref_count == 0
                && n.broadcast_pins == 0
                && n.residency == Residency::Gpu
                && n.gpu_children == 0)
            {
                return Err(format!("lru node {id} is not a valid candidate"));
            }
        }
        let flagged = self.nodes.iter().filter(|n| n.in_lru).count();
        if flagged != self.lru.len() {
            return Err(format!(
                "{flagged} nodes flagged in_lru, {} in the index",
                self.lru.len()
            ));
        }
        let fast = self.evictable_gpu_tokens();
        let slow = self.evictable_gpu_tokens_slow();
        if fast != slow {
            return Err(format!(
                "evictable fast {fast} != slow {slow} (pinned={})",
                self.pinned_gpu_tokens
            ));
        }
        Ok(())
    }

    // -- test support -----------------------------------------------------------

    /// Eviction-order snapshot of the LRU candidate index.  Test support:
    /// the stale-re-entry regression test compares this against the slow
    /// `(last_access, version, id)` sort — the safety net that caught the
    /// intrusive-list → ordered-index swap.
    pub fn lru_order_for_tests(&self) -> Vec<NodeId> {
        self.lru.iter().map(|&(_, _, _, id)| id).collect()
    }

    /// The `(lifetime_component, last_access, version, id)` eviction key
    /// of a node (test support for the slow-order comparison; the leading
    /// component is constant 0 under `Lru`).
    pub fn lru_key_for_tests(&self, id: NodeId) -> (u64, Micros, u64, NodeId) {
        self.lru_key(id)
    }
}

impl Default for RadixTree {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(range: std::ops::Range<u32>) -> Vec<Token> {
        range.collect()
    }

    #[test]
    fn insert_then_full_match() {
        let mut t = RadixTree::new();
        let seq = toks(0..100);
        let ins = t.insert(&seq, Micros(1));
        assert_eq!(ins.new_gpu_tokens, 100);
        assert_eq!(t.gpu_tokens(), 100);
        let m = t.match_prefix(&seq, Micros(2));
        assert_eq!(m.gpu_tokens, 100);
        assert_eq!(m.cpu_tokens, 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_is_deduplicated() {
        let mut t = RadixTree::new();
        let a: Vec<Token> = (0..50).chain(100..150).collect();
        let b: Vec<Token> = (0..50).chain(200..250).collect();
        assert_eq!(t.insert(&a, Micros(1)).new_gpu_tokens, 100);
        // Second insert shares the first 50 tokens.
        assert_eq!(t.insert(&b, Micros(2)).new_gpu_tokens, 50);
        assert_eq!(t.gpu_tokens(), 150);
        t.check_invariants().unwrap();
    }

    #[test]
    fn partial_edge_match_splits() {
        let mut t = RadixTree::new();
        t.insert(&toks(0..100), Micros(1));
        let m = t.match_prefix(&toks(0..30), Micros(2));
        assert_eq!(m.gpu_tokens, 30);
        assert_eq!(m.path.len(), 1);
        // The 100-token edge is now split 30 + 70.
        assert_eq!(t.node_count(), 2);
        assert_eq!(t.gpu_tokens(), 100);
        t.check_invariants().unwrap();
    }

    #[test]
    fn split_is_zero_copy() {
        let mut t = RadixTree::new();
        t.insert(&toks(0..1000), Micros(1));
        let before = t.arena_len();
        t.match_prefix(&toks(0..400), Micros(2)); // forces a split
        assert_eq!(t.arena_len(), before, "split must not grow the arena");
        assert_eq!(t.node_count(), 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn insert_parts_equals_insert_of_concatenation() {
        let head = toks(0..500);
        let tail = toks(9000..9300);
        let full: Vec<Token> = head.iter().chain(tail.iter()).copied().collect();

        let mut a = RadixTree::new();
        let mut b = RadixTree::new();
        a.insert(&toks(0..200), Micros(1));
        b.insert(&toks(0..200), Micros(1));
        let ia = a.insert(&full, Micros(2));
        let ib = b.insert_parts(&head, &tail, Micros(2));
        assert_eq!(ia.new_gpu_tokens, ib.new_gpu_tokens);
        assert_eq!(ia.cpu_tokens, ib.cpu_tokens);
        assert_eq!(ia.path.len(), ib.path.len());
        assert_eq!(a.gpu_tokens(), b.gpu_tokens());
        assert_eq!(a.node_count(), b.node_count());
        // Both trees must now fully match the concatenation.
        assert_eq!(b.match_prefix(&full, Micros(3)).total(), full.len() as u64);
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
    }

    #[test]
    fn eviction_frees_lru_first() {
        let mut t = RadixTree::new();
        let a = toks(0..100);
        let b = toks(1000..1100);
        t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        // Touch `a` so `b` is LRU.
        t.match_prefix(&a, Micros(3));
        let ev = t.evict(50, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100); // whole-leaf granularity
        assert_eq!(t.gpu_tokens(), 100);
        // `a` must still fully match; `b` is gone.
        assert_eq!(t.match_prefix(&a, Micros(4)).gpu_tokens, 100);
        assert_eq!(t.match_prefix(&b, Micros(5)).gpu_tokens, 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn touched_candidate_is_parked_until_repushed() {
        // Heap-parity quirk: a candidate touched by a bare match loses its
        // (only) valid LRU registration and survives even a full eviction
        // sweep until something re-pushes it.
        let mut t = RadixTree::new();
        let a = toks(0..100);
        t.insert(&a, Micros(1)); // leaf pushed as candidate
        t.match_prefix(&a, Micros(2)); // touch: registration goes stale
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 0, "touched leaf must be parked");
        assert_eq!(t.gpu_tokens(), 100);
        // An unlock re-push restores evictability.
        let m = t.match_prefix(&a, Micros(3));
        t.lock_path(&m.path);
        t.unlock_path(&m.path);
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100);
        t.check_invariants().unwrap();
    }

    #[test]
    fn stale_stamp_reentry_sorts_before_fresher_candidates() {
        // A node unlocked long after its last touch must re-enter the LRU
        // order at its (old) stamp, i.e. be evicted before fresher nodes —
        // paused agents' caches losing recency is the paper's §3 pathology.
        let mut t = RadixTree::new();
        let a = toks(0..100);
        let b = toks(1000..1100);
        let ins = t.insert(&a, Micros(1));
        t.lock_path(&ins.path);
        t.insert(&b, Micros(50)); // fresher candidate while `a` is locked
        t.unlock_path(&ins.path); // `a` re-enters with stamp 1
        let ev = t.evict(10, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100);
        // `a` (stamp 1) went first; `b` survives.
        assert_eq!(t.match_prefix(&b, Micros(60)).gpu_tokens, 100);
        assert_eq!(t.match_prefix(&a, Micros(61)).gpu_tokens, 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn locked_paths_survive_eviction() {
        let mut t = RadixTree::new();
        let a = toks(0..100);
        let b = toks(1000..1100);
        let ins = t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        t.lock_path(&ins.path);
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100); // only b evicted
        assert_eq!(t.match_prefix(&a, Micros(3)).gpu_tokens, 100);
        t.unlock_path(&ins.path);
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100);
        assert_eq!(t.gpu_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn offload_then_reload_roundtrip() {
        let mut t = RadixTree::new();
        let a = toks(0..100);
        t.insert(&a, Micros(1));
        let ev = t.evict(u64::MAX, EvictPolicy::OffloadToCpu);
        assert_eq!(ev.offloaded_tokens, 100);
        assert_eq!(t.gpu_tokens(), 0);
        assert_eq!(t.cpu_tokens(), 100);
        // Still matchable, but in the CPU tier.
        let m = t.match_prefix(&a, Micros(2));
        assert_eq!(m.cpu_tokens, 100);
        assert_eq!(m.gpu_tokens, 0);
        let reloaded = t.reload_path(&m.path, Micros(3));
        assert_eq!(reloaded, 100);
        assert_eq!(t.gpu_tokens(), 100);
        assert_eq!(t.cpu_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn inner_nodes_evicted_after_children() {
        let mut t = RadixTree::new();
        let a: Vec<Token> = (0..50).chain(100..150).collect();
        let b: Vec<Token> = (0..50).chain(200..250).collect();
        t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        // Evict everything: should take both leaves AND then the shared
        // 50-token parent.
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 150);
        assert_eq!(t.gpu_tokens(), 0);
        assert_eq!(t.node_count(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn evictable_accounting() {
        let mut t = RadixTree::new();
        let a = toks(0..100);
        let ins = t.insert(&a, Micros(1));
        assert_eq!(t.evictable_gpu_tokens(), 100);
        t.lock_path(&ins.path);
        assert_eq!(t.evictable_gpu_tokens(), 0);
        t.unlock_path(&ins.path);
        assert_eq!(t.evictable_gpu_tokens(), 100);
    }

    #[test]
    fn trim_cpu_caps_the_tier() {
        let mut t = RadixTree::new();
        t.insert(&toks(0..100), Micros(1));
        t.insert(&toks(1000..1200), Micros(2));
        t.evict(u64::MAX, EvictPolicy::OffloadToCpu);
        assert_eq!(t.cpu_tokens(), 300);
        let dropped = t.trim_cpu(150);
        assert!(dropped >= 100);
        assert!(t.cpu_tokens() <= 200);
        t.check_invariants().unwrap();
    }

    /// The demotion sink observes exactly what `trim_cpu` drops — the
    /// dropped leaf's edge plus the root→parent token prefix it extended
    /// — and its presence changes nothing about what is dropped.
    #[test]
    fn trim_cpu_sink_reports_dropped_extents() {
        let mk = || {
            let mut t = RadixTree::new();
            // Shared 100-token head, two tails → head becomes an inner
            // node, tails become CPU leaves under it after offload.
            let a: Vec<Token> = (0..100).chain(1_000..1_200).collect();
            let b: Vec<Token> = (0..100).chain(2_000..2_100).collect();
            t.insert(&a, Micros(1));
            t.insert(&b, Micros(2));
            t.evict(u64::MAX, EvictPolicy::OffloadToCpu);
            t
        };
        let mut plain = mk();
        let mut observed = mk();
        let mut extents: Vec<(Vec<Token>, Vec<Token>)> = Vec::new();
        let dropped_plain = plain.trim_cpu(0);
        let dropped = observed
            .trim_cpu_with(0, Some(&mut |prefix, edge| extents.push((prefix, edge))));
        assert_eq!(dropped, dropped_plain, "sink must not change what is dropped");
        assert_eq!(observed.cpu_tokens(), plain.cpu_tokens());
        assert_eq!(observed.epoch(), plain.epoch());
        let total: usize = extents.iter().map(|(_, e)| e.len()).sum();
        assert_eq!(total as u64, dropped);
        for (prefix, edge) in &extents {
            assert!(!edge.is_empty());
            // Every reported extent reconstructs a real inserted sequence:
            // prefix ++ edge is a prefix of one of the two prompts.
            let full: Vec<Token> = prefix.iter().chain(edge.iter()).copied().collect();
            let a: Vec<Token> = (0..100).chain(1_000..1_200).collect();
            let b: Vec<Token> = (0..100).chain(2_000..2_100).collect();
            assert!(
                a.starts_with(&full) || b.starts_with(&full),
                "extent must reconstruct an inserted sequence"
            );
        }
        observed.check_invariants().unwrap();
    }

    #[test]
    fn epoch_tracks_content_mutations_only() {
        let mut t = RadixTree::new();
        let e0 = t.epoch();
        t.insert(&toks(0..100), Micros(1));
        let e1 = t.epoch();
        assert!(e1 > e0, "insert must bump the epoch");
        let m = t.match_prefix(&toks(0..100), Micros(2));
        assert_eq!(t.epoch(), e1, "a full (split-free) match must not bump the epoch");
        // A splitting match changes path structure and must bump it.
        let mut t2 = RadixTree::new();
        t2.insert(&toks(0..100), Micros(1));
        let e2 = t2.epoch();
        t2.match_prefix(&toks(0..40), Micros(2));
        assert!(t2.epoch() > e2, "a splitting match must bump the epoch");
        // Re-arm candidacy (the match parked the leaf), then evict.
        t.lock_path(&m.path);
        t.unlock_path(&m.path);
        assert_eq!(t.epoch(), e1, "lock/unlock must not bump the epoch");
        let ev = t.evict(u64::MAX, EvictPolicy::OffloadToCpu);
        assert_eq!(ev.offloaded_tokens, 100);
        assert!(t.epoch() > e1, "eviction must bump the epoch");
    }

    #[test]
    fn broadcast_pin_survives_eviction_in_both_policies() {
        for policy in [EvictPolicy::Discard, EvictPolicy::OffloadToCpu] {
            let mut t = RadixTree::new();
            let shared = toks(0..512);
            let other = toks(9_000..9_400);
            let ins = t.insert(&shared, Micros(1));
            t.insert(&other, Micros(2));
            t.pin_broadcast(&ins.path);
            assert_eq!(t.broadcast_tokens(), 512);
            assert_eq!(t.evictable_gpu_tokens(), 400, "pin must leave the aggregate");
            let ev = t.evict(u64::MAX, policy);
            assert_eq!(ev.freed_gpu_tokens, 400, "{policy:?}: only the other leaf moves");
            let m = t.match_prefix(&shared, Micros(3));
            assert_eq!(m.gpu_tokens, 512, "{policy:?}: broadcast prefix must stay GPU");
            assert_eq!(m.broadcast_tokens, 512);
            t.check_invariants().unwrap();
        }
    }

    #[test]
    fn demote_broadcast_restores_evictability() {
        let mut t = RadixTree::new();
        let shared = toks(0..256);
        let ins = t.insert(&shared, Micros(1));
        t.pin_broadcast(&ins.path);
        assert_eq!(t.evict(u64::MAX, EvictPolicy::Discard).freed_gpu_tokens, 0);
        t.demote_broadcast(&ins.path);
        assert_eq!(t.broadcast_tokens(), 0);
        assert_eq!(t.evictable_gpu_tokens(), 256);
        // The demoted deepest node re-armed as a candidate (unlock rule).
        assert_eq!(t.evict(u64::MAX, EvictPolicy::Discard).freed_gpu_tokens, 256);
        assert_eq!(t.gpu_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn broadcast_coverage_survives_edge_splits() {
        let mut t = RadixTree::new();
        let shared = toks(0..512);
        let ins = t.insert(&shared, Micros(1));
        t.pin_broadcast(&ins.path);
        // A partial match splits the broadcast edge; both halves stay
        // covered and the token total is unchanged.
        let m = t.match_prefix(&toks(0..100), Micros(2));
        assert_eq!(m.broadcast_tokens, 100);
        assert_eq!(t.broadcast_tokens(), 512);
        assert_eq!(t.evict(u64::MAX, EvictPolicy::Discard).freed_gpu_tokens, 0);
        t.check_invariants().unwrap();
        // Demoting via the original path releases both halves.
        t.demote_broadcast(&ins.path);
        assert_eq!(t.broadcast_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn overlapping_broadcast_pins_nest() {
        let mut t = RadixTree::new();
        let a = toks(0..128);
        let ia = t.insert(&a, Micros(1));
        t.pin_broadcast(&ia.path);
        let ib = t.insert(&a, Micros(2)); // same path, second registration
        t.pin_broadcast(&ib.path);
        assert_eq!(t.broadcast_tokens(), 128, "per-node, not per-registration");
        t.demote_broadcast(&ia.path);
        assert_eq!(t.broadcast_tokens(), 128, "still covered by the second pin");
        assert_eq!(t.evict(u64::MAX, EvictPolicy::Discard).freed_gpu_tokens, 0);
        t.demote_broadcast(&ib.path);
        assert_eq!(t.broadcast_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn peek_prefix_is_read_only() {
        let mut t = RadixTree::new();
        t.insert(&toks(0..100), Micros(1));
        let epoch = t.epoch();
        let nodes = t.node_count();
        // Peek a partial prefix: no split, no epoch bump, exact count.
        assert_eq!(t.peek_prefix(&toks(0..40)), (40, 0));
        assert_eq!(t.peek_prefix(&toks(0..100)), (100, 0));
        assert_eq!(t.peek_prefix(&toks(50..90)), (0, 0));
        assert_eq!(t.node_count(), nodes, "peek must not split edges");
        assert_eq!(t.epoch(), epoch, "peek must not bump the epoch");
        // Residency split: offload, then peek reports the CPU tier.
        let m = t.match_prefix(&toks(0..100), Micros(2));
        t.lock_path(&m.path);
        t.unlock_path(&m.path);
        t.evict(u64::MAX, EvictPolicy::OffloadToCpu);
        assert_eq!(t.peek_prefix(&toks(0..100)), (0, 100));
        t.check_invariants().unwrap();
    }

    #[test]
    fn compaction_preserves_matches_and_epoch() {
        let mut t = RadixTree::new();
        t.set_auto_compaction(false);
        let a: Vec<Token> = (0..50).chain(100..150).collect();
        let b: Vec<Token> = (0..50).chain(200..250).collect();
        let c = toks(5000..5300);
        t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        t.insert(&c, Micros(3));
        // Park `a` and `b` (touch quirk), leaving `c` the only candidate.
        t.match_prefix(&a, Micros(4));
        t.match_prefix(&b, Micros(5));
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.discarded_tokens, 300, "only `c` was evictable");
        let epoch = t.epoch();
        let before = t.arena_len();
        t.compact_arena();
        assert!(t.arena_len() < before, "dead range must be reclaimed");
        assert_eq!(t.arena_len() as u64, t.gpu_tokens() + t.cpu_tokens());
        assert_eq!(t.epoch(), epoch, "compaction must not bump the epoch");
        assert_eq!(t.compactions(), 1);
        assert_eq!(t.compacted_tokens(), 300);
        assert_eq!(t.match_prefix(&a, Micros(6)).gpu_tokens, 100);
        assert_eq!(t.match_prefix(&b, Micros(7)).gpu_tokens, 100);
        t.check_invariants().unwrap();
    }

    #[test]
    fn auto_compaction_bounds_arena_under_eviction_churn() {
        // The acceptance bound: a thrashing-scale insert/evict churn keeps
        // the slab under `COMPACT_MIN_ARENA` plus one round of inserts,
        // where the pre-compaction slab grew without bound.
        let mut t = RadixTree::new();
        let round_tokens = 20_000usize;
        for round in 0u32..40 {
            for k in 0..10u32 {
                let base = (round * 10 + k + 1) * 100_000;
                let seq: Vec<Token> = (base..base + 2_000).collect();
                t.insert(&seq, Micros(u64::from(round) + 1));
            }
            t.evict(u64::MAX, EvictPolicy::Discard);
            assert!(
                t.arena_len() <= COMPACT_MIN_ARENA + round_tokens,
                "round {round}: slab {} grew past the compaction bound",
                t.arena_len()
            );
            t.check_invariants().unwrap();
        }
        assert!(t.compactions() > 0, "churn must have triggered compaction");
        assert!(t.compacted_tokens() > 0);
    }

    #[test]
    fn lru_policy_ignores_lifetime_stamps() {
        // Under the default policy, stamping is a no-op and the 4-tuple
        // key's leading component is constant 0 — eviction order is
        // bit-identical to the classic recency order.
        let mut t = RadixTree::with_policy(KvLifetimePolicy::Lru);
        let a = toks(0..100);
        let b = toks(1000..1100);
        let ia = t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        t.stamp_path_lifetime(&ia.path, 999, Micros(777));
        let keys: Vec<_> =
            t.lru_order_for_tests().iter().map(|&id| t.lru_key_for_tests(id)).collect();
        assert!(keys.iter().all(|k| k.0 == 0), "Lru leading component must stay 0");
        // `a` (stamp 1) still evicts before `b` despite the stamp attempt.
        t.evict(50, EvictPolicy::Discard);
        assert_eq!(t.match_prefix(&a, Micros(3)).gpu_tokens, 0);
        assert_eq!(t.match_prefix(&b, Micros(4)).gpu_tokens, 100);
        t.check_invariants().unwrap();
    }

    #[test]
    fn steps_class_outranks_recency() {
        // StepsToExecution: a *fresher* node in a lower class evicts
        // before a staler node in a higher class.
        let mut t = RadixTree::with_policy(KvLifetimePolicy::StepsToExecution);
        let a = toks(0..100);
        let b = toks(1000..1100);
        let ia = t.insert(&a, Micros(1));
        t.insert(&b, Micros(2)); // fresher, but class 0
        t.stamp_path_lifetime(&ia.path, 5, Micros::ZERO);
        let ev = t.evict(50, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100);
        assert_eq!(t.match_prefix(&b, Micros(3)).gpu_tokens, 0, "class 0 goes first");
        assert_eq!(t.match_prefix(&a, Micros(4)).gpu_tokens, 100);
        t.check_invariants().unwrap();
    }

    #[test]
    fn tool_ttl_pin_defers_eviction_until_expiry() {
        let mut t = RadixTree::with_policy(KvLifetimePolicy::ToolTtl);
        let a = toks(0..100);
        let b = toks(1000..1100);
        let ia = t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        t.stamp_path_lifetime(&ia.path, 0, Micros(100)); // pinned until t=100
        // Before expiry: the unpinned (fresher!) `b` is taken instead.
        let ev = t.evict_at(50, EvictPolicy::Discard, Micros(50));
        assert_eq!(ev.freed_gpu_tokens, 100);
        assert_eq!(t.match_prefix(&b, Micros(60)).gpu_tokens, 0);
        t.check_invariants().unwrap();
        // Re-arm `a`'s candidacy (matches above only touched root-misses,
        // but `a` itself was never parked — it is still registered).
        // After expiry the pin is lazily cleared and `a` evicts normally.
        let ev = t.evict_at(u64::MAX, EvictPolicy::Discard, Micros(150));
        assert_eq!(ev.freed_gpu_tokens, 100);
        assert_eq!(t.gpu_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn tool_ttl_live_pins_evict_as_last_resort() {
        // Pinning shapes order, never feasibility: when everything is
        // pinned into the future, eviction still makes progress.
        let mut t = RadixTree::with_policy(KvLifetimePolicy::ToolTtl);
        let a = toks(0..100);
        let ia = t.insert(&a, Micros(1));
        t.stamp_path_lifetime(&ia.path, 0, Micros(1_000_000));
        let ev = t.evict_at(u64::MAX, EvictPolicy::Discard, Micros(10));
        assert_eq!(ev.freed_gpu_tokens, 100, "live pin must not block a forced evict");
        assert_eq!(t.gpu_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn stamping_changes_neither_epoch_nor_feasibility() {
        let mut t = RadixTree::with_policy(KvLifetimePolicy::StepsToExecution);
        let ia = t.insert(&toks(0..100), Micros(1));
        let epoch = t.epoch();
        let evictable = t.evictable_gpu_tokens();
        t.stamp_path_lifetime(&ia.path, 7, Micros(42));
        assert_eq!(t.epoch(), epoch, "stamping must not bump the epoch");
        assert_eq!(t.evictable_gpu_tokens(), evictable);
        t.check_invariants().unwrap();
    }

    #[test]
    fn split_inherits_lifetime_stamps() {
        // A partial match splits a stamped edge; both halves keep the
        // stamp so coverage stays contiguous (mirrors broadcast pins).
        let mut t = RadixTree::with_policy(KvLifetimePolicy::ToolTtl);
        let ia = t.insert(&toks(0..100), Micros(1));
        t.stamp_path_lifetime(&ia.path, 3, Micros(500));
        t.insert(&toks(2000..2100), Micros(2)); // unpinned victim
        t.match_prefix(&toks(0..40), Micros(3)); // splits the stamped edge
        t.check_invariants().unwrap();
        // Both halves are now parked (touch quirk); re-arm and verify the
        // split-off upper half still sorts behind the unpinned node.
        let m = t.match_prefix(&toks(0..100), Micros(4));
        t.lock_path(&m.path);
        t.unlock_path(&m.path);
        let ev = t.evict_at(50, EvictPolicy::Discard, Micros(10));
        assert_eq!(ev.freed_gpu_tokens, 100);
        assert_eq!(t.match_prefix(&toks(2000..2100), Micros(5)).gpu_tokens, 0);
        assert_eq!(t.match_prefix(&toks(0..100), Micros(6)).gpu_tokens, 100);
        t.check_invariants().unwrap();
    }

    #[test]
    fn agentic_growth_pattern_reuses_own_history() {
        // An agent's request k+1 extends request k's sequence: the whole
        // previous context should hit.
        let mut t = RadixTree::new();
        let mut history = toks(0..500);
        t.insert(&history, Micros(1));
        for step in 0..5u32 {
            history.extend((step + 1) * 10_000..(step + 1) * 10_000 + 300);
            let m = t.match_prefix(&history, Micros(2 + step as u64));
            assert_eq!(m.total(), history.len() as u64 - 300);
            t.insert(&history, Micros(3 + step as u64));
        }
        t.check_invariants().unwrap();
    }
}
