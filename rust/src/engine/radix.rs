//! Radix-tree prefix cache with LRU eviction (SGLang-style).
//!
//! Cached token sequences are stored in a compressed trie: each node holds a
//! token-run edge label and the KV slots for those tokens.  A new request
//! matches its prompt from the root; matched prefixes reuse cached KV and
//! only the divergent suffix is prefetched.  Under memory pressure, LRU
//! *leaves* with no active references are evicted — either discarded
//! (vanilla) or demoted to a CPU tier (HiCache) that can be matched but must
//! be reloaded over the host link before use.
//!
//! This is exactly the structure whose recency-based eviction produces
//! middle-phase thrashing (paper §3): a paused agent's path loses recency
//! while it waits on a tool, gets evicted, and must be recomputed on resume.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::core::{Micros, Token};

pub type NodeId = usize;

const ROOT: NodeId = 0;

/// Where a node's KV currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    Gpu,
    Cpu,
}

#[derive(Debug)]
struct Node {
    key: Vec<Token>,
    children: HashMap<Token, NodeId>,
    parent: NodeId,
    ref_count: u32,
    /// Number of locked nodes in this node's subtree (including itself).
    /// A node with `pin_count > 0` lies on a root→locked path and cannot
    /// be reclaimed; maintained incrementally by lock/unlock walks.
    pin_count: u32,
    last_access: Micros,
    residency: Residency,
    alive: bool,
    /// Bumped on every access; stale LRU heap entries are skipped.
    version: u64,
}

impl Node {
    fn tokens(&self) -> u64 {
        self.key.len() as u64
    }
}

/// Result of matching a prompt against the tree.
#[derive(Debug, Clone, Default)]
pub struct MatchResult {
    /// Node path (root excluded) covering the matched prefix, in order.
    pub path: Vec<NodeId>,
    /// Matched tokens resident on GPU.
    pub gpu_tokens: u64,
    /// Matched tokens resident in the CPU tier (must be reloaded).
    pub cpu_tokens: u64,
}

impl MatchResult {
    pub fn total(&self) -> u64 {
        self.gpu_tokens + self.cpu_tokens
    }
}

/// Result of inserting a sequence.
#[derive(Debug, Clone, Default)]
pub struct InsertResult {
    /// Full node path (root excluded) covering the sequence.
    pub path: Vec<NodeId>,
    /// Tokens newly added to the GPU tier by this insert.
    pub new_gpu_tokens: u64,
    /// Matched CPU-tier tokens along the path (caller decides reload).
    pub cpu_tokens: u64,
}

/// Outcome of an eviction request.
#[derive(Debug, Clone, Default)]
pub struct EvictResult {
    /// GPU token slots freed.
    pub freed_gpu_tokens: u64,
    /// Tokens demoted to the CPU tier (Offload mode only).
    pub offloaded_tokens: u64,
    /// Tokens dropped entirely.
    pub discarded_tokens: u64,
    /// Number of nodes touched.
    pub nodes: usize,
}

/// Eviction behaviour (mirrors `config::EvictionMode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    Discard,
    OffloadToCpu,
}

/// The prefix cache.
pub struct RadixTree {
    nodes: Vec<Node>,
    free_slots: Vec<NodeId>,
    gpu_tokens: u64,
    cpu_tokens: u64,
    /// GPU tokens pinned by locked paths (incremental; see `pin_count`).
    pinned_gpu_tokens: u64,
    /// Lazy min-heap of eviction candidates: (last_access, version, id).
    lru: BinaryHeap<Reverse<(Micros, u64, NodeId)>>,
}

impl RadixTree {
    pub fn new() -> RadixTree {
        let root = Node {
            key: Vec::new(),
            children: HashMap::new(),
            parent: ROOT,
            ref_count: 1, // the root is never evictable
            pin_count: 0,
            last_access: Micros::ZERO,
            residency: Residency::Gpu,
            alive: true,
            version: 0,
        };
        RadixTree {
            nodes: vec![root],
            free_slots: Vec::new(),
            gpu_tokens: 0,
            cpu_tokens: 0,
            pinned_gpu_tokens: 0,
            lru: BinaryHeap::new(),
        }
    }

    /// Tokens currently resident on GPU (must equal the pool's `used` minus
    /// per-request transient allocations).
    pub fn gpu_tokens(&self) -> u64 {
        self.gpu_tokens
    }

    /// Tokens parked in the CPU tier.
    pub fn cpu_tokens(&self) -> u64 {
        self.cpu_tokens
    }

    /// Number of live nodes (excluding the root).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.alive).count() - 1
    }

    // -- allocation ---------------------------------------------------------

    fn alloc_node(&mut self, node: Node) -> NodeId {
        if let Some(id) = self.free_slots.pop() {
            self.nodes[id] = node;
            id
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    fn touch(&mut self, id: NodeId, now: Micros) {
        let node = &mut self.nodes[id];
        node.last_access = now;
        node.version += 1;
    }

    /// True when `id` has no GPU-resident children.  In Offload mode a
    /// node's children may be demoted to the CPU tier without being
    /// removed; the node is then a *GPU leaf* and must stay evictable or
    /// GPU inner nodes leak unreclaimably.
    fn is_gpu_leaf(&self, id: NodeId) -> bool {
        self.nodes[id]
            .children
            .values()
            .all(|&c| self.nodes[c].residency == Residency::Cpu)
    }

    /// Register `id` as a potential LRU candidate with its current stamp.
    fn push_candidate(&mut self, id: NodeId) {
        if id == ROOT {
            return;
        }
        let n = &self.nodes[id];
        if n.alive
            && n.ref_count == 0
            && n.residency == Residency::Gpu
            && self.is_gpu_leaf(id)
        {
            self.lru.push(Reverse((n.last_access, n.version, id)));
        }
    }

    /// Split `id`'s edge so its first `at` tokens become a new parent node.
    /// Returns the new parent's id.
    fn split(&mut self, id: NodeId, at: usize) -> NodeId {
        debug_assert!(at > 0 && at < self.nodes[id].key.len());
        let (upper_key, parent, last_access, residency) = {
            let n = &mut self.nodes[id];
            let upper_key: Vec<Token> = n.key[..at].to_vec();
            let rest: Vec<Token> = n.key[at..].to_vec();
            n.key = rest;
            (upper_key, n.parent, n.last_access, n.residency)
        };
        let first_upper = upper_key[0];
        // Locks live on the *deepest* node of a request's path only (see
        // `lock_path`), so the new upper node starts unreferenced: the
        // still-locked lower half protects it transitively via the child
        // link.  Copying the ref here would leak it when the locker later
        // unlocks the lower node.
        let lower_pins = self.nodes[id].pin_count;
        let upper = self.alloc_node(Node {
            key: upper_key,
            children: HashMap::new(),
            parent,
            ref_count: 0,
            // The upper half sits on every root→locked path the lower half
            // is on; pinned-token totals are unchanged by the split.
            pin_count: lower_pins,
            last_access,
            residency,
            alive: true,
            version: 0,
        });
        let first_lower = self.nodes[id].key[0];
        self.nodes[upper].children.insert(first_lower, id);
        self.nodes[id].parent = upper;
        self.nodes[parent].children.insert(first_upper, upper);
        upper
    }

    // -- match / insert -------------------------------------------------------

    /// Match `tokens` against the tree, splitting edges so the matched
    /// prefix is covered by whole nodes.  Updates recency on the path.
    pub fn match_prefix(&mut self, tokens: &[Token], now: Micros) -> MatchResult {
        let mut result = MatchResult::default();
        let mut cur = ROOT;
        let mut pos = 0usize;
        while pos < tokens.len() {
            let Some(&child) = self.nodes[cur].children.get(&tokens[pos]) else {
                break;
            };
            let klen = self.nodes[child].key.len();
            let maxcmp = klen.min(tokens.len() - pos);
            let same = {
                let key = &self.nodes[child].key;
                // Fast path: whole-window slice equality compiles to memcmp
                // (full-edge matches dominate agent-history reuse).
                if key[..maxcmp] == tokens[pos..pos + maxcmp] {
                    maxcmp
                } else {
                    key[..maxcmp]
                        .iter()
                        .zip(&tokens[pos..pos + maxcmp])
                        .take_while(|(a, b)| a == b)
                        .count()
                }
            };
            if same == 0 {
                break;
            }
            let matched_node = if same < klen {
                // Partial edge: split so the matched half is its own node.
                self.split(child, same)
            } else {
                child
            };
            self.touch(matched_node, now);
            match self.nodes[matched_node].residency {
                Residency::Gpu => result.gpu_tokens += same as u64,
                Residency::Cpu => result.cpu_tokens += same as u64,
            }
            result.path.push(matched_node);
            pos += same;
            cur = matched_node;
            if same < klen {
                break; // diverged inside the edge
            }
        }
        result
    }

    /// Insert `tokens`, reusing any matched prefix.  New tokens land on GPU.
    pub fn insert(&mut self, tokens: &[Token], now: Micros) -> InsertResult {
        let m = self.match_prefix(tokens, now);
        let matched = m.total() as usize;
        let mut path = m.path;
        let cur = path.last().copied().unwrap_or(ROOT);
        let mut new_gpu = 0u64;
        if matched < tokens.len() {
            let rest: Vec<Token> = tokens[matched..].to_vec();
            new_gpu = rest.len() as u64;
            let first = rest[0];
            let leaf = self.alloc_node(Node {
                key: rest,
                children: HashMap::new(),
                parent: cur,
                ref_count: 0,
                pin_count: 0,
                last_access: now,
                residency: Residency::Gpu,
                alive: true,
                version: 0,
            });
            self.nodes[cur].children.insert(first, leaf);
            self.gpu_tokens += new_gpu;
            path.push(leaf);
            self.push_candidate(leaf);
        }
        InsertResult { path, new_gpu_tokens: new_gpu, cpu_tokens: m.cpu_tokens }
    }

    // -- locking ---------------------------------------------------------------

    /// Prevent every node on `path` from being evicted.
    ///
    /// Only the deepest node carries the reference: ancestors are protected
    /// transitively because eviction only ever removes childless nodes.
    /// This keeps locks stable across later edge splits.
    pub fn lock_path(&mut self, path: &[NodeId]) {
        if let Some(&last) = path.last() {
            debug_assert!(self.nodes[last].alive);
            self.nodes[last].ref_count += 1;
            // Pin the root→last chain (O(depth), keeps the evictable
            // counter O(1) to read — the controller samples it every step).
            let mut id = last;
            while id != ROOT {
                let n = &mut self.nodes[id];
                n.pin_count += 1;
                if n.pin_count == 1 && n.residency == Residency::Gpu {
                    self.pinned_gpu_tokens += n.key.len() as u64;
                }
                id = n.parent;
            }
        }
    }

    /// Release a previous `lock_path`; nodes become eviction candidates.
    pub fn unlock_path(&mut self, path: &[NodeId]) {
        if let Some(&last) = path.last() {
            debug_assert!(self.nodes[last].ref_count > 0, "unlock of unlocked node");
            self.nodes[last].ref_count -= 1;
            let mut id = last;
            while id != ROOT {
                let n = &mut self.nodes[id];
                debug_assert!(n.pin_count > 0);
                n.pin_count -= 1;
                if n.pin_count == 0 && n.residency == Residency::Gpu {
                    self.pinned_gpu_tokens -= n.key.len() as u64;
                }
                id = n.parent;
            }
            self.push_candidate(last);
        }
    }

    // -- eviction ---------------------------------------------------------------

    /// GPU tokens that could be freed right now (unlocked subtrees).
    /// O(1): `gpu_tokens - pinned_gpu_tokens`, maintained incrementally.
    pub fn evictable_gpu_tokens(&self) -> u64 {
        self.gpu_tokens - self.pinned_gpu_tokens
    }

    /// Reference implementation of [`evictable_gpu_tokens`] — O(n) subtree
    /// walk, used by `check_invariants` and tests.
    pub fn evictable_gpu_tokens_slow(&self) -> u64 {
        // A node is evictable iff it and all its descendants are unlocked.
        // Compute by propagating "subtree locked" from leaves up; simpler:
        // sum over nodes that are unlocked and whose entire subtree is
        // unlocked.  We do a post-order accumulation.
        let mut locked_subtree = vec![false; self.nodes.len()];
        // Iterative post-order: process children before parents using a
        // stack of (node, visited) pairs.
        let mut stack = vec![(ROOT, false)];
        let mut total = 0u64;
        while let Some((id, visited)) = stack.pop() {
            if visited {
                let n = &self.nodes[id];
                let mut locked = n.ref_count > 0 && id != ROOT || id == ROOT;
                for (&_, &c) in &n.children {
                    locked |= locked_subtree[c];
                }
                locked_subtree[id] = locked;
                if id != ROOT && !locked && n.residency == Residency::Gpu {
                    total += n.tokens();
                }
            } else {
                stack.push((id, true));
                for (&_, &c) in &self.nodes[id].children {
                    if self.nodes[c].alive {
                        stack.push((c, false));
                    }
                }
            }
        }
        total
    }

    /// Evict LRU leaves until `want` GPU tokens are freed or nothing is
    /// evictable.  In `OffloadToCpu` mode evicted nodes stay matchable in
    /// the CPU tier.
    pub fn evict(&mut self, want: u64, policy: EvictPolicy) -> EvictResult {
        let mut out = EvictResult::default();
        while out.freed_gpu_tokens < want {
            let Some(Reverse((stamp, version, id))) = self.lru.pop() else {
                break;
            };
            // Lazy validation: skip stale heap entries.
            let valid = {
                let n = &self.nodes[id];
                n.alive
                    && n.ref_count == 0
                    && n.residency == Residency::Gpu
                    && n.version == version
                    && n.last_access == stamp
            } && self.is_gpu_leaf(id);
            if !valid {
                continue;
            }
            // Discard may only remove fully childless nodes; a GPU node
            // whose children live in the CPU tier (possible when policies
            // are mixed across calls) must stay to anchor them.
            if policy == EvictPolicy::Discard && !self.nodes[id].children.is_empty() {
                continue;
            }
            let tokens = self.nodes[id].tokens();
            out.freed_gpu_tokens += tokens;
            out.nodes += 1;
            self.gpu_tokens -= tokens;
            match policy {
                EvictPolicy::Discard => {
                    out.discarded_tokens += tokens;
                    self.remove_leaf(id);
                }
                EvictPolicy::OffloadToCpu => {
                    out.offloaded_tokens += tokens;
                    self.cpu_tokens += tokens;
                    let n = &mut self.nodes[id];
                    if n.pin_count > 0 {
                        // Pinned via a locked CPU descendant: it leaves the
                        // GPU tier, so it leaves the pinned-GPU total too.
                        self.pinned_gpu_tokens -= tokens;
                    }
                    let n = &mut self.nodes[id];
                    n.residency = Residency::Cpu;
                    n.version += 1;
                    // A CPU parent whose children are gone stays in the
                    // tree; GPU ancestors may now be leaves.
                    let parent = self.nodes[id].parent;
                    self.push_candidate(parent);
                }
            }
        }
        out
    }

    fn remove_leaf(&mut self, id: NodeId) {
        debug_assert!(self.nodes[id].children.is_empty());
        let parent = self.nodes[id].parent;
        let first = self.nodes[id].key[0];
        self.nodes[parent].children.remove(&first);
        self.nodes[id].alive = false;
        self.nodes[id].key = Vec::new();
        self.free_slots.push(id);
        // The parent may have become an eviction candidate.
        self.push_candidate(parent);
    }

    /// Drop LRU CPU-tier nodes until at most `limit` CPU tokens remain.
    /// Only childless CPU nodes can be dropped (structure preserved).
    pub fn trim_cpu(&mut self, limit: u64) -> u64 {
        if self.cpu_tokens <= limit {
            return 0;
        }
        let mut dropped = 0u64;
        // CPU nodes are not in the GPU LRU heap; scan (rare path).
        let mut cpu_leaves: Vec<(Micros, NodeId)> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(id, n)| {
                *id != ROOT
                    && n.alive
                    && n.residency == Residency::Cpu
                    && n.children.is_empty()
                    && n.ref_count == 0
            })
            .map(|(id, n)| (n.last_access, id))
            .collect();
        cpu_leaves.sort_unstable();
        for (_, id) in cpu_leaves {
            if self.cpu_tokens <= limit {
                break;
            }
            let tokens = self.nodes[id].tokens();
            self.cpu_tokens -= tokens;
            dropped += tokens;
            self.remove_leaf(id);
        }
        dropped
    }

    /// Promote every CPU-resident node on `path` back to GPU (the engine
    /// charges the PCIe reload and pool allocation).  Returns promoted
    /// token count.
    pub fn reload_path(&mut self, path: &[NodeId], now: Micros) -> u64 {
        let mut promoted = 0u64;
        for &id in path {
            let n = &mut self.nodes[id];
            if n.alive && n.residency == Residency::Cpu {
                n.residency = Residency::Gpu;
                n.last_access = now;
                n.version += 1;
                promoted += n.key.len() as u64;
                if n.pin_count > 0 {
                    self.pinned_gpu_tokens += n.key.len() as u64;
                }
            }
        }
        self.cpu_tokens -= promoted;
        self.gpu_tokens += promoted;
        promoted
    }

    /// Debug invariant: recomputed token counters match node contents.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let mut gpu = 0u64;
        let mut cpu = 0u64;
        for (id, n) in self.nodes.iter().enumerate() {
            if !n.alive || id == ROOT {
                continue;
            }
            match n.residency {
                Residency::Gpu => gpu += n.tokens(),
                Residency::Cpu => cpu += n.tokens(),
            }
            if !n.alive {
                continue;
            }
            let parent = &self.nodes[n.parent];
            if !parent.alive {
                return Err(format!("node {id} has dead parent {}", n.parent));
            }
            if parent.children.get(&n.key[0]) != Some(&id) {
                return Err(format!("node {id} not linked from parent"));
            }
        }
        if gpu != self.gpu_tokens {
            return Err(format!("gpu tokens {gpu} != counter {}", self.gpu_tokens));
        }
        if cpu != self.cpu_tokens {
            return Err(format!("cpu tokens {cpu} != counter {}", self.cpu_tokens));
        }
        let fast = self.evictable_gpu_tokens();
        let slow = self.evictable_gpu_tokens_slow();
        if fast != slow {
            return Err(format!(
                "evictable fast {fast} != slow {slow} (pinned={})",
                self.pinned_gpu_tokens
            ));
        }
        Ok(())
    }
}

impl Default for RadixTree {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(range: std::ops::Range<u32>) -> Vec<Token> {
        range.collect()
    }

    #[test]
    fn insert_then_full_match() {
        let mut t = RadixTree::new();
        let seq = toks(0..100);
        let ins = t.insert(&seq, Micros(1));
        assert_eq!(ins.new_gpu_tokens, 100);
        assert_eq!(t.gpu_tokens(), 100);
        let m = t.match_prefix(&seq, Micros(2));
        assert_eq!(m.gpu_tokens, 100);
        assert_eq!(m.cpu_tokens, 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_is_deduplicated() {
        let mut t = RadixTree::new();
        let a: Vec<Token> = (0..50).chain(100..150).collect();
        let b: Vec<Token> = (0..50).chain(200..250).collect();
        assert_eq!(t.insert(&a, Micros(1)).new_gpu_tokens, 100);
        // Second insert shares the first 50 tokens.
        assert_eq!(t.insert(&b, Micros(2)).new_gpu_tokens, 50);
        assert_eq!(t.gpu_tokens(), 150);
        t.check_invariants().unwrap();
    }

    #[test]
    fn partial_edge_match_splits() {
        let mut t = RadixTree::new();
        t.insert(&toks(0..100), Micros(1));
        let m = t.match_prefix(&toks(0..30), Micros(2));
        assert_eq!(m.gpu_tokens, 30);
        assert_eq!(m.path.len(), 1);
        // The 100-token edge is now split 30 + 70.
        assert_eq!(t.node_count(), 2);
        assert_eq!(t.gpu_tokens(), 100);
        t.check_invariants().unwrap();
    }

    #[test]
    fn eviction_frees_lru_first() {
        let mut t = RadixTree::new();
        let a = toks(0..100);
        let b = toks(1000..1100);
        t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        // Touch `a` so `b` is LRU.
        t.match_prefix(&a, Micros(3));
        let ev = t.evict(50, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100); // whole-leaf granularity
        assert_eq!(t.gpu_tokens(), 100);
        // `a` must still fully match; `b` is gone.
        assert_eq!(t.match_prefix(&a, Micros(4)).gpu_tokens, 100);
        assert_eq!(t.match_prefix(&b, Micros(5)).gpu_tokens, 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn locked_paths_survive_eviction() {
        let mut t = RadixTree::new();
        let a = toks(0..100);
        let b = toks(1000..1100);
        let ins = t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        t.lock_path(&ins.path);
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100); // only b evicted
        assert_eq!(t.match_prefix(&a, Micros(3)).gpu_tokens, 100);
        t.unlock_path(&ins.path);
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 100);
        assert_eq!(t.gpu_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn offload_then_reload_roundtrip() {
        let mut t = RadixTree::new();
        let a = toks(0..100);
        t.insert(&a, Micros(1));
        let ev = t.evict(u64::MAX, EvictPolicy::OffloadToCpu);
        assert_eq!(ev.offloaded_tokens, 100);
        assert_eq!(t.gpu_tokens(), 0);
        assert_eq!(t.cpu_tokens(), 100);
        // Still matchable, but in the CPU tier.
        let m = t.match_prefix(&a, Micros(2));
        assert_eq!(m.cpu_tokens, 100);
        assert_eq!(m.gpu_tokens, 0);
        let reloaded = t.reload_path(&m.path, Micros(3));
        assert_eq!(reloaded, 100);
        assert_eq!(t.gpu_tokens(), 100);
        assert_eq!(t.cpu_tokens(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn inner_nodes_evicted_after_children() {
        let mut t = RadixTree::new();
        let a: Vec<Token> = (0..50).chain(100..150).collect();
        let b: Vec<Token> = (0..50).chain(200..250).collect();
        t.insert(&a, Micros(1));
        t.insert(&b, Micros(2));
        // Evict everything: should take both leaves AND then the shared
        // 50-token parent.
        let ev = t.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, 150);
        assert_eq!(t.gpu_tokens(), 0);
        assert_eq!(t.node_count(), 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn evictable_accounting() {
        let mut t = RadixTree::new();
        let a = toks(0..100);
        let ins = t.insert(&a, Micros(1));
        assert_eq!(t.evictable_gpu_tokens(), 100);
        t.lock_path(&ins.path);
        assert_eq!(t.evictable_gpu_tokens(), 0);
        t.unlock_path(&ins.path);
        assert_eq!(t.evictable_gpu_tokens(), 100);
    }

    #[test]
    fn trim_cpu_caps_the_tier() {
        let mut t = RadixTree::new();
        t.insert(&toks(0..100), Micros(1));
        t.insert(&toks(1000..1200), Micros(2));
        t.evict(u64::MAX, EvictPolicy::OffloadToCpu);
        assert_eq!(t.cpu_tokens(), 300);
        let dropped = t.trim_cpu(150);
        assert!(dropped >= 100);
        assert!(t.cpu_tokens() <= 200);
        t.check_invariants().unwrap();
    }

    #[test]
    fn agentic_growth_pattern_reuses_own_history() {
        // An agent's request k+1 extends request k's sequence: the whole
        // previous context should hit.
        let mut t = RadixTree::new();
        let mut history = toks(0..500);
        t.insert(&history, Micros(1));
        for step in 0..5u32 {
            history.extend((step + 1) * 10_000..(step + 1) * 10_000 + 300);
            let m = t.match_prefix(&history, Micros(2 + step as u64));
            assert_eq!(m.total(), history.len() as u64 - 300);
            t.insert(&history, Micros(3 + step as u64));
        }
        t.check_invariants().unwrap();
    }
}
