//! Paged KV-slot accounting.
//!
//! The simulator tracks KV memory at token granularity (one slot = the KV
//! bytes of one context token; pages group slots for allocator realism).
//! Actual cache *content* identity lives in the radix tree — this type is
//! pure capacity bookkeeping, with invariants checked on every transition.

use crate::core::{ConcurError, Result};

/// Token-slot pool shared by every sequence on one serving replica.
#[derive(Debug, Clone)]
pub struct KvPool {
    capacity: u64,
    used: u64,
    page_size: u32,
    /// Peak usage high-water mark (telemetry).
    pub peak: u64,
}

impl KvPool {
    pub fn new(capacity_tokens: u64, page_size: u32) -> KvPool {
        assert!(page_size > 0, "page_size must be positive");
        KvPool { capacity: capacity_tokens, used: 0, page_size, peak: 0 }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    /// Pool utilization in [0,1] — the controller's `U_t` signal.
    pub fn usage(&self) -> f64 {
        if self.capacity == 0 {
            1.0
        } else {
            self.used as f64 / self.capacity as f64
        }
    }

    /// Tokens rounded up to whole pages (allocation granularity).
    pub fn round_to_pages(&self, tokens: u64) -> u64 {
        let ps = self.page_size as u64;
        tokens.div_ceil(ps) * ps
    }

    /// Whether `tokens` could be allocated right now without eviction.
    pub fn can_alloc(&self, tokens: u64) -> bool {
        self.used + tokens <= self.capacity
    }

    /// Allocate exactly `tokens` slots (caller rounds to pages if desired).
    pub fn alloc(&mut self, tokens: u64) -> Result<()> {
        if !self.can_alloc(tokens) {
            return Err(ConcurError::engine(format!(
                "kv pool exhausted: want {tokens}, free {}",
                self.free()
            )));
        }
        self.used += tokens;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    /// Release `tokens` slots.
    pub fn release(&mut self, tokens: u64) {
        assert!(
            tokens <= self.used,
            "kv pool underflow: release {tokens} > used {}",
            self.used
        );
        self.used -= tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut p = KvPool::new(1000, 16);
        p.alloc(600).unwrap();
        assert_eq!(p.used(), 600);
        assert_eq!(p.free(), 400);
        assert!((p.usage() - 0.6).abs() < 1e-12);
        p.release(600);
        assert_eq!(p.used(), 0);
        assert_eq!(p.peak, 600);
    }

    #[test]
    fn alloc_fails_beyond_capacity() {
        let mut p = KvPool::new(100, 16);
        p.alloc(90).unwrap();
        assert!(p.alloc(11).is_err());
        assert!(p.can_alloc(10));
        p.alloc(10).unwrap();
        assert_eq!(p.free(), 0);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn release_more_than_used_panics() {
        let mut p = KvPool::new(100, 16);
        p.alloc(10).unwrap();
        p.release(11);
    }

    #[test]
    fn page_rounding() {
        let p = KvPool::new(1000, 16);
        assert_eq!(p.round_to_pages(1), 16);
        assert_eq!(p.round_to_pages(16), 16);
        assert_eq!(p.round_to_pages(17), 32);
        assert_eq!(p.round_to_pages(0), 0);
    }

    #[test]
    fn empty_pool_is_saturated() {
        let p = KvPool::new(0, 16);
        assert_eq!(p.usage(), 1.0);
        assert!(!p.can_alloc(1));
    }
}
