//! Storage-resident KV extent map — the third (NVMe-class) memory tier —
//! and the reload-vs-recompute dual-path decision.
//!
//! When the CPU tier trims, demoted prefixes land here instead of being
//! dropped: each trimmed radix leaf becomes a [`StoredExtent`] keyed by a
//! hash of the token prefix it extended, so a later request whose prompt
//! reaches the end of the cached tiers can chain extent lookups across
//! the remainder and discover how much of it is storage-resident.
//!
//! Reading an extent back is not free — it queues on a contended
//! [`StorageLink`] (NVMe bandwidth, per-op overhead, queue-depth
//! degradation) — so the engine weighs the modeled read time against the
//! modeled prefill-FLOPs time for the same span and takes the cheaper
//! path ([`choose`]).  That per-request argmin is the DualPath argument
//! (PAPERS.md): always-reload collapses when the link congests,
//! always-recompute pays the quadratic attention term however idle the
//! link is, and the crossover moves with storage bandwidth.

use crate::config::{DualPathMode, StorageTierConfig};
use crate::core::{FxHashMap, Micros, Token};
use crate::costmodel::StorageLink;
use std::collections::BTreeSet;
use std::hash::Hasher;

/// One demoted KV extent: the tokens of a trimmed radix edge, stored
/// under the hash of the context prefix they extended.
#[derive(Debug, Clone)]
struct StoredExtent {
    tokens: Vec<Token>,
    stamp: Micros,
    seq: u64,
}

/// Hash key of a context-prefix token sequence (deterministic FxHash;
/// length-prefixed so nested prefixes cannot alias trivially).  Chained
/// lookups verify tokens before trusting a hit, so a collision can only
/// cost a wasted comparison, never a wrong reload.
pub fn extent_key(prefix: &[Token]) -> u64 {
    let mut h = crate::core::fxhash::FxHasher::default();
    h.write_usize(prefix.len());
    for &t in prefix {
        h.write_u32(t);
    }
    h.finish()
}

/// The storage tier: a capacity-bounded extent map plus the contended
/// link reads and writes travel over.
#[derive(Debug, Clone)]
pub struct StorageTier {
    extents: FxHashMap<u64, StoredExtent>,
    /// Deterministic staleness order: `(stamp, seq, key)` — the smallest
    /// entry is the coldest extent and the first dropped at capacity.
    order: BTreeSet<(Micros, u64, u64)>,
    used_tokens: u64,
    capacity: u64,
    next_seq: u64,
    pub link: StorageLink,
    /// Tokens demoted into the tier (telemetry).
    pub demoted_tokens: u64,
    /// Tokens dropped out of the tier at capacity (telemetry).
    pub evicted_tokens: u64,
}

impl StorageTier {
    pub fn new(cfg: &StorageTierConfig) -> StorageTier {
        StorageTier {
            extents: FxHashMap::default(),
            order: BTreeSet::new(),
            used_tokens: 0,
            capacity: cfg.capacity_tokens,
            next_seq: 0,
            link: StorageLink::new(cfg.bandwidth_gbps),
            demoted_tokens: 0,
            evicted_tokens: 0,
        }
    }

    pub fn used_tokens(&self) -> u64 {
        self.used_tokens
    }

    pub fn extent_count(&self) -> usize {
        self.extents.len()
    }

    /// Demote a trimmed CPU-tier edge into the tier.  A re-demotion under
    /// the same prefix replaces the old extent (the tree held the newer
    /// content).  Exceeding capacity drops the stalest extents — possibly
    /// including the one just written, if it alone exceeds the budget.
    pub fn insert(&mut self, prefix: &[Token], tokens: Vec<Token>, now: Micros) {
        if tokens.is_empty() {
            return;
        }
        let key = extent_key(prefix);
        self.remove(key);
        self.demoted_tokens += tokens.len() as u64;
        self.used_tokens += tokens.len() as u64;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.order.insert((now, seq, key));
        self.extents.insert(key, StoredExtent { tokens, stamp: now, seq });
        while self.used_tokens > self.capacity {
            let &(_, _, coldest) = self.order.first().expect("used>0 implies extents");
            let dropped = self.remove(coldest).expect("ordered key must exist");
            self.evicted_tokens += dropped;
        }
    }

    fn remove(&mut self, key: u64) -> Option<u64> {
        let ext = self.extents.remove(&key)?;
        self.order.remove(&(ext.stamp, ext.seq, key));
        self.used_tokens -= ext.tokens.len() as u64;
        Some(ext.tokens.len() as u64)
    }

    /// How many tokens of `prompt[start..]` are storage-resident: chains
    /// extent lookups from the `start` boundary, token-verifying each hit
    /// and following complete extents into the next lookup.  A partial
    /// extent match ends the chain (the divergence point is mid-extent).
    /// Read-only — pricing a path must not disturb the tier.
    pub fn match_extents(&self, prompt: &[Token], start: usize) -> u64 {
        let mut pos = start;
        while pos < prompt.len() {
            let Some(ext) = self.extents.get(&extent_key(&prompt[..pos])) else {
                break;
            };
            let n = ext
                .tokens
                .iter()
                .zip(&prompt[pos..])
                .take_while(|(a, b)| a == b)
                .count();
            if n == 0 {
                break;
            }
            pos += n;
            if n < ext.tokens.len() {
                break;
            }
        }
        (pos - start) as u64
    }

    /// Re-stamp the extents a committed reload of `span` tokens read
    /// (non-destructive read: the data stays resident, now hot).
    pub fn touch(&mut self, prompt: &[Token], start: usize, span: u64, now: Micros) {
        let mut pos = start;
        let end = start + span as usize;
        while pos < end {
            let key = extent_key(&prompt[..pos]);
            let Some(ext) = self.extents.get_mut(&key) else {
                break;
            };
            let len = ext.tokens.len();
            let old = (ext.stamp, ext.seq, key);
            ext.stamp = now;
            self.order.remove(&old);
            self.order.insert((now, ext.seq, key));
            pos += len.min(end - pos);
        }
    }

    pub fn clear(&mut self) {
        self.extents.clear();
        self.order.clear();
        self.used_tokens = 0;
        self.next_seq = 0;
        self.link.reset();
        self.demoted_tokens = 0;
        self.evicted_tokens = 0;
    }

    /// Debug invariant: counters match extent contents and the staleness
    /// order indexes exactly the live extents.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let tokens: u64 = self.extents.values().map(|e| e.tokens.len() as u64).sum();
        if tokens != self.used_tokens {
            return Err(format!("storage tokens {tokens} != counter {}", self.used_tokens));
        }
        if self.order.len() != self.extents.len() {
            return Err(format!(
                "order entries {} != extents {}",
                self.order.len(),
                self.extents.len()
            ));
        }
        for &(stamp, seq, key) in &self.order {
            match self.extents.get(&key) {
                Some(e) if e.stamp == stamp && e.seq == seq => {}
                _ => return Err(format!("order entry for key {key} is stale")),
            }
        }
        if self.used_tokens > self.capacity {
            return Err(format!(
                "used {} exceeds capacity {}",
                self.used_tokens, self.capacity
            ));
        }
        Ok(())
    }
}

/// Which way a storage-resident prefix is served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathChoice {
    /// Read the extents back over the storage link.
    Reload,
    /// Re-prefill the span from scratch (leave the extents untouched).
    Recompute,
}

/// The dual-path decision: pure argmin of the two modeled costs under
/// `DualPath` (ties go to `Reload` — equal latency, but a reload spares
/// the compute roofline), forced under the two pure modes.
pub fn choose(mode: DualPathMode, reload_cost: Micros, recompute_cost: Micros) -> PathChoice {
    match mode {
        DualPathMode::AlwaysReload => PathChoice::Reload,
        DualPathMode::AlwaysRecompute => PathChoice::Recompute,
        DualPathMode::DualPath => {
            if reload_cost <= recompute_cost {
                PathChoice::Reload
            } else {
                PathChoice::Recompute
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Bytes, Rng};
    use crate::costmodel::{ClusterSpec, CostModel, GpuSpec, ModelSpec};

    fn tier(capacity: u64) -> StorageTier {
        StorageTier::new(&StorageTierConfig {
            enabled: true,
            capacity_tokens: capacity,
            bandwidth_gbps: 6.0,
            cpu_tier_tokens: 0,
        })
    }

    fn toks(range: std::ops::Range<u32>) -> Vec<Token> {
        range.collect()
    }

    #[test]
    fn insert_then_chained_match() {
        let mut t = tier(10_000);
        let prompt: Vec<Token> = (0..300).collect();
        // Demoted as two consecutive extents: [100..200) under prefix
        // [0..100), then [200..300) under prefix [0..200).
        t.insert(&prompt[..100], prompt[100..200].to_vec(), Micros(1));
        t.insert(&prompt[..200], prompt[200..300].to_vec(), Micros(2));
        assert_eq!(t.match_extents(&prompt, 100), 200, "chain across both extents");
        assert_eq!(t.match_extents(&prompt, 200), 100);
        assert_eq!(t.match_extents(&prompt, 0), 0, "no extent under the empty prefix");
        assert_eq!(t.used_tokens(), 200);
        t.check_invariants().unwrap();
    }

    #[test]
    fn diverging_prompt_matches_only_verified_tokens() {
        let mut t = tier(10_000);
        let stored: Vec<Token> = (0..200).collect();
        t.insert(&stored[..100], stored[100..200].to_vec(), Micros(1));
        // Same prefix, but the prompt diverges 30 tokens into the extent.
        let mut diverged = stored.clone();
        for tok in diverged.iter_mut().skip(130) {
            *tok += 10_000;
        }
        assert_eq!(t.match_extents(&diverged, 100), 30, "partial verified span only");
        // Fully diverged: hash hits, token verification rejects.
        let mut alien = stored.clone();
        for tok in alien.iter_mut().skip(100) {
            *tok += 10_000;
        }
        assert_eq!(t.match_extents(&alien, 100), 0);
    }

    #[test]
    fn capacity_drops_stalest_first() {
        let mut t = tier(250);
        let prompt: Vec<Token> = (0..400).collect();
        t.insert(&prompt[..100], prompt[100..200].to_vec(), Micros(1));
        t.insert(&prompt[..200], prompt[200..300].to_vec(), Micros(2));
        assert_eq!(t.used_tokens(), 200);
        // Third extent pushes past 250: the stamp-1 extent is dropped.
        t.insert(&prompt[..300], prompt[300..400].to_vec(), Micros(3));
        assert_eq!(t.used_tokens(), 200);
        assert_eq!(t.evicted_tokens, 100);
        assert_eq!(t.match_extents(&prompt, 100), 0, "coldest extent gone breaks the chain");
        assert_eq!(t.match_extents(&prompt, 200), 200, "warm extents intact");
        t.check_invariants().unwrap();
    }

    #[test]
    fn touch_protects_hot_extents_from_capacity_eviction() {
        let mut t = tier(250);
        let prompt: Vec<Token> = (0..400).collect();
        t.insert(&prompt[..100], prompt[100..200].to_vec(), Micros(1));
        t.insert(&prompt[..200], prompt[200..300].to_vec(), Micros(2));
        // A reload re-reads the first extent: it becomes the warmest.
        t.touch(&prompt, 100, 100, Micros(5));
        t.insert(&prompt[..300], prompt[300..400].to_vec(), Micros(6));
        assert_eq!(t.match_extents(&prompt, 100), 100, "touched extent survives");
        assert_eq!(t.match_extents(&prompt, 200), 0, "untouched stamp-2 extent dropped");
        t.check_invariants().unwrap();
    }

    #[test]
    fn reinsert_under_same_prefix_replaces() {
        let mut t = tier(10_000);
        let prefix = toks(0..100);
        t.insert(&prefix, toks(500..600), Micros(1));
        t.insert(&prefix, toks(700..900), Micros(2));
        assert_eq!(t.extent_count(), 1);
        assert_eq!(t.used_tokens(), 200);
        let prompt: Vec<Token> = (0..100).chain(700..900).collect();
        assert_eq!(t.match_extents(&prompt, 100), 200);
        t.check_invariants().unwrap();
    }

    #[test]
    fn clear_resets_everything() {
        let mut t = tier(1_000);
        t.insert(&toks(0..10), toks(10..20), Micros(1));
        t.link.transfer(Micros::ZERO, Bytes(1_000_000));
        t.clear();
        assert_eq!(t.used_tokens(), 0);
        assert_eq!(t.extent_count(), 0);
        assert_eq!(t.link.transfers, 0);
        assert_eq!(t.demoted_tokens, 0);
        t.check_invariants().unwrap();
    }

    #[test]
    fn choose_respects_forced_modes() {
        let (a, b) = (Micros(100), Micros(10));
        assert_eq!(choose(DualPathMode::AlwaysReload, a, b), PathChoice::Reload);
        assert_eq!(choose(DualPathMode::AlwaysRecompute, b, a), PathChoice::Recompute);
        // Ties go to reload.
        assert_eq!(choose(DualPathMode::DualPath, a, a), PathChoice::Reload);
    }

    /// PROPERTY (satellite): over a seeded grid of spans, context depths,
    /// link queue states and storage bandwidths —
    ///  1. the dual-path choice always equals the argmin of the two
    ///     modeled costs, and
    ///  2. at fixed (span, context, queue state) the reload→recompute
    ///     crossover is monotone in storage bandwidth: once reload wins
    ///     at some bandwidth, it wins at every higher bandwidth (reload
    ///     cost is nonincreasing in bandwidth; recompute cost is
    ///     constant).
    #[test]
    fn dual_path_is_argmin_and_crossover_is_monotone_in_bandwidth() {
        let cm = CostModel::new(ClusterSpec::new(
            GpuSpec::h100(),
            ModelSpec::qwen3_32b(),
            2,
            2,
        ));
        let kv_bytes = cm.cluster.model.kv_bytes_per_token();
        let mut rng = Rng::new(0xD0A1);
        let bandwidths = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        for _case in 0..200 {
            let span = rng.gen_range(64, 16_384);
            let start_ctx = rng.gen_range(0, 32_768);
            let queued = rng.gen_range(0, 6);
            let queued_bytes = Bytes(rng.gen_range(1, 64) * 100_000_000);
            let recompute_cost = cm.prefill_time(span, start_ctx);
            let mut reload_won = false;
            let mut prev_reload_cost = Micros(u64::MAX);
            for &bw in &bandwidths {
                let mut link = StorageLink::new(bw);
                for _ in 0..queued {
                    link.transfer(Micros::ZERO, queued_bytes);
                }
                let reload_cost =
                    link.latency_at(Micros::ZERO, Bytes(span * kv_bytes));
                // 1. argmin.
                let got = choose(DualPathMode::DualPath, reload_cost, recompute_cost);
                let want = if reload_cost <= recompute_cost {
                    PathChoice::Reload
                } else {
                    PathChoice::Recompute
                };
                assert_eq!(got, want, "span={span} ctx={start_ctx} bw={bw}");
                // 2. monotone crossover.
                assert!(
                    reload_cost <= prev_reload_cost,
                    "reload cost must be nonincreasing in bandwidth"
                );
                prev_reload_cost = reload_cost;
                if reload_won {
                    assert_eq!(
                        got,
                        PathChoice::Reload,
                        "reload must keep winning above the crossover \
                         (span={span} ctx={start_ctx} bw={bw})"
                    );
                }
                reload_won = got == PathChoice::Reload;
            }
        }
    }
}
