//! SGLang-like serving-engine substrate.
//!
//! Implements the mechanisms the paper's pathology lives in: a paged KV
//! pool, a radix-tree prefix cache with LRU eviction (optionally demoting
//! to a CPU tier — HiCache), continuous batching with chunked prefill, and
//! vLLM-style preemption when decode cannot allocate.
//!
//! The engine is *iteration-driven*: [`SimEngine::step`] performs one
//! continuous-batching iteration (admission → prefill chunks → decode one
//! token per running sequence) and returns the simulated duration from the
//! [`CostModel`] roofline plus what finished.  The driver owns the clock.
//!
//! After every iteration the engine exposes the paper's control signals
//! via [`SimEngine::signals`]: `U_t`-style usage ([`SimEngine::kv_usage`],
//! working set only — paper §4.2) and the windowed prefix hit rate `H_t`
//! that feeds the AIMD law (§4.3).  For the cluster layer it additionally
//! exports a per-agent cache-heat stamp ([`SimEngine::agent_heat`]) and a
//! crash/refill primitive ([`SimEngine::clear_state`]).

pub mod kvpool;
pub mod radix;
pub mod request;
pub mod storage;

pub use kvpool::KvPool;
pub use radix::{EvictPolicy, KvLifetimePolicy, MatchResult, RadixTree};
pub use request::{Request, RunningSeq, SeqPhase};
pub use storage::{PathChoice, StorageTier};

use std::collections::VecDeque;

use crate::config::{EngineConfig, EvictionMode, KvLifetimeMode};
use crate::core::{AgentId, Bytes, FxHashMap, Micros, RequestId, Token};
use crate::costmodel::{CostModel, PcieLink, StepWork};
use crate::metrics::{profiler, Breakdown, LifetimeRatio, Phase, WindowedRatio};

/// A request that completed this step.
#[derive(Debug, Clone)]
pub struct FinishedReq {
    pub id: RequestId,
    pub agent: AgentId,
    pub output: Vec<Token>,
    pub context_len: u64,
    pub admitted_at: Micros,
    pub submitted_at: Micros,
}

/// What one engine iteration did.
#[derive(Debug, Default)]
pub struct StepOutcome {
    pub duration: Micros,
    pub finished: Vec<FinishedReq>,
    pub work: StepWork,
    pub admitted: usize,
    pub preempted: usize,
    /// Tokens prefilled this step that are recomputation of previously
    /// computed (then evicted) context.
    pub recompute_tokens: u64,
    /// Host-link reload time folded into this step (HiCache).
    pub reload_time: Micros,
    /// Storage-link reload time folded into this step (storage tier;
    /// includes the host-link hop storage reads take on the way up).
    pub storage_reload_time: Micros,
    /// Storage-tier reads committed this step, `(tokens, completion)` —
    /// the cluster layer mirrors these onto the shared-fabric accounting.
    pub storage_transfers: Vec<(u64, Micros)>,
}

/// What one broadcast-prefix install did on a replica (cluster
/// shared-prefix tier; see [`SimEngine::install_broadcast_prefix`]).
#[derive(Debug, Clone)]
pub struct BroadcastInstall {
    /// Tokens newly materialised on GPU by the install.
    pub installed_tokens: u64,
    /// CPU-tier tokens promoted back to GPU by the install.
    pub reloaded_tokens: u64,
    /// Broadcast-pinned radix path (the tier's demotion handle).
    pub path: Vec<radix::NodeId>,
    /// When the simulated interconnect transfer completes.
    pub transfer_done: Micros,
}

/// Pool capacity reserved for an in-flight broadcast install (delayed
/// transport visibility; see [`SimEngine::reserve_broadcast_prefix`]).
#[derive(Debug, Clone, Copy)]
pub struct BroadcastReserve {
    /// Pool slots reserved — the tokens the transfer will materialise,
    /// sized against this replica's coverage at issue (CPU-tier parts
    /// included: their promotion needs GPU slots too).
    pub reserved: u64,
    /// Tokens that genuinely have to cross the wire — neither GPU- nor
    /// CPU-resident here (CPU-tier parts reload over the local host
    /// link, they never leave the node).  Delta shipping's fabric size.
    pub uncached: u64,
    /// When this replica's host-link leg of the transfer completes.
    pub host_done: Micros,
}

/// Cumulative engine counters (telemetry / tables).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineCounters {
    pub admitted: u64,
    pub finished: u64,
    pub preemptions: u64,
    pub evictions: u64,
    pub evicted_tokens: u64,
    pub offloaded_tokens: u64,
    pub reloaded_tokens: u64,
    pub recompute_tokens: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub stalled_decode_steps: u64,
    /// Tokens materialised on this replica by broadcast-prefix installs
    /// (cluster shared-prefix tier; zero with the tier off).
    pub broadcast_installed_tokens: u64,
    /// Prompt tokens that hit a broadcast-pinned radix path at admission.
    pub broadcast_hit_tokens: u64,
    /// Tokens materialised on this replica by drain handoffs (cluster
    /// transport; zero with the transport off).
    pub handoff_installed_tokens: u64,
    /// Tokens demoted from the CPU tier into the storage tier (zero with
    /// the storage tier off).
    pub storage_demoted_tokens: u64,
    /// Tokens reloaded from the storage tier at admission.
    pub storage_reloaded_tokens: u64,
    /// Storage-resident tokens the dual-path policy chose to re-prefill
    /// instead of reloading.
    pub storage_recomputed_tokens: u64,
    /// Tokens dropped out of the storage tier at capacity.
    pub storage_evicted_tokens: u64,
}

impl EngineCounters {
    /// Fold another replica's counters in (per-replica → fleet totals).
    pub fn merge(&mut self, other: &EngineCounters) {
        self.admitted += other.admitted;
        self.finished += other.finished;
        self.preemptions += other.preemptions;
        self.evictions += other.evictions;
        self.evicted_tokens += other.evicted_tokens;
        self.offloaded_tokens += other.offloaded_tokens;
        self.reloaded_tokens += other.reloaded_tokens;
        self.recompute_tokens += other.recompute_tokens;
        self.prefill_tokens += other.prefill_tokens;
        self.decode_tokens += other.decode_tokens;
        self.stalled_decode_steps += other.stalled_decode_steps;
        self.broadcast_installed_tokens += other.broadcast_installed_tokens;
        self.broadcast_hit_tokens += other.broadcast_hit_tokens;
        self.handoff_installed_tokens += other.handoff_installed_tokens;
        self.storage_demoted_tokens += other.storage_demoted_tokens;
        self.storage_reloaded_tokens += other.storage_reloaded_tokens;
        self.storage_recomputed_tokens += other.storage_recomputed_tokens;
        self.storage_evicted_tokens += other.storage_evicted_tokens;
    }
}

/// Signals exposed to admission controllers after every step — `U_t` and
/// `H_t` in the paper's control law, plus queue depths.
#[derive(Debug, Clone, Copy)]
pub struct EngineSignals {
    /// Working-set usage (the controller's congestion signal).
    pub kv_usage: f64,
    /// Raw pool usage including reclaimable cache (telemetry series).
    pub pool_usage: f64,
    pub hit_rate: f64,
    pub running: usize,
    pub waiting: usize,
}

/// Memoized admission match for a waiting request.  The radix tree's
/// mutation [`epoch`](RadixTree::epoch) guarantees that while it is
/// unchanged, re-matching the same prompt returns the same totals over the
/// same node path with no splits — so `admit` caches the match per request
/// and walks the tree once per tree mutation instead of once per step per
/// request.  The feasibility *verdict* is recomputed every step from the
/// cached sizes against the live pool (free/evictable move every step; the
/// match does not), and the re-match's one side effect — refreshing the
/// matched path's recency — is replayed from the cached path, so LRU aging
/// is indistinguishable from the full re-match.
#[derive(Debug, Clone)]
struct AdmitMemo {
    /// Tree epoch the match was computed at; stale entries re-match.
    tree_epoch: u64,
    /// The cached match (path + gpu/cpu/broadcast token totals).
    m: MatchResult,
}

/// The simulated serving engine for one TP replica.
pub struct SimEngine {
    pub cfg: EngineConfig,
    pub cost: CostModel,
    pool: KvPool,
    tree: RadixTree,
    pcie: PcieLink,
    /// Third (NVMe-class) KV tier: CPU-tier trims demote extents here
    /// instead of dropping them.  `None` with the knob off — the enabled
    /// paths never execute, keeping the default run bit-identical.
    storage: Option<StorageTier>,
    cpu_tier_limit: u64,
    running: Vec<RunningSeq>,
    waiting: VecDeque<Request>,
    hit_window: WindowedRatio,
    pub lifetime_hits: LifetimeRatio,
    pub breakdown: Breakdown,
    pub counters: EngineCounters,
    policy: EvictPolicy,
    /// Set when the over-admission deadlock breaker fires; suppresses new
    /// admissions until a sequence completes (drain-to-fit).
    congested: bool,
    /// Per-request memoized admission matches (see [`AdmitMemo`]).
    /// Entries are written when a request blocks at the head of the line,
    /// consumed on admission, and dropped wholesale by `clear_state`; a
    /// stale epoch makes an entry inert, so the map never poisons
    /// correctness, only saves tree walks.
    admit_memo: FxHashMap<RequestId, AdmitMemo>,
    /// Per-agent cache heat: when each agent last completed a generation
    /// step here (stamped in `collect_finished`, one O(1) insert per
    /// finished request).  Exported via [`SimEngine::agent_heat`] for the
    /// cluster's cold-first rebalancing router.
    heat: FxHashMap<AgentId, Micros>,
    /// Pool slots held by in-flight broadcast installs (reserved at
    /// transfer issue, consumed or released at commit/abort).  Zero
    /// unless the cluster transport runs with delayed visibility.
    broadcast_reserved: u64,
    /// Per-agent KV lifetime hints (see [`SimEngine::set_lifetime_hint`]):
    /// remaining steps under `StepsToExecution`, expected tool latency in
    /// micros under `ToolTtl`.  Unused (and never populated by the
    /// cluster) under `Lru`.
    lifetime_hints: FxHashMap<AgentId, u64>,
}

/// Class cap for `StepsToExecution` stamping: a hint of 1 (one step left
/// — the agent's context is largest and frees the pool soonest) maps to
/// the highest class, larger hints map progressively lower, and hint 0
/// (no future: the agent is done and nothing consumes its context) maps
/// to class 0 — first in the eviction order, like unhinted cache.
const LIFETIME_CLASS_CAP: u64 = 1 << 20;

fn lifetime_class(hint: u64) -> u64 {
    if hint == 0 {
        0
    } else {
        LIFETIME_CLASS_CAP - hint.min(LIFETIME_CLASS_CAP - 1)
    }
}

impl SimEngine {
    pub fn new(cfg: EngineConfig, cost: CostModel) -> SimEngine {
        let capacity = cost.cluster.kv_pool_tokens();
        let policy = match cfg.eviction {
            EvictionMode::Discard => EvictPolicy::Discard,
            EvictionMode::Offload => EvictPolicy::OffloadToCpu,
        };
        let lifetime = match cfg.kv_lifetime {
            KvLifetimeMode::Lru => KvLifetimePolicy::Lru,
            KvLifetimeMode::StepsToExecution => KvLifetimePolicy::StepsToExecution,
            KvLifetimeMode::ToolTtl => KvLifetimePolicy::ToolTtl,
        };
        let pcie = PcieLink::new(cost.cluster.agg_pcie_bw());
        let storage = cfg.storage_tier.enabled.then(|| StorageTier::new(&cfg.storage_tier));
        SimEngine {
            pool: KvPool::new(capacity, cfg.page_size),
            tree: RadixTree::with_policy(lifetime),
            pcie,
            storage,
            // CPU tier sized by host RAM (2 TB/node) unless a storage-tier
            // run caps it to manufacture demotion pressure at sim scale.
            cpu_tier_limit: if cfg.storage_tier.enabled && cfg.storage_tier.cpu_tier_tokens > 0 {
                cfg.storage_tier.cpu_tier_tokens
            } else {
                cost.cluster.cpu_tier_tokens()
            },
            running: Vec::new(),
            waiting: VecDeque::new(),
            hit_window: WindowedRatio::new(cfg.hit_window),
            lifetime_hits: LifetimeRatio::default(),
            breakdown: Breakdown::new(),
            counters: EngineCounters::default(),
            policy,
            congested: false,
            admit_memo: FxHashMap::default(),
            heat: FxHashMap::default(),
            broadcast_reserved: 0,
            lifetime_hints: FxHashMap::default(),
            cfg,
            cost,
        }
    }

    /// The KV lifetime policy this engine's radix tree runs.
    pub fn lifetime_policy(&self) -> KvLifetimePolicy {
        self.tree.lifetime_policy()
    }

    /// Whether the cluster should compute and push per-agent lifetime
    /// hints before submitting (false under plain `Lru`, where hints are
    /// dead weight on the submit path).
    pub fn wants_lifetime_hint(&self) -> bool {
        self.tree.lifetime_policy() != KvLifetimePolicy::Lru
    }

    /// Record `agent`'s current lifetime hint, consumed when its requests
    /// are admitted and when their KV folds back into the radix cache:
    /// under `StepsToExecution` the hint is the agent's remaining step
    /// count (0 = no future, evict first); under `ToolTtl` it is the
    /// expected latency (in micros) of the tool call the agent issues
    /// after the current step (0 = no tool call, no pin).
    pub fn set_lifetime_hint(&mut self, agent: AgentId, hint: u64) {
        self.lifetime_hints.insert(agent, hint);
    }

    // -- introspection ----------------------------------------------------

    /// `U_t`: working-set KV usage.  Like SGLang's `token_usage`, evictable
    /// cache does not count as "in use" — only slots pinned by running
    /// requests (their matched prefixes + private allocations).  Old agents'
    /// idle caches are reclaimable, so they are congestion *victims*, not
    /// congestion.
    pub fn kv_usage(&self) -> f64 {
        if self.pool.capacity() == 0 {
            return 1.0;
        }
        let evictable = self.tree.evictable_gpu_tokens();
        let pinned = self.pool.used().saturating_sub(evictable);
        pinned as f64 / self.pool.capacity() as f64
    }

    /// Raw pool usage (cache included) — the Fig. 3a / Fig. 5 "KV cache
    /// usage" series, which *does* saturate during the middle phase.
    pub fn pool_usage(&self) -> f64 {
        self.pool.usage()
    }

    pub fn hit_rate(&self) -> f64 {
        // Optimistic default before observations: the controller should
        // probe upward during warmup, not cut.
        self.hit_window.ratio_or(1.0)
    }

    /// Admissions currently inside the `H_t` window — the weight of this
    /// replica's hit rate in fleet-level aggregation (a long-idle replica
    /// holds at most a full window of stale observations, it can never
    /// outvote replicas that are actively admitting).
    pub fn hit_observations(&self) -> usize {
        self.hit_window.observations()
    }

    pub fn signals(&self) -> EngineSignals {
        EngineSignals {
            kv_usage: self.kv_usage(),
            pool_usage: self.pool_usage(),
            hit_rate: self.hit_rate(),
            running: self.running.len(),
            waiting: self.waiting.len(),
        }
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn has_work(&self) -> bool {
        !self.running.is_empty() || !self.waiting.is_empty()
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn tree(&self) -> &RadixTree {
        &self.tree
    }

    /// The storage (NVMe) tier, when enabled.
    pub fn storage(&self) -> Option<&StorageTier> {
        self.storage.as_ref()
    }

    /// Cache-heat signal: when `agent` last completed a generation step
    /// on this replica (`None` = never, or the state was wiped).  Age
    /// correlates with LRU eviction depth — the staler the stamp, the
    /// less of the agent's radix path is likely still GPU-resident — so
    /// time-since-last-decode ranks agents coldest-first for migration
    /// (`cluster::router::RebalanceRouter`).
    pub fn agent_heat(&self, agent: AgentId) -> Option<Micros> {
        self.heat.get(&agent).copied()
    }

    /// Wipe all serving state — KV pool, radix cache, request queues,
    /// hit window, host link, heat stamps — as a replica crash or a
    /// drain-refill does.  Cumulative telemetry (counters, breakdown,
    /// lifetime hits) survives: the work happened and the fleet harvests
    /// it at the end of the run.  In-flight and queued requests are
    /// dropped; the caller owns re-queueing their agents.
    pub fn clear_state(&mut self) {
        self.pool = KvPool::new(self.pool.capacity(), self.cfg.page_size);
        self.tree = RadixTree::with_policy(self.tree.lifetime_policy());
        self.lifetime_hints.clear();
        self.pcie = PcieLink::new(self.cost.cluster.agg_pcie_bw());
        // Node-local NVMe extents die with the replica too (the tier
        // indexes KV produced by the pool that was just wiped).
        if let Some(tier) = &mut self.storage {
            tier.clear();
        }
        self.running.clear();
        self.waiting.clear();
        self.hit_window = WindowedRatio::new(self.cfg.hit_window);
        self.congested = false;
        self.admit_memo.clear();
        self.heat.clear();
        // In-flight reservations died with the pool; the transport
        // cancels the transfers themselves (`Transport::cancel_dst`).
        self.broadcast_reserved = 0;
    }

    /// Debug invariant: pool usage equals tree-resident plus per-request
    /// private tokens.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        self.tree.check_invariants()?;
        if let Some(tier) = &self.storage {
            tier.check_invariants()?;
        }
        let private: u64 = self.running.iter().map(|s| s.private_tokens).sum();
        let expect = self.tree.gpu_tokens() + private + self.broadcast_reserved;
        if expect != self.pool.used() {
            return Err(format!(
                "pool used {} != tree {} + private {private} + reserved {}",
                self.pool.used(),
                self.tree.gpu_tokens(),
                self.broadcast_reserved
            ));
        }
        Ok(())
    }

    // -- submission ---------------------------------------------------------

    /// Queue a generation request (the admission controller has already
    /// decided this agent may proceed).
    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    /// Override the KV pool capacity (unit studies and demos that need a
    /// pool much smaller than any real cluster preset).  Must be called
    /// before any work is submitted.
    pub fn shrink_pool_for_tests(&mut self, capacity_tokens: u64) {
        assert!(
            self.pool.used() == 0 && self.running.is_empty(),
            "shrink_pool_for_tests must precede submissions"
        );
        self.pool = KvPool::new(capacity_tokens, self.cfg.page_size);
        self.cpu_tier_limit = capacity_tokens * 4;
    }

    /// Override the CPU-tier budget (unit studies of the storage tier that
    /// need demotion pressure without gigantic workloads).
    pub fn shrink_cpu_tier_for_tests(&mut self, limit_tokens: u64) {
        self.cpu_tier_limit = limit_tokens;
    }

    // -- broadcast prefix tier ----------------------------------------------

    /// Materialise `tokens` in this replica's radix cache as a read-only
    /// broadcast prefix (cluster shared-prefix tier): any part not yet
    /// GPU-resident is allocated from the pool (evicting as needed),
    /// CPU-tier parts are promoted, and the whole path is broadcast-pinned
    /// so per-replica eviction can never drop it while it stays hot.  The
    /// shipped bytes occupy this replica's host link (delaying later
    /// HiCache reloads, as real interconnect traffic would).
    ///
    /// Returns `None` — installing nothing — when the pool cannot free
    /// enough room; the tier retries on a later pass.
    pub fn install_broadcast_prefix(
        &mut self,
        tokens: &[Token],
        now: Micros,
    ) -> Option<BroadcastInstall> {
        if tokens.is_empty() {
            return None;
        }
        let needed = self.free_for_prefix(tokens, now)?;
        if needed > 0 {
            self.pool.alloc(needed).expect("install sized by peek");
        }
        let ins = self.tree.insert(tokens, now);
        let reloaded =
            if ins.cpu_tokens > 0 { self.tree.reload_path(&ins.path, now) } else { 0 };
        debug_assert_eq!(ins.new_gpu_tokens + reloaded, needed);
        self.tree.pin_broadcast(&ins.path);
        let moved = ins.new_gpu_tokens + reloaded;
        self.counters.broadcast_installed_tokens += moved;
        self.counters.reloaded_tokens += reloaded;
        let transfer_done =
            if moved > 0 { self.pcie.transfer(now, self.kv_bytes(moved)) } else { now };
        Some(BroadcastInstall {
            installed_tokens: ins.new_gpu_tokens,
            reloaded_tokens: reloaded,
            path: ins.path,
            transfer_done,
        })
    }

    /// Release a broadcast pin taken by
    /// [`install_broadcast_prefix`](SimEngine::install_broadcast_prefix)
    /// (tier demotion: the prefix cooled or was displaced by the budget).
    /// The KV stays cached but becomes ordinary evictable state.
    pub fn demote_broadcast_prefix(&mut self, path: &[radix::NodeId]) {
        self.tree.demote_broadcast(path);
    }

    /// Reserve pool capacity for a broadcast-prefix install whose
    /// transfer is still in flight (transport delayed visibility).  The
    /// slots for the not-yet-resident part of `tokens` are allocated and
    /// held outside the radix tree, so nothing becomes matchable — the
    /// prefix "matches zero tokens" until
    /// [`commit_broadcast_prefix`](SimEngine::commit_broadcast_prefix)
    /// lands it — while the capacity is committed (it counts as working
    /// set, exactly like a locked path).  The replica's host-link leg of
    /// the transfer is charged here; `host_done` is its completion.
    ///
    /// Returns `None` — reserving nothing — when the pool cannot free
    /// enough room (same feasibility guard as the immediate install).
    pub fn reserve_broadcast_prefix(
        &mut self,
        tokens: &[Token],
        now: Micros,
    ) -> Option<BroadcastReserve> {
        if tokens.is_empty() {
            return None;
        }
        let (needed, cpu) = self.free_for_prefix_peeked(tokens, now, 0)?;
        if needed > 0 {
            self.pool.alloc(needed).expect("reserve sized by peek");
        }
        self.broadcast_reserved += needed;
        let host_done =
            if needed > 0 { self.pcie.transfer(now, self.kv_bytes(needed)) } else { now };
        Some(BroadcastReserve { reserved: needed, uncached: needed.saturating_sub(cpu), host_done })
    }

    /// Land a reserved broadcast install: materialise `tokens`, promote
    /// CPU-tier parts, broadcast-pin the path.  Coverage may have moved
    /// since the reservation — grown (another agent re-prefilled the
    /// family prefix: the surplus reservation is released) or shrunk
    /// (eviction took the previously-resident part: the shortfall is
    /// allocated here, with the same no-destructive-eviction guard).
    ///
    /// Returns `None` when the shortfall cannot be freed; the
    /// reservation is released and the tier retries on a later pass.
    pub fn commit_broadcast_prefix(
        &mut self,
        tokens: &[Token],
        reserved: u64,
        now: Micros,
    ) -> Option<BroadcastInstall> {
        debug_assert!(self.broadcast_reserved >= reserved, "commit without reservation");
        let Some(needed) = self.free_for_prefix_with(tokens, now, reserved) else {
            self.abort_broadcast_reserve(reserved);
            return None;
        };
        if needed > reserved {
            self.pool.alloc(needed - reserved).expect("commit sized by peek");
        } else if needed < reserved {
            self.pool.release(reserved - needed);
        }
        self.broadcast_reserved -= reserved;
        let ins = self.tree.insert(tokens, now);
        let reloaded =
            if ins.cpu_tokens > 0 { self.tree.reload_path(&ins.path, now) } else { 0 };
        debug_assert_eq!(ins.new_gpu_tokens + reloaded, needed);
        self.tree.pin_broadcast(&ins.path);
        self.counters.broadcast_installed_tokens += ins.new_gpu_tokens + reloaded;
        self.counters.reloaded_tokens += reloaded;
        Some(BroadcastInstall {
            installed_tokens: ins.new_gpu_tokens,
            reloaded_tokens: reloaded,
            path: ins.path,
            transfer_done: now,
        })
    }

    /// Release a reservation whose transfer will never commit (the hot
    /// prefix was demoted, or the commit could not fit).
    pub fn abort_broadcast_reserve(&mut self, reserved: u64) {
        debug_assert!(self.broadcast_reserved >= reserved, "abort without reservation");
        self.pool.release(reserved);
        self.broadcast_reserved -= reserved;
    }

    /// Install a drained replica's handed-off agent context as ordinary
    /// **evictable** warm cache (no broadcast pin — this is private agent
    /// state), stamping the agent's cache heat so cold-first routing
    /// treats it as freshly warm here.  The link charges happened at
    /// transfer issue; this is the landing.  Returns tokens materialised
    /// (0 when the pool cannot fit the context — the handoff is dropped,
    /// exactly what drop-on-drain would have done).
    pub fn install_handoff_context(
        &mut self,
        agent: AgentId,
        tokens: &[Token],
        now: Micros,
    ) -> u64 {
        if tokens.is_empty() {
            return 0;
        }
        let Some(needed) = self.free_for_prefix(tokens, now) else { return 0 };
        if needed > 0 {
            self.pool.alloc(needed).expect("handoff sized by peek");
        }
        let ins = self.tree.insert(tokens, now);
        let reloaded =
            if ins.cpu_tokens > 0 { self.tree.reload_path(&ins.path, now) } else { 0 };
        debug_assert_eq!(ins.new_gpu_tokens + reloaded, needed);
        self.counters.handoff_installed_tokens += ins.new_gpu_tokens + reloaded;
        self.counters.reloaded_tokens += reloaded;
        self.heat.insert(agent, now);
        needed
    }

    /// Charge this replica's host link with a `tokens`-sized KV movement
    /// (the read-out/write-in leg of a cross-replica transfer); returns
    /// its completion instant.
    pub fn charge_link_transfer(&mut self, tokens: u64, now: Micros) -> Micros {
        if tokens == 0 {
            return now;
        }
        self.pcie.transfer(now, self.kv_bytes(tokens))
    }

    /// Make the not-yet-GPU-resident part of `tokens` allocatable,
    /// evicting as needed but never destructively (the admission-style
    /// free+evictable feasibility guard).  Returns the stable token count
    /// to allocate, or `None` when it cannot fit.  Factored out of
    /// [`install_broadcast_prefix`](SimEngine::install_broadcast_prefix)
    /// so reserve/commit/handoff size their allocations identically.
    fn free_for_prefix(&mut self, tokens: &[Token], now: Micros) -> Option<u64> {
        self.free_for_prefix_with(tokens, now, 0)
    }

    /// [`free_for_prefix`](SimEngine::free_for_prefix) with `held` slots
    /// already allocated to this operation (a commit's reservation).
    fn free_for_prefix_with(&mut self, tokens: &[Token], now: Micros, held: u64) -> Option<u64> {
        self.free_for_prefix_peeked(tokens, now, held).map(|(needed, _)| needed)
    }

    /// Core of [`free_for_prefix_with`]: a single sized walk.  Eviction
    /// inside `ensure_free` may drop part of the matched prefix, so the
    /// estimate is re-derived until stable (GPU coverage only shrinks) —
    /// but each retry peeks the tree exactly once: the stability peek
    /// after `ensure_free` *is* the next iteration's sizing, since
    /// nothing mutates between them.  Returns `(needed, cpu)`, the stable
    /// allocation size and the CPU-tier coverage from the final peek, so
    /// callers that need the post-free residency split
    /// ([`reserve_broadcast_prefix`]) do not re-walk the tree for it.
    ///
    /// [`free_for_prefix_with`]: SimEngine::free_for_prefix_with
    /// [`reserve_broadcast_prefix`]: SimEngine::reserve_broadcast_prefix
    fn free_for_prefix_peeked(
        &mut self,
        tokens: &[Token],
        now: Micros,
        held: u64,
    ) -> Option<(u64, u64)> {
        let (gpu, mut cpu) = self.tree.peek_prefix(tokens);
        let mut needed = tokens.len() as u64 - gpu;
        loop {
            let shortfall = needed.saturating_sub(held);
            if self.pool.can_alloc(shortfall) {
                return Some((needed, cpu));
            }
            // Feasibility precheck, mirroring admission's free+evictable
            // guard: never evict for an install that cannot fit anyway.
            // A failed install is retried on every tier maintenance pass,
            // and a destructive retry loop would evict (and force the
            // re-prefill of) the running agents' reclaimable cache each
            // pass — strictly worse than having no tier at all.
            if self.pool.free() + self.tree.evictable_gpu_tokens() < shortfall {
                return None;
            }
            if !self.ensure_free(shortfall, now) {
                return None;
            }
            let (gpu_after, cpu_after) = self.tree.peek_prefix(tokens);
            let still_needed = tokens.len() as u64 - gpu_after;
            cpu = cpu_after;
            if still_needed == needed {
                return Some((needed, cpu)); // estimate stable; ensure_free succeeded
            }
            needed = still_needed;
        }
    }

    // -- memory helpers ------------------------------------------------------

    /// Make room for `tokens`; evicts LRU cache entries if needed.
    /// Returns true when the allocation can now succeed.
    fn ensure_free(&mut self, tokens: u64, now: Micros) -> bool {
        if self.pool.can_alloc(tokens) {
            return true;
        }
        let deficit = tokens - self.pool.free();
        let ev = self.tree.evict_at(deficit, self.policy, now);
        if ev.freed_gpu_tokens > 0 {
            self.pool.release(ev.freed_gpu_tokens);
            self.counters.evictions += ev.nodes as u64;
            self.counters.evicted_tokens += ev.freed_gpu_tokens;
            if ev.offloaded_tokens > 0 {
                self.counters.offloaded_tokens += ev.offloaded_tokens;
                // Write-behind offload occupies the host link, delaying
                // future reloads (the Fig. 1c contention effect).
                let bytes = self.kv_bytes(ev.offloaded_tokens);
                self.pcie.transfer(now, bytes);
                self.trim_cpu_tier(now);
            }
        }
        self.pool.can_alloc(tokens)
    }

    /// Trim the CPU tier back to its budget.  With the storage tier on,
    /// trimmed extents demote into it (write-behind on the storage link)
    /// instead of being dropped; off, this is exactly the old destructive
    /// trim.
    fn trim_cpu_tier(&mut self, now: Micros) {
        let Some(tier) = &mut self.storage else {
            self.tree.trim_cpu(self.cpu_tier_limit);
            return;
        };
        let evicted_before = tier.evicted_tokens;
        let mut demoted = 0u64;
        let mut sink = |prefix: Vec<Token>, edge: Vec<Token>| {
            demoted += edge.len() as u64;
            tier.insert(&prefix, edge, now);
        };
        self.tree.trim_cpu_with(self.cpu_tier_limit, Some(&mut sink));
        if demoted > 0 {
            self.counters.storage_demoted_tokens += demoted;
            let bytes = Bytes(demoted * self.cost.cluster.model.kv_bytes_per_token());
            tier.link.transfer(now, bytes);
        }
        self.counters.storage_evicted_tokens += tier.evicted_tokens - evicted_before;
    }

    fn kv_bytes(&self, tokens: u64) -> Bytes {
        Bytes(tokens * self.cost.cluster.model.kv_bytes_per_token())
    }

    // -- the iteration ---------------------------------------------------------

    /// One continuous-batching iteration at simulated time `now`.
    pub fn step(&mut self, now: Micros) -> StepOutcome {
        let _prof = profiler::scope(profiler::Section::Step);
        let mut out = StepOutcome::default();

        out.reload_time = self.admit(now, &mut out);
        self.run_prefill(&mut out, now);
        self.run_decode(&mut out, now);

        // Deadlock breaker: concurrent prefills can collectively over-commit
        // the pool (each admission looked safe against caches that later got
        // locked by peers).  If nothing at all progressed, preempt youngest
        // sequences until the oldest's remaining work fits, and suppress new
        // admissions until something completes — guaranteed progress, paid
        // as recompute churn exactly like real engines under over-admission.
        if out.work.is_empty() && self.running.len() > 1 {
            self.congested = true;
            let oldest_need = {
                let s0 = &self.running[0];
                s0.prefill_remaining() + s0.req.gen.len() as u64
            };
            while self.running.len() > 1
                && self.pool.free() + self.tree.evictable_gpu_tokens() < oldest_need
            {
                if self.preempt_youngest_prefill(0, &mut out).is_none() {
                    break;
                }
            }
        }

        let finished = self.collect_finished(now);

        // Roofline timing, with the prefill/decode split needed for the
        // Fig. 3b breakdown: time each side alone, then scale both so they
        // sum to the rooflined total (they overlap on real hardware).
        let total = self.cost.step_time(&out.work);
        let prefill_only = StepWork {
            prefill_tokens: out.work.prefill_tokens,
            prefill_ctx_tokens: out.work.prefill_ctx_tokens,
            ..Default::default()
        };
        let decode_only = StepWork {
            decode_seqs: out.work.decode_seqs,
            decode_ctx_tokens: out.work.decode_ctx_tokens,
            ..Default::default()
        };
        let tp = self.cost.step_time(&prefill_only).0 as f64;
        let td = self.cost.step_time(&decode_only).0 as f64;
        let scale = if tp + td > 0.0 { total.0 as f64 / (tp + td) } else { 0.0 };
        let prefill_time = Micros((tp * scale) as u64);
        let decode_time = Micros((td * scale) as u64);
        if out.work.prefill_tokens > 0 {
            let rec_frac = out.recompute_tokens as f64 / out.work.prefill_tokens as f64;
            let rec = Micros((prefill_time.0 as f64 * rec_frac) as u64);
            self.breakdown.add(Phase::Recompute, rec);
            self.breakdown.add(Phase::Prefill, prefill_time.saturating_sub(rec));
        }
        self.breakdown.add(Phase::Decode, decode_time);

        // Host-link reloads overlap compute; only the excess extends the step.
        let mut duration = total;
        if out.reload_time > duration {
            self.breakdown
                .add(Phase::Offload, out.reload_time.saturating_sub(duration));
            duration = out.reload_time;
        }
        // Storage reads overlap both; only their further excess extends it.
        if out.storage_reload_time > duration {
            self.breakdown.add(
                Phase::StorageReload,
                out.storage_reload_time.saturating_sub(duration),
            );
            duration = out.storage_reload_time;
        }
        out.duration = duration;
        out.finished = finished;
        self.counters.recompute_tokens += out.recompute_tokens;
        out
    }

    /// Drop every memoized admission match, forcing the next `admit` pass
    /// to fully re-match the waiting head against the tree.  Differential
    /// oracle hook: `tests/proptests.rs` steps a twin engine that clears
    /// the memo before every iteration (the pre-memo behaviour) and
    /// asserts bit-identical outcomes against a memoized engine.  Hidden
    /// because production code has no reason to defeat the memo — it is
    /// always exact (see [`AdmitMemo`]).
    #[doc(hidden)]
    pub fn clear_admit_memo(&mut self) {
        self.admit_memo.clear();
    }

    /// FIFO admission from the waiting queue into the running batch.
    /// Returns accumulated host-link reload latency for this step.
    fn admit(&mut self, now: Micros, out: &mut StepOutcome) -> Micros {
        let _prof = profiler::scope(profiler::Section::Admit);
        let mut reload_time = Micros::ZERO;
        while self.running.len() < self.cfg.max_running && !self.congested {
            let Some(req) = self.waiting.pop_front() else { break };

            // Memoized match: while the tree epoch is unchanged since this
            // request's last match, a full re-match would return the same
            // totals over the same node path with no splits (every
            // match-visible mutation — insert, split, evict, reload, trim,
            // broadcast pin transition — bumps the epoch), so the tree is
            // walked once per mutation instead of once per step.  The
            // re-match's only side effect — touching the matched path's
            // recency — is replayed from the cached path, so LRU aging is
            // indistinguishable from the full re-match.  The feasibility
            // verdict below is recomputed every step regardless: it reads
            // the live pool, which moves even when the tree does not.
            let m = match self.admit_memo.get(&req.id) {
                Some(memo) if memo.tree_epoch == self.tree.epoch() => {
                    self.tree.touch_path(&memo.m.path, now);
                    memo.m.clone()
                }
                _ => self.tree.match_prefix(&req.prompt, now),
            };
            let prompt_len = req.prompt.len() as u64;
            let gen_len = req.gen.len() as u64;
            let uncached = prompt_len - m.total();
            // Admission needs room for the uncached prompt, the upcoming
            // generation, any CPU-tier reload, and the configured headroom.
            let headroom =
                (self.pool.capacity() as f64 * self.cfg.decode_headroom) as u64;
            let needed = uncached + gen_len + m.cpu_tokens + headroom;
            let evictable = self.tree.evictable_gpu_tokens();
            if self.pool.free() + evictable < needed {
                // FIFO head-of-line: wait for memory, keeping the match.
                self.admit_memo
                    .insert(req.id, AdmitMemo { tree_epoch: self.tree.epoch(), m });
                self.waiting.push_front(req);
                break;
            }
            self.admit_memo.remove(&req.id);

            // Reload the CPU-tier prefix over the contended host link.
            let mut cached = m.gpu_tokens;
            let mut reloaded = 0u64;
            if m.cpu_tokens > 0 && self.ensure_free(m.cpu_tokens, now) {
                self.pool
                    .alloc(m.cpu_tokens)
                    .expect("ensure_free guaranteed space");
                let promoted = self.tree.reload_path(&m.path, now);
                // `ensure_free`'s own CPU-tier trim can drop part of the
                // matched span before the reload lands (tight tiers);
                // release the overshoot instead of leaking the slots.
                debug_assert!(promoted <= m.cpu_tokens);
                self.pool.release(m.cpu_tokens - promoted);
                reloaded = promoted;
                cached += promoted;
                self.counters.reloaded_tokens += promoted;
                if promoted > 0 {
                    let done = self.pcie.transfer(now, self.kv_bytes(promoted));
                    let lat = done.saturating_sub(now);
                    if lat > reload_time {
                        reload_time = lat;
                    }
                }
            }

            // Storage tier: past the GPU-resident coverage the prompt may
            // continue into storage-resident extents (including ones the
            // CPU-tier trim inside `ensure_free` demoted *during* the
            // reload above).  Price the storage read against re-prefilling
            // the same span and take the cheaper path (the dual-path
            // decision; the pure modes force a side).
            let mut lock = m.path;
            let mut storage_hits = 0u64;
            if cached < prompt_len {
                if let Some(span_hit) =
                    self.try_storage_path(&req.prompt, cached, now, out)
                {
                    storage_hits = span_hit.0;
                    cached += storage_hits;
                    lock = span_hit.1;
                }
            }

            // Hit accounting: GPU hits always count; CPU-tier hits count as
            // hits only under HiCache (the data *is* retained, it just has
            // to cross PCIe — exactly the paper's Table 2 vs Table 1 split).
            // Storage reloads are retained-and-paid-for the same way; a
            // dual-path *recompute* of a storage-resident span is a policy
            // miss and does not count.
            let hits = match self.policy {
                EvictPolicy::Discard => m.gpu_tokens,
                EvictPolicy::OffloadToCpu => m.gpu_tokens + reloaded + storage_hits,
            };
            self.hit_window.record(hits, prompt_len.max(1));
            self.lifetime_hits.record(hits, prompt_len.max(1));
            // Broadcast short-circuit accounting: prompt tokens covered by
            // a pinned broadcast prefix were never at eviction risk and
            // skip prefill like any other hit — this counter sizes how
            // much of the hit volume the tier is carrying.
            self.counters.broadcast_hit_tokens += m.broadcast_tokens;

            let _ = gen_len;
            self.tree.lock_path(&lock);
            // Stamp the matched path with the agent's lifetime class so a
            // preemption-unlocked path re-enters the eviction order where
            // the workflow position says, not where raw recency does.
            // (ToolTtl pins are stamped at completion only: the path is
            // locked for the whole generation anyway.)
            if self.tree.lifetime_policy() == KvLifetimePolicy::StepsToExecution {
                let hint = self.lifetime_hints.get(&req.agent).copied().unwrap_or(0);
                self.tree.stamp_path_lifetime(&lock, lifetime_class(hint), Micros::ZERO);
            }
            self.running.push(RunningSeq::new(req, cached, lock, now));
            self.counters.admitted += 1;
            out.admitted += 1;
        }
        reload_time
    }

    /// Serve the storage-resident continuation of `prompt` past the radix
    /// boundary `cached`, if any: chain-match extents, price a storage
    /// read against re-prefilling the span, and commit the chosen path.
    /// Returns `(span, full radix path)` when a reload materialised the
    /// span on GPU; `None` when there is no extent, the dual-path policy
    /// chose recompute (the span stays uncached and prefills normally),
    /// or the pool could not make room.
    fn try_storage_path(
        &mut self,
        prompt: &[Token],
        cached: u64,
        now: Micros,
        out: &mut StepOutcome,
    ) -> Option<(u64, Vec<radix::NodeId>)> {
        let boundary = cached as usize;
        let kv_per_token = self.cost.cluster.model.kv_bytes_per_token();
        let (span, reload_cost) = {
            let tier = self.storage.as_ref()?;
            let span = tier.match_extents(prompt, boundary);
            if span == 0 {
                return None;
            }
            (span, tier.link.latency_at(now, Bytes(span * kv_per_token)))
        };
        let recompute_cost = self.cost.prefill_time(span, cached);
        match storage::choose(self.cfg.dual_path, reload_cost, recompute_cost) {
            PathChoice::Recompute => {
                self.counters.storage_recomputed_tokens += span;
                None
            }
            PathChoice::Reload => {
                // The admission feasibility guard already budgeted the
                // span (it is part of `uncached`); the peek-sized
                // free/alloc/insert sequence below is the same robust
                // pattern the broadcast and handoff installs use, so a
                // concurrent eviction nibbling the prefix mid-flight is
                // re-derived rather than leaking pool slots.  On failure
                // the span prefills like any other miss.
                let covered_len = boundary + span as usize;
                let needed = self.free_for_prefix(&prompt[..covered_len], now)?;
                if needed > 0 {
                    self.pool.alloc(needed).expect("reload sized by peek");
                }
                let ins = self.tree.insert(&prompt[..covered_len], now);
                let promoted = if ins.cpu_tokens > 0 {
                    self.tree.reload_path(&ins.path, now)
                } else {
                    0
                };
                debug_assert_eq!(ins.new_gpu_tokens + promoted, needed);
                self.counters.reloaded_tokens += promoted;
                self.counters.storage_reloaded_tokens += span;
                let bytes = Bytes(span * kv_per_token);
                // The read queues on the storage link, then hops the host
                // link up to the GPU; both legs congest like any transfer.
                let tier = self.storage.as_mut().expect("present above");
                let read_done = tier.link.transfer(now, bytes);
                tier.touch(prompt, boundary, span, now);
                let done = self.pcie.transfer(read_done, bytes);
                let lat = done.saturating_sub(now);
                if lat > out.storage_reload_time {
                    out.storage_reload_time = lat;
                }
                out.storage_transfers.push((span, done));
                Some((span, ins.path))
            }
        }
    }

    /// Chunked prefill under a global per-step token budget, FIFO order.
    fn run_prefill(&mut self, out: &mut StepOutcome, now: Micros) {
        let mut budget = self.cfg.prefill_chunk as u64;
        // Indexed loop: the body re-borrows `self` mutably (ensure_free,
        // pool.alloc) between accesses, which `for seq in &mut running`
        // cannot express.
        #[allow(clippy::needless_range_loop)]
        for i in 0..self.running.len() {
            if budget == 0 {
                break;
            }
            if !self.running[i].is_prefill() {
                continue;
            }
            let remaining = self.running[i].prefill_remaining();
            let mut chunk = remaining.min(budget);
            if !self.ensure_free(chunk, now) {
                // Partial chunk with whatever fits.
                chunk = chunk.min(self.pool.free());
                if chunk == 0 {
                    continue;
                }
            }
            self.pool.alloc(chunk).expect("checked");
            let seq = &mut self.running[i];
            seq.private_tokens += chunk;
            let start = seq.context_len();
            out.recompute_tokens += seq.recompute_in_next(chunk);
            out.work.prefill_tokens += chunk;
            // Σ context over the chunk ≈ mean(start, start+chunk) * chunk.
            out.work.prefill_ctx_tokens += (start + start + chunk) * chunk / 2;
            seq.prefilled += chunk;
            budget -= chunk;
            self.counters.prefill_tokens += chunk;
            if seq.prefill_remaining() == 0 {
                seq.phase = SeqPhase::Decode;
            }
        }
    }

    /// One decode token per running sequence; preempts the youngest
    /// prefilling sequence if decode cannot allocate (vLLM-style).
    fn run_decode(&mut self, out: &mut StepOutcome, now: Micros) {
        let n_decode = self.running.iter().filter(|s| s.is_decode()).count() as u64;
        if n_decode == 0 {
            return;
        }
        // Batched fast path: one pool reservation for the whole decode
        // batch instead of one ensure_free per sequence.  In Discard mode
        // a batched eviction pops exactly the LRU prefix the per-sequence
        // calls would have popped, so outcomes are identical; in Offload
        // mode batching would merge per-call host-link transfers (changing
        // PCIe timing), so it is taken only when no eviction is needed.
        let batched = match self.policy {
            EvictPolicy::Discard => self.ensure_free(n_decode, now),
            EvictPolicy::OffloadToCpu => self.pool.can_alloc(n_decode),
        };
        if batched {
            self.pool.alloc(n_decode).expect("reserved above");
            for seq in &mut self.running {
                if !seq.is_decode() {
                    continue;
                }
                seq.advance_decode(&mut out.work);
                self.counters.decode_tokens += 1;
            }
            return;
        }
        // Memory-pressure path: per-sequence allocation with vLLM-style
        // recompute preemption, exactly as before.
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].phase != SeqPhase::Decode {
                i += 1;
                continue;
            }
            let mut ok = self.ensure_free(1, now);
            while !ok {
                match self.preempt_youngest_prefill(i, out) {
                    Some(j) => {
                        if j < i {
                            i -= 1; // current sequence shifted left
                        }
                        ok = self.ensure_free(1, now);
                    }
                    None => break,
                }
            }
            if !ok {
                self.counters.stalled_decode_steps += 1;
                i += 1;
                continue; // sequence stalls this iteration
            }
            self.pool.alloc(1).expect("checked");
            self.running[i].advance_decode(&mut out.work);
            self.counters.decode_tokens += 1;
            i += 1;
        }
    }

    /// Preempt the most recently admitted sequence other than `keep`,
    /// preferring prefilling victims (cheapest to redo), else the youngest
    /// decoding sequence (vLLM recompute-preemption).  The victim's request
    /// returns to the waiting queue; its private slots are freed and that
    /// work will be redone — this is precisely the eviction/recompute churn
    /// the paper's controller exists to avoid.
    /// Returns the removed index so callers can fix up loop cursors.
    fn preempt_youngest_prefill(&mut self, keep: usize, out: &mut StepOutcome) -> Option<usize> {
        let find = |phase: SeqPhase| {
            self.running
                .iter()
                .enumerate()
                .rev()
                .find(|(j, s)| *j != keep && s.phase == phase)
                .map(|(j, _)| j)
        };
        let victim = find(SeqPhase::Prefill).or_else(|| find(SeqPhase::Decode))?;
        let j = victim;
        let seq = self.running.remove(j);
        self.tree.unlock_path(&seq.locked_path);
        self.pool.release(seq.private_tokens);
        self.waiting.push_front(seq.req);
        self.counters.preemptions += 1;
        out.preempted += 1;
        Some(j)
    }

    /// Extract finished sequences, folding their KV into the radix cache.
    fn collect_finished(&mut self, now: Micros) -> Vec<FinishedReq> {
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].phase != SeqPhase::Finished {
                i += 1;
                continue;
            }
            let seq = self.running.remove(i);
            self.congested = false; // capacity released: admissions may resume
            self.heat.insert(seq.req.agent, now);
            self.tree.unlock_path(&seq.locked_path);
            // Full sequence (prompt + output) becomes reusable prefix
            // state; inserted straight from the two slices — no O(context)
            // concatenation per finished request.
            let ins = self.tree.insert_parts(&seq.req.prompt, &seq.output, now);
            // Stamp the folded-in path from the agent's lifetime hint:
            // its remaining-steps class (KVFlow), or a pin covering the
            // tool call it is about to wait on (Continuum) — precisely
            // the window where plain LRU loses the race to fresher
            // traffic and evicts an about-to-return agent's context.
            match self.tree.lifetime_policy() {
                KvLifetimePolicy::Lru => {}
                KvLifetimePolicy::StepsToExecution => {
                    let hint =
                        self.lifetime_hints.get(&seq.req.agent).copied().unwrap_or(0);
                    self.tree.stamp_path_lifetime(
                        &ins.path,
                        lifetime_class(hint),
                        Micros::ZERO,
                    );
                }
                KvLifetimePolicy::ToolTtl => {
                    let hint =
                        self.lifetime_hints.get(&seq.req.agent).copied().unwrap_or(0);
                    let pin = if hint > 0 { now + Micros(hint) } else { Micros::ZERO };
                    self.tree.stamp_path_lifetime(&ins.path, 0, pin);
                }
            }
            // The tree took ownership of `new_gpu_tokens` of this request's
            // private slots; anything beyond that duplicates existing cache
            // (another agent inserted the same prefix meanwhile) — free it.
            debug_assert!(ins.new_gpu_tokens <= seq.private_tokens);
            self.pool
                .release(seq.private_tokens - ins.new_gpu_tokens.min(seq.private_tokens));
            self.counters.finished += 1;
            finished.push(FinishedReq {
                id: seq.req.id,
                agent: seq.req.agent,
                context_len: seq.context_len(),
                output: seq.output,
                admitted_at: seq.admitted_at,
                submitted_at: seq.req.submitted_at,
            });
        }
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{ClusterSpec, GpuSpec, ModelSpec};

    fn tiny_engine(capacity_tokens: u64) -> SimEngine {
        // Use the qwen3 cost model but shrink the pool via a fake cluster:
        // easiest is to construct and then overwrite the pool.
        let cost = CostModel::new(ClusterSpec::new(
            GpuSpec::h100(),
            ModelSpec::qwen3_32b(),
            8,
            8,
        ));
        let cfg = EngineConfig { prefill_chunk: 8192, ..EngineConfig::default() };
        let mut e = SimEngine::new(cfg, cost);
        e.shrink_pool_for_tests(capacity_tokens);
        e
    }

    fn mk_req(id: u64, agent: u64, prompt: Vec<Token>, gen: usize, prev_ctx: u64) -> Request {
        Request {
            id: RequestId(id),
            agent: AgentId(agent),
            prompt,
            gen: (0..gen as u32).map(|k| 500_000 + id as u32 * 1000 + k).collect(),
            prev_ctx,
            submitted_at: Micros::ZERO,
        }
    }

    fn drive(e: &mut SimEngine, max_steps: usize) -> Vec<FinishedReq> {
        let mut now = Micros::ZERO;
        let mut done = Vec::new();
        for _ in 0..max_steps {
            if !e.has_work() {
                break;
            }
            let out = e.step(now);
            now += out.duration + Micros(1);
            done.extend(out.finished);
            e.check_invariants().unwrap();
        }
        done
    }

    #[test]
    fn single_request_completes() {
        let mut e = tiny_engine(100_000);
        e.submit(mk_req(1, 1, (0..1000).collect(), 50, 0));
        let done = drive(&mut e, 100);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].output.len(), 50);
        assert_eq!(done[0].context_len, 1050);
        // Its KV is now cached.
        assert_eq!(e.tree().gpu_tokens(), 1050);
    }

    #[test]
    fn agent_resubmission_hits_cache() {
        let mut e = tiny_engine(100_000);
        let prompt: Vec<Token> = (0..1000).collect();
        e.submit(mk_req(1, 1, prompt.clone(), 50, 0));
        let done = drive(&mut e, 100);
        // Next step: history + tool tokens.
        let mut next = prompt;
        next.extend(done[0].output.iter());
        let prev_ctx = next.len() as u64;
        next.extend(2_000_000..2_000_200u32);
        e.submit(mk_req(2, 1, next, 50, prev_ctx));
        drive(&mut e, 100);
        // 1050 of 1250 prompt tokens were cached.
        let hr = e.lifetime_hits;
        assert_eq!(hr.num, 1050);
        assert_eq!(hr.den, 1000 + 1250);
        assert_eq!(e.counters.recompute_tokens, 0);
    }

    #[test]
    fn eviction_causes_recompute_on_resume() {
        // Pool fits ~one agent; a second agent's activity evicts the
        // first's cache, so its resumption recomputes.
        let mut e = tiny_engine(3_000);
        e.submit(mk_req(1, 1, (0..1000).collect(), 20, 0));
        let d1 = drive(&mut e, 200);
        assert_eq!(d1.len(), 1);
        // Agent 2 floods the pool.
        e.submit(mk_req(2, 2, (100_000..102_500).collect(), 20, 0));
        drive(&mut e, 200);
        // Agent 1 resumes; its prefix was evicted.
        let mut next: Vec<Token> = (0..1000).collect();
        next.extend(d1[0].output.iter());
        let prev = next.len() as u64;
        next.extend(3_000_000..3_000_100u32);
        e.submit(mk_req(3, 1, next, 20, prev));
        drive(&mut e, 200);
        assert!(
            e.counters.recompute_tokens > 500,
            "expected heavy recompute, got {}",
            e.counters.recompute_tokens
        );
        assert!(e.counters.evicted_tokens > 0);
    }

    #[test]
    fn offload_mode_retains_hits_but_pays_reload() {
        let mut e = tiny_engine(3_000);
        e.cfg.eviction = EvictionMode::Offload;
        e.policy = EvictPolicy::OffloadToCpu;
        e.submit(mk_req(1, 1, (0..1000).collect(), 20, 0));
        let d1 = drive(&mut e, 200);
        e.submit(mk_req(2, 2, (100_000..102_500).collect(), 20, 0));
        drive(&mut e, 200);
        let mut next: Vec<Token> = (0..1000).collect();
        next.extend(d1[0].output.iter());
        let prev = next.len() as u64;
        next.extend(3_000_000..3_000_100u32);
        e.submit(mk_req(3, 1, next, 20, prev));
        drive(&mut e, 300);
        // HiCache: the prefix survived in the CPU tier → counted as hits,
        // recompute stays near zero, but reload traffic happened.
        assert_eq!(e.counters.recompute_tokens, 0);
        assert!(e.counters.reloaded_tokens >= 1000);
        assert!(e.counters.offloaded_tokens >= 1000);
    }

    #[test]
    fn concurrent_shared_prefix_is_counted_once() {
        let mut e = tiny_engine(100_000);
        let sys: Vec<Token> = (0..512).collect();
        for a in 0..4u64 {
            let mut p = sys.clone();
            p.extend(10_000 * (a as u32 + 1)..10_000 * (a as u32 + 1) + 500);
            e.submit(mk_req(a + 1, a + 1, p, 30, 0));
        }
        drive(&mut e, 300);
        // Tree stores the shared 512-token system prompt once.
        assert_eq!(
            e.tree().gpu_tokens(),
            512 + 4 * (500 + 30),
        );
        e.check_invariants().unwrap();
    }

    #[test]
    fn request_cap_via_max_running() {
        let mut e = tiny_engine(100_000);
        e.cfg.max_running = 2;
        for a in 0..6u64 {
            let base = (a as u32) * 50_000;
            e.submit(mk_req(a + 1, a + 1, (base..base + 800).collect(), 20, 0));
        }
        let out = e.step(Micros::ZERO);
        assert_eq!(out.admitted, 2);
        assert_eq!(e.running_len(), 2);
        assert_eq!(e.waiting_len(), 4);
    }

    #[test]
    fn blocked_head_admits_once_memory_frees() {
        // Exercises the head-of-line admit cache: while the head doesn't
        // fit and nothing moves, the re-match is skipped; once capacity
        // frees (first request finishes and its cache becomes evictable),
        // the head must still be admitted and complete.
        let mut e = tiny_engine(10_000);
        e.submit(mk_req(1, 1, (0..6000).collect(), 30, 0));
        // Let request 1 occupy the pool.
        let mut now = Micros::ZERO;
        for _ in 0..4 {
            let out = e.step(now);
            now += out.duration + Micros(1);
        }
        // Head-of-line: needs more than the current free pool.
        e.submit(mk_req(2, 2, (100_000..107_000).collect(), 30, 0));
        let done = drive(&mut e, 300);
        assert_eq!(e.counters.finished, 2);
        assert!(done.iter().any(|f| f.id == RequestId(2)));
    }

    #[test]
    fn usage_signal_tracks_pool() {
        let mut e = tiny_engine(10_000);
        assert_eq!(e.kv_usage(), 0.0);
        e.submit(mk_req(1, 1, (0..5000).collect(), 10, 0));
        drive(&mut e, 100);
        // All requests done: the cache is reclaimable, so the working-set
        // signal returns to ~0 while raw pool usage stays high.
        assert!(e.pool_usage() > 0.45, "pool={}", e.pool_usage());
        assert!(e.kv_usage() < 0.05, "working={}", e.kv_usage());
    }

    #[test]
    fn heat_stamps_follow_finished_steps() {
        let mut e = tiny_engine(100_000);
        assert_eq!(e.agent_heat(AgentId(1)), None);
        e.submit(mk_req(1, 1, (0..500).collect(), 20, 0));
        e.submit(mk_req(2, 2, (10_000..10_500).collect(), 40, 0));
        drive(&mut e, 200);
        let h1 = e.agent_heat(AgentId(1)).expect("agent 1 decoded");
        let h2 = e.agent_heat(AgentId(2)).expect("agent 2 decoded");
        // Agent 2 generates more tokens, so it finishes (and stamps) later.
        assert!(h2 > h1, "h1={h1} h2={h2}");
        assert_eq!(e.agent_heat(AgentId(3)), None);
    }

    #[test]
    fn clear_state_wipes_serving_state_but_keeps_telemetry() {
        let mut e = tiny_engine(100_000);
        e.submit(mk_req(1, 1, (0..1000).collect(), 50, 0));
        drive(&mut e, 100);
        e.submit(mk_req(2, 2, (50_000..51_000).collect(), 50, 0));
        let finished_before = e.counters.finished;
        assert!(e.has_work());
        assert!(e.pool().used() > 0);

        e.clear_state();
        assert!(!e.has_work(), "queued work must be dropped");
        assert_eq!(e.pool().used(), 0);
        assert_eq!(e.pool().capacity(), 100_000, "capacity survives the wipe");
        assert_eq!(e.tree().gpu_tokens(), 0);
        assert_eq!(e.agent_heat(AgentId(1)), None, "heat stamps are wiped");
        assert_eq!(e.counters.finished, finished_before, "telemetry survives");
        e.check_invariants().unwrap();

        // The engine serves fresh work normally after the wipe.
        e.submit(mk_req(3, 3, (80_000..81_000).collect(), 20, 0));
        let done = drive(&mut e, 100);
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn broadcast_install_pins_and_counts_hits() {
        let mut e = tiny_engine(100_000);
        let shared: Vec<Token> = (0..512).collect();
        let out = e.install_broadcast_prefix(&shared, Micros::ZERO).expect("room");
        assert_eq!(out.installed_tokens, 512);
        assert_eq!(out.reloaded_tokens, 0);
        assert_eq!(e.pool().used(), 512, "install allocates its pool slots");
        assert_eq!(e.tree().broadcast_tokens(), 512);
        assert_eq!(e.counters.broadcast_installed_tokens, 512);
        e.check_invariants().unwrap();

        // A request whose prompt extends the prefix hits it (short-circuit)
        // and the hit is tagged as broadcast-carried.
        let mut p = shared.clone();
        p.extend(10_000..10_400u32);
        e.submit(mk_req(1, 1, p, 20, 0));
        drive(&mut e, 200);
        assert_eq!(e.counters.broadcast_hit_tokens, 512);
        assert_eq!(e.lifetime_hits.num, 512);

        // Re-installing an already-resident prefix moves nothing.
        let again = e.install_broadcast_prefix(&shared, Micros(1)).expect("no-op");
        assert_eq!(again.installed_tokens + again.reloaded_tokens, 0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn broadcast_prefix_survives_pressure_until_demoted() {
        let mut e = tiny_engine(3_000);
        let shared: Vec<Token> = (0..512).collect();
        let install = e.install_broadcast_prefix(&shared, Micros::ZERO).expect("room");
        // Flood the pool: everything else churns, the pinned prefix stays.
        e.submit(mk_req(1, 1, (100_000..102_200).collect(), 20, 0));
        drive(&mut e, 300);
        assert_eq!(e.tree().peek_prefix(&shared).0, 512, "pinned prefix evicted");
        // Demote: the prefix becomes ordinary cache and pressure can take it.
        e.demote_broadcast_prefix(&install.path);
        assert_eq!(e.tree().broadcast_tokens(), 0);
        e.submit(mk_req(2, 2, (200_000..202_200).collect(), 20, 0));
        drive(&mut e, 300);
        assert!(e.tree().peek_prefix(&shared).0 < 512, "demoted prefix still pinned");
        e.check_invariants().unwrap();
    }

    #[test]
    fn reserved_prefix_matches_zero_tokens_until_commit() {
        let mut e = tiny_engine(100_000);
        let shared: Vec<Token> = (0..512).collect();
        let res = e.reserve_broadcast_prefix(&shared, Micros::ZERO).expect("room");
        assert_eq!(res.reserved, 512);
        assert_eq!(e.pool().used(), 512, "capacity is committed at reserve");
        assert_eq!(e.tree().gpu_tokens(), 0, "nothing matchable yet");
        assert_eq!(e.tree().peek_prefix(&shared).0, 0);
        e.check_invariants().unwrap();

        // A request overlapping the in-flight prefix gets zero hits and
        // prefills from scratch — the KV has not arrived.
        let mut p = shared.clone();
        p.extend(10_000..10_400u32);
        e.submit(mk_req(1, 1, p, 20, 0));
        drive(&mut e, 200);
        assert_eq!(e.counters.broadcast_hit_tokens, 0);
        assert_eq!(e.lifetime_hits.num, 0);

        // Commit: the prefix lands, pinned; the duplicate coverage the
        // request inserted meanwhile shrinks the materialisation.
        let out = e.commit_broadcast_prefix(&shared, res.reserved, Micros(10)).expect("lands");
        assert_eq!(out.installed_tokens, 0, "request already re-prefilled the prefix");
        assert_eq!(e.tree().broadcast_tokens(), 512);
        e.check_invariants().unwrap();

        // Post-commit requests hit the pinned path normally.
        let mut p2 = shared.clone();
        p2.extend(20_000..20_400u32);
        e.submit(mk_req(2, 2, p2, 20, 0));
        drive(&mut e, 200);
        assert_eq!(e.counters.broadcast_hit_tokens, 512);
    }

    #[test]
    fn commit_on_untouched_tree_materialises_the_reservation() {
        let mut e = tiny_engine(100_000);
        let shared: Vec<Token> = (0..512).collect();
        let res = e.reserve_broadcast_prefix(&shared, Micros::ZERO).expect("room");
        let out = e.commit_broadcast_prefix(&shared, res.reserved, Micros(5)).expect("lands");
        assert_eq!(out.installed_tokens, 512);
        assert_eq!(e.pool().used(), 512);
        assert_eq!(e.counters.broadcast_installed_tokens, 512);
        e.check_invariants().unwrap();
    }

    #[test]
    fn aborted_reservation_releases_the_pool() {
        let mut e = tiny_engine(100_000);
        let shared: Vec<Token> = (0..512).collect();
        let res = e.reserve_broadcast_prefix(&shared, Micros::ZERO).expect("room");
        e.abort_broadcast_reserve(res.reserved);
        assert_eq!(e.pool().used(), 0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn infeasible_reserve_is_refused_without_eviction() {
        let mut e = tiny_engine(1_000);
        // A prefix larger than the whole pool can never fit.
        let huge: Vec<Token> = (0..2_000).collect();
        assert!(e.reserve_broadcast_prefix(&huge, Micros::ZERO).is_none());
        assert_eq!(e.pool().used(), 0);
        assert_eq!(e.counters.evictions, 0, "refusal must not evict");
    }

    #[test]
    fn handoff_context_installs_as_evictable_warm_cache() {
        let mut e = tiny_engine(100_000);
        let ctx: Vec<Token> = (0..1_000).collect();
        let moved = e.install_handoff_context(AgentId(7), &ctx, Micros(3));
        assert_eq!(moved, 1_000);
        assert_eq!(e.counters.handoff_installed_tokens, 1_000);
        assert_eq!(e.tree().broadcast_tokens(), 0, "handoff state is not pinned");
        assert_eq!(e.agent_heat(AgentId(7)), Some(Micros(3)), "agent is warm here now");
        e.check_invariants().unwrap();

        // The agent's next step hits the shipped context.
        let mut next = ctx.clone();
        next.extend(5_000_000..5_000_100u32);
        e.submit(mk_req(1, 7, next, 20, 1_000));
        drive(&mut e, 200);
        assert_eq!(e.lifetime_hits.num, 1_000);

        // An infeasible handoff is dropped, not forced.
        let mut tight = tiny_engine(500);
        assert_eq!(tight.install_handoff_context(AgentId(1), &ctx, Micros(1)), 0);
        assert_eq!(tight.pool().used(), 0);
    }

    #[test]
    fn breakdown_accumulates_all_time() {
        let mut e = tiny_engine(50_000);
        for a in 0..3u64 {
            let base = (a as u32) * 50_000;
            e.submit(mk_req(a + 1, a + 1, (base..base + 1500).collect(), 25, 0));
        }
        drive(&mut e, 300);
        assert!(e.breakdown.total().0 > 0);
        assert!(e.breakdown.fraction(Phase::Decode) > 0.0);
        assert!(e.breakdown.fraction(Phase::Prefill) > 0.0);
    }

    fn policy_engine(mode: crate::config::KvLifetimeMode, capacity: u64) -> SimEngine {
        let cost = CostModel::new(ClusterSpec::new(
            GpuSpec::h100(),
            ModelSpec::qwen3_32b(),
            8,
            8,
        ));
        let cfg = EngineConfig {
            prefill_chunk: 8192,
            kv_lifetime: mode,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, cost);
        e.shrink_pool_for_tests(capacity);
        e
    }

    /// Cache A (hinted) then B (unhinted), then admit a C big enough to
    /// force exactly one whole-leaf eviction; returns the surviving GPU
    /// coverage of A's and B's prompts.
    fn pressure_one_eviction(mode: crate::config::KvLifetimeMode, hint_a: u64) -> (u64, u64) {
        let mut e = policy_engine(mode, 3_600);
        let pa: Vec<Token> = (0..1_000).collect();
        let pb: Vec<Token> = (100_000..101_000).collect();
        e.set_lifetime_hint(AgentId(1), hint_a);
        e.submit(mk_req(1, 1, pa.clone(), 20, 0));
        drive(&mut e, 200);
        e.submit(mk_req(2, 2, pb.clone(), 20, 0));
        drive(&mut e, 200);
        // C's prefill overflows the free pool and must evict one victim.
        e.submit(mk_req(3, 3, (200_000..202_000).collect(), 20, 0));
        drive(&mut e, 300);
        assert!(e.counters.evicted_tokens > 0, "pressure must have evicted");
        e.check_invariants().unwrap();
        (e.tree().peek_prefix(&pa).0, e.tree().peek_prefix(&pb).0)
    }

    #[test]
    fn wants_lifetime_hint_only_off_lru() {
        use crate::config::KvLifetimeMode;
        assert!(!policy_engine(KvLifetimeMode::Lru, 1_000).wants_lifetime_hint());
        assert!(policy_engine(KvLifetimeMode::StepsToExecution, 1_000).wants_lifetime_hint());
        assert!(policy_engine(KvLifetimeMode::ToolTtl, 1_000).wants_lifetime_hint());
    }

    #[test]
    fn steps_hint_inverts_the_lru_eviction_choice() {
        use crate::config::KvLifetimeMode;
        // LRU control: A is staler, so pressure takes A and keeps B.
        let (a, b) = pressure_one_eviction(KvLifetimeMode::Lru, 1);
        assert_eq!((a, b), (0, 1_000), "LRU must evict the staler A");
        // StepsToExecution: A hinted one-step-from-done outranks the
        // fresher-but-futureless B.
        let (a, b) = pressure_one_eviction(KvLifetimeMode::StepsToExecution, 1);
        assert_eq!((a, b), (1_000, 0), "hinted A must survive, unhinted B goes");
        // An explicit 0 hint (no future) keeps plain recency order.
        let (a, b) = pressure_one_eviction(KvLifetimeMode::StepsToExecution, 0);
        assert_eq!((a, b), (0, 1_000));
    }

    #[test]
    fn tool_ttl_pin_inverts_the_lru_eviction_choice() {
        use crate::config::KvLifetimeMode;
        // A pinned across a long tool wait survives pressure that takes
        // the fresher unpinned B — the paper's recency inversion, fixed.
        let (a, b) = pressure_one_eviction(KvLifetimeMode::ToolTtl, 3_600_000_000);
        assert_eq!((a, b), (1_000, 0), "pinned A must survive its tool wait");
        // No tool call, no pin: plain recency order.
        let (a, b) = pressure_one_eviction(KvLifetimeMode::ToolTtl, 0);
        assert_eq!((a, b), (0, 1_000));
    }

    #[test]
    fn clear_state_preserves_lifetime_policy() {
        use crate::config::KvLifetimeMode;
        let mut e = policy_engine(KvLifetimeMode::ToolTtl, 10_000);
        e.set_lifetime_hint(AgentId(1), 5_000);
        e.clear_state();
        assert_eq!(e.lifetime_policy(), KvLifetimePolicy::ToolTtl);
        assert!(e.wants_lifetime_hint());
        e.check_invariants().unwrap();
    }

    // -- storage tier ------------------------------------------------------

    fn storage_engine(
        capacity: u64,
        bandwidth_gbps: f64,
        mode: crate::config::DualPathMode,
    ) -> SimEngine {
        let cost = CostModel::new(ClusterSpec::new(
            GpuSpec::h100(),
            ModelSpec::qwen3_32b(),
            8,
            8,
        ));
        let cfg = EngineConfig {
            prefill_chunk: 8192,
            eviction: crate::config::EvictionMode::Offload,
            storage_tier: crate::config::StorageTierConfig {
                enabled: true,
                capacity_tokens: 1_000_000,
                bandwidth_gbps,
                cpu_tier_tokens: 0,
            },
            dual_path: mode,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, cost);
        e.shrink_pool_for_tests(capacity);
        // Tight CPU tier so offloads demote to storage immediately.
        e.shrink_cpu_tier_for_tests(capacity / 2);
        e
    }

    /// Run agent 1, displace it through CPU into storage with agent 2's
    /// flood, then resubmit agent 1's continuation; returns the engine.
    fn storage_round_trip(mut e: SimEngine) -> SimEngine {
        let prompt: Vec<Token> = (0..2_500).collect();
        e.submit(mk_req(1, 1, prompt.clone(), 10, 0));
        let d1 = drive(&mut e, 300);
        assert_eq!(d1.len(), 1);
        // Agent 2 floods the pool: agent 1's cache offloads to the tiny
        // CPU tier, which trims it straight into storage.
        e.submit(mk_req(2, 2, (100_000..102_500).collect(), 10, 0));
        drive(&mut e, 300);
        // Agent 1 returns with its grown context.  Its cache drains
        // GPU→CPU→storage under agent 2's pressure plus this admission's
        // own reload attempt (the tight CPU tier trims whatever lands).
        let mut next = prompt;
        next.extend(d1[0].output.iter());
        let prev = next.len() as u64;
        next.extend(3_000_000..3_000_100u32);
        e.submit(mk_req(3, 1, next, 10, prev));
        drive(&mut e, 400);
        assert!(
            e.counters.storage_demoted_tokens >= 2_000,
            "agent 1's context must demote to storage, got {}",
            e.counters.storage_demoted_tokens
        );
        e.check_invariants().unwrap();
        e
    }

    #[test]
    fn storage_reload_serves_demoted_context_without_recompute() {
        use crate::config::DualPathMode;
        let e = storage_round_trip(storage_engine(4_000, 6.0, DualPathMode::AlwaysReload));
        assert!(
            e.counters.storage_reloaded_tokens >= 2_000,
            "demoted context must reload from storage, got {}",
            e.counters.storage_reloaded_tokens
        );
        assert_eq!(e.counters.storage_recomputed_tokens, 0);
        assert_eq!(
            e.counters.recompute_tokens, 0,
            "a storage reload is not recompute"
        );
        assert!(e.storage().unwrap().link.bytes_moved > 0);
    }

    #[test]
    fn always_recompute_leaves_extents_cold_and_pays_prefill() {
        use crate::config::DualPathMode;
        let e =
            storage_round_trip(storage_engine(4_000, 6.0, DualPathMode::AlwaysRecompute));
        assert_eq!(e.counters.storage_reloaded_tokens, 0);
        assert!(
            e.counters.storage_recomputed_tokens >= 2_000,
            "the storage span must be re-prefilled, got {}",
            e.counters.storage_recomputed_tokens
        );
        assert!(
            e.counters.recompute_tokens >= 2_000,
            "re-prefilling previously computed context is recompute churn"
        );
    }

    #[test]
    fn dual_path_follows_the_modeled_crossover() {
        use crate::config::DualPathMode;
        // A fast link makes the read cheaper than the quadratic prefill…
        let fast = storage_round_trip(storage_engine(4_000, 1_000.0, DualPathMode::DualPath));
        assert!(fast.counters.storage_reloaded_tokens >= 2_000, "fast link → reload");
        // …and a glacial one flips the argmin to recompute.
        let slow = storage_round_trip(storage_engine(4_000, 0.001, DualPathMode::DualPath));
        assert!(slow.counters.storage_recomputed_tokens >= 2_000, "slow link → recompute");
        assert_eq!(slow.counters.storage_reloaded_tokens, 0);
    }

    #[test]
    fn storage_reload_excess_lands_in_its_breakdown_phase() {
        use crate::config::DualPathMode;
        // Slow enough that the read dominates the step, fast enough that
        // dual-path pricing would still pick it — force it via AlwaysReload.
        let e = storage_round_trip(storage_engine(4_000, 0.05, DualPathMode::AlwaysReload));
        assert!(
            e.breakdown.get(Phase::StorageReload) > Micros::ZERO,
            "read excess over compute must be attributed to StorageReload"
        );
    }

    #[test]
    fn clear_state_wipes_the_storage_tier() {
        use crate::config::DualPathMode;
        let mut e = storage_round_trip(storage_engine(4_000, 6.0, DualPathMode::AlwaysReload));
        assert!(e.storage().unwrap().extent_count() > 0, "round trip left extents behind");
        let reloaded = e.counters.storage_reloaded_tokens;
        e.clear_state();
        let tier = e.storage().expect("tier survives the wipe, empty");
        assert_eq!(tier.used_tokens(), 0);
        assert_eq!(tier.extent_count(), 0);
        assert_eq!(tier.link.transfers, 0);
        assert_eq!(
            e.counters.storage_reloaded_tokens, reloaded,
            "cumulative telemetry survives"
        );
        e.check_invariants().unwrap();
    }
}
