//! # CONCUR — congestion-based agent-level admission control
//!
//! Reproduction of *"CONCUR: High-Throughput Agentic Batch Inference of LLM
//! via Congestion-Based Concurrency Control"* (CS.DC 2026).
//!
//! The paper's contribution is a lightweight **agent-level controller**
//! interposed between an agent execution framework and an LLM serving
//! engine.  It regulates how many agents may issue generation steps
//! concurrently via an AIMD control law driven by KV-cache usage `U_t` and
//! prefix-cache hit-rate `H_t` signals, preventing *middle-phase thrashing*.
//!
//! ## Crate layout (three-layer architecture, see DESIGN.md)
//!
//! * [`core`]        — ids, deterministic RNG, minimal JSON codec, errors.
//! * [`config`]      — experiment/system configuration and presets.
//! * [`costmodel`]   — H100 roofline + KV geometry + PCIe contention model.
//! * [`sim`]         — discrete-event simulation clock and event queue.
//! * [`metrics`]     — time series, histograms, latency breakdowns, tables.
//! * [`engine`]      — SGLang-like serving-engine substrate: paged KV pool,
//!                     radix-tree prefix cache with LRU eviction, HiCache
//!                     offload tier, continuous batcher.
//! * [`agent`]       — ReAct agent state machine + workload generator.
//! * [`coordinator`] — the paper's system contribution: CONCUR AIMD
//!                     admission control plus all evaluated baselines.
//! * [`cluster`]     — data-parallel serving fleet: N engine replicas,
//!                     cache-affine + cold-first rebalancing routing,
//!                     aggregated control signals, scripted and
//!                     stochastic (MTBF/MTTR-sampled) replica faults
//!                     (kill / drain-and-refill / revive), per-replica
//!                     tool-latency skew, open-loop session traffic with
//!                     SLO accounting and overload shedding, and an
//!                     optional cross-replica shared-prefix broadcast
//!                     tier.
//! * [`driver`]      — glue that runs a full agentic batch job end-to-end.
//! * [`gate`]        — CI perf gate: BENCH json vs checked-in thresholds.
//! * [`runtime`]     — PJRT bridge: loads `artifacts/*.hlo.txt` (lowered
//!                     from the L2 JAX model + L1 Pallas kernels) and
//!                     executes them from the request path.
//! * [`server`]      — real-model serving path on top of [`runtime`].
//! * [`repro`]       — one harness per paper table/figure.
//!
//! Python (JAX + Pallas) exists only on the compile path (`make artifacts`);
//! the request path is pure rust.

pub mod agent;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod costmodel;
pub mod driver;
pub mod engine;
pub mod gate;
pub mod metrics;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod sim;
#[doc(hidden)]
pub mod xla_stub;
