//! The CONCUR cache-aware AIMD control law (paper Eq. 1).
//!
//! ```text
//! W_{t+1} = W_t + α     if U_t < U_low                      (probe)
//!         = W_t × β     if U_t > U_high ∧ H_t < H_thresh    (cut)
//!         = W_t         otherwise                            (hold)
//! ```
//!
//! * **Linear exploration (α)** probes the unknown effective capacity
//!   without the overshoot risk of exponential growth.
//! * **Multiplicative cut (β)** exits the quadratic-penalty regime (O(L²)
//!   recompute) exponentially fast.
//! * The `[U_low, U_high]` gap is an allocation buffer absorbing the
//!   discrete memory spikes of admitting long-context agents, and the
//!   `H_t < H_thresh` conjunct lets the system *sustain* saturation while
//!   the cache is still effective (throughput over preemptive throttling).

use crate::config::AimdParams;

use super::{ControlInputs, Controller};

/// CONCUR's adaptive admission controller.
#[derive(Debug, Clone)]
pub struct AimdController {
    p: AimdParams,
    w: f64,
    steps_seen: u64,
    /// Control intervals remaining before another cut is allowed.
    cut_timer: u32,
    /// Control intervals seen since the last cut (gates band probing).
    since_cut: u64,
    /// Control intervals seen (for the band-probe cadence).
    intervals: u64,
    history: Vec<(u64, f64)>,
    /// Counters for tests / reports.
    pub increases: u64,
    pub cuts: u64,
    pub holds: u64,
}

impl AimdController {
    pub fn new(p: AimdParams) -> AimdController {
        p.validate().expect("invalid AIMD parameters");
        AimdController {
            w: p.w_init,
            p,
            steps_seen: 0,
            cut_timer: 0,
            since_cut: u64::MAX / 2,
            intervals: 0,
            history: Vec::new(),
            increases: 0,
            cuts: 0,
            holds: 0,
        }
    }

    pub fn params(&self) -> &AimdParams {
        &self.p
    }

    pub fn window_f(&self) -> f64 {
        self.w
    }

    /// Apply one control decision for signals (U_t, H_t).
    ///
    /// The additive increase is gated on window *saturation* (active agents
    /// actually reaching the window) — the congestion-window-validation
    /// rule (cf. RFC 7661): an app-limited sender must not inflate its
    /// window, or a burst of agents returning from tool calls would be
    /// admitted against a stale, meaninglessly large W.
    fn control(&mut self, u: f64, h: f64, active: usize) {
        let saturated = active >= self.w.floor() as usize;
        if self.cut_timer > 0 {
            self.cut_timer -= 1;
        }
        self.intervals += 1;
        self.since_cut = self.since_cut.saturating_add(1);
        // Congestion avoidance inside the hold band: slow additive probe
        // while the cache is demonstrably healthy (see AimdParams docs).
        let band_probe = self.p.band_probe_every > 0
            && saturated
            && u < self.p.u_high
            && h >= self.p.h_healthy
            && self.since_cut > (4 * self.p.cut_cooldown) as u64
            && self.intervals % self.p.band_probe_every as u64 == 0;
        if (u < self.p.u_low && saturated) || band_probe {
            self.w += self.p.alpha;
            self.increases += 1;
        } else if u > self.p.u_high && h < self.p.h_thresh {
            // One cut per congestion epoch (TCP fast recovery): a second
            // cut is only meaningful once the previous one has taken
            // effect — the active population has drained to the window and
            // the hit window has had time to refresh.  Cascading cuts on a
            // stale signal would crash W and serialize the batch.
            let previous_cut_effective = active <= self.w.floor() as usize;
            if self.cut_timer == 0 && previous_cut_effective {
                self.w *= self.p.beta;
                self.cuts += 1;
                self.cut_timer = self.p.cut_cooldown;
                self.since_cut = 0;
            } else {
                self.holds += 1;
            }
        } else {
            self.holds += 1;
        }
        self.w = self.w.clamp(self.p.w_min, self.p.w_max);
        self.history.push((self.steps_seen, self.w));
    }
}

/// Hysteretic overload detector for open-loop admission (SLO shedding).
///
/// The AIMD window tracks what the fleet can *execute*; under open-loop
/// traffic the backlog of sessions waiting for a slot can still grow
/// without bound when arrivals outpace service.  The governor watches the
/// backlog-to-window ratio and flips into the shedding state when it
/// exceeds `on_ratio`, staying there until the ratio falls below
/// `off_ratio` — the hysteresis band prevents admission flapping around a
/// single threshold while the backlog oscillates with the diurnal curve.
/// While shedding, low-priority arrivals are rejected at the door so the
/// waiting time saved accrues to high-priority sessions (graceful
/// degradation rather than uniform SLO collapse).
#[derive(Debug, Clone)]
pub struct OverloadGovernor {
    on_ratio: f64,
    off_ratio: f64,
    shedding: bool,
    /// Counters for tests / reports.
    pub trips: u64,
    pub recoveries: u64,
}

impl OverloadGovernor {
    pub fn new(on_ratio: f64, off_ratio: f64) -> OverloadGovernor {
        assert!(
            on_ratio.is_finite() && off_ratio.is_finite() && off_ratio < on_ratio,
            "governor needs a hysteresis band: off_ratio {off_ratio} < on_ratio {on_ratio}"
        );
        OverloadGovernor { on_ratio, off_ratio, shedding: false, trips: 0, recoveries: 0 }
    }

    /// Feed one observation of the waiting backlog against the current
    /// admission window; returns the (possibly updated) shedding state.
    pub fn observe(&mut self, backlog: usize, window: usize) -> bool {
        let ratio = backlog as f64 / window.max(1) as f64;
        if self.shedding {
            if ratio < self.off_ratio {
                self.shedding = false;
                self.recoveries += 1;
            }
        } else if ratio > self.on_ratio {
            self.shedding = true;
            self.trips += 1;
        }
        self.shedding
    }

    pub fn is_shedding(&self) -> bool {
        self.shedding
    }
}

impl Controller for AimdController {
    fn name(&self) -> String {
        "concur".into()
    }

    fn on_signals(&mut self, inputs: &ControlInputs) {
        self.steps_seen += 1;
        if self.steps_seen % self.p.control_interval as u64 == 0 {
            self.control(
                inputs.usage(),
                inputs.engine.hit_rate,
                inputs.active_agents,
            );
        }
    }

    fn window(&self) -> usize {
        self.w.floor() as usize
    }

    fn window_history(&self) -> &[(u64, f64)] {
        &self.history
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::engine::EngineSignals;

    fn sig_active(u: f64, h: f64, active: usize) -> ControlInputs {
        ControlInputs {
            engine: EngineSignals {
                kv_usage: u,
                pool_usage: u,
                hit_rate: h,
                running: 0,
                waiting: 0,
            },
            active_agents: active,
            active_footprint: (u * 1_000_000.0) as u64,
            capacity: 1_000_000,
        }
    }

    /// Signals with the active population exactly at the window: satisfies
    /// both the growth-saturation gate and the cut-drained gate, isolating
    /// the control law itself.
    fn step(c: &mut AimdController, u: f64, h: f64) {
        let active = c.window();
        c.on_signals(&sig_active(u, h, active));
    }

    fn ctrl() -> AimdController {
        let p = AimdParams {
            control_interval: 1,
            cut_cooldown: 0,
            band_probe_every: 0,
            ..AimdParams::default()
        };
        AimdController::new(p)
    }

    #[test]
    fn additive_increase_when_underutilized() {
        let mut c = ctrl();
        let w0 = c.window_f();
        for _ in 0..5 {
            step(&mut c, 0.1, 0.9);
        }
        assert_eq!(c.window_f(), w0 + 5.0 * 2.0);
        assert_eq!(c.increases, 5);
    }

    #[test]
    fn multiplicative_cut_on_thrash() {
        let mut c = ctrl();
        // Grow first.
        for _ in 0..16 {
            step(&mut c, 0.1, 0.9);
        }
        let grown = c.window_f();
        // Saturated AND hit rate collapsed → cut by β each step.
        step(&mut c, 0.9, 0.1);
        assert_eq!(c.window_f(), grown * 0.5);
        step(&mut c, 0.9, 0.1);
        assert_eq!(c.window_f(), grown * 0.25);
        assert_eq!(c.cuts, 2);
    }

    #[test]
    fn holds_in_the_buffer_zone() {
        let mut c = ctrl();
        let w0 = c.window_f();
        // Usage between thresholds → hold regardless of hit rate.
        step(&mut c, 0.35, 0.05);
        assert_eq!(c.window_f(), w0);
        // Saturated but hit rate healthy → also hold (throughput over
        // preemptive throttling).
        step(&mut c, 0.95, 0.8);
        assert_eq!(c.window_f(), w0);
        assert_eq!(c.holds, 2);
    }

    #[test]
    fn window_respects_floor_and_ceiling() {
        let p = AimdParams {
            control_interval: 1,
            cut_cooldown: 0,
            band_probe_every: 0,
            w_init: 2.0,
            w_min: 1.0,
            w_max: 10.0,
            ..AimdParams::default()
        };
        let mut c = AimdController::new(p);
        for _ in 0..50 {
            step(&mut c, 0.9, 0.0); // cut forever
        }
        assert_eq!(c.window_f(), 1.0);
        assert!(c.window() >= 1);
        for _ in 0..50 {
            step(&mut c, 0.05, 1.0); // grow forever
        }
        assert_eq!(c.window_f(), 10.0);
    }

    #[test]
    fn control_interval_batches_decisions() {
        let p = AimdParams {
            control_interval: 4,
            cut_cooldown: 0,
            band_probe_every: 0,
            ..AimdParams::default()
        };
        let mut c = AimdController::new(p);
        let w0 = c.window_f();
        for _ in 0..3 {
            step(&mut c, 0.1, 0.9);
        }
        assert_eq!(c.window_f(), w0); // not yet
        step(&mut c, 0.1, 0.9);
        assert_eq!(c.window_f(), w0 + 2.0); // fires on the 4th
    }

    #[test]
    fn cut_cooldown_limits_to_one_cut_per_epoch() {
        let p = AimdParams {
            control_interval: 1,
            cut_cooldown: 4,
            band_probe_every: 0,
            ..AimdParams::default()
        };
        let mut c = AimdController::new(p);
        for _ in 0..16 {
            step(&mut c, 0.1, 0.9);
        }
        let grown = c.window_f();
        // Five consecutive congested intervals → exactly one cut.
        for _ in 0..4 {
            step(&mut c, 0.9, 0.05);
        }
        assert_eq!(c.cuts, 1);
        assert_eq!(c.window_f(), grown * 0.5);
        // After the cooldown expires, the next congested interval cuts again.
        step(&mut c, 0.9, 0.05);
        assert_eq!(c.cuts, 2);
    }

    #[test]
    fn band_probe_creeps_upward_when_healthy() {
        let p = AimdParams {
            control_interval: 1,
            cut_cooldown: 1,
            band_probe_every: 2,
            ..AimdParams::default()
        };
        let mut c = AimdController::new(p);
        let w0 = c.window_f();
        // In the hold band (u between thresholds) with a healthy cache the
        // window creeps upward every 2nd interval.
        for _ in 0..8 {
            step(&mut c, 0.35, 0.95);
        }
        assert_eq!(c.window_f(), w0 + 4.0 * 2.0);
        // With a mediocre hit rate it holds instead.
        let w1 = c.window_f();
        for _ in 0..8 {
            step(&mut c, 0.35, 0.5);
        }
        assert_eq!(c.window_f(), w1);
    }

    #[test]
    fn governor_hysteresis_prevents_flapping() {
        let mut g = OverloadGovernor::new(2.0, 1.0);
        assert!(!g.observe(10, 8)); // ratio 1.25: inside the band, stays off
        assert!(g.observe(20, 8)); // ratio 2.5 > 2.0: trips
        // Back inside the band: a plain threshold would flap here.
        assert!(g.observe(12, 8)); // ratio 1.5: still shedding
        assert!(g.observe(20, 8)); // re-exceeding while on is not a new trip
        assert!(!g.observe(6, 8)); // ratio 0.75 < 1.0: recovers
        assert!(!g.observe(12, 8)); // 1.5 again: off until > on_ratio
        assert_eq!((g.trips, g.recoveries), (1, 1));
        // A dead fleet (window 0) treats the backlog against window 1.
        assert!(g.observe(3, 0));
    }

    #[test]
    #[should_panic(expected = "hysteresis band")]
    fn governor_rejects_inverted_band() {
        OverloadGovernor::new(1.0, 2.0);
    }

    #[test]
    fn aimd_converges_in_sawtooth_under_oscillating_load() {
        // Classic AIMD: alternating congestion produces a bounded sawtooth,
        // not divergence.
        let mut c = ctrl();
        let mut ws = Vec::new();
        for i in 0..200 {
            let congested = i % 10 == 9;
            if congested {
                step(&mut c, 0.9, 0.05);
            } else {
                step(&mut c, 0.1, 0.9);
            }
            ws.push(c.window_f());
        }
        let late = &ws[100..];
        let max = late.iter().cloned().fold(f64::MIN, f64::max);
        let min = late.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max < 64.0, "sawtooth escaped: max={max}");
        assert!(min >= 1.0);
        assert!(c.window_history().len() == 200);
    }
}
