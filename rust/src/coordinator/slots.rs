//! Admission-slot bookkeeping: the admit / pause / resume primitives.
//!
//! A slot represents the right of one agent to issue generation steps.
//! Agents keep their slot across tool waits (execution continuity); slots
//! are only revoked at step boundaries when the controller's window has
//! shrunk.  Resumption prefers the most recently paused agent — its cached
//! prefix is the warmest — before admitting never-run agents FIFO.

use std::collections::{HashSet, VecDeque};

use crate::core::AgentId;

/// Decision for an agent arriving at a step boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundaryDecision {
    /// Keep the slot; submit the next step immediately.
    Continue,
    /// Slot revoked; the agent waits in the paused pool.
    Paused,
}

/// Tracks which agents hold admission slots.
#[derive(Debug, Default)]
pub struct SlotManager {
    active: HashSet<AgentId>,
    /// Recently paused agents, most recent last (LIFO resume).
    paused: Vec<AgentId>,
    /// Never-admitted agents, FIFO.
    fresh: VecDeque<AgentId>,
    /// Never-admitted low-priority agents (open-loop priority admission),
    /// FIFO behind `fresh`.  Always empty in closed-batch runs, so the
    /// closed admission order is untouched.
    fresh_low: VecDeque<AgentId>,
    pub admissions: u64,
    pub pauses: u64,
    pub resumes: u64,
}

impl SlotManager {
    pub fn new() -> SlotManager {
        SlotManager::default()
    }

    /// Register a new agent awaiting first admission.
    pub fn register(&mut self, agent: AgentId) {
        self.fresh.push_back(agent);
    }

    /// Register a low-priority agent: admitted only once every paused
    /// and regular fresh agent has a slot (open-loop priority admission).
    pub fn register_low(&mut self, agent: AgentId) {
        self.fresh_low.push_back(agent);
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn pending_count(&self) -> usize {
        self.paused.len() + self.fresh.len() + self.fresh_low.len()
    }

    pub fn is_active(&self, agent: AgentId) -> bool {
        self.active.contains(&agent)
    }

    /// Iterate over slot-holding agents (order unspecified).
    pub fn active_ids(&self) -> impl Iterator<Item = AgentId> + '_ {
        self.active.iter().copied()
    }

    /// An active agent reached a step boundary (tool returned).  If the
    /// window has shrunk below the active population, revoke its slot.
    pub fn on_step_boundary(&mut self, agent: AgentId, window: usize) -> BoundaryDecision {
        debug_assert!(self.active.contains(&agent), "agent without slot at boundary");
        if self.active.len() > window {
            self.active.remove(&agent);
            self.paused.push(agent);
            self.pauses += 1;
            BoundaryDecision::Paused
        } else {
            BoundaryDecision::Continue
        }
    }

    /// Agent finished its trajectory: release the slot.
    pub fn release(&mut self, agent: AgentId) {
        let had = self.active.remove(&agent);
        debug_assert!(had, "release of agent without slot");
    }

    /// Revoke `agent`'s slot outside a step boundary (its replica died
    /// mid-step): it re-enters the fresh admission queue FIFO.  Unlike
    /// [`SlotManager::on_step_boundary`] pausing, it gets no warm-resume
    /// priority — its cache died with the replica, so it is
    /// indistinguishable from a never-admitted agent.
    pub fn requeue(&mut self, agent: AgentId) {
        let had = self.active.remove(&agent);
        debug_assert!(had, "requeue of agent without slot");
        self.fresh.push_back(agent);
    }

    /// Grant slots up to `window`, returning agents to (re)start, paused
    /// agents first (LIFO), then fresh agents (FIFO), then low-priority
    /// fresh agents (FIFO).
    pub fn grant_up_to(&mut self, window: usize) -> Vec<AgentId> {
        let mut granted = Vec::new();
        while self.active.len() < window {
            let next = if let Some(a) = self.paused.pop() {
                self.resumes += 1;
                Some(a)
            } else if let Some(a) = self.fresh.pop_front() {
                self.admissions += 1;
                Some(a)
            } else if let Some(a) = self.fresh_low.pop_front() {
                self.admissions += 1;
                Some(a)
            } else {
                None
            };
            let Some(a) = next else { break };
            self.active.insert(a);
            granted.push(a);
        }
        granted
    }

    /// Remove every *waiting* agent (paused or fresh — never one with a
    /// step in flight) for which `expired` holds: open-loop abandonment.
    /// Returns the removed ids in queue order.
    pub fn take_expired(&mut self, expired: impl Fn(AgentId) -> bool) -> Vec<AgentId> {
        let mut gone = Vec::new();
        let mut keep = |a: AgentId| {
            if expired(a) {
                gone.push(a);
                false
            } else {
                true
            }
        };
        self.paused.retain(|&a| keep(a));
        self.fresh.retain(|&a| keep(a));
        self.fresh_low.retain(|&a| keep(a));
        gone
    }

    /// Drain the whole low-priority fresh queue — the overload governor
    /// has decided the fleet cannot serve it within SLO.  Returns the
    /// shed ids in queue order.
    pub fn shed_low_fresh(&mut self) -> Vec<AgentId> {
        self.fresh_low.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(v: &[u64]) -> Vec<AgentId> {
        v.iter().map(|&i| AgentId(i)).collect()
    }

    #[test]
    fn fresh_admission_is_fifo() {
        let mut s = SlotManager::new();
        for i in 0..5 {
            s.register(AgentId(i));
        }
        assert_eq!(s.grant_up_to(3), ids(&[0, 1, 2]));
        assert_eq!(s.active_count(), 3);
        assert_eq!(s.pending_count(), 2);
    }

    #[test]
    fn window_shrink_pauses_at_boundary() {
        let mut s = SlotManager::new();
        for i in 0..4 {
            s.register(AgentId(i));
        }
        s.grant_up_to(4);
        // Window shrinks to 2: the first two agents reaching a boundary
        // get paused.
        assert_eq!(s.on_step_boundary(AgentId(0), 2), BoundaryDecision::Paused);
        assert_eq!(s.on_step_boundary(AgentId(1), 2), BoundaryDecision::Paused);
        assert_eq!(s.on_step_boundary(AgentId(2), 2), BoundaryDecision::Continue);
        assert_eq!(s.active_count(), 2);
        assert_eq!(s.pauses, 2);
    }

    #[test]
    fn resume_prefers_recently_paused_lifo() {
        let mut s = SlotManager::new();
        for i in 0..4 {
            s.register(AgentId(i));
        }
        s.grant_up_to(3); // 0,1,2 active; 3 fresh
        s.on_step_boundary(AgentId(0), 1); // paused: [0]
        s.on_step_boundary(AgentId(1), 1); // paused: [0, 1]
        // Window back to 3: grant 2 slots → most-recent paused (1) first,
        // then 0; fresh 3 stays queued.
        assert_eq!(s.grant_up_to(3), ids(&[1, 0]));
        assert_eq!(s.resumes, 2);
        assert_eq!(s.grant_up_to(4), ids(&[3]));
        assert_eq!(s.admissions, 4);
    }

    #[test]
    fn release_frees_capacity() {
        let mut s = SlotManager::new();
        for i in 0..3 {
            s.register(AgentId(i));
        }
        s.grant_up_to(2);
        s.release(AgentId(0));
        assert_eq!(s.active_count(), 1);
        assert_eq!(s.grant_up_to(2), ids(&[2]));
    }

    #[test]
    fn requeue_rejoins_the_fresh_queue_behind_waiters() {
        let mut s = SlotManager::new();
        for i in 0..4 {
            s.register(AgentId(i));
        }
        s.grant_up_to(3); // 0,1,2 active; 3 fresh
        s.requeue(AgentId(1));
        assert_eq!(s.active_count(), 2);
        assert_eq!(s.pending_count(), 2);
        // Re-grant: the never-admitted 3 goes first (FIFO), then 1.
        assert_eq!(s.grant_up_to(4), ids(&[3, 1]));
        // A requeue is neither a pause nor a resume.
        assert_eq!(s.pauses, 0);
        assert_eq!(s.resumes, 0);
    }

    #[test]
    fn low_priority_fresh_waits_behind_everyone() {
        let mut s = SlotManager::new();
        s.register_low(AgentId(0)); // arrives first, but low priority
        s.register(AgentId(1));
        s.register(AgentId(2));
        assert_eq!(s.grant_up_to(2), ids(&[1, 2]));
        s.on_step_boundary(AgentId(1), 1); // paused: [1]
        // Paused high beats the queued low even after a window regrowth.
        assert_eq!(s.grant_up_to(3), ids(&[1, 0]));
        assert_eq!(s.pending_count(), 0);
    }

    #[test]
    fn take_expired_only_touches_waiters() {
        let mut s = SlotManager::new();
        for i in 0..4 {
            s.register(AgentId(i));
        }
        s.register_low(AgentId(4));
        s.grant_up_to(2); // 0,1 active; 2,3 fresh; 4 fresh_low
        s.on_step_boundary(AgentId(0), 1); // paused: [0]
        let gone = s.take_expired(|a| a.0 != 1);
        // Active agent 1 is untouched; every waiter matching the
        // predicate is removed, queue order within each pool.
        assert_eq!(gone, ids(&[0, 2, 3, 4]));
        assert_eq!(s.active_count(), 1);
        assert_eq!(s.pending_count(), 0);
        assert!(s.is_active(AgentId(1)));
    }

    #[test]
    fn shedding_drains_only_the_low_queue() {
        let mut s = SlotManager::new();
        s.register(AgentId(0));
        s.register_low(AgentId(1));
        s.register_low(AgentId(2));
        assert_eq!(s.shed_low_fresh(), ids(&[1, 2]));
        assert_eq!(s.shed_low_fresh(), ids(&[]));
        assert_eq!(s.pending_count(), 1);
        assert_eq!(s.grant_up_to(4), ids(&[0]));
    }

    #[test]
    fn unbounded_window_admits_everyone() {
        let mut s = SlotManager::new();
        for i in 0..100 {
            s.register(AgentId(i));
        }
        assert_eq!(s.grant_up_to(usize::MAX).len(), 100);
        assert_eq!(s.pending_count(), 0);
    }
}
