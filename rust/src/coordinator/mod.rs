//! Agent-level admission control — the paper's system contribution.
//!
//! The coordinator sits *between* the agent execution layer and the serving
//! engine.  It observes the engine's runtime signals (`U_t` KV usage, `H_t`
//! windowed hit rate) after every iteration and regulates how many agents
//! may hold an *admission slot* at once.  Slots are the paper's three
//! primitives:
//!
//! * **admit**  — grant a slot; the agent's generation steps flow to the
//!   engine without further gating (execution continuity);
//! * **pause**  — at a step boundary (tool return), revoke the slot when
//!   the window has shrunk below the active population;
//! * **resume** — re-grant a slot when capacity returns, preferring
//!   recently-paused agents (their cache is warmest).
//!
//! [`AimdController`] implements the paper's cache-aware control law
//! (Eq. 1, §4.3): additive increase while `U_t < u_low`, multiplicative
//! decrease when `U_t > u_high` *and* `H_t < h_thresh` — high usage with
//! a healthy hit rate is throughput, not thrashing.  The other
//! [`Controller`]s are the evaluated baselines (§5).
//!
//! In a multi-replica fleet the same `Controller` trait regulates the
//! whole cluster: `cluster::run_sharded` aggregates per-replica signals
//! (max usage over live replicas, admission-weighted hit rate — dead
//! replicas excluded) into one [`ControlInputs`] stream, so controllers
//! are topology- and fault-oblivious by construction.

pub mod aimd;
pub mod slots;

pub use aimd::{AimdController, OverloadGovernor};
pub use slots::SlotManager;

use crate::config::{AimdParams, SchedulerKind};
use crate::engine::EngineSignals;

/// Everything a controller observes per engine iteration.
///
/// `U_t` for CONCUR is the *agent-level* footprint: the aggregate context
/// of agents currently holding admission slots over pool capacity (paper
/// §4.2 — "the aggregate working set of concurrently active agents"), not
/// the engine's transient pinned slots.  Tool-waiting agents count: their
/// KV is exactly what admission control exists to protect.
#[derive(Debug, Clone, Copy)]
pub struct ControlInputs {
    pub engine: EngineSignals,
    /// Agents currently holding admission slots.
    pub active_agents: usize,
    /// Σ context length (tokens) over slot-holding agents.
    pub active_footprint: u64,
    /// KV pool capacity in tokens.
    pub capacity: u64,
}

impl ControlInputs {
    /// The controller's congestion signal `U_t`.
    pub fn usage(&self) -> f64 {
        if self.capacity == 0 {
            1.0
        } else {
            self.active_footprint as f64 / self.capacity as f64
        }
    }
}

/// An admission controller: decides the active-agent budget over time.
pub trait Controller {
    fn name(&self) -> String;

    /// Observe the per-iteration control inputs.
    fn on_signals(&mut self, inputs: &ControlInputs);

    /// Current window: how many agents may hold admission slots.
    /// `usize::MAX` means unbounded.
    fn window(&self) -> usize;

    /// Engine-internal running-request cap, if this scheduler regulates at
    /// request granularity instead (the RequestCap baseline).
    fn engine_request_cap(&self) -> Option<usize> {
        None
    }

    /// Window trajectory for Fig. 5-style plots: (step, window).
    fn window_history(&self) -> &[(u64, f64)] {
        &[]
    }
}

/// SGLang baseline: no admission control at all.
pub struct Uncontrolled;

impl Controller for Uncontrolled {
    fn name(&self) -> String {
        "sglang".into()
    }
    fn on_signals(&mut self, _inputs: &ControlInputs) {}
    fn window(&self) -> usize {
        usize::MAX
    }
}

/// Fixed cap on in-flight *requests* inside the engine.  Unlike agent-level
/// control, a paused agent's next request queues behind strangers while its
/// cached prefix decays — the paper's explanation for why this baseline can
/// be *worse* than no control.
pub struct RequestCap(pub usize);

impl Controller for RequestCap {
    fn name(&self) -> String {
        format!("request-cap({})", self.0)
    }
    fn on_signals(&mut self, _inputs: &ControlInputs) {}
    fn window(&self) -> usize {
        usize::MAX
    }
    fn engine_request_cap(&self) -> Option<usize> {
        Some(self.0)
    }
}

/// Fixed cap on concurrently active *agents* (Fig. 6 baselines).
pub struct AgentCap(pub usize);

impl Controller for AgentCap {
    fn name(&self) -> String {
        format!("agent-cap({})", self.0)
    }
    fn on_signals(&mut self, _inputs: &ControlInputs) {}
    fn window(&self) -> usize {
        self.0
    }
}

/// Instantiate a controller from configuration.
pub fn make_controller(kind: &SchedulerKind) -> Box<dyn Controller> {
    match kind {
        SchedulerKind::Uncontrolled => Box::new(Uncontrolled),
        SchedulerKind::RequestCap(n) => Box::new(RequestCap(*n)),
        SchedulerKind::AgentCap(n) => Box::new(AgentCap(*n)),
        SchedulerKind::Concur(p) => Box::new(AimdController::new(*p)),
    }
}

/// Convenience: CONCUR with paper defaults.
pub fn concur_default() -> Box<dyn Controller> {
    Box::new(AimdController::new(AimdParams::default()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(u: f64, h: f64) -> ControlInputs {
        ControlInputs {
            engine: EngineSignals {
                kv_usage: u,
                pool_usage: u,
                hit_rate: h,
                running: 0,
                waiting: 0,
            },
            active_agents: 1,
            active_footprint: (u * 1000.0) as u64,
            capacity: 1000,
        }
    }

    #[test]
    fn baselines_hold_constant_windows() {
        let mut u = Uncontrolled;
        let mut r = RequestCap(64);
        let mut a = AgentCap(32);
        for _ in 0..10 {
            u.on_signals(&sig(0.99, 0.0));
            r.on_signals(&sig(0.99, 0.0));
            a.on_signals(&sig(0.99, 0.0));
        }
        assert_eq!(u.window(), usize::MAX);
        assert_eq!(r.window(), usize::MAX);
        assert_eq!(r.engine_request_cap(), Some(64));
        assert_eq!(a.window(), 32);
    }

    #[test]
    fn factory_dispatches() {
        assert_eq!(make_controller(&SchedulerKind::Uncontrolled).name(), "sglang");
        assert_eq!(
            make_controller(&SchedulerKind::AgentCap(8)).name(),
            "agent-cap(8)"
        );
        assert_eq!(
            make_controller(&SchedulerKind::Concur(AimdParams::default())).name(),
            "concur"
        );
    }
}
