//! Latency histogram with percentile queries (log-bucketed, HdrHistogram
//! style but minimal).

use crate::core::Micros;
use std::sync::OnceLock;

/// The shared default bucket ladder: 1us to ~2h growing 8% per bucket
/// (~220 entries).  Computed once per process — `Histogram::new` used to
/// rebuild (and heap-allocate) this identical ladder on every
/// construction, which showed up in cluster runs that make a histogram
/// per shard per run.
fn default_bounds() -> &'static [u64] {
    static BOUNDS: OnceLock<Vec<u64>> = OnceLock::new();
    BOUNDS.get_or_init(|| {
        let mut bounds = Vec::new();
        let mut b = 1.0f64;
        while b < 8.0e9 {
            bounds.push(b as u64);
            b *= 1.08;
        }
        bounds
    })
}

/// Log-bucketed histogram over microsecond latencies, 8% bucket growth.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub name: String,
    buckets: Vec<u64>,
    bounds: &'static [u64],
    count: u64,
    sum: u64,
    max: u64,
    min: u64,
}

impl Histogram {
    pub fn new(name: impl Into<String>) -> Histogram {
        let bounds = default_bounds();
        Histogram {
            name: name.into(),
            buckets: vec![0; bounds.len() + 1],
            bounds,
            count: 0,
            sum: 0,
            max: 0,
            min: u64::MAX,
        }
    }

    pub fn record(&mut self, v: Micros) {
        let idx = self.bounds.partition_point(|&b| b <= v.0);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v.0;
        self.max = self.max.max(v.0);
        self.min = self.min.min(v.0);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Micros {
        if self.count == 0 {
            Micros::ZERO
        } else {
            Micros(self.sum / self.count)
        }
    }

    pub fn max(&self) -> Micros {
        Micros(if self.count == 0 { 0 } else { self.max })
    }

    pub fn min(&self) -> Micros {
        Micros(if self.count == 0 { 0 } else { self.min })
    }

    /// Approximate percentile (upper bound of the containing bucket).
    pub fn percentile(&self, p: f64) -> Micros {
        if self.count == 0 {
            return Micros::ZERO;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let bound = if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
                return Micros(bound.min(self.max));
            }
        }
        Micros(self.max)
    }

    /// Fold another histogram into this one (cross-replica aggregation).
    ///
    /// Bucket bounds are identical by construction (`new` derives them
    /// from constants), so merging is element-wise bucket addition; the
    /// merged percentiles are exactly the percentiles the receiver would
    /// report had it recorded the concatenated sample stream.  A layout
    /// mismatch would silently zip-truncate and miscount every merged
    /// percentile, so it is a hard error in every build profile.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(
            self.bounds, other.bounds,
            "Histogram::merge: bucket layouts differ ({} vs {})",
            self.name, other.name
        );
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }

    pub fn summary(&self) -> String {
        format!(
            "{}: n={} mean={} min={} p50={} p95={} p99={} max={}",
            self.name,
            self.count,
            self.mean(),
            self.min(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max()
        )
    }

    /// Test-only: a histogram with a custom bucket growth factor, so the
    /// merge layout guard can be exercised with genuinely different
    /// bounds (the public `new` derives identical bounds by construction).
    #[cfg(test)]
    fn with_growth(name: &str, growth: f64) -> Histogram {
        let mut bounds = Vec::new();
        let mut b = 1.0f64;
        while b < 8.0e9 {
            bounds.push(b as u64);
            b *= growth;
        }
        Histogram {
            name: name.into(),
            buckets: vec![0; bounds.len() + 1],
            // Leaked on purpose: test-only, a handful of ladders per run.
            bounds: Box::leak(bounds.into_boxed_slice()),
            count: 0,
            sum: 0,
            max: 0,
            min: u64::MAX,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new("x");
        assert_eq!(h.mean(), Micros::ZERO);
        assert_eq!(h.percentile(99.0), Micros::ZERO);
    }

    /// REGRESSION: the `min: u64::MAX` sentinel of an empty histogram must
    /// never leak into step-summary lines or bench JSON — every accessor
    /// and the rendered summary report 0 when nothing was recorded.
    #[test]
    fn empty_summary_reports_zero_not_sentinel() {
        let h = Histogram::new("ttft");
        assert_eq!(h.min(), Micros::ZERO);
        assert_eq!(
            h.summary(),
            "ttft: n=0 mean=0us min=0us p50=0us p95=0us p99=0us max=0us"
        );
    }

    #[test]
    fn percentiles_ordered() {
        let mut h = Histogram::new("lat");
        for i in 1..=1000u64 {
            h.record(Micros(i * 100));
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max());
        // p50 of uniform 100..100_000 ≈ 50_000 (log buckets → ~8% error).
        assert!((40_000..60_000).contains(&p50.0), "p50={p50}");
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new("m");
        h.record(Micros(100));
        h.record(Micros(300));
        assert_eq!(h.mean(), Micros(200));
        assert_eq!(h.min(), Micros(100));
        assert_eq!(h.max(), Micros(300));
    }

    #[test]
    fn huge_values_clamp_to_last_bucket() {
        let mut h = Histogram::new("h");
        h.record(Micros(u64::MAX / 2));
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(100.0), h.max());
    }

    /// PROPERTY: for any split of a sample stream across shards, merging
    /// the shard histograms yields exactly the percentiles (and count /
    /// mean / min / max) of one histogram fed the concatenated stream.
    /// Holds at the log-bucket resolution because every histogram shares
    /// the same bounds by construction.
    #[test]
    fn merged_percentiles_equal_concatenated_stream() {
        let mut rng = crate::core::Rng::new(0xBEEF);
        for round in 0..20u64 {
            let shards = 1 + (round as usize % 4);
            let mut parts: Vec<Histogram> =
                (0..shards).map(|i| Histogram::new(format!("s{i}"))).collect();
            let mut whole = Histogram::new("whole");
            let n = rng.gen_range(1, 2000);
            for _ in 0..n {
                // Span many orders of magnitude to cross bucket scales.
                let v = Micros(1 + rng.gen_range(0, 1u64 << rng.gen_range(1, 33)));
                whole.record(v);
                let shard = rng.gen_range(0, shards as u64) as usize;
                parts[shard].record(v);
            }
            let mut merged = Histogram::new("merged");
            for p in &parts {
                merged.merge(p);
            }
            assert_eq!(merged.count(), whole.count(), "round {round}: count");
            assert_eq!(merged.mean(), whole.mean(), "round {round}: mean");
            assert_eq!(merged.min(), whole.min(), "round {round}: min");
            assert_eq!(merged.max(), whole.max(), "round {round}: max");
            for p in [0.1, 1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
                assert_eq!(
                    merged.percentile(p),
                    whole.percentile(p),
                    "round {round}: p{p}"
                );
            }
        }
    }

    /// Mismatched bucket layouts must be a hard error in release builds
    /// too — a zip-truncating merge would silently miscount percentiles.
    #[test]
    #[should_panic(expected = "bucket layouts differ")]
    fn merge_rejects_mismatched_layouts() {
        let mut a = Histogram::new("a");
        a.record(Micros(100));
        let mut b = Histogram::with_growth("b", 1.25);
        b.record(Micros(100));
        a.merge(&b);
    }

    /// REGRESSION: the process-wide shared bounds must be exactly the
    /// 8%-growth ladder every `new` previously derived locally — bucket
    /// indices (and with them merged percentiles and bench JSON) are
    /// pinned to that layout.  Recomputes the ladder here and checks both
    /// the bounds and where 500 random samples land.
    #[test]
    fn shared_bounds_match_local_derivation() {
        let mut expect = Vec::new();
        let mut b = 1.0f64;
        while b < 8.0e9 {
            expect.push(b as u64);
            b *= 1.08;
        }
        let mut h = Histogram::new("pin");
        assert_eq!(h.bounds, expect.as_slice());
        let mut buckets = vec![0u64; expect.len() + 1];
        let mut rng = crate::core::Rng::new(7);
        for _ in 0..500 {
            let v = 1 + rng.gen_range(0, 1u64 << rng.gen_range(1, 40));
            h.record(Micros(v));
            buckets[expect.partition_point(|&x| x <= v)] += 1;
        }
        assert_eq!(h.buckets, buckets);
        // Two fresh histograms share the very same static ladder.
        assert!(std::ptr::eq(Histogram::new("a").bounds, Histogram::new("b").bounds));
    }

    #[test]
    fn merging_an_empty_histogram_is_identity() {
        let mut h = Histogram::new("a");
        h.record(Micros(500));
        let before = (h.count(), h.mean(), h.min(), h.max(), h.percentile(50.0));
        h.merge(&Histogram::new("empty"));
        assert_eq!(before, (h.count(), h.mean(), h.min(), h.max(), h.percentile(50.0)));
        let mut e = Histogram::new("e");
        e.merge(&h);
        assert_eq!(e.percentile(99.0), h.percentile(99.0));
        assert_eq!(e.count(), 1);
    }
}
