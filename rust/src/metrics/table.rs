//! Terminal table rendering for the repro harnesses (paper tables).

/// A simple aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Table {
        Table { title: title.into(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header(mut self, cols: &[&str]) -> Table {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        let measure = |widths: &mut Vec<usize>, row: &[String]| {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        };
        measure(&mut widths, &self.header);
        for r in &self.rows {
            measure(&mut widths, r);
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |row: &[String]| {
            let mut s = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = row.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!("| {:<width$} ", cell, width = w));
            }
            s.push('|');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// CSV form (for EXPERIMENTS.md appendices / plotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        if !self.header.is_empty() {
            out.push_str(
                &self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Table 1").header(&["model", "latency (s)"]);
        t.row(vec!["Qwen3-32B".into(), "362".into()]);
        t.row(vec!["DeepSeek-V3".into(), "2043".into()]);
        let s = t.render();
        assert!(s.contains("Table 1"));
        assert!(s.contains("| Qwen3-32B "));
        // All data lines have equal width.
        let widths: Vec<usize> = s
            .lines()
            .skip(1)
            .map(|l| l.chars().count())
            .collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("").header(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }
}
