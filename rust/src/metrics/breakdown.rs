//! End-to-end latency breakdown (Fig. 3b): where did the time go?
//!
//! The paper's key empirical claim is that during middle-phase thrashing
//! the *recompute* share (prefill work redone because the prefix had been
//! evicted) reaches ~49% of end-to-end latency.  The engine tags every
//! microsecond of simulated step time with one of these categories.

use crate::core::Micros;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Prefill of genuinely new tokens (first time they are seen).
    Prefill,
    /// Prefill of tokens that *had* been cached and were evicted — the
    /// thrashing penalty ("retransmission").
    Recompute,
    /// Decode (token generation).
    Decode,
    /// KV reload over the host link (HiCache tier).
    Offload,
    /// KV reload from the storage (NVMe) tier — extent reads back into
    /// the GPU pool (zero with the storage tier off).
    StorageReload,
    /// Broadcast-prefix shipping over the interconnect (cluster
    /// shared-prefix tier; zero with the tier off).
    Broadcast,
    /// Drain-handoff KV migration over the interconnect (cluster
    /// transport; zero with the transport off).
    Handoff,
    /// Engine idle while every running agent waits on tools.
    ToolWait,
}

pub const ALL_PHASES: [Phase; 8] = [
    Phase::Prefill,
    Phase::Recompute,
    Phase::Decode,
    Phase::Offload,
    Phase::StorageReload,
    Phase::Broadcast,
    Phase::Handoff,
    Phase::ToolWait,
];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Recompute => "recompute",
            Phase::Decode => "decode",
            Phase::Offload => "offload",
            Phase::StorageReload => "storage_reload",
            Phase::Broadcast => "broadcast",
            Phase::Handoff => "handoff",
            Phase::ToolWait => "tool_wait",
        }
    }
}

/// Accumulated time per phase.
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    prefill: u64,
    recompute: u64,
    decode: u64,
    offload: u64,
    storage_reload: u64,
    broadcast: u64,
    handoff: u64,
    tool_wait: u64,
}

impl Breakdown {
    pub fn new() -> Breakdown {
        Breakdown::default()
    }

    /// Fold another breakdown in (per-replica → fleet totals).
    pub fn merge(&mut self, other: &Breakdown) {
        for p in ALL_PHASES {
            self.add(p, other.get(p));
        }
    }

    pub fn add(&mut self, phase: Phase, t: Micros) {
        match phase {
            Phase::Prefill => self.prefill += t.0,
            Phase::Recompute => self.recompute += t.0,
            Phase::Decode => self.decode += t.0,
            Phase::Offload => self.offload += t.0,
            Phase::StorageReload => self.storage_reload += t.0,
            Phase::Broadcast => self.broadcast += t.0,
            Phase::Handoff => self.handoff += t.0,
            Phase::ToolWait => self.tool_wait += t.0,
        }
    }

    pub fn get(&self, phase: Phase) -> Micros {
        Micros(match phase {
            Phase::Prefill => self.prefill,
            Phase::Recompute => self.recompute,
            Phase::Decode => self.decode,
            Phase::Offload => self.offload,
            Phase::StorageReload => self.storage_reload,
            Phase::Broadcast => self.broadcast,
            Phase::Handoff => self.handoff,
            Phase::ToolWait => self.tool_wait,
        })
    }

    pub fn total(&self) -> Micros {
        Micros(
            self.prefill
                + self.recompute
                + self.decode
                + self.offload
                + self.storage_reload
                + self.broadcast
                + self.handoff
                + self.tool_wait,
        )
    }

    /// Fraction of total time in `phase` (0 when empty).
    pub fn fraction(&self, phase: Phase) -> f64 {
        let total = self.total().0;
        if total == 0 {
            0.0
        } else {
            self.get(phase).0 as f64 / total as f64
        }
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for p in ALL_PHASES {
            s.push_str(&format!(
                "  {:<10} {:>12}  {:>5.1}%\n",
                p.name(),
                self.get(p).to_string(),
                self.fraction(p) * 100.0
            ));
        }
        s.push_str(&format!("  {:<10} {:>12}\n", "total", self.total().to_string()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let mut b = Breakdown::new();
        b.add(Phase::Prefill, Micros(100));
        b.add(Phase::Recompute, Micros(300));
        b.add(Phase::Decode, Micros(500));
        b.add(Phase::ToolWait, Micros(100));
        let sum: f64 = ALL_PHASES.iter().map(|&p| b.fraction(p)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert_eq!(b.total(), Micros(1000));
        assert_eq!(b.fraction(Phase::Recompute), 0.3);
    }

    #[test]
    fn empty_breakdown() {
        let b = Breakdown::new();
        assert_eq!(b.total(), Micros::ZERO);
        assert_eq!(b.fraction(Phase::Decode), 0.0);
    }

    #[test]
    fn report_contains_all_phases() {
        let mut b = Breakdown::new();
        b.add(Phase::Offload, Micros(42));
        let r = b.report();
        for p in ALL_PHASES {
            assert!(r.contains(p.name()), "missing {}", p.name());
        }
    }
}
