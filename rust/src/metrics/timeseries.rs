//! Time-series collection for the Fig. 3 / Fig. 5 style traces.

use crate::core::Micros;

/// A (time, value) series with optional down-sampling into fixed buckets.
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pub name: String,
    points: Vec<(Micros, f64)>,
}

impl TimeSeries {
    pub fn new(name: impl Into<String>) -> TimeSeries {
        TimeSeries { name: name.into(), points: Vec::new() }
    }

    pub fn record(&mut self, at: Micros, value: f64) {
        self.points.push((at, value));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn points(&self) -> &[(Micros, f64)] {
        &self.points
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|p| p.1).sum::<f64>() / self.points.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Mean restricted to a time window (for phase analysis).  Single
    /// alloc-free pass; the left-to-right summation order matches the old
    /// collect-then-sum exactly, so reported means are bit-identical.
    pub fn mean_in(&self, from: Micros, to: Micros) -> f64 {
        let (sum, n) = self
            .points
            .iter()
            .filter(|(t, _)| *t >= from && *t < to)
            .fold((0.0f64, 0u64), |(s, n), (_, v)| (s + v, n + 1));
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Downsample into `n` equal time buckets (bucket mean); used when
    /// printing figure series at terminal width.
    ///
    /// Points need not be time-sorted: series assembled across replicas
    /// (open-loop shard merges) interleave timestamps, so the bucket
    /// range is the min/max over all points, not first/last.
    pub fn resample(&self, n: usize) -> Vec<(Micros, f64)> {
        if self.points.is_empty() || n == 0 {
            return Vec::new();
        }
        let t0 = self.points.iter().map(|p| p.0 .0).min().unwrap();
        let t1 = self.points.iter().map(|p| p.0 .0).max().unwrap().max(t0 + 1);
        let width = ((t1 - t0) as f64 / n as f64).max(1.0);
        let mut sums = vec![0.0; n];
        let mut counts = vec![0u64; n];
        for (t, v) in &self.points {
            let idx = (((t.0 - t0) as f64 / width) as usize).min(n - 1);
            sums[idx] += v;
            counts[idx] += 1;
        }
        (0..n)
            .filter(|&i| counts[i] > 0)
            .map(|i| {
                let mid = t0 as f64 + (i as f64 + 0.5) * width;
                (Micros(mid as u64), sums[i] / counts[i] as f64)
            })
            .collect()
    }

    /// Render as an ASCII sparkline-with-axis block (for figure harnesses).
    pub fn ascii_plot(&self, width: usize, height: usize) -> String {
        let pts = self.resample(width);
        if pts.is_empty() {
            return format!("{}: (no data)\n", self.name);
        }
        let lo = pts.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
        let hi = pts.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-12);
        let mut grid = vec![vec![' '; width]; height];
        for (i, (_, v)) in pts.iter().enumerate() {
            let row = ((v - lo) / span * (height - 1) as f64).round() as usize;
            grid[height - 1 - row][i.min(width - 1)] = '*';
        }
        let mut out = format!("{}  [min={lo:.3} max={hi:.3}]\n", self.name);
        for row in grid {
            out.push('|');
            out.extend(row);
            out.push('\n');
        }
        out.push('+');
        out.extend(std::iter::repeat('-').take(width));
        out.push('\n');
        out
    }

    /// CSV dump: `time_s,value`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("time_s,value\n");
        for (t, v) in &self.points {
            s.push_str(&format!("{:.6},{v}\n", t.as_secs_f64()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(vals: &[(u64, f64)]) -> TimeSeries {
        let mut ts = TimeSeries::new("t");
        for (t, v) in vals {
            ts.record(Micros(*t), *v);
        }
        ts
    }

    #[test]
    fn stats() {
        let ts = series(&[(0, 1.0), (10, 3.0), (20, 5.0)]);
        assert_eq!(ts.mean(), 3.0);
        assert_eq!(ts.min(), 1.0);
        assert_eq!(ts.max(), 5.0);
        assert_eq!(ts.last(), Some(5.0));
    }

    #[test]
    fn windowed_mean() {
        let ts = series(&[(0, 1.0), (10, 3.0), (20, 5.0), (30, 7.0)]);
        assert_eq!(ts.mean_in(Micros(10), Micros(30)), 4.0);
        assert_eq!(ts.mean_in(Micros(100), Micros(200)), 0.0);
    }

    #[test]
    fn resample_buckets() {
        let ts = series(&[(0, 0.0), (25, 1.0), (50, 2.0), (75, 3.0), (100, 4.0)]);
        let r = ts.resample(2);
        assert_eq!(r.len(), 2);
        assert!(r[0].1 < r[1].1);
    }

    /// REGRESSION: out-of-order points (cross-replica series merges) used
    /// to underflow `t.0 - t0` because the bucket range was taken from the
    /// first/last point instead of the min/max.  A permuted series must
    /// resample exactly like its sorted twin.
    #[test]
    fn resample_handles_unsorted_points() {
        let unsorted = series(&[(50, 2.0), (0, 0.0), (100, 4.0), (25, 1.0), (75, 3.0)]);
        let sorted = series(&[(0, 0.0), (25, 1.0), (50, 2.0), (75, 3.0), (100, 4.0)]);
        let r = unsorted.resample(2);
        assert_eq!(r, sorted.resample(2));
        assert_eq!(r.len(), 2);
        assert!(r[0].1 < r[1].1);
    }

    #[test]
    fn ascii_plot_has_expected_rows() {
        let ts = series(&[(0, 0.0), (50, 1.0), (100, 0.5)]);
        let plot = ts.ascii_plot(20, 5);
        assert_eq!(plot.lines().count(), 7); // header + 5 rows + axis
        assert!(plot.contains('*'));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let ts = series(&[(1_000_000, 2.5)]);
        let csv = ts.to_csv();
        assert!(csv.contains("1.000000,2.5"));
    }
}
