//! Self-profiler: wall-clock scoped timers over named hot sections.
//!
//! The perf gate can tell *that* a nightly regressed but not *where*; this
//! module answers the second question without pulling in a profiling crate.
//! Each hot section ([`Section`]) owns three process-global relaxed
//! atomics — call count, accumulated wall nanoseconds, and an optional
//! work-unit count (tokens for the radix match) — written by an RAII
//! [`ScopedTimer`] on drop.
//!
//! **Default off.**  When disabled (the default), [`scope`] costs one
//! relaxed atomic load and never reads the clock, so instrumenting a hot
//! path is free in production runs — the acceptance bar is that the perf
//! gate cannot measure the disabled overhead.  Enable with the
//! `CONCUR_PROFILE=1` environment variable (read once, lazily) or
//! programmatically with [`set_enabled`] (benches do this around dedicated
//! measurement runs).
//!
//! **Wall clock, not simulated time.**  The profiler never touches
//! simulation state, so enabling it cannot perturb results; conversely its
//! numbers are host-load-dependent and — being process-global — blend all
//! concurrently running engines.  Benches therefore profile dedicated
//! single runs ([`reset`] + run + [`snapshot`]); the per-run delta folded
//! into `RunResult` is meaningful only when nothing else runs in parallel.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// The instrumented hot sections, in display order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Section {
    /// Radix-tree prefix match (`RadixTree::match_probe`); units = tokens
    /// matched, so `units / seconds` is the match throughput the gate
    /// floors as `radix/match_tokens_per_s`.
    RadixMatch,
    /// LRU eviction sweeps (`RadixTree::evict_at`).
    Evict,
    /// Token-arena compaction (`RadixTree::compact_arena`).
    Compact,
    /// Engine admission pass (`SimEngine::admit`).
    Admit,
    /// Whole engine iteration (`SimEngine::step`; contains Admit and
    /// RadixMatch — sections nest, times are inclusive).
    Step,
    /// Cluster clock-stop computation (candidate sync + heap pop).
    ClockAdvance,
}

/// All sections, for iteration (order matches [`Section`]).
pub const ALL_SECTIONS: [Section; 6] = [
    Section::RadixMatch,
    Section::Evict,
    Section::Compact,
    Section::Admit,
    Section::Step,
    Section::ClockAdvance,
];

const N: usize = ALL_SECTIONS.len();

impl Section {
    /// Stable snake-case name (bench metric keys derive from these).
    pub fn name(self) -> &'static str {
        match self {
            Section::RadixMatch => "radix_match",
            Section::Evict => "evict",
            Section::Compact => "compact",
            Section::Admit => "admit",
            Section::Step => "step",
            Section::ClockAdvance => "clock_advance",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

struct Counters {
    calls: [AtomicU64; N],
    nanos: [AtomicU64; N],
    units: [AtomicU64; N],
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: OnceLock<()> = OnceLock::new();

fn counters() -> &'static Counters {
    static C: OnceLock<Counters> = OnceLock::new();
    C.get_or_init(|| Counters {
        calls: std::array::from_fn(|_| AtomicU64::new(0)),
        nanos: std::array::from_fn(|_| AtomicU64::new(0)),
        units: std::array::from_fn(|_| AtomicU64::new(0)),
    })
}

/// Is profiling currently on?  One relaxed load — this is the entire
/// disabled-path cost of an instrumented section.
#[inline]
pub fn enabled() -> bool {
    ENV_INIT.get_or_init(|| {
        if std::env::var("CONCUR_PROFILE").is_ok_and(|v| v != "0" && !v.is_empty()) {
            ENABLED.store(true, Ordering::Relaxed);
        }
    });
    ENABLED.load(Ordering::Relaxed)
}

/// Turn profiling on or off programmatically (benches; overrides the env).
pub fn set_enabled(on: bool) {
    ENV_INIT.get_or_init(|| ());
    ENABLED.store(on, Ordering::Relaxed);
}

/// Zero all accumulators (start of a dedicated measurement run).
pub fn reset() {
    let c = counters();
    for i in 0..N {
        c.calls[i].store(0, Ordering::Relaxed);
        c.nanos[i].store(0, Ordering::Relaxed);
        c.units[i].store(0, Ordering::Relaxed);
    }
}

/// Accumulated totals for one section.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SectionTotals {
    pub calls: u64,
    pub nanos: u64,
    pub units: u64,
}

impl SectionTotals {
    /// Mean wall nanoseconds per call (0 when never entered).
    pub fn ns_per_call(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.nanos as f64 / self.calls as f64
        }
    }

    /// Work units per wall second (0 when no time accumulated).
    pub fn units_per_s(&self) -> f64 {
        if self.nanos == 0 {
            0.0
        } else {
            self.units as f64 * 1e9 / self.nanos as f64
        }
    }
}

/// Point-in-time copy of every section's totals.  `sub` of two snapshots
/// brackets a region; all-zero when the profiler was off throughout.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProfileSnapshot {
    totals: [SectionTotals; N],
}

impl ProfileSnapshot {
    pub fn get(&self, s: Section) -> SectionTotals {
        self.totals[s.idx()]
    }

    /// Totals accumulated since `earlier` (saturating; a `reset` between
    /// the two snapshots yields zeros, not wraparound).
    pub fn since(&self, earlier: &ProfileSnapshot) -> ProfileSnapshot {
        let mut out = ProfileSnapshot::default();
        for i in 0..N {
            out.totals[i] = SectionTotals {
                calls: self.totals[i].calls.saturating_sub(earlier.totals[i].calls),
                nanos: self.totals[i].nanos.saturating_sub(earlier.totals[i].nanos),
                units: self.totals[i].units.saturating_sub(earlier.totals[i].units),
            };
        }
        out
    }

    /// True when nothing was recorded (profiler off, or no sections hit).
    pub fn is_empty(&self) -> bool {
        self.totals.iter().all(|t| t.calls == 0)
    }
}

/// Read the current accumulated totals.
pub fn snapshot() -> ProfileSnapshot {
    let c = counters();
    let mut out = ProfileSnapshot::default();
    for i in 0..N {
        out.totals[i] = SectionTotals {
            calls: c.calls[i].load(Ordering::Relaxed),
            nanos: c.nanos[i].load(Ordering::Relaxed),
            units: c.units[i].load(Ordering::Relaxed),
        };
    }
    out
}

/// RAII timer for one section entry; records on drop.  Obtain via
/// [`scope`].
pub struct ScopedTimer {
    section: Section,
    start: Option<Instant>,
    units: u64,
}

impl ScopedTimer {
    /// Attribute `n` work units (e.g. matched tokens) to this entry.
    #[inline]
    pub fn add_units(&mut self, n: u64) {
        if self.start.is_some() {
            self.units += n;
        }
    }
}

impl Drop for ScopedTimer {
    #[inline]
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let ns = start.elapsed().as_nanos() as u64;
        let i = self.section.idx();
        let c = counters();
        c.calls[i].fetch_add(1, Ordering::Relaxed);
        c.nanos[i].fetch_add(ns, Ordering::Relaxed);
        if self.units > 0 {
            c.units[i].fetch_add(self.units, Ordering::Relaxed);
        }
    }
}

/// Enter `section`: returns a timer that records the section's wall time
/// when dropped.  When profiling is disabled this neither reads the clock
/// nor writes any counter.
#[inline]
pub fn scope(section: Section) -> ScopedTimer {
    let start = if enabled() { Some(Instant::now()) } else { None };
    ScopedTimer { section, start, units: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The accumulators are process-global and `cargo test` runs tests in
    // parallel, so everything that asserts on totals lives in this one
    // test (tests within a binary that need exclusivity must share a
    // serial section; a single test is the degenerate form).
    #[test]
    fn scoped_recording_end_to_end() {
        set_enabled(false);
        reset();
        {
            let mut t = scope(Section::RadixMatch);
            t.add_units(100);
        }
        assert!(snapshot().is_empty(), "disabled scope must record nothing");

        set_enabled(true);
        let before = snapshot();
        {
            let mut t = scope(Section::RadixMatch);
            t.add_units(64);
            std::hint::black_box(());
        }
        {
            let _t = scope(Section::Evict);
        }
        let delta = snapshot().since(&before);
        set_enabled(false);

        let m = delta.get(Section::RadixMatch);
        assert_eq!(m.calls, 1);
        assert_eq!(m.units, 64);
        assert_eq!(delta.get(Section::Evict).calls, 1);
        assert_eq!(delta.get(Section::Admit).calls, 0);
        assert!(m.ns_per_call() >= 0.0);
        // units_per_s is finite even for ~0ns sections.
        assert!(m.units_per_s().is_finite());
    }

    #[test]
    fn section_names_are_stable() {
        // Bench metric keys are built from these; renaming breaks the
        // perf-gate threshold file.
        let names: Vec<&str> = ALL_SECTIONS.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            ["radix_match", "evict", "compact", "admit", "step", "clock_advance"]
        );
    }
}
