//! Metrics: time series, histograms, latency breakdowns and table output.
//!
//! Every repro harness reports through these types so the paper's tables
//! and figures can be regenerated as text (`concur repro ...`) and CSV.
//!
//! Two of these instruments double as *control* state, not just
//! telemetry: [`WindowedRatio`] is the engine's `H_t` hit-rate window
//! (paper §4.2 — its observation count also weighs a replica's vote in
//! fleet-level aggregation), and [`TimeSeries`] carries the per-run
//! `U_t`/`H_t`/window/admissible-replica trajectories that the Fig. 5
//! style plots and the fault study read back.

pub mod breakdown;
pub mod histogram;
pub mod profiler;
pub mod table;
pub mod timeseries;

pub use breakdown::{Breakdown, Phase, ALL_PHASES};
pub use histogram::Histogram;
pub use profiler::ProfileSnapshot;
pub use table::Table;
pub use timeseries::TimeSeries;

/// Windowed ratio counter (e.g. prefix-cache hit rate over the last N
/// requests).  This is the `H_t` signal the CONCUR controller consumes.
#[derive(Debug, Clone)]
pub struct WindowedRatio {
    window: usize,
    entries: std::collections::VecDeque<(u64, u64)>, // (num, den)
    total_num: u64,
    total_den: u64,
}

impl WindowedRatio {
    pub fn new(window: usize) -> WindowedRatio {
        WindowedRatio {
            window: window.max(1),
            entries: std::collections::VecDeque::new(),
            total_num: 0,
            total_den: 0,
        }
    }

    /// Record one observation (e.g. matched tokens / prompt tokens).
    pub fn record(&mut self, num: u64, den: u64) {
        self.entries.push_back((num, den));
        self.total_num += num;
        self.total_den += den;
        if self.entries.len() > self.window {
            let (n, d) = self.entries.pop_front().unwrap();
            self.total_num -= n;
            self.total_den -= d;
        }
    }

    /// Current windowed ratio; `default` when no denominator yet.
    pub fn ratio_or(&self, default: f64) -> f64 {
        if self.total_den == 0 {
            default
        } else {
            self.total_num as f64 / self.total_den as f64
        }
    }

    pub fn observations(&self) -> usize {
        self.entries.len()
    }
}

/// Lifetime (unwindowed) ratio, for end-of-run table cells.
#[derive(Debug, Clone, Copy, Default)]
pub struct LifetimeRatio {
    pub num: u64,
    pub den: u64,
}

impl LifetimeRatio {
    pub fn record(&mut self, num: u64, den: u64) {
        self.num += num;
        self.den += den;
    }

    pub fn ratio(&self) -> f64 {
        if self.den == 0 {
            0.0
        } else {
            self.num as f64 / self.den as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windowed_ratio_evicts_old_entries() {
        let mut w = WindowedRatio::new(2);
        w.record(1, 1); // hit
        w.record(1, 1); // hit
        assert_eq!(w.ratio_or(0.0), 1.0);
        w.record(0, 1); // miss, evicts first hit
        assert_eq!(w.ratio_or(0.0), 0.5);
        w.record(0, 1);
        assert_eq!(w.ratio_or(0.0), 0.0);
    }

    #[test]
    fn windowed_ratio_default_when_empty() {
        let w = WindowedRatio::new(4);
        assert_eq!(w.ratio_or(0.9), 0.9);
    }

    #[test]
    fn windowed_ratio_token_weighted() {
        let mut w = WindowedRatio::new(10);
        w.record(90, 100);
        w.record(0, 900);
        // 90 / 1000, not mean(0.9, 0.0).
        assert!((w.ratio_or(0.0) - 0.09).abs() < 1e-12);
    }

    #[test]
    fn lifetime_ratio() {
        let mut r = LifetimeRatio::default();
        assert_eq!(r.ratio(), 0.0);
        r.record(3, 4);
        r.record(1, 4);
        assert_eq!(r.ratio(), 0.5);
    }
}
