//! Byte-level tokenizer for the tiny served model (vocab = 256).

use crate::core::Token;

/// Encode UTF-8 text as byte tokens.
pub fn encode(text: &str) -> Vec<Token> {
    text.bytes().map(|b| b as Token).collect()
}

/// Decode byte tokens back to text (lossy on invalid UTF-8).
pub fn decode(tokens: &[Token]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "Hello, CONCUR!";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo ☂";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn tokens_fit_vocab() {
        assert!(encode("any text at all").iter().all(|&t| t < 256));
    }
}
