//! Real-model serving path: batched continuous serving of the AOT-compiled
//! tiny transformer through PJRT, fronted by the same agent-level admission
//! controller as the simulator.
//!
//! This is the end-to-end proof that all three layers compose: L1 Pallas
//! attention kernels → L2 JAX graphs → HLO text → PJRT executables → this
//! rust loop, with CONCUR regulating slot admission.  Prefix-cache
//! *economics* (radix tree, eviction) are studied in the simulator — the
//! dense `[L, B, T, H, D]` cache layout here has one KV region per batch
//! row, so the controller's capacity signal is slot occupancy-weighted
//! context, not a shared pool (see DESIGN.md §2).

pub mod sampler;
pub mod tokenizer;

pub use sampler::{sample, Sampling};

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::{ControlInputs, Controller};
use crate::core::{ConcurError, Result, Rng, Token};
use crate::engine::EngineSignals;
use crate::metrics::Histogram;
use crate::runtime::{KvState, ModelRuntime};

/// One generation request against the real model.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub sampling: Sampling,
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// Queue + prefill latency until the first generated token.
    pub ttft: Duration,
    pub e2e: Duration,
}

/// Aggregate statistics over one batch run.
pub struct ServeStats {
    pub wall: Duration,
    pub completed: usize,
    pub total_gen_tokens: usize,
    pub decode_steps: usize,
    pub extend_calls: usize,
    pub tokens_per_sec: f64,
    pub ttft: Histogram,
    pub e2e: Histogram,
}

struct SlotRun {
    req: ServeRequest,
    prompt: Vec<Token>,
    prefilled: usize,
    produced: Vec<Token>,
    next_token: Option<Token>,
    submitted: Instant,
    first_token: Option<Instant>,
}

/// Synchronous batched server over one compiled batch variant.
pub struct RealServer {
    rt: ModelRuntime,
    batch: usize,
    state: KvState,
    slots: Vec<Option<SlotRun>>,
    queue: VecDeque<ServeRequest>,
    controller: Box<dyn Controller>,
    rng: Rng,
    steps_done: usize,
    extends_done: usize,
}

impl RealServer {
    pub fn new(
        rt: ModelRuntime,
        batch: usize,
        controller: Box<dyn Controller>,
    ) -> Result<RealServer> {
        let state = rt.new_state(batch)?;
        Ok(RealServer {
            rt,
            batch,
            state,
            slots: (0..batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            controller,
            rng: Rng::new(0xC0C0),
            steps_done: 0,
            extends_done: 0,
        })
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }

    pub fn submit(&mut self, req: ServeRequest) {
        self.queue.push_back(req);
    }

    fn busy_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Drive everything to completion; returns results in completion order.
    pub fn run_to_completion(&mut self) -> Result<(Vec<ServeResult>, ServeStats)> {
        let start = Instant::now();
        let mut results = Vec::new();
        let mut ttft_h = Histogram::new("ttft");
        let mut e2e_h = Histogram::new("e2e");
        let max_seq = self.rt.geometry().max_seq;

        while !self.queue.is_empty() || self.busy_slots() > 0 {
            // 1. Admission under the controller's window.
            let window = self.controller.window().min(self.batch);
            while self.busy_slots() < window && !self.queue.is_empty() {
                let req = self.queue.pop_front().unwrap();
                let prompt = tokenizer::encode(&req.prompt);
                if prompt.is_empty() || prompt.len() + req.max_new >= max_seq {
                    return Err(ConcurError::runtime(format!(
                        "request {} needs {} tokens; model max_seq is {max_seq}",
                        req.id,
                        prompt.len() + req.max_new
                    )));
                }
                let row = self.slots.iter().position(|s| s.is_none()).unwrap();
                self.state.lens[row] = 0; // reclaim the parked row
                self.slots[row] = Some(SlotRun {
                    prompt,
                    req,
                    prefilled: 0,
                    produced: Vec::new(),
                    next_token: None,
                    submitted: Instant::now(),
                    first_token: None,
                });
            }

            // 2. Prefill pass: one extend call covering every slot that
            //    still has prompt left (idle rows ride along with chunk 0).
            let chunk = self.rt.extend_chunk_size(self.batch)?;
            let needs_prefill = self
                .slots
                .iter()
                .any(|s| s.as_ref().is_some_and(|r| r.prefilled < r.prompt.len()));
            if needs_prefill {
                let mut toks = vec![0u32; self.batch * chunk];
                let mut chunk_lens = vec![0i32; self.batch];
                for (b, slot) in self.slots.iter().enumerate() {
                    if let Some(r) = slot {
                        let rest = &r.prompt[r.prefilled..];
                        let n = rest.len().min(chunk);
                        toks[b * chunk..b * chunk + n].copy_from_slice(&rest[..n]);
                        chunk_lens[b] = n as i32;
                    }
                }
                let out = self.rt.extend_chunk(&mut self.state, &toks, &chunk_lens)?;
                self.extends_done += 1;
                for (b, slot) in self.slots.iter_mut().enumerate() {
                    if let Some(r) = slot {
                        let n = chunk_lens[b] as usize;
                        if n > 0 {
                            r.prefilled += n;
                            if r.prefilled == r.prompt.len() {
                                // Prompt complete: the extend output at this
                                // row is the first next-token distribution.
                                let tok =
                                    sample(out.row(b), r.req.sampling, &mut self.rng);
                                r.next_token = Some(tok);
                            }
                        }
                    }
                }
                self.observe();
                continue;
            }

            // 3. Decode pass: all rows step together (idle rows are parked
            //    on token 0 — masked garbage).
            if self.busy_slots() > 0 {
                let mut toks = vec![0u32; self.batch];
                for (b, slot) in self.slots.iter().enumerate() {
                    if let Some(r) = slot {
                        toks[b] = r.next_token.expect("decode without pending token");
                    }
                }
                let out = self.rt.decode_step(&mut self.state, &toks)?;
                self.steps_done += 1;
                for (b, slot) in self.slots.iter_mut().enumerate() {
                    let Some(r) = slot else { continue };
                    // The token we just fed is now part of the context;
                    // record it as produced output (prompt tokens were fed
                    // via extend, so next_token is always generated).
                    let produced_tok = toks[b];
                    r.produced.push(produced_tok);
                    if r.first_token.is_none() {
                        r.first_token = Some(Instant::now());
                    }
                    if r.produced.len() >= r.req.max_new {
                        let now = Instant::now();
                        let res = ServeResult {
                            id: r.req.id,
                            text: tokenizer::decode(&r.produced),
                            prompt_tokens: r.prompt.len(),
                            gen_tokens: r.produced.len(),
                            ttft: r
                                .first_token
                                .map(|t| t - r.submitted)
                                .unwrap_or_default(),
                            e2e: now - r.submitted,
                        };
                        ttft_h.record(crate::core::Micros(
                            res.ttft.as_micros() as u64
                        ));
                        e2e_h.record(crate::core::Micros(res.e2e.as_micros() as u64));
                        results.push(res);
                        *slot = None;
                    } else {
                        let tok = sample(out.row(b), r.req.sampling, &mut self.rng);
                        r.next_token = Some(tok);
                    }
                }
                self.observe();
            }
        }

        let wall = start.elapsed();
        let total_gen: usize = results.iter().map(|r| r.gen_tokens).sum();
        let stats = ServeStats {
            wall,
            completed: results.len(),
            total_gen_tokens: total_gen,
            decode_steps: self.steps_done,
            extend_calls: self.extends_done,
            tokens_per_sec: total_gen as f64 / wall.as_secs_f64().max(1e-9),
            ttft: ttft_h,
            e2e: e2e_h,
        };
        Ok((results, stats))
    }

    /// Feed the controller the real engine's occupancy signals.
    fn observe(&mut self) {
        let max_seq = self.rt.geometry().max_seq;
        let footprint: u64 = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(b, _)| self.state.lens[b].max(0) as u64)
            .sum();
        let capacity = (self.batch * max_seq) as u64;
        let busy = self.busy_slots();
        let inputs = ControlInputs {
            engine: EngineSignals {
                kv_usage: footprint as f64 / capacity as f64,
                pool_usage: footprint as f64 / capacity as f64,
                hit_rate: 1.0, // dense per-slot cache: no prefix sharing here
                running: busy,
                waiting: self.queue.len(),
            },
            active_agents: busy,
            active_footprint: footprint,
            capacity,
        };
        self.controller.on_signals(&inputs);
    }
}
