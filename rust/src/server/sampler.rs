//! Token sampling for the real-model serving path.

use crate::core::{Rng, Token};

/// Sampling strategy.
#[derive(Debug, Clone, Copy)]
pub enum Sampling {
    Greedy,
    /// Softmax sampling with the given temperature (> 0).
    Temperature(f64),
}

/// Sample the next token from a logits row.
pub fn sample(logits: &[f32], strategy: Sampling, rng: &mut Rng) -> Token {
    match strategy {
        Sampling::Greedy => argmax(logits),
        Sampling::Temperature(t) => {
            debug_assert!(t > 0.0);
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f64> = logits
                .iter()
                .map(|&x| (((x - max) as f64) / t).exp())
                .collect();
            let total: f64 = weights.iter().sum();
            let mut u = rng.next_f64() * total;
            for (i, w) in weights.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    return i as Token;
                }
            }
            (weights.len() - 1) as Token
        }
    }
}

fn argmax(logits: &[f32]) -> Token {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as Token
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(0);
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = vec![0.0, 5.0, 0.0];
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            assert_eq!(sample(&logits, Sampling::Temperature(0.05), &mut rng), 1);
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = vec![0.0, 1.0, 0.5, 0.2];
        let mut rng = Rng::new(2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(sample(&logits, Sampling::Temperature(5.0), &mut rng));
        }
        assert!(seen.len() >= 3, "only saw {seen:?}");
    }

    #[test]
    fn temperature_sampling_is_deterministic_per_seed() {
        let logits = vec![0.3, 0.7, 0.1, 0.9];
        let draw = |seed| {
            let mut rng = Rng::new(seed);
            (0..20)
                .map(|_| sample(&logits, Sampling::Temperature(1.0), &mut rng))
                .collect::<Vec<_>>()
        };
        assert_eq!(draw(7), draw(7));
        assert_ne!(draw(7), draw(8));
    }
}
