//! Deterministic pseudo-random number generation.
//!
//! The whole reproduction pipeline must be bit-reproducible from a seed
//! (workload generation, tool latencies, sampling), and the vendored crate
//! set has no `rand`, so we ship a small splitmix64/xoshiro256** pair —
//! the standard public-domain constructions.

/// splitmix64: used to expand a user seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator.  Identical seeds produce identical streams on
    /// every platform.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. one per agent) without
    /// correlating with the parent.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    #[inline]
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range {lo}..{hi}");
        lo + self.next_u64() % (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, (i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.gen_range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let a: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
