//! Crate-wide error type.
//!
//! Hand-rolled Display/Error impls — the offline build has no `thiserror`.

use crate::xla_stub as xla;

/// Unified error for every layer of the stack.
#[derive(Debug)]
pub enum ConcurError {
    Config(String),
    Json { offset: usize, message: String },
    Artifact(String),
    Runtime(String),
    Engine(String),
    Workload(String),
    Io(std::io::Error),
    Xla(String),
}

impl std::fmt::Display for ConcurError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConcurError::Config(m) => write!(f, "configuration error: {m}"),
            ConcurError::Json { offset, message } => {
                write!(f, "json parse error at byte {offset}: {message}")
            }
            ConcurError::Artifact(m) => write!(f, "artifact error: {m}"),
            ConcurError::Runtime(m) => write!(f, "runtime error: {m}"),
            ConcurError::Engine(m) => write!(f, "engine error: {m}"),
            ConcurError::Workload(m) => write!(f, "workload error: {m}"),
            ConcurError::Io(e) => write!(f, "io error: {e}"),
            ConcurError::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for ConcurError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConcurError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ConcurError {
    fn from(e: std::io::Error) -> Self {
        ConcurError::Io(e)
    }
}

impl From<xla::Error> for ConcurError {
    fn from(e: xla::Error) -> Self {
        ConcurError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, ConcurError>;

impl ConcurError {
    pub fn config(msg: impl Into<String>) -> Self {
        ConcurError::Config(msg.into())
    }

    pub fn engine(msg: impl Into<String>) -> Self {
        ConcurError::Engine(msg.into())
    }

    pub fn artifact(msg: impl Into<String>) -> Self {
        ConcurError::Artifact(msg.into())
    }

    pub fn runtime(msg: impl Into<String>) -> Self {
        ConcurError::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = ConcurError::config("bad batch");
        assert_eq!(e.to_string(), "configuration error: bad batch");
        let e = ConcurError::Json { offset: 12, message: "expected ','".into() };
        assert!(e.to_string().contains("byte 12"));
    }

    #[test]
    fn io_errors_chain_as_source() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = ConcurError::from(io);
        assert!(e.to_string().starts_with("io error:"));
        assert!(e.source().is_some());
    }
}
