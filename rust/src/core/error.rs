//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every layer of the stack.
#[derive(Debug, Error)]
pub enum ConcurError {
    #[error("configuration error: {0}")]
    Config(String),

    #[error("json parse error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("engine error: {0}")]
    Engine(String),

    #[error("workload error: {0}")]
    Workload(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for ConcurError {
    fn from(e: xla::Error) -> Self {
        ConcurError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, ConcurError>;

impl ConcurError {
    pub fn config(msg: impl Into<String>) -> Self {
        ConcurError::Config(msg.into())
    }

    pub fn engine(msg: impl Into<String>) -> Self {
        ConcurError::Engine(msg.into())
    }

    pub fn artifact(msg: impl Into<String>) -> Self {
        ConcurError::Artifact(msg.into())
    }

    pub fn runtime(msg: impl Into<String>) -> Self {
        ConcurError::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = ConcurError::config("bad batch");
        assert_eq!(e.to_string(), "configuration error: bad batch");
        let e = ConcurError::Json { offset: 12, message: "expected ','".into() };
        assert!(e.to_string().contains("byte 12"));
    }
}
