//! Word-wise token-slice comparison for the radix hot paths.
//!
//! Every radix descent, split probe, and content-hash confirm reduces to
//! "how long is the common prefix of two `&[Token]`?".  The scalar
//! `iter().zip().take_while()` form compares one `u32` per iteration;
//! this module packs four tokens into a `u128` per iteration instead,
//! locating the diverging lane with a single XOR + `trailing_zeros`.
//!
//! The chunking is safe Rust over slice indexing — no pointers, no
//! alignment assumptions, no `unsafe` — so it vectorises or at least
//! unrolls on every target the crate builds for, and a scalar tail
//! handles the last `len % 4` tokens.  Laning is endianness-independent:
//! token `i` occupies bits `32·i..32·(i+1)` of the packed word by
//! construction, so lower bit positions always correspond to earlier
//! slice indices.
//!
//! Callers (and the proptest in `tests/proptests.rs`) rely on this being
//! *exactly* equivalent to
//! `a.iter().zip(b).take_while(|(x, y)| x == y).count()`.

use super::Token;

/// Tokens packed per comparison word.
const LANES: usize = 4;

#[inline]
fn pack(s: &[Token], at: usize) -> u128 {
    // Four independent indexed loads; bounds checks are hoisted by the
    // `at + LANES <= len` loop guard.
    (s[at] as u128)
        | ((s[at + 1] as u128) << 32)
        | ((s[at + 2] as u128) << 64)
        | ((s[at + 3] as u128) << 96)
}

/// Length of the longest common prefix of `a` and `b`.
///
/// Equivalent to `a.iter().zip(b).take_while(|(x, y)| x == y).count()`,
/// computed four tokens at a time.
#[inline]
pub fn common_prefix_len(a: &[Token], b: &[Token]) -> usize {
    let n = a.len().min(b.len());
    let mut i = 0usize;
    while i + LANES <= n {
        let wa = pack(a, i);
        let wb = pack(b, i);
        if wa != wb {
            // The first differing token is the lowest differing 32-bit
            // lane of the XOR.
            return i + (wa ^ wb).trailing_zeros() as usize / 32;
        }
        i += LANES;
    }
    while i < n && a[i] == b[i] {
        i += 1;
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar(a: &[Token], b: &[Token]) -> usize {
        a.iter().zip(b).take_while(|(x, y)| x == y).count()
    }

    #[test]
    fn empty_and_trivial() {
        assert_eq!(common_prefix_len(&[], &[]), 0);
        assert_eq!(common_prefix_len(&[1], &[]), 0);
        assert_eq!(common_prefix_len(&[], &[1]), 0);
        assert_eq!(common_prefix_len(&[1], &[2]), 0);
        assert_eq!(common_prefix_len(&[7], &[7]), 1);
    }

    #[test]
    fn divergence_at_every_offset() {
        // For every length up to a couple of whole words plus a ragged
        // tail, diverge at every position (including "no divergence").
        for len in 0..=19usize {
            let a: Vec<Token> = (0..len as Token).collect();
            for d in 0..=len {
                let mut b = a.clone();
                if d < len {
                    b[d] ^= 0x8000_0001; // flip high and low bits
                }
                assert_eq!(common_prefix_len(&a, &b), scalar(&a, &b), "len={len} d={d}");
                assert_eq!(common_prefix_len(&b, &a), scalar(&b, &a), "len={len} d={d} swapped");
            }
        }
    }

    #[test]
    fn unequal_lengths_cap_at_shorter() {
        let a: Vec<Token> = (0..100).collect();
        for cut in 0..=100usize {
            assert_eq!(common_prefix_len(&a, &a[..cut]), cut);
            assert_eq!(common_prefix_len(&a[..cut], &a), cut);
        }
    }

    #[test]
    fn divergence_within_each_lane_of_a_word() {
        // Place the diverging token in each of the four lanes of the
        // second packed word, with equal earlier words.
        let a: Vec<Token> = (100..116).collect();
        for lane in 0..LANES {
            let mut b = a.clone();
            b[LANES + lane] = 0;
            assert_eq!(common_prefix_len(&a, &b), LANES + lane);
        }
    }

    #[test]
    fn extreme_token_values() {
        let a = [Token::MAX, 0, Token::MAX, 0, Token::MAX, 0, 1];
        let mut b = a;
        assert_eq!(common_prefix_len(&a, &b), a.len());
        b[5] = Token::MAX;
        assert_eq!(common_prefix_len(&a, &b), 5);
    }
}
