//! Fast deterministic hashing for hot-path maps.
//!
//! `std::collections::HashMap`'s default `RandomState` (SipHash-1-3) costs
//! tens of nanoseconds per lookup and seeds itself randomly per process.
//! The radix tree does one child lookup per matched node per request, so
//! the hash is squarely on the serving hot path — and determinism across
//! processes is a crate-wide invariant.  This is the well-known FxHash
//! multiply-rotate construction (rustc's internal hasher): not DoS-hardened,
//! which is fine for token-id keys we generate ourselves.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash: one multiply + rotate per word, deterministic, zero state.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add(b as u64);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Drop-in `HashMap` with the fast deterministic hasher.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a: FxHashMap<u32, u32> = FxHashMap::default();
        let mut b: FxHashMap<u32, u32> = FxHashMap::default();
        for i in 0..100u32 {
            a.insert(i * 7, i);
            b.insert(i * 7, i);
        }
        assert_eq!(a.get(&21), Some(&3));
        assert_eq!(b.get(&21), Some(&3));
        // Same build hasher => identical hashes for identical keys.
        use std::hash::{BuildHasher, Hash};
        let bh = FxBuildHasher::default();
        let h = |k: u32| {
            let mut s = bh.build_hasher();
            k.hash(&mut s);
            s.finish()
        };
        assert_eq!(h(42), h(42));
        assert_ne!(h(42), h(43));
    }
}
