//! Foundational types shared across the whole stack: identifiers, simulated
//! time, deterministic RNG, a minimal JSON codec and the crate error type.

pub mod error;
pub mod fxhash;
pub mod json;
pub mod rng;
pub mod simd;

pub use error::{ConcurError, Result};
pub use fxhash::FxHashMap;
pub use rng::Rng;

/// Token identifier (byte-level vocab on the real-model path; synthetic ids
/// on the simulator path — the radix tree only needs equality).
pub type Token = u32;

/// Monotone agent identifier, unique within one run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AgentId(pub u64);

/// Monotone request identifier (one ReAct generation step of one agent).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

impl std::fmt::Display for AgentId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "agent-{}", self.0)
    }
}

impl std::fmt::Display for RequestId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "req-{}", self.0)
    }
}

/// Simulated time in microseconds.  All DES arithmetic is integral to keep
/// runs bit-reproducible across platforms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Micros(pub u64);

impl Micros {
    pub const ZERO: Micros = Micros(0);

    pub fn from_secs_f64(s: f64) -> Micros {
        Micros((s * 1e6).round().max(0.0) as u64)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }

    pub fn saturating_sub(self, rhs: Micros) -> Micros {
        Micros(self.0.saturating_sub(rhs.0))
    }
}

impl std::ops::Add for Micros {
    type Output = Micros;
    fn add(self, rhs: Micros) -> Micros {
        Micros(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for Micros {
    fn add_assign(&mut self, rhs: Micros) {
        self.0 += rhs.0;
    }
}

impl std::ops::Sub for Micros {
    type Output = Micros;
    fn sub(self, rhs: Micros) -> Micros {
        Micros(self.0 - rhs.0)
    }
}

impl std::fmt::Display for Micros {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0 >= 1_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}ms", self.as_millis_f64())
        } else {
            write!(f, "{}us", self.0)
        }
    }
}

/// Bytes, with human-readable display.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Bytes(pub u64);

impl Bytes {
    pub const ZERO: Bytes = Bytes(0);

    pub fn from_gb(gb: f64) -> Bytes {
        Bytes((gb * 1e9) as u64)
    }

    pub fn as_gb(self) -> f64 {
        self.0 as f64 / 1e9
    }
}

impl std::ops::Add for Bytes {
    type Output = Bytes;
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}

impl std::ops::Mul<u64> for Bytes {
    type Output = Bytes;
    fn mul(self, rhs: u64) -> Bytes {
        Bytes(self.0 * rhs)
    }
}

impl std::fmt::Display for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let b = self.0 as f64;
        if b >= 1e9 {
            write!(f, "{:.2}GB", b / 1e9)
        } else if b >= 1e6 {
            write!(f, "{:.2}MB", b / 1e6)
        } else if b >= 1e3 {
            write!(f, "{:.2}KB", b / 1e3)
        } else {
            write!(f, "{}B", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micros_arithmetic_and_display() {
        let a = Micros(1_500_000);
        let b = Micros(500_000);
        assert_eq!(a + b, Micros(2_000_000));
        assert_eq!(a - b, Micros(1_000_000));
        assert_eq!(format!("{a}"), "1.500s");
        assert_eq!(format!("{}", Micros(1500)), "1.500ms");
        assert_eq!(format!("{}", Micros(42)), "42us");
        assert_eq!(Micros::from_secs_f64(1.5), a);
    }

    #[test]
    fn micros_saturating_sub() {
        assert_eq!(Micros(5).saturating_sub(Micros(10)), Micros(0));
        assert_eq!(Micros(10).saturating_sub(Micros(5)), Micros(5));
    }

    #[test]
    fn bytes_conversions() {
        let b = Bytes::from_gb(6.67);
        assert!((b.as_gb() - 6.67).abs() < 1e-9);
        assert_eq!(format!("{}", Bytes(2_500_000_000)), "2.50GB");
        assert_eq!(format!("{}", Bytes(1_500)), "1.50KB");
    }

    #[test]
    fn id_display() {
        assert_eq!(AgentId(3).to_string(), "agent-3");
        assert_eq!(RequestId(9).to_string(), "req-9");
    }
}
