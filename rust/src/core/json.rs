//! Minimal JSON codec (parse + emit).
//!
//! The vendored crate set has no `serde`/`serde_json`, and the crate only
//! needs JSON for three small, fully self-controlled surfaces: the AOT
//! `artifacts/manifest.json`, experiment config files, and metric/trace
//! dumps.  A ~300-line recursive-descent parser covers all of it.

use std::collections::BTreeMap;

use super::error::{ConcurError, Result};

/// A JSON value.  Object keys are stored sorted (BTreeMap) — key order is
/// never semantically meaningful in our formats.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON document"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field accessors for config/manifest parsing.
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .as_u64()
            .ok_or_else(|| ConcurError::config(format!("missing/invalid integer field '{key}'")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| ConcurError::config(format!("missing/invalid number field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| ConcurError::config(format!("missing/invalid string field '{key}'")))
    }

    // -- emit ----------------------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::String(s) => write_escaped(out, s),
            Value::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Value {
        Value::Number(n)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Value {
        Value::Number(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Value {
        Value::Number(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::String(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::String(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Value {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for object literals.
#[macro_export]
macro_rules! json_obj {
    ( $( $k:expr => $v:expr ),* $(,)? ) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($k.to_string(), $crate::core::json::Value::from($v)); )*
        $crate::core::json::Value::Object(m)
    }};
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> ConcurError {
        ConcurError::Json { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("invalid \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("invalid \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so this is safe).
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0xC0) == 0x80
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Value::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Value::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("\"hi\"").unwrap().as_str(), Some("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").as_array().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(*v.get("c"), Value::Null);
        assert_eq!(*v.get("missing"), Value::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Value::parse(r#""a\n\t\"\\ é é""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ é é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model": {"vocab": 256, "f": 1.25}, "xs": [1, 2, 3], "s": "a\"b", "t": true}"#;
        let v = Value::parse(src).unwrap();
        let emitted = v.to_string_pretty();
        assert_eq!(Value::parse(&emitted).unwrap(), v);
        let compact = v.to_string_compact();
        assert_eq!(Value::parse(&compact).unwrap(), v);
    }

    #[test]
    fn json_obj_macro() {
        let v = json_obj! { "a" => 1u64, "b" => "x", "c" => vec![1u64, 2] };
        assert_eq!(v.get("a").as_u64(), Some(1));
        assert_eq!(v.get("c").as_array().unwrap().len(), 2);
    }

    #[test]
    fn parses_real_manifest() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("model").req_u64("vocab").unwrap() >= 256);
            assert!(!v.get("artifacts").as_array().unwrap().is_empty());
        }
    }
}
