//! Asynchronous-transport study (`concur repro transport`): what does
//! honest KV movement cost, and what does drain handoff buy back?
//!
//! Not a paper artifact — this closes the ROADMAP's prefix-tier-realism
//! and drain-checkpoint items together, because they are two faces of
//! the same question: the paper's Fig. 1c argues KV movement is a
//! *bandwidth* pathology, so cross-replica features must neither teleport
//! KV (free shipping flatters the tier) nor drop it (free re-prefill
//! flatters a drain).  One anchored workload — 96 Qwen3-class agents, 4
//! TP2 replicas, CONCUR admission, the shared-prefix tier on, and a
//! mid-run drain of replica 0 — runs under every transport mode in the
//! {instant, delayed} × {full, delta} × {drop, handoff} cube, plus a
//! transport-off control row.  Every cell sees the bit-identical
//! workload and fault timeline; the transport knobs are the only moving
//! part.
//!
//! Expected headlines: delayed visibility charges real Broadcast phase
//! time and forfeits the first-wave hits instant shipping pretended to
//! have; delta shipping claws wire bytes (and with them visibility
//! latency) back; and drain handoff lifts the **post-drain aggregate
//! hit rate** `H_t` over drop-on-drain — the acceptance gate
//! `tests/transport_integration.rs` pins at a smaller scale.
//!
//! The sweep writes `BENCH_transport.json` (override the path with
//! `BENCH_TRANSPORT_PATH`) so the nightly CI job can archive the
//! transport trajectory next to the cluster, fault and prefix artifacts.

use std::collections::BTreeMap;

use crate::config::presets;
use crate::config::{
    AimdParams, EngineConfig, FaultEvent, FaultPlan, JobConfig, PrefixTierConfig, RouterKind,
    SchedulerKind, TopologyConfig, TransportConfig,
};
use crate::core::json::Value;
use crate::core::{Micros, Result};
use crate::driver::RunResult;
use crate::metrics::Table;

use super::{run_systems, ExpOutput};

/// Replicas in the fleet (replica 0 is the drained one).
pub const REPLICAS: usize = 4;

/// Offered load held fixed across the grid.
pub const SWEEP_AGENTS: usize = 96;

/// Task families: coprime with the replica count, so every family's
/// prefix splits across all replicas and the broadcast tier has real
/// work in every cell.
pub const TASK_FAMILIES: u32 = 5;

/// Drain instant as a fraction of the healthy anchor makespan.
pub const DRAIN_AT: f64 = 0.4;

/// One grid cell: a transport mode label and its run.
pub struct TransportCell {
    /// `off`, or `{instant|delayed}/{full|delta}/{drop|handoff}`.
    pub label: String,
    pub result: RunResult,
    /// The anchored drain instant (for post-drain windowing).
    pub drain_at: Micros,
}

impl TransportCell {
    /// Aggregate hit rate over the post-drain window — the recovery
    /// signal the handoff exists to lift.
    pub fn post_drain_hit_rate(&self) -> f64 {
        self.result.hit_series.mean_in(self.drain_at, self.result.total_time + Micros(1))
    }
}

/// The eight-corner transport cube, row-major in table order.
pub fn transport_modes() -> Vec<(String, TransportConfig)> {
    let mut modes = Vec::new();
    for &delayed in &[false, true] {
        for &delta in &[false, true] {
            for &handoff in &[false, true] {
                let label = format!(
                    "{}/{}/{}",
                    if delayed { "delayed" } else { "instant" },
                    if delta { "delta" } else { "full" },
                    if handoff { "handoff" } else { "drop" },
                );
                modes.push((label, TransportConfig {
                    enabled: true,
                    delayed_visibility: delayed,
                    delta_ship: delta,
                    drain_handoff: handoff,
                    ..TransportConfig::default()
                }));
            }
        }
    }
    modes
}

/// The repro-standard job for one cell (healthy topology; the drain
/// plan is anchored in afterwards).
pub fn base_job(transport: TransportConfig) -> JobConfig {
    let mut workload = presets::qwen3_workload(SWEEP_AGENTS);
    workload.task_families = TASK_FAMILIES;
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload,
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig {
            replicas: REPLICAS,
            router: RouterKind::Rebalance,
            prefix_tier: PrefixTierConfig::on(),
            transport,
            ..TopologyConfig::default()
        },
    }
}

/// Run the whole grid: a healthy transport-off probe provides the
/// anchor, then the drained control row and the eight cube cells run on
/// the identical fault timeline, fanned out across cores.
pub fn run_sweep() -> Result<Vec<TransportCell>> {
    let probe = run_systems(vec![base_job(TransportConfig::default())])?;
    let anchor = probe.into_iter().next().expect("probe ran").total_time;
    let drain_at = Micros((anchor.0 as f64 * DRAIN_AT) as u64);
    let plan = FaultPlan::new(vec![FaultEvent::drain(0, drain_at)]);

    let mut labels = vec!["off".to_string()];
    let mut cfgs = vec![TransportConfig::default()];
    for (label, cfg) in transport_modes() {
        labels.push(label);
        cfgs.push(cfg);
    }
    let jobs = cfgs
        .into_iter()
        .map(|transport| {
            let mut job = base_job(transport);
            job.topology.fault_plan = plan.clone();
            job
        })
        .collect();
    Ok(labels
        .into_iter()
        .zip(run_systems(jobs)?)
        .map(|(label, result)| TransportCell { label, result, drain_at })
        .collect())
}

/// Machine-readable sweep dump (`BENCH_transport.json`): one entry per
/// cell, keyed by the mode label.
pub fn bench_json(cells: &[TransportCell]) -> Value {
    let mut map: BTreeMap<String, Value> = BTreeMap::new();
    for c in cells {
        let r = &c.result;
        let mut entry: BTreeMap<String, Value> = BTreeMap::new();
        entry.insert("latency_s".into(), Value::Number(r.total_time.as_secs_f64()));
        entry.insert("throughput_tps".into(), Value::Number(r.throughput_tps));
        entry.insert("hit_rate".into(), Value::Number(r.hit_rate));
        entry.insert("post_drain_hit_rate".into(), Value::Number(c.post_drain_hit_rate()));
        entry.insert("drain_at_s".into(), Value::Number(c.drain_at.as_secs_f64()));
        entry.insert(
            "broadcast_hit_tokens".into(),
            Value::Number(r.counters.broadcast_hit_tokens as f64),
        );
        entry.insert("shipped_tokens".into(), Value::Number(r.prefix_tier.shipped_tokens as f64));
        entry.insert("wire_tokens".into(), Value::Number(r.transport.wire_tokens as f64));
        entry.insert("transfers".into(), Value::Number(r.transport.transfers as f64));
        entry.insert("cancelled".into(), Value::Number(r.transport.cancelled as f64));
        entry.insert("handoff_agents".into(), Value::Number(r.faults.handoff_agents as f64));
        entry.insert("handoff_tokens".into(), Value::Number(r.faults.handoff_tokens as f64));
        map.insert(c.label.clone(), Value::Object(entry));
    }
    Value::Object(map)
}

fn cell<'a>(cells: &'a [TransportCell], label: &str) -> &'a TransportCell {
    cells.iter().find(|c| c.label == label).expect("complete grid")
}

/// Render the grid as a repro table with recovery notes.
pub fn output_from(cells: &[TransportCell]) -> ExpOutput {
    let mut table = Table::new(
        "Asynchronous transport: throughput (tok/s), lifetime and \
         post-drain hit rate (%) across transport modes",
    )
    .header(&[
        "Mode",
        "tok/s",
        "hit%",
        "post-drain hit%",
        "wire tok",
        "handoff tok",
    ]);
    for c in cells {
        table.row(vec![
            c.label.clone(),
            format!("{:.0}", c.result.throughput_tps),
            format!("{:.1}", c.result.hit_rate * 100.0),
            format!("{:.1}", c.post_drain_hit_rate() * 100.0),
            c.result.transport.wire_tokens.to_string(),
            c.result.faults.handoff_tokens.to_string(),
        ]);
    }

    let drop_cell = cell(cells, "instant/full/drop");
    let hand = cell(cells, "instant/full/handoff");
    let delayed_full = cell(cells, "delayed/full/drop");
    let delayed_delta = cell(cells, "delayed/delta/drop");
    let notes = vec![
        format!(
            "drain handoff lifts the post-drain aggregate hit rate from \
             {:.2}% (drop-on-drain) to {:.2}% — {} warm context tokens \
             crossed the fabric instead of being re-prefilled cold",
            drop_cell.post_drain_hit_rate() * 100.0,
            hand.post_drain_hit_rate() * 100.0,
            hand.result.faults.handoff_tokens
        ),
        format!(
            "delta shipping moves {} wire tokens vs {} under full-ship \
             ({:.0}% saved): targets holding partial family prefixes stop \
             re-receiving what they already cache",
            delayed_delta.result.transport.wire_tokens,
            delayed_full.result.transport.wire_tokens,
            (1.0
                - delayed_delta.result.transport.wire_tokens as f64
                    / delayed_full.result.transport.wire_tokens.max(1) as f64)
                * 100.0
        ),
        "every cell runs the bit-identical workload and drain timeline \
         (anchored to the healthy transport-off makespan): the transport \
         knobs are the only difference between rows"
            .into(),
    ];

    ExpOutput {
        name: "transport",
        title: "Asynchronous cluster transport (visibility x shipping x drain)".into(),
        table,
        figures: vec![],
        notes,
    }
}

/// Run the study and write `BENCH_transport.json` (path overridable via
/// `BENCH_TRANSPORT_PATH`).
pub fn run() -> Result<ExpOutput> {
    let cells = run_sweep()?;
    let path = std::env::var("BENCH_TRANSPORT_PATH")
        .unwrap_or_else(|_| "BENCH_transport.json".to_string());
    std::fs::write(&path, format!("{}\n", bench_json(&cells).to_string_pretty()))?;
    let mut out = output_from(&cells);
    out.notes.push(format!("machine-readable results written to {path}"));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_the_cube_plus_control() {
        let modes = transport_modes();
        assert_eq!(modes.len(), 8, "2x2x2 transport cube");
        for (label, cfg) in &modes {
            assert!(cfg.enabled);
            cfg.validate().unwrap();
            assert_eq!(label.matches('/').count(), 2);
        }
        // Labels are unique (sort first — dedup only removes adjacent
        // duplicates, and a labeling bug would collide non-adjacently).
        let mut labels: Vec<&String> = modes.iter().map(|(l, _)| l).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 8);
    }

    #[test]
    fn grid_jobs_validate() {
        for (_, cfg) in transport_modes() {
            let mut job = base_job(cfg);
            job.topology.fault_plan =
                FaultPlan::new(vec![FaultEvent::drain(0, Micros(1_000_000))]);
            job.validate().unwrap();
        }
        base_job(TransportConfig::default()).validate().unwrap();
    }

    #[test]
    fn families_are_coprime_with_the_fleet() {
        fn gcd(a: u32, b: u32) -> u32 {
            if b == 0 {
                a
            } else {
                gcd(b, a % b)
            }
        }
        assert_eq!(gcd(TASK_FAMILIES, REPLICAS as u32), 1);
    }
}
