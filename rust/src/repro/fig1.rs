//! Figure 1: workload growth curves and the offload-vs-recompute crossover.
//!
//! * (a) input length growth across 10 generation steps (both models);
//! * (b) the same curve in KV-cache gigabytes;
//! * (c) GPU→CPU offload latency vs prefill recomputation latency for
//!   DeepSeek-V3 (6.67 GB / 4096-token requests) under rising concurrency.

use crate::agent::WorkloadGenerator;
use crate::config::presets;
use crate::core::{Bytes, Result};
use crate::costmodel::{CostModel, PcieLink};
use crate::metrics::Table;

use super::ExpOutput;

/// Congestion degradation factor for Fig. 1c (see
/// `PcieLink::contended_makespan`).  Stronger than the engine's in-path
/// value because the microbenchmark's transfers all collide at t=0.
pub const PCIE_GAMMA: f64 = 0.80;
pub const FIG1C_TOKENS: u64 = 4096;
pub const FIG1C_CONCURRENCY: [u32; 7] = [1, 2, 4, 8, 16, 32, 64];

pub fn run() -> Result<Vec<ExpOutput>> {
    Ok(vec![fig1ab()?, fig1c()?])
}

fn fig1ab() -> Result<ExpOutput> {
    let qwen = presets::qwen3_workload(64);
    let dsv3 = presets::dsv3_workload(64);
    let q_agents = WorkloadGenerator::new(qwen).generate();
    let d_agents = WorkloadGenerator::new(dsv3).generate();
    let q_stats = WorkloadGenerator::stats(&q_agents);
    let d_stats = WorkloadGenerator::stats(&d_agents);
    let q_kv = presets::qwen3_cluster(8).model.kv_bytes_per_token();
    let d_kv = presets::dsv3_cluster(16).model.kv_bytes_per_token();

    let mut table = Table::new(
        "Fig 1a/1b: mean context length (tokens) and KV footprint (GB) at step start",
    )
    .header(&[
        "Step",
        "Qwen3 tokens",
        "Qwen3 KV (GB)",
        "DSV3 tokens",
        "DSV3 KV (GB)",
    ]);
    let steps = q_stats.ctx_at_step.len().min(d_stats.ctx_at_step.len()).min(10);
    for k in 0..steps {
        let qt = q_stats.ctx_at_step[k];
        let dt = d_stats.ctx_at_step[k];
        table.row(vec![
            (k + 1).to_string(),
            format!("{qt:.0}"),
            format!("{:.3}", qt * q_kv as f64 / 1e9),
            format!("{dt:.0}"),
            format!("{:.3}", dt * d_kv as f64 / 1e9),
        ]);
    }

    let last_d = d_stats.ctx_at_step[steps - 1];
    Ok(ExpOutput {
        name: "fig1ab",
        title: "Input length & KV memory growth across generation steps".into(),
        table,
        figures: vec![],
        notes: vec![
            format!(
                "monotone growth ~1.2k -> ~{:.0} tokens by step 10 (paper: ~10-12k)",
                last_d
            ),
            "DeepSeek-V3 KV grows ~6x faster per token than Qwen3-32B (MLA-era \
             cache calibrated to the paper's 6.67 GB / 4096 tokens)"
                .into(),
        ],
    })
}

fn fig1c() -> Result<ExpOutput> {
    let cluster = presets::dsv3_cluster(16);
    let per_req_bytes = Bytes(FIG1C_TOKENS * cluster.model.kv_bytes_per_token());
    // One contiguous per-request blob moves at nominal link speed (the
    // in-engine path derates for scattered MLA pages instead).
    let nominal_bw = (cluster.gpu.pcie_gbps * cluster.tp as f64)
        .min(100.0 * cluster.nodes() as f64);
    let link = PcieLink::new(nominal_bw);
    let cost = CostModel::new(cluster);

    let mut table = Table::new(
        "Fig 1c: offload vs recompute latency (ms) for 4096-token DeepSeek-V3 \
         requests under concurrency",
    )
    .header(&["Concurrency", "Offload+reload (ms)", "Recompute (ms)", "Winner"]);

    let mut crossover: Option<u32> = None;
    for &n in &FIG1C_CONCURRENCY {
        let off = link.contended_makespan(n, per_req_bytes, PCIE_GAMMA);
        // Recompute: batched prefill of n requests (compute parallelizes
        // across the batch on the same roofline).
        let rec = cost.step_time(&crate::costmodel::StepWork {
            prefill_tokens: FIG1C_TOKENS * n as u64,
            prefill_ctx_tokens: n as u64 * FIG1C_TOKENS * FIG1C_TOKENS / 2,
            ..Default::default()
        });
        let winner = if off < rec { "offload" } else { "recompute" };
        if off >= rec && crossover.is_none() {
            crossover = Some(n);
        }
        table.row(vec![
            n.to_string(),
            format!("{:.1}", off.as_millis_f64()),
            format!("{:.1}", rec.as_millis_f64()),
            winner.to_string(),
        ]);
    }

    Ok(ExpOutput {
        name: "fig1c",
        title: "Offload latency vs recomputation latency under concurrency".into(),
        table,
        figures: vec![],
        notes: vec![
            "offload wins in isolation; loses beyond the crossover (paper Fig. 1c)"
                .into(),
            match crossover {
                Some(n) => format!("crossover at concurrency {n} (paper: O(10))"),
                None => "no crossover observed in the swept range".into(),
            },
        ],
    })
}
