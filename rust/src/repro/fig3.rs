//! Figure 3: the middle-phase-thrashing trace — three-phase KV usage /
//! hit-rate evolution (a) and the end-to-end latency breakdown (b).
//!
//! Reproduced on the configuration where the pathology is strongest in
//! Table 1: DeepSeek-V3, batch 40, TP16, uncontrolled (SGLang-like).

use crate::config::presets;
use crate::config::{EvictionMode, SchedulerKind};
use crate::core::{Micros, Result};
use crate::metrics::{Phase, Table, ALL_PHASES};

use super::{run_system, ExpOutput};

pub fn run() -> Result<ExpOutput> {
    let r = run_system(
        presets::dsv3_cluster(16),
        presets::dsv3_workload(40),
        SchedulerKind::Uncontrolled,
        EvictionMode::Discard,
    )?;

    // Phase detection on the usage trace: warmup ends when pool usage
    // first exceeds 80%; cooldown begins when the hit rate has recovered
    // above 60% while usage is saturated near the end of the run.
    let total = r.total_time;
    let warmup_end = r
        .usage_series
        .points()
        .iter()
        .find(|(_, u)| *u > 0.8)
        .map(|(t, _)| *t)
        .unwrap_or(total);
    // Cooldown: last crossing from low (<0.5) to sustained-high hit rate.
    let mut cooldown_start = total;
    let pts = r.hit_series.points();
    for w in pts.windows(2).rev() {
        if w[0].1 < 0.5 && w[1].1 >= 0.5 {
            cooldown_start = w[1].0;
            break;
        }
    }
    if cooldown_start <= warmup_end {
        cooldown_start = total;
    }
    let middle = cooldown_start.saturating_sub(warmup_end);
    let frac = |t: Micros| t.0 as f64 / total.0.max(1) as f64 * 100.0;

    let mut table = Table::new("Fig 3a: three-phase execution pattern").header(&[
        "Phase",
        "Interval (s)",
        "Share of run",
        "Mean KV usage",
        "Mean hit rate",
    ]);
    let phases = [
        ("Warmup", Micros::ZERO, warmup_end),
        ("Middle (thrashing)", warmup_end, cooldown_start),
        ("Cooldown", cooldown_start, total),
    ];
    for (name, from, to) in phases {
        table.row(vec![
            name.to_string(),
            format!("{:.0} - {:.0}", from.as_secs_f64(), to.as_secs_f64()),
            format!("{:.1}%", frac(to.saturating_sub(from))),
            format!("{:.2}", r.usage_series.mean_in(from, to)),
            format!("{:.2}", r.hit_series.mean_in(from, to)),
        ]);
    }

    let mut bd = Table::new("Fig 3b: end-to-end latency breakdown").header(&[
        "Component",
        "Time",
        "Share",
    ]);
    for p in ALL_PHASES {
        bd.row(vec![
            p.name().to_string(),
            r.breakdown.get(p).to_string(),
            format!("{:.1}%", r.breakdown.fraction(p) * 100.0),
        ]);
    }
    let usage_plot = r.usage_series.ascii_plot(72, 8);
    let hit_plot = r.hit_series.ascii_plot(72, 8);

    let recompute_share = r.breakdown.fraction(Phase::Recompute) * 100.0;
    let combined = table;
    for row in bd.render().lines() {
        let _ = row; // breakdown rendered via figures below
    }

    Ok(ExpOutput {
        name: "fig3",
        title: "Middle-phase thrashing in agentic batch inference (DSV3, batch 40)"
            .into(),
        table: combined,
        figures: vec![
            usage_plot,
            hit_plot,
            bd.render(),
        ],
        notes: vec![
            format!(
                "middle phase dominates the run ({:.0}% of wall time; paper: >90%)",
                frac(middle)
            ),
            format!(
                "recomputation consumes {recompute_share:.1}% of end-to-end latency \
                 (paper: 49.1%)"
            ),
            "usage saturates while the hit rate collapses — memory is busy, not \
             useful (the thrashing signature)"
                .into(),
        ],
    })
}
