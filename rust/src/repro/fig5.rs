//! Figure 5: temporal dynamics of the KV cache during large-batch offline
//! agentic inference — hit rate (top) and usage (bottom), CONCUR vs the
//! SGLang baseline.  Qwen3-32B, batch 256, TP2 (constrained resources).

use crate::config::presets;
use crate::config::{AimdParams, EvictionMode, SchedulerKind};
use crate::core::Result;
use crate::metrics::Table;

use super::{run_systems, system_job, ExpOutput};

pub fn run() -> Result<ExpOutput> {
    let cluster = presets::qwen3_cluster(2);
    let workload = presets::qwen3_workload(256);

    // Baseline and CONCUR runs are independent: run them side by side.
    let mut results = run_systems(vec![
        system_job(
            cluster.clone(),
            workload.clone(),
            SchedulerKind::Uncontrolled,
            EvictionMode::Discard,
        ),
        system_job(
            cluster,
            workload,
            SchedulerKind::Concur(AimdParams::default()),
            EvictionMode::Discard,
        ),
    ])?;
    let conc = results.pop().expect("two results");
    let base = results.pop().expect("two results");

    // Resampled series side by side (normalized to each run's duration).
    let n = 24;
    let mut table = Table::new(
        "Fig 5: KV hit rate and usage over normalized run time (24 buckets)",
    )
    .header(&[
        "Progress",
        "SGLang hit",
        "CONCUR hit",
        "SGLang usage",
        "CONCUR usage",
        "CONCUR window",
    ]);
    let bh = base.hit_series.resample(n);
    let ch = conc.hit_series.resample(n);
    let bu = base.usage_series.resample(n);
    let cu = conc.usage_series.resample(n);
    let cw = conc.window_series.resample(n);
    let rows = bh.len().min(ch.len()).min(bu.len()).min(cu.len()).min(cw.len());
    for i in 0..rows {
        table.row(vec![
            format!("{:.0}%", (i as f64 + 0.5) / n as f64 * 100.0),
            format!("{:.2}", bh[i].1),
            format!("{:.2}", ch[i].1),
            format!("{:.2}", bu[i].1),
            format!("{:.2}", cu[i].1),
            format!("{:.0}", cw[i].1),
        ]);
    }

    // Mid-phase comparison (middle half of each run).
    let mid = |r: &crate::driver::RunResult, s: &crate::metrics::TimeSeries| {
        let t = r.total_time;
        s.mean_in(crate::core::Micros(t.0 / 4), crate::core::Micros(3 * t.0 / 4))
    };
    let base_mid_hit = mid(&base, &base.hit_series);
    let conc_mid_hit = mid(&conc, &conc.hit_series);

    Ok(ExpOutput {
        name: "fig5",
        title: "Temporal KV dynamics, Qwen3-32B batch 256 TP2".into(),
        table,
        figures: vec![
            base.hit_series.ascii_plot(72, 6),
            conc.hit_series.ascii_plot(72, 6),
        ],
        notes: vec![
            format!(
                "mid-phase hit rate: SGLang {:.0}% vs CONCUR {:.0}% (paper: baseline \
                 collapses while CONCUR stays high)",
                base_mid_hit * 100.0,
                conc_mid_hit * 100.0
            ),
            format!(
                "end-to-end: SGLang {:.0}s vs CONCUR {:.0}s ({:.2}x)",
                base.total_time.as_secs_f64(),
                conc.total_time.as_secs_f64(),
                base.total_time.as_secs_f64() / conc.total_time.as_secs_f64()
            ),
            "usage saturates (~80-100%) in both systems; only CONCUR keeps it useful"
                .into(),
        ],
    })
}
