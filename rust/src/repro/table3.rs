//! Table 3 (Appendix A.1): sensitivity of end-to-end latency to the
//! utilization thresholds (U_low, U_high), Qwen3-32B, TP {8,4,2}.

use crate::config::presets;
use crate::config::{AimdParams, EvictionMode, SchedulerKind};
use crate::core::Result;
use crate::metrics::Table;

use super::{run_systems, system_job, ExpOutput};
use crate::config::JobConfig;

/// Paper's sweep: vary U_high at U_low=0.2, then vary U_low at U_high=0.5.
pub const U_HIGH_SWEEP: [f64; 4] = [0.4, 0.5, 0.6, 0.8];
pub const U_LOW_SWEEP: [f64; 4] = [0.1, 0.2, 0.3, 0.5];
pub const TPS: [u32; 3] = [8, 4, 2];

fn sensitivity_job(u_low: f64, u_high: f64, tp: u32) -> JobConfig {
    // Sensitivity of the *paper's control law* (Eq. 1): the band-probe
    // congestion-avoidance extension is disabled here, otherwise it masks
    // the U_low starvation the paper reports (see EXPERIMENTS.md).
    let p = AimdParams {
        u_low,
        u_high,
        band_probe_every: 0,
        ..AimdParams::default()
    };
    system_job(
        presets::qwen3_cluster(tp),
        presets::qwen3_workload(256),
        SchedulerKind::Concur(p),
        EvictionMode::Discard,
    )
}

pub fn run() -> Result<ExpOutput> {
    let mut table = Table::new(
        "Table 3: sensitivity of latency (s) to utilization thresholds, Qwen3-32B",
    )
    .header(&["U_low", "U_high", "TP8 (s)", "TP4 (s)", "TP2 (s)"]);

    // Collect the (u_low, u_high) grid, then run rows x TPs in parallel.
    let mut grid: Vec<(f64, f64)> = Vec::new();
    for &u_high in &U_HIGH_SWEEP {
        grid.push((0.2, u_high));
    }
    for &u_low in &U_LOW_SWEEP {
        if u_low == 0.2 {
            continue; // (0.2, 0.5) already measured above
        }
        grid.push((u_low, 0.5));
    }
    let jobs: Vec<JobConfig> = grid
        .iter()
        .flat_map(|&(u_low, u_high)| {
            // u_low = 0.5 with u_high = 0.5 is invalid (must be strictly
            // ordered); the paper's row is u_low just below; use 0.49.
            let ul = if u_low >= u_high { 0.49 } else { u_low };
            TPS.iter().map(move |&tp| sensitivity_job(ul, u_high, tp))
        })
        .collect();
    let results = run_systems(jobs)?;

    let rows: Vec<(f64, f64, Vec<f64>)> = grid
        .iter()
        .zip(results.chunks(TPS.len()))
        .map(|(&(u_low, u_high), r)| {
            (u_low, u_high, r.iter().map(|x| x.total_time.as_secs_f64()).collect())
        })
        .collect();

    // Identify the default row for the "optimal is (0.2, 0.5)" note.
    let default_lats = rows
        .iter()
        .find(|(l, h, _)| *l == 0.2 && *h == 0.5)
        .map(|(_, _, v)| v.clone())
        .unwrap_or_default();
    for (u_low, u_high, lats) in &rows {
        let mark = if *u_low == 0.2 && *u_high == 0.5 { " *" } else { "" };
        table.row(vec![
            format!("{u_low}{mark}"),
            format!("{u_high}"),
            format!("{:.0}", lats[0]),
            format!("{:.0}", lats[1]),
            format!("{:.0}", lats[2]),
        ]);
    }

    // Quantify the paper's two qualitative claims.
    let u_high_spread: f64 = rows
        .iter()
        .filter(|(l, h, _)| *l == 0.2 && (*h == 0.5 || *h == 0.6))
        .map(|(_, _, v)| v.iter().sum::<f64>())
        .fold(f64::NAN, |a, b| if a.is_nan() { b } else { (a - b).abs() / a });
    let _ = u_high_spread;

    Ok(ExpOutput {
        name: "table3",
        title: "Sensitivity analysis of utilization thresholds (Appendix A.1)".into(),
        table,
        figures: vec![],
        notes: vec![
            format!(
                "default (0.2, 0.5) latencies: TP8={:.0}s TP4={:.0}s TP2={:.0}s (marked *)",
                default_lats.first().copied().unwrap_or(f64::NAN),
                default_lats.get(1).copied().unwrap_or(f64::NAN),
                default_lats.get(2).copied().unwrap_or(f64::NAN),
            ),
            "paper: U_high is robust in 0.5-0.6, degrades at 0.8 (late cuts) and 0.4 \
             (premature throttling)".into(),
            "paper: U_low is the sensitive knob — 0.1 starves growth, 0.3-0.5 over-admit"
                .into(),
        ],
    })
}
