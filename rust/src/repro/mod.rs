//! Paper reproduction harnesses: one module per table/figure.
//!
//! Each harness regenerates the corresponding artifact of the paper's
//! evaluation — same rows/series, measured on this crate's serving-engine
//! substrate + cost model (see DESIGN.md §2 for the substitutions and §5
//! for the experiment index).  Absolute numbers differ from the paper's
//! H100 testbed; the *shape* (who wins, by roughly what factor, where
//! crossovers fall) is the reproduction target.
//!
//! Run via `concur repro <table1|table2|table3|fig1|fig3|fig5|fig6|all>`
//! or `cargo bench --bench paper_tables` / `paper_figures`.  Beyond the
//! paper, `concur repro cluster` runs the data-parallel replica-scaling
//! study (see [`cluster_scaling`]), `concur repro cluster_faults` the
//! fault-tolerance study (see [`faults`] — emits `BENCH_faults.json`),
//! `concur repro prefix_sharing` the shared-prefix tier study (see
//! [`prefix_sharing`] — emits `BENCH_prefix.json`), `concur repro
//! transport` the asynchronous-transport study (see [`transport`] —
//! emits `BENCH_transport.json`), `concur repro openloop` the
//! open-loop traffic / SLO study (see [`openloop`] — emits
//! `BENCH_openloop.json`), `concur repro workflow` the
//! workflow-DAG / KV-lifetime-policy study (see [`workflow`] — emits
//! `BENCH_workflow.json`), and `concur repro storage` the storage-tier
//! dual-path study (see [`storage`] — emits `BENCH_storage.json`).
//! The full experiment index lives in one table ([`EXPERIMENTS`])
//! shared with the CLI usage string.

pub mod cluster_scaling;
pub mod faults;
pub mod fig1;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod openloop;
pub mod prefix_sharing;
pub mod storage;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod transport;
pub mod workflow;

use crate::config::{EngineConfig, EvictionMode, JobConfig, SchedulerKind, WorkloadConfig};
use crate::core::Result;
use crate::costmodel::ClusterSpec;
use crate::driver::{run_job, RunResult};
use crate::metrics::Table;

/// Output of one experiment harness.
pub struct ExpOutput {
    pub name: &'static str,
    pub title: String,
    pub table: Table,
    /// ASCII-rendered figure panels (empty for pure tables).
    pub figures: Vec<String>,
    /// Shape expectations vs the paper (printed as a footer).
    pub notes: Vec<String>,
}

impl ExpOutput {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("== {} — {}\n\n", self.name, self.title));
        for f in &self.figures {
            s.push_str(f);
            s.push('\n');
        }
        s.push_str(&self.table.render());
        if !self.notes.is_empty() {
            s.push_str("\nShape vs paper:\n");
            for n in &self.notes {
                s.push_str(&format!("  - {n}\n"));
            }
        }
        s
    }

    /// Write the table as CSV under `results/`.
    pub fn write_csv(&self, dir: &std::path::Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        std::fs::write(&path, self.table.to_csv())?;
        Ok(path)
    }
}

/// Build the repro-standard job for a (cluster, workload, scheduler,
/// eviction) tuple.
pub fn system_job(
    cluster: ClusterSpec,
    workload: WorkloadConfig,
    scheduler: SchedulerKind,
    eviction: EvictionMode,
) -> JobConfig {
    let engine = EngineConfig {
        eviction,
        // H_t responsiveness matters for the control loop (see DESIGN.md
        // §CONCUR-implementation-notes).
        hit_window: 8,
        ..EngineConfig::default()
    };
    let topology = crate::config::TopologyConfig::default();
    JobConfig { cluster, engine, workload, scheduler, topology }
}

/// Run one job for a (cluster, workload, scheduler, eviction) tuple with
/// the repro-standard engine settings.
pub fn run_system(
    cluster: ClusterSpec,
    workload: WorkloadConfig,
    scheduler: SchedulerKind,
    eviction: EvictionMode,
) -> Result<RunResult> {
    run_job(&system_job(cluster, workload, scheduler, eviction))
}

/// Run a batch of repro jobs across all cores (results positionally
/// aligned; first error aborts the harness).  Every table/figure harness
/// funnels its grid through here so a full paper reproduction fans out
/// instead of running cell by cell.
pub fn run_systems(jobs: Vec<JobConfig>) -> Result<Vec<RunResult>> {
    crate::driver::run_jobs_parallel(&jobs).into_iter().collect()
}

/// One dispatchable experiment: the canonical CLI name, accepted
/// aliases, and whether it is a paper artifact (`"all"` runs those in
/// table order).  This table is the **single source of truth** shared by
/// the `concur` usage string, [`run`]'s dispatch and its unknown-name
/// error — they can no longer drift apart.
pub struct Experiment {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub paper: bool,
}

/// Every experiment, paper artifacts first (in paper order), then our
/// studies.
pub const EXPERIMENTS: [Experiment; 14] = [
    Experiment { name: "fig1", aliases: &[], paper: true },
    Experiment { name: "fig3", aliases: &[], paper: true },
    Experiment { name: "table1", aliases: &[], paper: true },
    Experiment { name: "table2", aliases: &[], paper: true },
    Experiment { name: "fig5", aliases: &[], paper: true },
    Experiment { name: "fig6", aliases: &[], paper: true },
    Experiment { name: "table3", aliases: &[], paper: true },
    Experiment { name: "cluster", aliases: &[], paper: false },
    Experiment { name: "cluster_faults", aliases: &["faults"], paper: false },
    Experiment { name: "prefix_sharing", aliases: &["prefix"], paper: false },
    Experiment { name: "transport", aliases: &[], paper: false },
    Experiment { name: "openloop", aliases: &["open_loop"], paper: false },
    Experiment { name: "workflow", aliases: &["workflows"], paper: false },
    Experiment { name: "storage", aliases: &["storage_tier"], paper: false },
];

/// Canonical names, in table order — what the usage string and the
/// unknown-name error list (plus the `all` meta-name).
pub fn experiment_names() -> impl Iterator<Item = &'static str> {
    EXPERIMENTS.iter().map(|e| e.name)
}

/// The `<exp>` alternatives for the CLI usage line: every canonical
/// name plus `all`.
pub fn cli_name_list() -> String {
    let mut names: Vec<&str> = experiment_names().collect();
    names.push("all");
    names.join("|")
}

/// Resolve a user-supplied name (canonical or alias) to its canonical
/// form.
fn canonical(name: &str) -> Option<&'static str> {
    EXPERIMENTS
        .iter()
        .find(|e| e.name == name || e.aliases.contains(&name))
        .map(|e| e.name)
}

/// Dispatch by name ("all" runs every paper artifact).
pub fn run(name: &str) -> Result<Vec<ExpOutput>> {
    let names: Vec<&str> = if name == "all" {
        EXPERIMENTS.iter().filter(|e| e.paper).map(|e| e.name).collect()
    } else {
        match canonical(name) {
            Some(n) => vec![n],
            None => {
                return Err(crate::core::ConcurError::config(format!(
                    "unknown experiment '{name}' (known: {})",
                    cli_name_list()
                )))
            }
        }
    };
    let mut out = Vec::new();
    for n in names {
        match n {
            "cluster" => out.push(cluster_scaling::run()?),
            "cluster_faults" => out.push(faults::run()?),
            "prefix_sharing" => out.push(prefix_sharing::run()?),
            "transport" => out.push(transport::run()?),
            "openloop" => out.push(openloop::run()?),
            "workflow" => out.push(workflow::run()?),
            "storage" => out.push(storage::run()?),
            "fig1" => out.extend(fig1::run()?),
            "fig3" => out.push(fig3::run()?),
            "fig5" => out.push(fig5::run()?),
            "fig6" => out.push(fig6::run()?),
            "table1" => out.push(table1::run()?),
            "table2" => out.push(table2::run()?),
            "table3" => out.push(table3::run()?),
            other => unreachable!("experiment '{other}' is in the table but not dispatched"),
        }
    }
    Ok(out)
}

/// Format seconds with a speedup annotation, Table-1 style.
pub(crate) fn cell_latency(seconds: f64, baseline: f64) -> String {
    format!("{:.0} ({:.2}x)", seconds, baseline / seconds)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_experiment_is_an_error() {
        let err = super::run("fig99").unwrap_err().to_string();
        // The error lists every valid name from the shared table, so it
        // cannot drift from the usage string or the dispatch.
        for name in super::experiment_names() {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
        assert!(err.contains("all"));
    }

    #[test]
    fn aliases_resolve_to_canonical_names() {
        assert_eq!(super::canonical("faults"), Some("cluster_faults"));
        assert_eq!(super::canonical("prefix"), Some("prefix_sharing"));
        assert_eq!(super::canonical("transport"), Some("transport"));
        assert_eq!(super::canonical("open_loop"), Some("openloop"));
        assert_eq!(super::canonical("workflows"), Some("workflow"));
        assert_eq!(super::canonical("storage_tier"), Some("storage"));
        assert_eq!(super::canonical("meteor"), None);
    }

    #[test]
    fn cli_name_list_covers_the_table() {
        let list = super::cli_name_list();
        for e in &super::EXPERIMENTS {
            assert!(list.contains(e.name));
        }
        assert!(list.ends_with("|all"));
        assert_eq!(super::EXPERIMENTS.iter().filter(|e| e.paper).count(), 7);
    }
}
