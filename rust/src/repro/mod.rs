//! Paper reproduction harnesses: one module per table/figure.
//!
//! Each harness regenerates the corresponding artifact of the paper's
//! evaluation — same rows/series, measured on this crate's serving-engine
//! substrate + cost model (see DESIGN.md §2 for the substitutions and §5
//! for the experiment index).  Absolute numbers differ from the paper's
//! H100 testbed; the *shape* (who wins, by roughly what factor, where
//! crossovers fall) is the reproduction target.
//!
//! Run via `concur repro <table1|table2|table3|fig1|fig3|fig5|fig6|all>`
//! or `cargo bench --bench paper_tables` / `paper_figures`.  Beyond the
//! paper, `concur repro cluster` runs the data-parallel replica-scaling
//! study (see [`cluster_scaling`]), `concur repro cluster_faults` the
//! fault-tolerance study (see [`faults`] — emits `BENCH_faults.json`),
//! and `concur repro prefix_sharing` the shared-prefix tier study (see
//! [`prefix_sharing`] — emits `BENCH_prefix.json`).

pub mod cluster_scaling;
pub mod faults;
pub mod fig1;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod prefix_sharing;
pub mod table1;
pub mod table2;
pub mod table3;

use crate::config::{EngineConfig, EvictionMode, JobConfig, SchedulerKind, WorkloadConfig};
use crate::core::Result;
use crate::costmodel::ClusterSpec;
use crate::driver::{run_job, RunResult};
use crate::metrics::Table;

/// Output of one experiment harness.
pub struct ExpOutput {
    pub name: &'static str,
    pub title: String,
    pub table: Table,
    /// ASCII-rendered figure panels (empty for pure tables).
    pub figures: Vec<String>,
    /// Shape expectations vs the paper (printed as a footer).
    pub notes: Vec<String>,
}

impl ExpOutput {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("== {} — {}\n\n", self.name, self.title));
        for f in &self.figures {
            s.push_str(f);
            s.push('\n');
        }
        s.push_str(&self.table.render());
        if !self.notes.is_empty() {
            s.push_str("\nShape vs paper:\n");
            for n in &self.notes {
                s.push_str(&format!("  - {n}\n"));
            }
        }
        s
    }

    /// Write the table as CSV under `results/`.
    pub fn write_csv(&self, dir: &std::path::Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        std::fs::write(&path, self.table.to_csv())?;
        Ok(path)
    }
}

/// Build the repro-standard job for a (cluster, workload, scheduler,
/// eviction) tuple.
pub fn system_job(
    cluster: ClusterSpec,
    workload: WorkloadConfig,
    scheduler: SchedulerKind,
    eviction: EvictionMode,
) -> JobConfig {
    let engine = EngineConfig {
        eviction,
        // H_t responsiveness matters for the control loop (see DESIGN.md
        // §CONCUR-implementation-notes).
        hit_window: 8,
        ..EngineConfig::default()
    };
    let topology = crate::config::TopologyConfig::default();
    JobConfig { cluster, engine, workload, scheduler, topology }
}

/// Run one job for a (cluster, workload, scheduler, eviction) tuple with
/// the repro-standard engine settings.
pub fn run_system(
    cluster: ClusterSpec,
    workload: WorkloadConfig,
    scheduler: SchedulerKind,
    eviction: EvictionMode,
) -> Result<RunResult> {
    run_job(&system_job(cluster, workload, scheduler, eviction))
}

/// Run a batch of repro jobs across all cores (results positionally
/// aligned; first error aborts the harness).  Every table/figure harness
/// funnels its grid through here so a full paper reproduction fans out
/// instead of running cell by cell.
pub fn run_systems(jobs: Vec<JobConfig>) -> Result<Vec<RunResult>> {
    crate::driver::run_jobs_parallel(&jobs).into_iter().collect()
}

/// All paper experiments in paper order ("all" runs these; the `cluster`
/// scaling and `cluster_faults` studies are dispatched by name — they
/// are ours, not the paper's).
pub const ALL: [&str; 7] =
    ["fig1", "fig3", "table1", "table2", "fig5", "fig6", "table3"];

/// Dispatch by name ("all" runs everything).
pub fn run(name: &str) -> Result<Vec<ExpOutput>> {
    let names: Vec<&str> = if name == "all" { ALL.to_vec() } else { vec![name] };
    let mut out = Vec::new();
    for n in names {
        match n {
            "cluster" => out.push(cluster_scaling::run()?),
            "cluster_faults" | "faults" => out.push(faults::run()?),
            "prefix_sharing" | "prefix" => out.push(prefix_sharing::run()?),
            "fig1" => out.extend(fig1::run()?),
            "fig3" => out.push(fig3::run()?),
            "fig5" => out.push(fig5::run()?),
            "fig6" => out.push(fig6::run()?),
            "table1" => out.push(table1::run()?),
            "table2" => out.push(table2::run()?),
            "table3" => out.push(table3::run()?),
            other => {
                return Err(crate::core::ConcurError::config(format!(
                    "unknown experiment '{other}' (known: {ALL:?}, 'cluster', \
                     'cluster_faults', 'prefix_sharing' or 'all')"
                )))
            }
        }
    }
    Ok(out)
}

/// Format seconds with a speedup annotation, Table-1 style.
pub(crate) fn cell_latency(seconds: f64, baseline: f64) -> String {
    format!("{:.0} ({:.2}x)", seconds, baseline / seconds)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_experiment_is_an_error() {
        assert!(super::run("fig99").is_err());
    }
}
