//! Shared-prefix tier study (`concur repro prefix_sharing`): does the
//! broadcast tier recover the cross-agent prefix hits that data-parallel
//! sharding splits?
//!
//! Not a paper artifact — this closes the "lost shared-prefix hits"
//! ROADMAP item the cluster sweeps exposed: at N>1 every replica
//! re-prefills the same family system prompt once, structurally
//! depressing the aggregate hit rate `H_t` the CONCUR controller feeds
//! on.  The grid holds the offered load fixed (128 Qwen3-class agents,
//! CONCUR admission, cache-affinity routing) and sweeps
//! {1, 2, 4, 8} replicas × {tier off, tier on} on **anchored timelines**:
//! every cell runs the bit-identical workload (same seed, same
//! trajectories, same tool latencies), so the tier is the only moving
//! part.  The workload uses 5 task families — coprime with every swept
//! replica count, so each family's prefix genuinely splits across all
//! replicas under id-hashed affinity homes (4 families would align with
//! N ∈ {2, 4} and hide the effect).
//!
//! Expected headline: `H_t` at N=8 with the tier on recovers toward the
//! N=1 level, and tier-on throughput is at least tier-off at every N>1
//! (the tier only removes prefill/recompute work).  At N=1 the single
//! replica is its own source, so nothing ships — but the pins still
//! shield the family prefixes from LRU churn under thrashing, so even
//! the N=1 pair is not exactly tied.
//!
//! The sweep writes `BENCH_prefix.json` (override the path with
//! `BENCH_PREFIX_PATH`) so the nightly CI job can archive the
//! prefix-recovery trajectory next to the cluster and fault artifacts.

use std::collections::BTreeMap;

use crate::config::presets;
use crate::config::{
    AimdParams, EngineConfig, JobConfig, PrefixTierConfig, RouterKind, SchedulerKind,
    TopologyConfig,
};
use crate::core::json::Value;
use crate::core::Result;
use crate::driver::RunResult;
use crate::metrics::Table;

use super::{run_systems, ExpOutput};

/// Replica counts swept (the N=1 column is the control and the tier
/// no-op case).
pub const REPLICAS: [usize; 4] = [1, 2, 4, 8];

/// Offered load held fixed across the grid.
pub const SWEEP_AGENTS: usize = 128;

/// Task families in the sweep workload: coprime with every swept replica
/// count so affinity homes split every family across all replicas.
pub const TASK_FAMILIES: u32 = 5;

/// The tier configuration the "on" cells run (defaults, switched on).
pub fn tier_config() -> PrefixTierConfig {
    PrefixTierConfig::on()
}

/// One grid cell: a (replica count, tier on/off) pair and its run.
pub struct PrefixCell {
    pub replicas: usize,
    pub tier_on: bool,
    pub result: RunResult,
}

/// The repro-standard job for one cell.
pub fn base_job(replicas: usize, tier_on: bool) -> JobConfig {
    let mut workload = presets::qwen3_workload(SWEEP_AGENTS);
    workload.task_families = TASK_FAMILIES;
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload,
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig {
            replicas,
            router: RouterKind::CacheAffinity,
            prefix_tier: if tier_on { tier_config() } else { PrefixTierConfig::default() },
            ..TopologyConfig::default()
        },
    }
}

/// Run the whole grid, row-major (replicas outer, off before on), fanned
/// out across cores.
pub fn run_sweep() -> Result<Vec<PrefixCell>> {
    let labels: Vec<(usize, bool)> =
        REPLICAS.iter().flat_map(|&n| [(n, false), (n, true)]).collect();
    let jobs = labels.iter().map(|&(n, on)| base_job(n, on)).collect();
    Ok(labels
        .into_iter()
        .zip(run_systems(jobs)?)
        .map(|((replicas, tier_on), result)| PrefixCell { replicas, tier_on, result })
        .collect())
}

/// Machine-readable sweep dump (`BENCH_prefix.json`): one entry per
/// cell, keyed `r{replicas}/tier-{on|off}`.
pub fn bench_json(cells: &[PrefixCell]) -> Value {
    let mut map: BTreeMap<String, Value> = BTreeMap::new();
    for c in cells {
        let mut entry: BTreeMap<String, Value> = BTreeMap::new();
        entry.insert("latency_s".into(), Value::Number(c.result.total_time.as_secs_f64()));
        entry.insert("throughput_tps".into(), Value::Number(c.result.throughput_tps));
        entry.insert("hit_rate".into(), Value::Number(c.result.hit_rate));
        let t = &c.result.prefix_tier;
        entry.insert("hot_prefixes".into(), Value::Number(t.hot_prefixes as f64));
        entry.insert("ships".into(), Value::Number(t.ships as f64));
        entry.insert("reships".into(), Value::Number(t.reships as f64));
        entry.insert("shipped_tokens".into(), Value::Number(t.shipped_tokens as f64));
        entry.insert("demotions".into(), Value::Number(t.demotions as f64));
        entry.insert(
            "broadcast_hit_tokens".into(),
            Value::Number(c.result.counters.broadcast_hit_tokens as f64),
        );
        let key = format!("r{}/tier-{}", c.replicas, if c.tier_on { "on" } else { "off" });
        map.insert(key, Value::Object(entry));
    }
    Value::Object(map)
}

fn cell(cells: &[PrefixCell], replicas: usize, tier_on: bool) -> &RunResult {
    &cells
        .iter()
        .find(|c| c.replicas == replicas && c.tier_on == tier_on)
        .expect("complete grid")
        .result
}

/// Render the grid as a repro table with recovery notes.
pub fn output_from(cells: &[PrefixCell]) -> ExpOutput {
    let mut table = Table::new(
        "Shared-prefix tier: throughput (tok/s) and lifetime hit rate (%) \
         across replicas x tier",
    )
    .header(&[
        "Replicas",
        "off tok/s",
        "off hit%",
        "on tok/s",
        "on hit%",
        "ships",
        "shipped tok",
    ]);

    for &n in &REPLICAS {
        let off = cell(cells, n, false);
        let on = cell(cells, n, true);
        table.row(vec![
            n.to_string(),
            format!("{:.0}", off.throughput_tps),
            format!("{:.1}", off.hit_rate * 100.0),
            format!("{:.0}", on.throughput_tps),
            format!("{:.1}", on.hit_rate * 100.0),
            on.prefix_tier.ships.to_string(),
            on.prefix_tier.shipped_tokens.to_string(),
        ]);
    }

    let max_n = REPLICAS[REPLICAS.len() - 1];
    let base = cell(cells, 1, false);
    let off8 = cell(cells, max_n, false);
    let on8 = cell(cells, max_n, true);
    let gap_off = (base.hit_rate - off8.hit_rate) * 100.0;
    let gap_on = (base.hit_rate - on8.hit_rate) * 100.0;
    let notes = vec![
        format!(
            "sharding costs {gap_off:+.2} hit points at N={max_n} without the \
             tier; with it the gap narrows to {gap_on:+.2} points \
             (H_t {:.2}% off vs {:.2}% on, N=1 anchor {:.2}%)",
            off8.hit_rate * 100.0,
            on8.hit_rate * 100.0,
            base.hit_rate * 100.0
        ),
        format!(
            "tier-on throughput at N={max_n}: {:.0} vs {:.0} tok/s off \
             ({:+.2}%) — broadcast installs replace per-replica re-prefill \
             of {} shipped tokens",
            on8.throughput_tps,
            off8.throughput_tps,
            (on8.throughput_tps / off8.throughput_tps - 1.0) * 100.0,
            on8.prefix_tier.shipped_tokens
        ),
        "all cells run the bit-identical workload (anchored timelines): \
         the tier flag is the only difference between paired rows"
            .into(),
    ];

    ExpOutput {
        name: "prefix_sharing",
        title: "Cross-replica shared-prefix tier (replicas x tier)".into(),
        table,
        figures: vec![],
        notes,
    }
}

/// Run the study and write `BENCH_prefix.json` (path overridable via
/// `BENCH_PREFIX_PATH`).
pub fn run() -> Result<ExpOutput> {
    let cells = run_sweep()?;
    let path =
        std::env::var("BENCH_PREFIX_PATH").unwrap_or_else(|_| "BENCH_prefix.json".to_string());
    std::fs::write(&path, format!("{}\n", bench_json(&cells).to_string_pretty()))?;
    let mut out = output_from(&cells);
    out.notes.push(format!("machine-readable results written to {path}"));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_jobs_validate() {
        for &n in &REPLICAS {
            for on in [false, true] {
                let job = base_job(n, on);
                job.validate().unwrap();
                assert_eq!(job.topology.prefix_tier.enabled, on);
                assert_eq!(job.workload.task_families, TASK_FAMILIES);
            }
        }
    }

    #[test]
    fn families_are_coprime_with_every_swept_replica_count() {
        fn gcd(a: u32, b: u32) -> u32 {
            if b == 0 { a } else { gcd(b, a % b) }
        }
        for &n in &REPLICAS {
            assert_eq!(
                gcd(TASK_FAMILIES, n as u32),
                1,
                "family count must split every family across all {n} replicas"
            );
        }
    }

    #[test]
    fn tier_config_is_the_enabled_default() {
        let t = tier_config();
        assert!(t.enabled);
        t.validate().unwrap();
    }
}
