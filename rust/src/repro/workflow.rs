//! Workflow-graph workload study (`concur repro workflow`): aggregate
//! cache hit rate and makespan across KV lifetime policies and workflow
//! shapes.
//!
//! Not a paper artifact — this opens the workflow-awareness axis the
//! ROADMAP calls for.  Fleets of planner→worker DAGs (see
//! [`crate::agent::workflow_fleet`]) run under each
//! [`KvLifetimeMode`]:
//!
//! * `lru`                — recency only (the baseline every serving
//!   engine ships);
//! * `steps-to-execution` — KVFlow-style: KV belonging to agents with
//!   the *least* remaining trajectory is retained hardest (their
//!   contexts are the largest, the most expensive to recompute, and the
//!   first to free the pool for good);
//! * `tool-ttl`           — Continuum-style: KV of a tool-waiting agent
//!   is pinned for the tool's expected latency, so plain recency cannot
//!   evict exactly the context that is about to be re-read.
//!
//! Two shapes (`fanout`: planner → workers; `mapreduce`: planner →
//! workers → reducer) at two pressure levels (fleet size against one
//! TP2 pool).  The question the grid answers: once the pool thrashes,
//! does knowing *when KV comes back* (tool-ttl) or *how much future it
//! has* (steps-to-execution) beat plain recency on aggregate hit rate?
//! `tests/workflow_integration.rs` pins the scaled-down claim.
//!
//! The sweep also writes `BENCH_workflow.json` (override the path with
//! `BENCH_WORKFLOW_PATH`) so the nightly CI job can archive the policy
//! comparison next to the other bench artifacts.

use std::collections::BTreeMap;

use crate::config::presets;
use crate::config::{
    AimdParams, EngineConfig, JobConfig, KvLifetimeMode, SchedulerKind, TopologyConfig,
    WorkflowConfig, WorkloadConfig,
};
use crate::core::json::Value;
use crate::core::Result;
use crate::driver::RunResult;
use crate::metrics::Table;

use super::{run_systems, ExpOutput};

/// KV lifetime policies compared in every cell, in table order.
pub const POLICIES: [KvLifetimeMode; 3] = [
    KvLifetimeMode::Lru,
    KvLifetimeMode::StepsToExecution,
    KvLifetimeMode::ToolTtl,
];

/// Workflow shapes: `(label, map_reduce_share)`.
pub const SHAPES: [(&str, f64); 2] = [("fanout", 0.0), ("mapreduce", 1.0)];

/// Pressure levels: `(label, graphs per fleet)` against one TP2 pool.
pub const PRESSURES: [(&str, u32); 2] = [("light", 6), ("heavy", 16)];

/// One grid cell: a (policy, shape, pressure) triple and its run.
pub struct WorkflowCell {
    pub policy: KvLifetimeMode,
    pub shape: &'static str,
    pub pressure: &'static str,
    pub result: RunResult,
}

/// The workflow generator shape for one (shape, pressure) cell.
pub fn workflow_for(shape: &str, graphs: u32) -> WorkflowConfig {
    let map_reduce_share = SHAPES
        .iter()
        .find(|(s, _)| *s == shape)
        .unwrap_or_else(|| panic!("unknown workflow shape '{shape}'"))
        .1;
    WorkflowConfig {
        graphs: graphs as usize,
        fanout_min: 2,
        fanout_max: 4,
        map_reduce_share,
        shared_context_tokens: 512,
        ..WorkflowConfig::on()
    }
}

/// The repro-standard job for one cell: workflow DAGs on a single
/// Qwen3-class TP2 replica (one pool carries the whole fleet, so the
/// heavy pressure level genuinely thrashes it).
pub fn base_job(policy: KvLifetimeMode, shape: &'static str, graphs: u32) -> JobConfig {
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig {
            hit_window: 8,
            kv_lifetime: policy,
            ..EngineConfig::default()
        },
        workload: WorkloadConfig {
            steps_min: 10,
            steps_max: 16,
            task_families: 4,
            workflow: workflow_for(shape, graphs),
            ..WorkloadConfig::default()
        },
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig::default(),
    }
}

/// Run the whole grid, fanned out across cores.
pub fn run_sweep() -> Result<Vec<WorkflowCell>> {
    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for &policy in &POLICIES {
        for &(shape, _) in &SHAPES {
            for &(pressure, graphs) in &PRESSURES {
                labels.push((policy, shape, pressure));
                jobs.push(base_job(policy, shape, graphs));
            }
        }
    }
    Ok(labels
        .into_iter()
        .zip(run_systems(jobs)?)
        .map(|((policy, shape, pressure), result)| WorkflowCell {
            policy,
            shape,
            pressure,
            result,
        })
        .collect())
}

/// Machine-readable sweep dump (`BENCH_workflow.json`): one entry per
/// cell, keyed `{policy}/{shape}/{pressure}`.
pub fn bench_json(cells: &[WorkflowCell]) -> Value {
    let mut map: BTreeMap<String, Value> = BTreeMap::new();
    for c in cells {
        let mut entry: BTreeMap<String, Value> = BTreeMap::new();
        entry.insert("latency_s".into(), Value::Number(c.result.total_time.as_secs_f64()));
        entry.insert("hit_rate".into(), Value::Number(c.result.hit_rate));
        entry.insert(
            "recompute_frac".into(),
            Value::Number(c.result.breakdown.fraction(crate::metrics::Phase::Recompute)),
        );
        entry.insert("throughput_tps".into(), Value::Number(c.result.throughput_tps));
        entry.insert("evictions".into(), Value::Number(c.result.counters.evictions as f64));
        entry.insert("agents".into(), Value::Number(c.result.agents_finished as f64));
        map.insert(
            format!("{}/{}/{}", c.policy.name(), c.shape, c.pressure),
            Value::Object(entry),
        );
    }
    Value::Object(map)
}

fn cell<'a>(
    cells: &'a [WorkflowCell],
    policy: KvLifetimeMode,
    shape: &str,
    pressure: &str,
) -> &'a RunResult {
    &cells
        .iter()
        .find(|c| c.policy == policy && c.shape == shape && c.pressure == pressure)
        .expect("complete grid")
        .result
}

/// Render the grid as a repro table with policy-vs-LRU notes.
pub fn output_from(cells: &[WorkflowCell]) -> ExpOutput {
    let mut table = Table::new(
        "Workflow DAG fleets: aggregate hit rate and makespan across KV \
         lifetime policy x workflow shape x pool pressure",
    )
    .header(&[
        "shape/pressure",
        "lru hit%",
        "steps hit%",
        "ttl hit%",
        "lru s",
        "steps s",
        "ttl s",
    ]);

    for &(shape, _) in &SHAPES {
        for &(pressure, _) in &PRESSURES {
            let lru = cell(cells, KvLifetimeMode::Lru, shape, pressure);
            let steps = cell(cells, KvLifetimeMode::StepsToExecution, shape, pressure);
            let ttl = cell(cells, KvLifetimeMode::ToolTtl, shape, pressure);
            table.row(vec![
                format!("{shape}/{pressure}"),
                format!("{:.1}", lru.hit_rate * 100.0),
                format!("{:.1}", steps.hit_rate * 100.0),
                format!("{:.1}", ttl.hit_rate * 100.0),
                format!("{:.0}", lru.total_time.as_secs_f64()),
                format!("{:.0}", steps.total_time.as_secs_f64()),
                format!("{:.0}", ttl.total_time.as_secs_f64()),
            ]);
        }
    }

    // Best lifetime-aware policy vs the LRU baseline on the most
    // pressured cells.
    let mut notes = Vec::new();
    for &(shape, _) in &SHAPES {
        let lru = cell(cells, KvLifetimeMode::Lru, shape, "heavy");
        let steps = cell(cells, KvLifetimeMode::StepsToExecution, shape, "heavy");
        let ttl = cell(cells, KvLifetimeMode::ToolTtl, shape, "heavy");
        let (best_name, best) = if steps.hit_rate >= ttl.hit_rate {
            ("steps-to-execution", steps)
        } else {
            ("tool-ttl", ttl)
        };
        notes.push(format!(
            "{shape}/heavy: {} hit {:.1}% vs lru {:.1}% (evictions {} vs {})",
            best_name,
            best.hit_rate * 100.0,
            lru.hit_rate * 100.0,
            best.counters.evictions,
            lru.counters.evictions,
        ));
    }
    notes.push(
        "identical fleets and release order within a cell: the policies \
         change which KV evicts under pressure, never who runs when"
            .into(),
    );

    ExpOutput {
        name: "workflow",
        title: "Workflow DAGs: KV lifetime policy x shape x pressure".into(),
        table,
        figures: vec![],
        notes,
    }
}

/// Run the study and write `BENCH_workflow.json` (path overridable via
/// `BENCH_WORKFLOW_PATH`).
pub fn run() -> Result<ExpOutput> {
    let cells = run_sweep()?;
    let path = std::env::var("BENCH_WORKFLOW_PATH")
        .unwrap_or_else(|_| "BENCH_workflow.json".to_string());
    std::fs::write(&path, format!("{}\n", bench_json(&cells).to_string_pretty()))?;
    let mut out = output_from(&cells);
    out.notes.push(format!("machine-readable results written to {path}"));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_jobs_validate_for_every_cell() {
        for &policy in &POLICIES {
            for &(shape, _) in &SHAPES {
                for &(pressure, graphs) in &PRESSURES {
                    let job = base_job(policy, shape, graphs);
                    job.validate().unwrap();
                    assert!(job.workload.workflow.enabled, "{shape}/{pressure}");
                    assert_eq!(job.engine.kv_lifetime, policy);
                }
            }
        }
    }

    #[test]
    fn shapes_differ_only_in_the_reduce_coin() {
        let fo = workflow_for("fanout", 6);
        let mr = workflow_for("mapreduce", 6);
        assert_eq!(fo.map_reduce_share, 0.0);
        assert_eq!(mr.map_reduce_share, 1.0);
        assert_eq!(
            (fo.graphs, fo.fanout_min, fo.fanout_max, fo.shared_context_tokens, fo.seed),
            (mr.graphs, mr.fanout_min, mr.fanout_max, mr.shared_context_tokens, mr.seed),
        );
    }

    #[test]
    #[should_panic(expected = "unknown workflow shape")]
    fn unknown_shape_panics() {
        workflow_for("meteor", 6);
    }
}
