//! Storage-tier dual-path study (`concur repro storage`): batch latency
//! and reload-vs-recompute traffic across storage bandwidth, cache
//! pressure, and the three [`DualPathMode`] policies.
//!
//! Not a paper artifact — this opens the capacity-tier axis the ROADMAP
//! calls for.  Every cell runs the same ReAct fleet on one Qwen3-class
//! TP2 replica with offload eviction, a deliberately small CPU tier (so
//! demotions reach NVMe at sim scale), and a storage tier whose link
//! bandwidth is the sweep axis:
//!
//! * `always-reload`    — HiCache extended down-stack: every
//!   storage-resident prefix is read back, however slow the link;
//! * `always-recompute` — the storage tier is write-only: missing
//!   prefixes are re-prefilled, paying the quadratic attention term
//!   however idle the link is;
//! * `dual-path`        — per-request argmin of modeled storage-read
//!   time vs modeled prefill time for the missing span.
//!
//! The question the grid answers: is a *per-request* decision worth it,
//! or does one pure policy dominate?  On a congested or slow link the
//! reload estimate inflates with queue depth, so dual-path degrades
//! into recompute; on a fast idle link it degrades into reload; in
//! between it mixes — and should sit at or below both pure policies.
//! `tests/storage_integration.rs` pins the scaled-down claim.
//!
//! The sweep also writes `BENCH_storage.json` (override the path with
//! `BENCH_STORAGE_PATH`) so the nightly CI job can archive the policy
//! comparison next to the other bench artifacts.

use std::collections::BTreeMap;

use crate::config::presets;
use crate::config::{
    DualPathMode, EngineConfig, EvictionMode, JobConfig, SchedulerKind, StorageTierConfig,
    TopologyConfig,
};
use crate::core::json::Value;
use crate::core::Result;
use crate::driver::RunResult;
use crate::metrics::{Phase, Table};

use super::{run_systems, ExpOutput};

/// Reload policies compared in every cell, in table order.
pub const POLICIES: [DualPathMode; 3] = [
    DualPathMode::AlwaysReload,
    DualPathMode::AlwaysRecompute,
    DualPathMode::DualPath,
];

/// Storage-link bandwidth levels: `(label, GB/s)`.  `slow` is a single
/// saturated QLC drive, `nvme` one enterprise NVMe, `fast` a striped
/// array — wide enough to cross the reload/recompute break-even.
pub const BANDWIDTHS: [(&str, f64); 3] = [("slow", 0.8), ("nvme", 6.0), ("fast", 32.0)];

/// Cache-pressure levels: `(label, fleet size)` against one TP2 pool.
pub const PRESSURES: [(&str, usize); 2] = [("light", 24), ("heavy", 48)];

/// CPU-tier cap for every cell, in tokens.  The stock cap derives from
/// 2 TB of host DRAM per node (~7.6M tokens for Qwen3-32B) — no
/// sim-scale fleet fills that, so the middle tier is squeezed until
/// offloaded prefixes genuinely spill to storage.
pub const CPU_TIER_TOKENS: u64 = 48_000;

/// One grid cell: a (policy, bandwidth, pressure) triple and its run.
pub struct StorageCell {
    pub policy: DualPathMode,
    pub bandwidth: &'static str,
    pub pressure: &'static str,
    pub result: RunResult,
}

/// The repro-standard job for one cell: a ReAct fleet on a single
/// Qwen3-class TP2 replica with offload eviction, a squeezed CPU tier,
/// and the storage tier on at the cell's link bandwidth.
pub fn base_job(policy: DualPathMode, bandwidth_gbps: f64, n_agents: usize) -> JobConfig {
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig {
            eviction: EvictionMode::Offload,
            storage_tier: StorageTierConfig {
                bandwidth_gbps,
                cpu_tier_tokens: CPU_TIER_TOKENS,
                ..StorageTierConfig::on()
            },
            dual_path: policy,
            ..EngineConfig::default()
        },
        workload: presets::qwen3_workload(n_agents),
        // No admission control: isolates the reload-policy effect (AIMD
        // would throttle the fleet until the pressure axis flattens).
        scheduler: SchedulerKind::Uncontrolled,
        topology: TopologyConfig::default(),
    }
}

/// Run the whole grid, fanned out across cores.
pub fn run_sweep() -> Result<Vec<StorageCell>> {
    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for &policy in &POLICIES {
        for &(bandwidth, gbps) in &BANDWIDTHS {
            for &(pressure, n_agents) in &PRESSURES {
                labels.push((policy, bandwidth, pressure));
                jobs.push(base_job(policy, gbps, n_agents));
            }
        }
    }
    Ok(labels
        .into_iter()
        .zip(run_systems(jobs)?)
        .map(|((policy, bandwidth, pressure), result)| StorageCell {
            policy,
            bandwidth,
            pressure,
            result,
        })
        .collect())
}

/// Machine-readable sweep dump (`BENCH_storage.json`): one entry per
/// cell, keyed `{policy}/{bandwidth}/{pressure}`.
pub fn bench_json(cells: &[StorageCell]) -> Value {
    let mut map: BTreeMap<String, Value> = BTreeMap::new();
    for c in cells {
        let n = &c.result.counters;
        let mut entry: BTreeMap<String, Value> = BTreeMap::new();
        entry.insert("latency_s".into(), Value::Number(c.result.total_time.as_secs_f64()));
        entry.insert("hit_rate".into(), Value::Number(c.result.hit_rate));
        entry.insert("throughput_tps".into(), Value::Number(c.result.throughput_tps));
        entry.insert(
            "storage_reload_frac".into(),
            Value::Number(c.result.breakdown.fraction(Phase::StorageReload)),
        );
        entry.insert(
            "recompute_frac".into(),
            Value::Number(c.result.breakdown.fraction(Phase::Recompute)),
        );
        entry
            .insert("demoted_tokens".into(), Value::Number(n.storage_demoted_tokens as f64));
        entry.insert(
            "reloaded_tokens".into(),
            Value::Number(n.storage_reloaded_tokens as f64),
        );
        entry.insert(
            "recomputed_tokens".into(),
            Value::Number(n.storage_recomputed_tokens as f64),
        );
        entry
            .insert("evicted_tokens".into(), Value::Number(n.storage_evicted_tokens as f64));
        map.insert(
            format!("{}/{}/{}", c.policy.name(), c.bandwidth, c.pressure),
            Value::Object(entry),
        );
    }
    Value::Object(map)
}

fn cell<'a>(
    cells: &'a [StorageCell],
    policy: DualPathMode,
    bandwidth: &str,
    pressure: &str,
) -> &'a RunResult {
    &cells
        .iter()
        .find(|c| c.policy == policy && c.bandwidth == bandwidth && c.pressure == pressure)
        .expect("complete grid")
        .result
}

/// Render the grid as a repro table with dual-path-vs-pure notes.
pub fn output_from(cells: &[StorageCell]) -> ExpOutput {
    let mut table = Table::new(
        "Storage tier: batch latency across reload policy x storage link \
         bandwidth x cache pressure (squeezed CPU tier)",
    )
    .header(&[
        "bw/pressure",
        "reload s",
        "recomp s",
        "dual s",
        "dual reload kt",
        "dual recomp kt",
    ]);

    for &(bandwidth, _) in &BANDWIDTHS {
        for &(pressure, _) in &PRESSURES {
            let rl = cell(cells, DualPathMode::AlwaysReload, bandwidth, pressure);
            let rc = cell(cells, DualPathMode::AlwaysRecompute, bandwidth, pressure);
            let dp = cell(cells, DualPathMode::DualPath, bandwidth, pressure);
            table.row(vec![
                format!("{bandwidth}/{pressure}"),
                format!("{:.0}", rl.total_time.as_secs_f64()),
                format!("{:.0}", rc.total_time.as_secs_f64()),
                format!("{:.0}", dp.total_time.as_secs_f64()),
                format!("{:.0}", dp.counters.storage_reloaded_tokens as f64 / 1e3),
                format!("{:.0}", dp.counters.storage_recomputed_tokens as f64 / 1e3),
            ]);
        }
    }

    // Where does the per-request decision beat both pure policies?
    let mut wins = Vec::new();
    let mut never_worse = true;
    for &(bandwidth, _) in &BANDWIDTHS {
        for &(pressure, _) in &PRESSURES {
            let rl = cell(cells, DualPathMode::AlwaysReload, bandwidth, pressure).total_time;
            let rc = cell(cells, DualPathMode::AlwaysRecompute, bandwidth, pressure).total_time;
            let dp = cell(cells, DualPathMode::DualPath, bandwidth, pressure).total_time;
            if dp < rl && dp < rc {
                wins.push(format!("{bandwidth}/{pressure}"));
            }
            if dp > rl.min(rc) {
                never_worse = false;
            }
        }
    }
    let mut notes = vec![if wins.is_empty() {
        "dual-path tracks the better pure policy in every cell (no strict win)".to_string()
    } else {
        format!("dual-path strictly beats both pure policies at: {}", wins.join(", "))
    }];
    notes.push(if never_worse {
        "dual-path is never slower than the better pure policy".into()
    } else {
        "dual-path trails the better pure policy in at least one cell \
         (estimate error under congestion)"
            .into()
    });
    notes.push(format!(
        "CPU tier squeezed to {}k tokens so offloads spill to storage at sim scale",
        CPU_TIER_TOKENS / 1_000
    ));

    ExpOutput {
        name: "storage",
        title: "Storage tier: reload policy x link bandwidth x pressure".into(),
        table,
        figures: vec![],
        notes,
    }
}

/// Run the study and write `BENCH_storage.json` (path overridable via
/// `BENCH_STORAGE_PATH`).
pub fn run() -> Result<ExpOutput> {
    let cells = run_sweep()?;
    let path =
        std::env::var("BENCH_STORAGE_PATH").unwrap_or_else(|_| "BENCH_storage.json".to_string());
    std::fs::write(&path, format!("{}\n", bench_json(&cells).to_string_pretty()))?;
    let mut out = output_from(&cells);
    out.notes.push(format!("machine-readable results written to {path}"));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_jobs_validate_for_every_cell() {
        for &policy in &POLICIES {
            for &(bandwidth, gbps) in &BANDWIDTHS {
                for &(pressure, n_agents) in &PRESSURES {
                    let job = base_job(policy, gbps, n_agents);
                    job.validate().unwrap_or_else(|e| {
                        panic!("{}/{bandwidth}/{pressure}: {e}", policy.name())
                    });
                    assert!(job.engine.storage_tier.enabled);
                    assert_eq!(job.engine.eviction, EvictionMode::Offload);
                    assert_eq!(job.engine.dual_path, policy);
                }
            }
        }
    }

    #[test]
    fn cpu_tier_cap_is_tighter_than_the_derived_one() {
        // The squeeze only means anything if it undercuts what the
        // cluster spec would derive (2 TB of host DRAM per node).
        let job = base_job(DualPathMode::DualPath, 6.0, 24);
        assert!(CPU_TIER_TOKENS < job.cluster.cpu_tier_tokens());
        // ...and the pool itself must outsize the CPU cap, or nothing
        // would ever offload past it.
        assert!(job.cluster.kv_pool_tokens() > CPU_TIER_TOKENS);
    }

    #[test]
    fn bandwidth_axis_brackets_the_break_even() {
        let (lo, hi) = (BANDWIDTHS[0].1, BANDWIDTHS[BANDWIDTHS.len() - 1].1);
        assert!(lo < 6.0 && hi > 6.0, "axis must straddle one-NVMe bandwidth");
    }
}
