//! Open-loop traffic study (`concur repro openloop`): goodput-under-SLO,
//! shedding and abandonment across admission policies and offered load.
//!
//! Not a paper artifact — this opens the open-loop realism axis the
//! ROADMAP calls for.  A fixed session population (64 Qwen3-class
//! multi-turn sessions, 25% high-priority, 45 s patience) *arrives* over
//! a seeded Poisson process instead of being present at t=0, at three
//! offered loads, into a 3-replica CONCUR-controlled fleet under
//! stochastic MTBF/MTTR fault injection (kills and drains, 60 s MTBF).
//! Three admission policies serve each load:
//!
//! * `fifo`          — arrival order, no shedding (the naive door);
//! * `priority`      — high-priority sessions admitted first;
//! * `priority+shed` — priority admission plus the hysteretic overload
//!   governor shedding not-yet-started low-priority sessions.
//!
//! The question the grid answers: once the offered load exceeds what the
//! fleet can serve within SLO, *who* you turn away decides how much
//! high-priority goodput survives — FIFO burns capacity on sessions that
//! abandon anyway, while priority + shedding degrades gracefully
//! (`tests/openloop_integration.rs` pins the claim on the overloaded
//! cell).
//!
//! The sweep also writes `BENCH_openloop.json` (override the path with
//! `BENCH_OPENLOOP_PATH`) so the nightly CI job can archive the
//! SLO/goodput trajectory next to the other bench artifacts.

use std::collections::BTreeMap;

use crate::config::presets;
use crate::config::{
    AimdParams, EngineConfig, FaultRateConfig, JobConfig, OpenLoopConfig, RouterKind,
    SchedulerKind, TopologyConfig, WorkloadConfig,
};
use crate::core::json::Value;
use crate::core::Result;
use crate::driver::RunResult;
use crate::metrics::Table;

use super::{run_systems, ExpOutput};

/// Admission policies compared at every offered load, in table order.
pub const POLICIES: [&str; 3] = ["fifo", "priority", "priority+shed"];

/// Offered loads (session arrivals per second).
pub const LOADS: [f64; 3] = [1.0, 2.0, 4.0];

/// Replicas in the fleet.
pub const REPLICAS: usize = 3;

/// Session population per cell.
pub const SWEEP_AGENTS: usize = 64;

/// One grid cell: a (policy, load) pair and its run.
pub struct OpenLoopCell {
    pub policy: &'static str,
    pub rate_per_s: f64,
    pub result: RunResult,
}

/// The open-loop traffic shape for one (policy, load) cell.
pub fn traffic_for(policy: &str, rate_per_s: f64) -> OpenLoopConfig {
    OpenLoopConfig {
        arrival_rate_per_s: rate_per_s,
        patience_s: 45.0,
        slo_ttft_s: 30.0,
        slo_step_s: 60.0,
        priority_admission: policy != "fifo",
        shed: policy == "priority+shed",
        ..OpenLoopConfig::on()
    }
}

/// The repro-standard job for one cell: Qwen3-class sessions on a
/// 3-replica CONCUR fleet with stochastic fault injection.
pub fn base_job(policy: &'static str, rate_per_s: f64) -> JobConfig {
    assert!(POLICIES.contains(&policy), "unknown admission policy '{policy}'");
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: WorkloadConfig {
            n_agents: SWEEP_AGENTS,
            steps_min: 3,
            steps_max: 5,
            task_families: 5,
            ..WorkloadConfig::default()
        },
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig {
            replicas: REPLICAS,
            router: RouterKind::CacheAffinity,
            open_loop: traffic_for(policy, rate_per_s),
            fault_rates: FaultRateConfig {
                mtbf_s: 60.0,
                mttr_s: 15.0,
                drain_share: 0.5,
                ..FaultRateConfig::on()
            },
            ..TopologyConfig::default()
        },
    }
}

/// Run the whole grid, fanned out across cores.
pub fn run_sweep() -> Result<Vec<OpenLoopCell>> {
    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for &policy in &POLICIES {
        for &rate in &LOADS {
            labels.push((policy, rate));
            jobs.push(base_job(policy, rate));
        }
    }
    Ok(labels
        .into_iter()
        .zip(run_systems(jobs)?)
        .map(|((policy, rate_per_s), result)| OpenLoopCell { policy, rate_per_s, result })
        .collect())
}

/// Machine-readable sweep dump (`BENCH_openloop.json`): one entry per
/// cell, keyed `{policy}/rate{λ}`.
pub fn bench_json(cells: &[OpenLoopCell]) -> Value {
    let mut map: BTreeMap<String, Value> = BTreeMap::new();
    for c in cells {
        let ol = &c.result.open_loop;
        let mut entry: BTreeMap<String, Value> = BTreeMap::new();
        entry.insert("latency_s".into(), Value::Number(c.result.total_time.as_secs_f64()));
        entry.insert("arrived".into(), Value::Number(ol.arrived as f64));
        entry.insert("served".into(), Value::Number(c.result.agents_finished as f64));
        entry.insert("shed".into(), Value::Number(ol.shed as f64));
        entry.insert("abandoned".into(), Value::Number(ol.abandoned as f64));
        entry.insert("turn_violations".into(), Value::Number(ol.turn_violations as f64));
        entry.insert("goodput_high_tokens".into(), Value::Number(ol.goodput_high as f64));
        entry.insert("goodput_low_tokens".into(), Value::Number(ol.goodput_low as f64));
        let ttft_p = |p: f64| Value::Number(c.result.ttft.percentile(p).as_secs_f64());
        entry.insert("ttft_p50_s".into(), ttft_p(50.0));
        entry.insert("ttft_p99_s".into(), ttft_p(99.0));
        entry.insert(
            "step_p99_s".into(),
            Value::Number(c.result.step_latency.percentile(99.0).as_secs_f64()),
        );
        map.insert(format!("{}/rate{}", c.policy, c.rate_per_s), Value::Object(entry));
    }
    Value::Object(map)
}

fn cell<'a>(cells: &'a [OpenLoopCell], policy: &str, rate: f64) -> &'a RunResult {
    &cells
        .iter()
        .find(|c| c.policy == policy && c.rate_per_s == rate)
        .expect("complete grid")
        .result
}

/// Render the grid as a repro table with degradation notes.
pub fn output_from(cells: &[OpenLoopCell]) -> ExpOutput {
    let mut table = Table::new(
        "Open-loop traffic: high-priority goodput-under-SLO (tokens), \
         shed and abandoned sessions across policy x offered load",
    )
    .header(&[
        "λ/s",
        "fifo good-hi",
        "fifo lost",
        "prio good-hi",
        "prio lost",
        "p+s good-hi",
        "p+s lost",
        "p+s shed",
    ]);

    for &rate in &LOADS {
        let fifo = cell(cells, "fifo", rate);
        let prio = cell(cells, "priority", rate);
        let ps = cell(cells, "priority+shed", rate);
        table.row(vec![
            format!("{rate}"),
            format!("{}", fifo.open_loop.goodput_high),
            format!("{}", fifo.open_loop.abandoned),
            format!("{}", prio.open_loop.goodput_high),
            format!("{}", prio.open_loop.abandoned),
            format!("{}", ps.open_loop.goodput_high),
            format!("{}", ps.open_loop.abandoned),
            format!("{}", ps.open_loop.shed),
        ]);
    }

    let peak = LOADS[LOADS.len() - 1];
    let fifo = cell(cells, "fifo", peak);
    let ps = cell(cells, "priority+shed", peak);
    let notes = vec![
        format!(
            "at the overloaded load (λ={peak}/s) priority+shed keeps {} \
             high-priority goodput tokens under SLO vs FIFO's {} — the \
             governor sheds {} low-priority sessions at the door instead \
             of letting {} sessions queue past their patience",
            ps.open_loop.goodput_high,
            fifo.open_loop.goodput_high,
            ps.open_loop.shed,
            fifo.open_loop.abandoned
        ),
        format!(
            "every cell runs under stochastic fault injection (60 s MTBF \
             kills/drains, 15 s MTTR) — e.g. the overloaded FIFO cell \
             absorbed {} injected faults",
            fifo.faults.stochastic_injected
        ),
        "identical session populations and fault seeds across policies: \
         only the door policy differs within a column group"
            .into(),
    ];

    ExpOutput {
        name: "openloop",
        title: "Open-loop traffic: admission policy x offered load".into(),
        table,
        figures: vec![],
        notes,
    }
}

/// Run the study and write `BENCH_openloop.json` (path overridable via
/// `BENCH_OPENLOOP_PATH`).
pub fn run() -> Result<ExpOutput> {
    let cells = run_sweep()?;
    let path = std::env::var("BENCH_OPENLOOP_PATH")
        .unwrap_or_else(|_| "BENCH_openloop.json".to_string());
    std::fs::write(&path, format!("{}\n", bench_json(&cells).to_string_pretty()))?;
    let mut out = output_from(&cells);
    out.notes.push(format!("machine-readable results written to {path}"));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_jobs_validate_for_every_cell() {
        for &policy in &POLICIES {
            for &rate in &LOADS {
                base_job(policy, rate).validate().unwrap();
            }
        }
    }

    #[test]
    fn traffic_shapes_differ_only_at_the_door() {
        for &rate in &LOADS {
            let fifo = traffic_for("fifo", rate);
            let prio = traffic_for("priority", rate);
            let ps = traffic_for("priority+shed", rate);
            assert!(!fifo.priority_admission && !fifo.shed);
            assert!(prio.priority_admission && !prio.shed);
            assert!(ps.priority_admission && ps.shed);
            // Same arrivals, patience, SLOs and seed within the group.
            let arrivals = |c: OpenLoopConfig| {
                (c.arrival_rate_per_s, c.patience_s, c.slo_ttft_s, c.slo_step_s, c.seed)
            };
            assert_eq!(arrivals(fifo), arrivals(prio));
            assert_eq!(arrivals(prio), arrivals(ps));
        }
    }

    #[test]
    #[should_panic(expected = "unknown admission policy")]
    fn unknown_policy_panics() {
        base_job("meteor", 1.0);
    }
}
