//! Cluster scaling study: throughput and lifetime hit rate across
//! data-parallel replica counts × routing policies.
//!
//! Not a paper artifact — this opens the data-parallel scenario axis the
//! ROADMAP calls for: a fixed offered load (128 Qwen3-class agents, CONCUR
//! admission) served by 1/2/4/8 TP2 engine replicas under each router.
//! The question the grid answers is the KVFlow observation: *where* an
//! agent's steps land relative to its warm prefix dominates throughput, so
//! cache-affinity routing should beat pure load balancing on hit rate as
//! soon as there is more than one replica to be wrong about.
//!
//! Run via `concur repro cluster` or the `replica_sweep` example; both
//! emit `BENCH_cluster.json` for the nightly perf trajectory (and for
//! the CI determinism job, which diffs two runs of it — override the
//! repro path with `BENCH_CLUSTER_PATH`, the example's with
//! `BENCH_JSON_PATH`).

use std::collections::BTreeMap;

use crate::config::presets;
use crate::config::{AimdParams, EngineConfig, JobConfig, RouterKind, SchedulerKind, TopologyConfig};
use crate::core::json::Value;
use crate::core::Result;
use crate::driver::RunResult;
use crate::metrics::Table;

use super::{run_systems, ExpOutput};

pub const REPLICAS: [usize; 4] = [1, 2, 4, 8];
pub const ROUTERS: [RouterKind; 3] = [
    RouterKind::RoundRobin,
    RouterKind::LeastLoaded,
    RouterKind::CacheAffinity,
];

/// Offered load held fixed across the grid so replica count is the only
/// capacity axis.
pub const SWEEP_AGENTS: usize = 128;

/// One grid cell: a (replica count, router) pair and its run.
pub struct Cell {
    pub replicas: usize,
    pub router: RouterKind,
    pub result: RunResult,
}

/// The full grid, row-major (replicas outer, routers inner).
pub fn sweep_jobs() -> Vec<JobConfig> {
    REPLICAS
        .iter()
        .flat_map(|&replicas| {
            ROUTERS.iter().map(move |&router| JobConfig {
                cluster: presets::qwen3_cluster(2),
                engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
                workload: presets::qwen3_workload(SWEEP_AGENTS),
                scheduler: SchedulerKind::Concur(AimdParams::default()),
                topology: TopologyConfig { replicas, router, ..TopologyConfig::default() },
            })
        })
        .collect()
}

/// Run the whole grid (fanned out across cores) and label the cells.
pub fn run_sweep() -> Result<Vec<Cell>> {
    let results = run_systems(sweep_jobs())?;
    Ok(REPLICAS
        .iter()
        .flat_map(|&replicas| ROUTERS.iter().map(move |&router| (replicas, router)))
        .zip(results)
        .map(|((replicas, router), result)| Cell { replicas, router, result })
        .collect())
}

/// Machine-readable sweep dump (`BENCH_cluster.json`): one entry per cell,
/// keyed `r{replicas}/{router}`.
pub fn bench_json(cells: &[Cell]) -> Value {
    let mut map: BTreeMap<String, Value> = BTreeMap::new();
    for c in cells {
        let mut entry: BTreeMap<String, Value> = BTreeMap::new();
        entry.insert(
            "latency_s".into(),
            Value::Number(c.result.total_time.as_secs_f64()),
        );
        entry.insert(
            "throughput_tps".into(),
            Value::Number(c.result.throughput_tps),
        );
        entry.insert("hit_rate".into(), Value::Number(c.result.hit_rate));
        entry.insert("pauses".into(), Value::Number(c.result.pauses as f64));
        map.insert(format!("r{}/{}", c.replicas, c.router.name()), Value::Object(entry));
    }
    Value::Object(map)
}

fn cell(cells: &[Cell], replicas: usize, router: RouterKind) -> &RunResult {
    &cells
        .iter()
        .find(|c| c.replicas == replicas && c.router == router)
        .expect("complete grid")
        .result
}

/// Render the grid as a repro table with scaling notes.
pub fn output_from(cells: &[Cell]) -> ExpOutput {
    let mut table = Table::new(
        "Cluster scaling: throughput (tok/s) and lifetime hit rate (%) \
         across replicas x router",
    )
    .header(&[
        "Replicas",
        "rr tok/s",
        "rr hit%",
        "ll tok/s",
        "ll hit%",
        "ca tok/s",
        "ca hit%",
    ]);

    for &n in &REPLICAS {
        let rr = cell(cells, n, RouterKind::RoundRobin);
        let ll = cell(cells, n, RouterKind::LeastLoaded);
        let ca = cell(cells, n, RouterKind::CacheAffinity);
        table.row(vec![
            n.to_string(),
            format!("{:.0}", rr.throughput_tps),
            format!("{:.1}", rr.hit_rate * 100.0),
            format!("{:.0}", ll.throughput_tps),
            format!("{:.1}", ll.hit_rate * 100.0),
            format!("{:.0}", ca.throughput_tps),
            format!("{:.1}", ca.hit_rate * 100.0),
        ]);
    }

    let max_n = REPLICAS[REPLICAS.len() - 1];
    let ca_1 = cell(cells, 1, RouterKind::CacheAffinity);
    let ca_max = cell(cells, max_n, RouterKind::CacheAffinity);
    let ll_max = cell(cells, max_n, RouterKind::LeastLoaded);
    let notes = vec![
        format!(
            "cache-affinity throughput scales {:.2}x from 1 to {} replicas \
             at fixed offered load",
            ca_max.throughput_tps / ca_1.throughput_tps,
            max_n
        ),
        format!(
            "at {} replicas, cache-affinity hit rate {:.1}% vs least-loaded \
             {:.1}% ({:+.1} points): pinning beats balancing once there is \
             a warm prefix to lose",
            max_n,
            ca_max.hit_rate * 100.0,
            ll_max.hit_rate * 100.0,
            (ca_max.hit_rate - ll_max.hit_rate) * 100.0
        ),
        "routers only differ for N>1: the N=1 row is a three-way control".into(),
    ];

    ExpOutput {
        name: "cluster",
        title: "Data-parallel cluster scaling (replicas x router)".into(),
        table,
        figures: vec![],
        notes,
    }
}

pub fn run() -> Result<ExpOutput> {
    let cells = run_sweep()?;
    // Emit the machine-readable dump alongside the table: the CI
    // determinism job runs `concur repro cluster` at two CONCUR_WORKERS
    // settings and byte-diffs this file, and the nightly perf trajectory
    // archives it.  Override the path with BENCH_CLUSTER_PATH.
    let path = std::env::var("BENCH_CLUSTER_PATH")
        .unwrap_or_else(|_| "BENCH_cluster.json".to_string());
    std::fs::write(&path, format!("{}\n", bench_json(&cells).to_string_pretty()))?;
    Ok(output_from(&cells))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_replicas_times_routers() {
        let jobs = sweep_jobs();
        assert_eq!(jobs.len(), REPLICAS.len() * ROUTERS.len());
        for j in &jobs {
            j.validate().unwrap();
        }
        assert_eq!(jobs[0].topology.replicas, 1);
        assert_eq!(jobs.last().unwrap().topology.replicas, 8);
        assert_eq!(jobs.last().unwrap().topology.router, RouterKind::CacheAffinity);
    }
}
