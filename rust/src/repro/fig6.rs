//! Figure 6: fixed-size vs cache-aware adaptive admission control.
//! Qwen3-32B, batch 256, TP2.

use crate::config::presets;
use crate::config::{AimdParams, EvictionMode, SchedulerKind};
use crate::core::Result;
use crate::metrics::Table;

use super::{cell_latency, run_systems, system_job, ExpOutput};

pub fn run() -> Result<ExpOutput> {
    let cluster = presets::qwen3_cluster(2);
    let workload = presets::qwen3_workload(256);

    // Uncontrolled + every fixed level + CONCUR: one parallel batch.
    let mut jobs = vec![system_job(
        cluster.clone(),
        workload.clone(),
        SchedulerKind::Uncontrolled,
        EvictionMode::Discard,
    )];
    for level in presets::FIG6_FIXED_LEVELS {
        jobs.push(system_job(
            cluster.clone(),
            workload.clone(),
            SchedulerKind::AgentCap(level),
            EvictionMode::Discard,
        ));
    }
    jobs.push(system_job(
        cluster,
        workload,
        SchedulerKind::Concur(AimdParams::default()),
        EvictionMode::Discard,
    ));
    let mut results = run_systems(jobs)?;
    let conc = results.pop().expect("last job is CONCUR");
    let fixed = results.split_off(1);
    let base = results.pop().expect("first job is uncontrolled");
    let b = base.total_time.as_secs_f64();

    let mut table = Table::new(
        "Fig 6: end-to-end latency, fixed admission levels vs CONCUR",
    )
    .header(&["Policy", "Latency (s)", "Hit rate", "Recompute share"]);
    table.row(vec![
        "uncontrolled".into(),
        cell_latency(b, b),
        format!("{:.1}%", base.hit_rate * 100.0),
        format!(
            "{:.1}%",
            base.breakdown.fraction(crate::metrics::Phase::Recompute) * 100.0
        ),
    ]);

    let mut best_fixed = f64::INFINITY;
    for (level, r) in presets::FIG6_FIXED_LEVELS.iter().zip(&fixed) {
        let lat = r.total_time.as_secs_f64();
        best_fixed = best_fixed.min(lat);
        table.row(vec![
            format!("fixed {level}"),
            cell_latency(lat, b),
            format!("{:.1}%", r.hit_rate * 100.0),
            format!(
                "{:.1}%",
                r.breakdown.fraction(crate::metrics::Phase::Recompute) * 100.0
            ),
        ]);
    }

    let clat = conc.total_time.as_secs_f64();
    table.row(vec![
        "CONCUR (adaptive)".into(),
        cell_latency(clat, b),
        format!("{:.1}%", conc.hit_rate * 100.0),
        format!(
            "{:.1}%",
            conc.breakdown.fraction(crate::metrics::Phase::Recompute) * 100.0
        ),
    ]);

    Ok(ExpOutput {
        name: "fig6",
        title: "Static vs cache-aware admission control (Qwen3 batch 256 TP2)".into(),
        table,
        figures: vec![],
        notes: vec![
            format!(
                "CONCUR {:.0}s vs best fixed {:.0}s ({:.2}x better; paper: 1.5-2.9x \
                 over the best fixed level) and {:.2}x over uncontrolled (paper 2.99x)",
                clat,
                best_fixed,
                best_fixed / clat,
                b / clat
            ),
            "small fixed levels underutilize; large ones thrash — the fixed-cap \
             U-shape brackets CONCUR from both sides"
                .into(),
        ],
    })
}
