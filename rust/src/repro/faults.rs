//! Fault-tolerance study (`concur repro cluster_faults`): throughput,
//! hit rate and continuity under replica loss, across routing policies.
//!
//! Not a paper artifact — this opens the fault/skew realism axis the
//! ROADMAP calls for.  A fixed offered load (96 Qwen3-class agents,
//! CONCUR admission, 4 TP2 replicas) is disrupted four ways:
//!
//! * `healthy`      — control row, no faults;
//! * `kill`         — replica 0 dies mid-run and stays dead;
//! * `kill-revive`  — replica 0 dies mid-run and rejoins (empty) later;
//! * `drain`        — replica 0 drains mid-run, then refills.
//!
//! Each disruption runs under least-loaded, cache-affinity and rebalance
//! routing on bit-identical fault timelines (instants are anchored to
//! the shortest healthy makespan so "mid-run" stays mid-run for every
//! router).  The question the grid answers is the KVFlow/Continuum one
//! extended to failures: *which* agents keep cache residency through a
//! disruption dominates recovery throughput, so cold-first re-homing
//! (rebalance) should beat load-only balancing (least-loaded) once a
//! replica dies — `tests/faults_integration.rs` pins that claim.
//!
//! The sweep also writes `BENCH_faults.json` (override the path with
//! `BENCH_FAULTS_PATH`) so the nightly CI job can archive the
//! fault-recovery trajectory next to `BENCH_cluster.json`.

use std::collections::BTreeMap;

use crate::config::presets;
use crate::config::{
    AimdParams, EngineConfig, FaultEvent, FaultPlan, JobConfig, RouterKind, SchedulerKind,
    TopologyConfig,
};
use crate::core::json::Value;
use crate::core::{Micros, Result};
use crate::driver::RunResult;
use crate::metrics::Table;

use super::{run_systems, ExpOutput};

/// Routers compared on every disruption.
pub const ROUTERS: [RouterKind; 3] =
    [RouterKind::LeastLoaded, RouterKind::CacheAffinity, RouterKind::Rebalance];

/// Disruption scenarios, in table order.
pub const SCENARIOS: [&str; 4] = ["healthy", "kill", "kill-revive", "drain"];

/// Replicas in the fleet (replica 0 is the disrupted one).
pub const REPLICAS: usize = 4;

/// Offered load held fixed across the grid.
pub const SWEEP_AGENTS: usize = 96;

/// One grid cell: a (scenario, router) pair and its run.
pub struct FaultCell {
    pub scenario: &'static str,
    pub router: RouterKind,
    pub result: RunResult,
}

/// The repro-standard job for one router (healthy topology).
pub fn base_job(router: RouterKind, agents: usize) -> JobConfig {
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: presets::qwen3_workload(agents),
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig { replicas: REPLICAS, router, ..TopologyConfig::default() },
    }
}

/// Build the fault plan for a scenario, anchored to a healthy makespan:
/// kill/drain fire at 40% of it, the revive at 70%.  Anchoring keeps the
/// disruption mid-run as the workload evolves, and using one shared
/// anchor gives every router the identical failure timeline.
pub fn plan_for(scenario: &str, anchor: Micros, replica: usize) -> FaultPlan {
    let at = |f: f64| Micros((anchor.0 as f64 * f) as u64);
    match scenario {
        "healthy" => FaultPlan::none(),
        "kill" => FaultPlan::new(vec![FaultEvent::kill(replica, at(0.4))]),
        "kill-revive" => FaultPlan::new(vec![
            FaultEvent::kill(replica, at(0.4)),
            FaultEvent::revive(replica, at(0.7)),
        ]),
        "drain" => FaultPlan::new(vec![FaultEvent::drain(replica, at(0.4))]),
        other => panic!("unknown fault scenario '{other}'"),
    }
}

/// Run the whole grid: healthy probes first (they double as the
/// `healthy` row and provide the anchor), then the disruptions, fanned
/// out across cores.
pub fn run_sweep(agents: usize) -> Result<Vec<FaultCell>> {
    let healthy = run_systems(ROUTERS.iter().map(|&r| base_job(r, agents)).collect())?;
    let anchor = healthy.iter().map(|r| r.total_time).min().expect("non-empty grid");

    let mut cells: Vec<FaultCell> = ROUTERS
        .iter()
        .zip(healthy)
        .map(|(&router, result)| FaultCell { scenario: "healthy", router, result })
        .collect();

    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for &scenario in SCENARIOS.iter().skip(1) {
        for &router in &ROUTERS {
            let mut job = base_job(router, agents);
            job.topology.fault_plan = plan_for(scenario, anchor, 0);
            labels.push((scenario, router));
            jobs.push(job);
        }
    }
    for ((scenario, router), result) in labels.into_iter().zip(run_systems(jobs)?) {
        cells.push(FaultCell { scenario, router, result });
    }
    Ok(cells)
}

/// Machine-readable sweep dump (`BENCH_faults.json`): one entry per
/// cell, keyed `{scenario}/{router}`.
pub fn bench_json(cells: &[FaultCell]) -> Value {
    let mut map: BTreeMap<String, Value> = BTreeMap::new();
    for c in cells {
        let mut entry: BTreeMap<String, Value> = BTreeMap::new();
        entry.insert("latency_s".into(), Value::Number(c.result.total_time.as_secs_f64()));
        entry.insert("throughput_tps".into(), Value::Number(c.result.throughput_tps));
        entry.insert("hit_rate".into(), Value::Number(c.result.hit_rate));
        entry.insert("kills".into(), Value::Number(c.result.faults.kills as f64));
        entry.insert("refills".into(), Value::Number(c.result.faults.refills as f64));
        entry.insert(
            "requeued_agents".into(),
            Value::Number(c.result.faults.requeued_agents as f64),
        );
        entry.insert("migrations".into(), Value::Number(c.result.faults.migrations as f64));
        map.insert(format!("{}/{}", c.scenario, c.router.name()), Value::Object(entry));
    }
    Value::Object(map)
}

fn cell<'a>(cells: &'a [FaultCell], scenario: &str, router: RouterKind) -> &'a RunResult {
    &cells
        .iter()
        .find(|c| c.scenario == scenario && c.router == router)
        .expect("complete grid")
        .result
}

/// Render the grid as a repro table with recovery notes.
pub fn output_from(cells: &[FaultCell]) -> ExpOutput {
    let mut table = Table::new(
        "Fault tolerance: throughput (tok/s) and lifetime hit rate (%) \
         across disruption x router",
    )
    .header(&[
        "Scenario",
        "ll tok/s",
        "ll hit%",
        "ca tok/s",
        "ca hit%",
        "rb tok/s",
        "rb hit%",
    ]);

    for &scenario in &SCENARIOS {
        let ll = cell(cells, scenario, RouterKind::LeastLoaded);
        let ca = cell(cells, scenario, RouterKind::CacheAffinity);
        let rb = cell(cells, scenario, RouterKind::Rebalance);
        table.row(vec![
            scenario.to_string(),
            format!("{:.0}", ll.throughput_tps),
            format!("{:.1}", ll.hit_rate * 100.0),
            format!("{:.0}", ca.throughput_tps),
            format!("{:.1}", ca.hit_rate * 100.0),
            format!("{:.0}", rb.throughput_tps),
            format!("{:.1}", rb.hit_rate * 100.0),
        ]);
    }

    let rb_kill = cell(cells, "kill", RouterKind::Rebalance);
    let ll_kill = cell(cells, "kill", RouterKind::LeastLoaded);
    let rb_drain = cell(cells, "drain", RouterKind::Rebalance);
    let notes = vec![
        format!(
            "under a mid-run kill, cold-first re-homing (rebalance) delivers \
             {:.2}x the throughput of least-loaded balancing ({:.0} vs {:.0} \
             tok/s): pins survive on the {} healthy replicas and only \
             stale-cache agents carry the rebalancing",
            rb_kill.throughput_tps / ll_kill.throughput_tps,
            rb_kill.throughput_tps,
            ll_kill.throughput_tps,
            REPLICAS - 1
        ),
        format!(
            "drain-and-refill preserves continuity: {} agents requeued \
             (vs {} on the kill row) and the drained replica refilled {} \
             time(s)",
            rb_drain.faults.requeued_agents,
            rb_kill.faults.requeued_agents,
            rb_drain.faults.refills
        ),
        "disruptions hit replica 0 on identical timelines for every \
         router (anchored to the shortest healthy makespan)"
            .into(),
    ];

    ExpOutput {
        name: "cluster_faults",
        title: "Fault-tolerant fleet: disruption x router".into(),
        table,
        figures: vec![],
        notes,
    }
}

/// Run the study and write `BENCH_faults.json` (path overridable via
/// `BENCH_FAULTS_PATH`).
pub fn run() -> Result<ExpOutput> {
    let cells = run_sweep(SWEEP_AGENTS)?;
    let path = std::env::var("BENCH_FAULTS_PATH")
        .unwrap_or_else(|_| "BENCH_faults.json".to_string());
    std::fs::write(&path, format!("{}\n", bench_json(&cells).to_string_pretty()))?;
    let mut out = output_from(&cells);
    out.notes.push(format!("machine-readable results written to {path}"));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_jobs_validate_for_every_router() {
        for &router in &ROUTERS {
            base_job(router, SWEEP_AGENTS).validate().unwrap();
        }
    }

    #[test]
    fn plans_validate_against_the_fleet() {
        let anchor = Micros(600_000_000);
        for &scenario in &SCENARIOS {
            let plan = plan_for(scenario, anchor, 0);
            plan.validate(REPLICAS).unwrap();
            assert_eq!(plan.is_empty(), scenario == "healthy");
        }
        let kr = plan_for("kill-revive", anchor, 0);
        assert_eq!(kr.events().len(), 2);
        assert_eq!(kr.events()[0].at, Micros(240_000_000));
        assert_eq!(kr.events()[1].at, Micros(420_000_000));
    }

    #[test]
    #[should_panic(expected = "unknown fault scenario")]
    fn unknown_scenario_panics() {
        plan_for("meteor", Micros(1), 0);
    }
}
