//! Table 2: lifetime KV-cache hit rate (%) under varying batch size for
//! DeepSeek-V3.
//!
//! The paper runs this at TP=8 on 8 GPUs; DeepSeek-V3's fp8 weights
//! (~671 GB) do not fit 8x80GB H100s in our memory model, so we use the
//! Table-1 cluster (TP16) — the batch sweep and system ordering are the
//! reproduction target (noted in EXPERIMENTS.md).

use crate::config::presets;
use crate::config::{AimdParams, EvictionMode, SchedulerKind};
use crate::core::Result;
use crate::metrics::Table;

use super::{run_systems, system_job, ExpOutput};

pub const BATCHES: [usize; 3] = [16, 32, 40];

pub fn run() -> Result<ExpOutput> {
    let mut table = Table::new("Table 2: KV cache hit rate (%), DeepSeek-V3")
        .header(&[
            "Batch",
            "SGLang (%)",
            "w/ HiCache (%)",
            "w/ Request Control (%)",
            "CONCUR (%)",
        ]);

    // 3 batches x 4 systems, fanned out across cores.
    let mut jobs = Vec::new();
    for batch in BATCHES {
        let cluster = presets::dsv3_cluster(16);
        let workload = presets::dsv3_workload(batch);
        let cap = super::table1::request_cap_for(batch);
        jobs.push(system_job(
            cluster.clone(),
            workload.clone(),
            SchedulerKind::Uncontrolled,
            EvictionMode::Discard,
        ));
        jobs.push(system_job(
            cluster.clone(),
            workload.clone(),
            SchedulerKind::Uncontrolled,
            EvictionMode::Offload,
        ));
        jobs.push(system_job(
            cluster.clone(),
            workload.clone(),
            SchedulerKind::RequestCap(cap),
            EvictionMode::Discard,
        ));
        jobs.push(system_job(
            cluster,
            workload,
            SchedulerKind::Concur(AimdParams::default()),
            EvictionMode::Discard,
        ));
    }
    let results = run_systems(jobs)?;

    let mut sglang_rates = Vec::new();
    let mut concur_rates = Vec::new();
    let mut hicache_rates = Vec::new();
    for (r, batch) in results.chunks(4).zip(BATCHES) {
        let [base, hic, reqc, conc] = r else { unreachable!("4 systems per batch") };
        sglang_rates.push(base.hit_rate);
        concur_rates.push(conc.hit_rate);
        hicache_rates.push(hic.hit_rate);
        table.row(vec![
            batch.to_string(),
            format!("{:.2}", base.hit_rate * 100.0),
            format!("{:.2}", hic.hit_rate * 100.0),
            format!("{:.2}", reqc.hit_rate * 100.0),
            format!("{:.2}", conc.hit_rate * 100.0),
        ]);
    }

    let sglang_drop = sglang_rates.first().copied().unwrap_or(0.0)
        - sglang_rates.last().copied().unwrap_or(0.0);
    Ok(ExpOutput {
        name: "table2",
        title: "KV cache hit rate under varying batch sizes (DeepSeek-V3)".into(),
        table,
        figures: vec![],
        notes: vec![
            format!(
                "SGLang hit rate collapses as batch grows (drop of {:.0} points; \
                 paper: 80.4% -> 35.4%)",
                sglang_drop * 100.0
            ),
            format!(
                "HiCache retains the highest hit rates ({:.0}-{:.0}%; paper 96-97%) \
                 yet loses on latency (Table 1) — hits are not free over PCIe",
                hicache_rates.iter().cloned().fold(f64::INFINITY, f64::min) * 100.0,
                hicache_rates.iter().cloned().fold(0.0, f64::max) * 100.0
            ),
            format!(
                "CONCUR sustains high hit rates at every batch ({:.0}-{:.0}%; \
                 paper 73-96%)",
                concur_rates.iter().cloned().fold(f64::INFINITY, f64::min) * 100.0,
                concur_rates.iter().cloned().fold(0.0, f64::max) * 100.0
            ),
            "run at TP16 (fp8 DSV3 weights cannot shard onto 8x80GB in our model)"
                .into(),
        ],
    })
}
