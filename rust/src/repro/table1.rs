//! Table 1: end-to-end latency and speedup of offline agentic inference
//! under increasing effective concurrency.
//!
//! Paper rows: Qwen3-32B at batch 256 / TP {8,4,2}; DeepSeek-V3 at batch
//! {16,32,40} / TP16.  Systems: SGLang, SGLang w/ request-level control,
//! SGLang w/ HiCache, CONCUR.

use crate::config::presets;
use crate::config::{AimdParams, EvictionMode, SchedulerKind};
use crate::core::Result;
use crate::metrics::Table;

use super::{cell_latency, run_systems, system_job, ExpOutput};

/// (model label, batch, tp) rows exactly as in the paper.
pub const ROWS: [(&str, usize, u32); 6] = [
    ("Qwen3-32B", 256, 8),
    ("Qwen3-32B", 256, 4),
    ("Qwen3-32B", 256, 2),
    ("DeepSeek-V3", 16, 16),
    ("DeepSeek-V3", 32, 16),
    ("DeepSeek-V3", 40, 16),
];

/// Request-level cap used for the "Request Control" column (the paper does
/// not state its value; batch/4 reproduces its mixed help/hurt behaviour).
pub fn request_cap_for(batch: usize) -> usize {
    (batch / 4).max(4)
}

pub fn run() -> Result<ExpOutput> {
    let mut table = Table::new(
        "Table 1: end-to-end latency (s) and speedup vs SGLang",
    )
    .header(&[
        "Model",
        "Batch / TP / #GPU",
        "SGLang (s)",
        "w/ Request Control (s)",
        "w/ HiCache (s)",
        "CONCUR (s)",
    ]);

    // Build the whole 6x4 grid up front and fan it out across cores.
    let mut jobs = Vec::new();
    for (model, batch, tp) in ROWS {
        let (cluster, workload) = if model.starts_with("Qwen3") {
            (presets::qwen3_cluster(tp), presets::qwen3_workload(batch))
        } else {
            (presets::dsv3_cluster(tp), presets::dsv3_workload(batch))
        };
        let cap = request_cap_for(batch);
        jobs.push(system_job(
            cluster.clone(),
            workload.clone(),
            SchedulerKind::Uncontrolled,
            EvictionMode::Discard,
        ));
        jobs.push(system_job(
            cluster.clone(),
            workload.clone(),
            SchedulerKind::RequestCap(cap),
            EvictionMode::Discard,
        ));
        jobs.push(system_job(
            cluster.clone(),
            workload.clone(),
            SchedulerKind::Uncontrolled,
            EvictionMode::Offload,
        ));
        jobs.push(system_job(
            cluster,
            workload,
            SchedulerKind::Concur(AimdParams::default()),
            EvictionMode::Discard,
        ));
    }
    let results = run_systems(jobs)?;

    let mut concur_wins = 0usize;
    for (r, (model, batch, tp)) in results.chunks(4).zip(ROWS) {
        let [base, reqc, hic, conc] = r else { unreachable!("4 systems per row") };
        let b = base.total_time.as_secs_f64();
        let all = [
            b,
            reqc.total_time.as_secs_f64(),
            hic.total_time.as_secs_f64(),
            conc.total_time.as_secs_f64(),
        ];
        if all[3] <= all.iter().cloned().fold(f64::INFINITY, f64::min) + 1e-9 {
            concur_wins += 1;
        }
        table.row(vec![
            model.to_string(),
            format!("{batch} / {tp} / {tp}"),
            cell_latency(all[0], b),
            cell_latency(all[1], b),
            cell_latency(all[2], b),
            cell_latency(all[3], b),
        ]);
    }

    Ok(ExpOutput {
        name: "table1",
        title: "End-to-end latency under increasing effective concurrency".into(),
        table,
        figures: vec![],
        notes: vec![
            format!("CONCUR has the lowest latency in {concur_wins}/6 rows (paper: 6/6)"),
            "gains widen as TP decreases (per-GPU concurrency rises)".into(),
            "request-level control can be worse than no control (paper: Qwen3 TP8 row)"
                .into(),
            "HiCache helps Qwen3 but collapses on DeepSeek-V3's 6x larger KV/token"
                .into(),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_cap_scales_with_batch() {
        assert_eq!(request_cap_for(256), 64);
        assert_eq!(request_cap_for(16), 4);
    }
}
