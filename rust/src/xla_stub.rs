//! Minimal stand-in for the `xla` (PJRT) bindings.
//!
//! The container builds fully offline with zero external crates, so the
//! real PJRT bindings are not available.  This shim provides the exact API
//! surface `runtime`/`server` consume so those layers keep type-checking
//! and the CLI fails with an actionable message at `PjRtClient::cpu()`
//! instead of at compile time.  Every simulator path (`concur sim`,
//! `concur repro`, all benches and examples except `agentic_serve`) is
//! unaffected — it never touches this module.
//!
//! Consumers import it as `use crate::xla_stub as xla;`, so swapping the
//! real bindings back in is a one-line change per file.

use std::path::Path;

/// Error type mirroring `xla::Error` (Display + std::error::Error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "the xla/PJRT backend is not vendored in this build; the simulator \
         path (`concur sim` / `concur repro`) is fully functional"
            .to_string(),
    ))
}

/// Host literal (opaque: no data survives without a real backend).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle; construction always fails in this build.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("not vendored"));
    }

    #[test]
    fn literal_shape_ops_are_inert() {
        let lit = Literal::vec1(&[1f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
