//! PJRT runtime bridge: loads the AOT-compiled HLO graphs (lowered once by
//! `python/compile/aot.py` from the L2 JAX model + L1 Pallas kernels) and
//! executes them on the request path.  Python is never involved here.
//!
//! HLO **text** is the interchange format — jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and aot.py).
//!
//! Model parameters are uploaded to the device once at load time and reused
//! across every call; KV caches round-trip as literals per step (CPU PJRT —
//! host copies are memcpy-cheap at tiny-model scale).
//!
//! The offline build has no PJRT bindings; `crate::xla_stub` provides the
//! same API and fails with a clear message at client construction, so this
//! layer stays compiled and the simulator path is unaffected.

pub mod artifacts;

pub use artifacts::{ArtifactKind, Manifest, ModelGeometry};

use std::collections::HashMap;

use crate::core::{ConcurError, Result};
use crate::xla_stub as xla;

/// KV cache state for one compiled batch variant, owned by the caller
/// between steps.  Shapes: `[L, B, T, H, D]` f32.
pub struct KvState {
    pub k: xla::Literal,
    pub v: xla::Literal,
    pub lens: Vec<i32>,
    pub batch: usize,
}

/// Output of one graph invocation.
pub struct StepOutput {
    /// `[B, vocab]` next-token logits (row-major).
    pub logits: Vec<f32>,
    pub vocab: usize,
}

impl StepOutput {
    /// Greedy argmax for row `b`.
    pub fn argmax(&self, b: usize) -> u32 {
        let row = self.row(b);
        let mut best = 0usize;
        for (i, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = i;
            }
        }
        best as u32
    }

    pub fn row(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }
}

/// The loaded model: PJRT client + compiled executables + device params.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    params: xla::PjRtBuffer,
    exes: HashMap<(ArtifactKind, usize), xla::PjRtLoadedExecutable>,
}

impl ModelRuntime {
    /// Load every artifact in `dir`, compile, and upload parameters.
    pub fn load(dir: &std::path::Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let params_host = manifest.load_params()?;
        let params_lit = xla::Literal::vec1(&params_host);
        let params = client.buffer_from_host_literal(None, &params_lit)?;

        let mut exes = HashMap::new();
        for entry in manifest.artifacts.clone() {
            let path = manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert((entry.kind, entry.batch), exe);
        }
        Ok(ModelRuntime { manifest, client, params, exes })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<ModelRuntime> {
        ModelRuntime::load(&artifacts::default_dir())
    }

    pub fn geometry(&self) -> &ModelGeometry {
        &self.manifest.model
    }

    /// Fresh zeroed KV state for a batch variant.
    pub fn new_state(&self, batch: usize) -> Result<KvState> {
        let g = &self.manifest.model;
        let n = g.n_layers * batch * g.max_seq * g.n_heads * g.head_dim;
        let dims: Vec<i64> = vec![
            g.n_layers as i64,
            batch as i64,
            g.max_seq as i64,
            g.n_heads as i64,
            g.head_dim as i64,
        ];
        let zeros = vec![0f32; n];
        let k = xla::Literal::vec1(&zeros).reshape(&dims)?;
        let v = xla::Literal::vec1(&zeros).reshape(&dims)?;
        Ok(KvState { k, v, lens: vec![0; batch], batch })
    }

    fn exe(&self, kind: ArtifactKind, batch: usize) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes.get(&(kind, batch)).ok_or_else(|| {
            ConcurError::runtime(format!(
                "no compiled {kind:?} graph for batch {batch} \
                 (available: {:?})",
                self.manifest.batches(kind)
            ))
        })
    }

    fn run(
        &self,
        kind: ArtifactKind,
        state: &mut KvState,
        tokens: xla::Literal,
        chunk_lens: Option<xla::Literal>,
    ) -> Result<StepOutput> {
        let g = &self.manifest.model;
        let exe = self.exe(kind, state.batch)?;

        // Input order (manifest): params, tokens, k, v, cache_lens[, chunk_lens].
        // The params buffer is device-resident and reused across calls; the
        // rest are uploaded per step (CPU PJRT: memcpy).
        let lens_lit = xla::Literal::vec1(&state.lens);
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(5);
        owned.push(self.client.buffer_from_host_literal(None, &tokens)?);
        owned.push(self.client.buffer_from_host_literal(None, &state.k)?);
        owned.push(self.client.buffer_from_host_literal(None, &state.v)?);
        owned.push(self.client.buffer_from_host_literal(None, &lens_lit)?);
        if let Some(cl) = &chunk_lens {
            owned.push(self.client.buffer_from_host_literal(None, cl)?);
        }
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(6);
        bufs.push(&self.params);
        bufs.extend(owned.iter());

        let result = exe.execute_b(&bufs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let mut parts = tuple.to_tuple()?;
        if parts.len() != 4 {
            return Err(ConcurError::runtime(format!(
                "expected 4 outputs, got {}",
                parts.len()
            )));
        }
        let lens_out = parts.pop().unwrap();
        let v_out = parts.pop().unwrap();
        let k_out = parts.pop().unwrap();
        let logits = parts.pop().unwrap();
        state.k = k_out;
        state.v = v_out;
        state.lens = lens_out.to_vec::<i32>()?;
        Ok(StepOutput { logits: logits.to_vec::<f32>()?, vocab: g.vocab })
    }

    /// One decode step: `tokens[b]` is the previous token of sequence `b`.
    pub fn decode_step(&self, state: &mut KvState, tokens: &[u32]) -> Result<StepOutput> {
        if tokens.len() != state.batch {
            return Err(ConcurError::runtime("tokens length != batch"));
        }
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_lit = xla::Literal::vec1(&toks);
        self.run(ArtifactKind::Decode, state, tok_lit, None)
    }

    /// One extend (chunked prefill) step.  `tokens` is `[B, C]` row-major,
    /// right-padded; `chunk_lens[b]` is the number of valid tokens (0 for
    /// idle batch rows — they write garbage beyond their valid length,
    /// which attention masking keeps invisible).
    pub fn extend_chunk(
        &self,
        state: &mut KvState,
        tokens: &[u32],
        chunk_lens: &[i32],
    ) -> Result<StepOutput> {
        let chunk = self.extend_chunk_size(state.batch)?;
        if tokens.len() != state.batch * chunk {
            return Err(ConcurError::runtime(format!(
                "tokens must be B*C = {}, got {}",
                state.batch * chunk,
                tokens.len()
            )));
        }
        if chunk_lens.len() != state.batch {
            return Err(ConcurError::runtime("chunk_lens length != batch"));
        }
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_lit =
            xla::Literal::vec1(&toks).reshape(&[state.batch as i64, chunk as i64])?;
        let chunk_lit = xla::Literal::vec1(chunk_lens);
        self.run(ArtifactKind::Extend, state, tok_lit, Some(chunk_lit))
    }

    /// Chunk size of the extend graph for a batch.
    pub fn extend_chunk_size(&self, batch: usize) -> Result<usize> {
        self.manifest
            .entry(ArtifactKind::Extend, batch)
            .map(|e| e.chunk)
            .ok_or_else(|| ConcurError::runtime("no extend graph for batch"))
    }

    /// Smallest compiled batch >= `n`, or the largest available.
    pub fn pick_batch(&self, n: usize) -> usize {
        let batches = self.manifest.batches(ArtifactKind::Decode);
        batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| batches.last().copied().unwrap_or(1))
    }
}
