//! AOT artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.

use std::path::{Path, PathBuf};

use crate::core::json::Value;
use crate::core::{ConcurError, Result};

/// Geometry of the compiled tiny model (mirrors `model.ModelConfig`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelGeometry {
    pub vocab: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub n_params: usize,
}

/// One compiled HLO graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub kind: ArtifactKind,
    pub batch: usize,
    pub chunk: usize,
    pub file: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Decode,
    Extend,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelGeometry,
    pub params_file: PathBuf,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            ConcurError::artifact(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let v = Value::parse(&text)?;
        let m = v.get("model");
        let model = ModelGeometry {
            vocab: m.req_u64("vocab")? as usize,
            n_layers: m.req_u64("n_layers")? as usize,
            d_model: m.req_u64("d_model")? as usize,
            n_heads: m.req_u64("n_heads")? as usize,
            head_dim: m.req_u64("head_dim")? as usize,
            d_ff: m.req_u64("d_ff")? as usize,
            max_seq: m.req_u64("max_seq")? as usize,
            n_params: m.req_u64("n_params")? as usize,
        };
        let params_file = dir.join(v.req_str("params_file")?);
        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .as_array()
            .ok_or_else(|| ConcurError::artifact("manifest missing artifacts"))?
        {
            let kind = match a.req_str("kind")? {
                "decode" => ArtifactKind::Decode,
                "extend" => ArtifactKind::Extend,
                other => {
                    return Err(ConcurError::artifact(format!(
                        "unknown artifact kind '{other}'"
                    )))
                }
            };
            artifacts.push(ArtifactEntry {
                kind,
                batch: a.req_u64("batch")? as usize,
                chunk: a.req_u64("chunk")? as usize,
                file: a.req_str("file")?.to_string(),
            });
        }
        if artifacts.is_empty() {
            return Err(ConcurError::artifact("manifest lists no artifacts"));
        }
        Ok(Manifest { dir: dir.to_path_buf(), model, params_file, artifacts })
    }

    /// Load the flat f32 parameter vector.
    pub fn load_params(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&self.params_file)?;
        if bytes.len() != self.model.n_params * 4 {
            return Err(ConcurError::artifact(format!(
                "params.bin has {} bytes, expected {} ({} f32)",
                bytes.len(),
                self.model.n_params * 4,
                self.model.n_params
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Available batch sizes for a graph kind, ascending.
    pub fn batches(&self, kind: ArtifactKind) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .map(|a| a.batch)
            .collect();
        b.sort_unstable();
        b
    }

    pub fn entry(&self, kind: ArtifactKind, batch: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.batch == batch)
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

/// Default artifacts directory: `$CONCUR_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("CONCUR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    #[test]
    fn loads_real_manifest() {
        let dir = repo_artifacts();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.vocab, 256);
        assert!(m.model.max_seq % 128 == 0);
        assert!(!m.batches(ArtifactKind::Decode).is_empty());
        assert!(!m.batches(ArtifactKind::Extend).is_empty());
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "missing {}", a.file);
        }
        let params = m.load_params().unwrap();
        assert_eq!(params.len(), m.model.n_params);
        // Deterministic seed: params are not all zeros and finite.
        assert!(params.iter().all(|x| x.is_finite()));
        assert!(params.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn missing_dir_is_actionable_error() {
        let err = Manifest::load(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
