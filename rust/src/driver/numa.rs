//! NUMA-aware placement for the parallel sweep workers.
//!
//! A paper-scale sweep saturates every core, and on a multi-socket box the
//! default scheduler happily migrates a simulation — and its multi-GiB
//! radix arena — across sockets mid-run, turning every arena access into a
//! remote-node miss.  The fix is boring: probe the node topology once from
//! sysfs, and pin sweep worker *w* to the CPUs of node `w % nodes` so each
//! simulation's allocations and accesses stay node-local.
//!
//! Deliberately conservative:
//!
//! * **Off by default on single-socket boxes** (the common case — laptops,
//!   most CI runners): zero syscalls, zero behavior change.
//! * `CONCUR_NUMA=0` force-disables pinning even on multi-socket boxes;
//!   `CONCUR_NUMA=1` force-enables it (useful for testing the mask
//!   plumbing on one node).
//! * Pinning affects **where** workers run, never **what** they compute —
//!   jobs are deterministic functions of their config, so sweep results
//!   stay bit-identical with pinning on, off, or unsupported.
//! * On non-Linux (or non-x86_64/aarch64) targets every probe returns
//!   "no topology" and pinning is a no-op; no libc dependency is taken.

use std::sync::OnceLock;

/// CPU lists per NUMA node, probed from sysfs once per process.
/// Empty ⇒ no usable multi-node topology (single node, non-Linux, or
/// unreadable sysfs).
fn topology() -> &'static [Vec<usize>] {
    static TOPO: OnceLock<Vec<Vec<usize>>> = OnceLock::new();
    TOPO.get_or_init(probe_topology)
}

fn probe_topology() -> Vec<Vec<usize>> {
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    let Ok(entries) = std::fs::read_dir("/sys/devices/system/node") else {
        return Vec::new();
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok())
        else {
            continue;
        };
        let path = entry.path().join("cpulist");
        let Ok(list) = std::fs::read_to_string(path) else { continue };
        let cpus = parse_cpulist(list.trim());
        if !cpus.is_empty() {
            nodes.push((idx, cpus));
        }
    }
    // Directory order is arbitrary; worker→node assignment must not be.
    nodes.sort_by_key(|&(idx, _)| idx);
    nodes.into_iter().map(|(_, cpus)| cpus).collect()
}

/// Parse a sysfs cpulist (`"0-3,8-11,16"`) into explicit CPU ids.
/// Malformed chunks are skipped rather than failing the probe — a weird
/// sysfs should degrade to "don't pin", never to a crash.
fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for chunk in s.split(',') {
        let chunk = chunk.trim();
        if chunk.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = chunk.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse()) {
                if lo <= hi {
                    cpus.extend(lo..=hi);
                }
            }
        } else if let Ok(cpu) = chunk.parse::<usize>() {
            cpus.push(cpu);
        }
    }
    cpus
}

/// Decide whether (and how) to pin sweep workers: `Some(nodes)` with the
/// per-node CPU lists when pinning should happen, `None` otherwise.
///
/// Pinning happens only when the box has more than one NUMA node (or
/// `CONCUR_NUMA=1` forces it) and is vetoed entirely by `CONCUR_NUMA=0`.
pub(crate) fn plan() -> Option<&'static [Vec<usize>]> {
    let force = std::env::var("CONCUR_NUMA").ok();
    match force.as_deref().map(str::trim) {
        Some("0") => return None,
        Some("1") => {
            let topo = topology();
            return if topo.is_empty() { None } else { Some(topo) };
        }
        _ => {}
    }
    let topo = topology();
    if topo.len() > 1 { Some(topo) } else { None }
}

/// Pin the calling thread to the given CPU set.  Best-effort: an empty
/// set, an unsupported platform, or a failed syscall leaves the thread
/// unpinned (affinity is a placement hint, never a correctness input).
pub(crate) fn pin_current_thread(cpus: &[usize]) {
    const MASK_WORDS: usize = 16; // 1024 CPUs, same as glibc's cpu_set_t
    let mut mask = [0u64; MASK_WORDS];
    let mut any = false;
    for &cpu in cpus {
        if cpu < MASK_WORDS * 64 {
            mask[cpu / 64] |= 1u64 << (cpu % 64);
            any = true;
        }
    }
    if any {
        sched_setaffinity_self(&mask);
    }
}

/// Raw `sched_setaffinity(0, ...)` — inline asm instead of libc so the
/// crate keeps its zero-dependency rule.  Errors are ignored (see
/// [`pin_current_thread`]).
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_setaffinity_self(mask: &[u64; 16]) {
    let mut _ret: isize;
    // SAFETY: sched_setaffinity reads `size` bytes from the mask pointer
    // and touches no other memory; the mask outlives the call and the
    // clobbers cover everything the Linux syscall ABI tramples.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => _ret, // __NR_sched_setaffinity
            in("rdi") 0usize,                  // pid 0 = calling thread
            in("rsi") std::mem::size_of_val(mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_setaffinity_self(mask: &[u64; 16]) {
    let mut _ret: isize;
    // SAFETY: as the x86_64 variant — the syscall only reads the mask.
    unsafe {
        std::arch::asm!(
            "svc 0",
            in("x8") 122usize, // __NR_sched_setaffinity
            inlateout("x0") 0usize => _ret, // pid 0 = calling thread
            in("x1") std::mem::size_of_val(mask),
            in("x2") mask.as_ptr(),
            options(nostack),
        );
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn sched_setaffinity_self(_mask: &[u64; 16]) {
    // Unsupported platform: stay unpinned.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parsing_covers_sysfs_shapes() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-1,4-5"), vec![0, 1, 4, 5]);
        assert_eq!(parse_cpulist("7"), vec![7]);
        assert_eq!(parse_cpulist("0, 2-3 , 9"), vec![0, 2, 3, 9]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        // Malformed chunks are dropped, valid ones kept.
        assert_eq!(parse_cpulist("x,3-1,2"), vec![2]);
    }

    #[test]
    fn pinning_to_current_cpus_is_harmless() {
        // Whatever this box looks like, pinning the thread to every CPU
        // of node 0 (or a superset mask) must not panic and must leave
        // the thread able to run.
        let topo = topology();
        if let Some(cpus) = topo.first() {
            pin_current_thread(cpus);
        }
        pin_current_thread(&(0..64).collect::<Vec<_>>());
        assert_eq!(1 + 1, 2); // still scheduled
    }

    #[test]
    fn empty_pin_set_is_a_no_op() {
        pin_current_thread(&[]);
    }
}
