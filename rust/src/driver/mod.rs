//! End-to-end batch-job driver: agents × controller × engine × clock.
//!
//! Runs one offline agentic batch-inference job to completion under a
//! given admission scheduler and collects everything the paper's tables
//! and figures need: end-to-end latency, lifetime hit rate, usage/hit-rate
//! time series, the latency breakdown and controller window trajectory.
//!
//! All agents are submitted at t=0 (offline batch); the DES clock advances
//! by engine-iteration durations and jumps across engine-idle gaps to the
//! next tool completion.

use crate::agent::{Agent, WorkloadGenerator};
use crate::config::JobConfig;
use crate::coordinator::{make_controller, Controller};
use crate::core::{AgentId, ConcurError, Micros, RequestId, Result};
use crate::costmodel::CostModel;
use crate::engine::{EngineCounters, SimEngine};
use crate::metrics::{Breakdown, Histogram, Phase, TimeSeries};
use crate::sim::{EventQueue, SimClock};

/// Everything measured over one job run.
pub struct RunResult {
    pub scheduler: String,
    /// End-to-end batch latency (time until the last agent finishes).
    pub total_time: Micros,
    pub breakdown: Breakdown,
    /// Lifetime prefix-cache hit rate (Table 2).
    pub hit_rate: f64,
    pub counters: EngineCounters,
    pub usage_series: TimeSeries,
    pub hit_series: TimeSeries,
    pub active_series: TimeSeries,
    pub window_series: TimeSeries,
    pub agents_total: usize,
    pub agents_finished: usize,
    pub total_gen_tokens: u64,
    /// Generated tokens per second of batch latency.
    pub throughput_tps: f64,
    /// Per-agent end-to-end latency distribution.
    pub agent_latency: Histogram,
    pub engine_steps: u64,
    pub pauses: u64,
    pub resumes: u64,
}

impl RunResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<24} latency={:>10}  hit={:>5.1}%  recompute={:>6.1}%  tput={:>8.0} tok/s  evictions={}",
            self.scheduler,
            self.total_time.to_string(),
            self.hit_rate * 100.0,
            self.breakdown.fraction(Phase::Recompute) * 100.0,
            self.throughput_tps,
            self.counters.evictions,
        )
    }
}

/// Run a complete job described by `job`.
pub fn run_job(job: &JobConfig) -> Result<RunResult> {
    job.validate()?;
    let agents = WorkloadGenerator::new(job.workload.clone()).generate();
    let controller = make_controller(&job.scheduler);
    let cost = CostModel::new(job.cluster.clone());
    let mut engine = SimEngine::new(job.engine.clone(), cost);
    run_with(&mut engine, agents, controller)
}

/// Run every job serially, in order.  Reference implementation for
/// [`run_jobs_parallel`]; results are positionally aligned with `jobs`.
pub fn run_jobs(jobs: &[JobConfig]) -> Vec<Result<RunResult>> {
    jobs.iter().map(run_job).collect()
}

/// Fan a batch of independent jobs out across CPU cores.
///
/// Jobs are deterministic functions of their config (every RNG is seeded),
/// so results are **bit-identical** to [`run_jobs`] regardless of thread
/// count or scheduling: workers pull indices from a shared counter and
/// results are scattered back by index.  This is what lets a full paper
/// reproduction (tables × figures × sweeps) saturate a box instead of
/// running one simulation at a time.
pub fn run_jobs_parallel(jobs: &[JobConfig]) -> Vec<Result<RunResult>> {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    run_jobs_parallel_with(jobs, threads)
}

/// [`run_jobs_parallel`] with an explicit worker count (`0`/`1` ⇒ serial).
pub fn run_jobs_parallel_with(
    jobs: &[JobConfig],
    threads: usize,
) -> Vec<Result<RunResult>> {
    use std::sync::atomic::{AtomicUsize, Ordering};

    let threads = threads.min(jobs.len());
    if threads <= 1 {
        return run_jobs(jobs);
    }
    let next = AtomicUsize::new(0);
    let per_worker: Vec<Vec<(usize, Result<RunResult>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut done = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        done.push((i, run_job(&jobs[i])));
                    }
                    done
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    let mut results: Vec<Option<Result<RunResult>>> =
        (0..jobs.len()).map(|_| None).collect();
    for (i, r) in per_worker.into_iter().flatten() {
        debug_assert!(results[i].is_none(), "job {i} ran twice");
        results[i] = Some(r);
    }
    results
        .into_iter()
        .map(|r| r.expect("every job produces exactly one result"))
        .collect()
}

/// Run with explicit parts (used by repro harnesses that customize the
/// engine, e.g. shrunken pools for unit-scale studies).
pub fn run_with(
    engine: &mut SimEngine,
    agents: Vec<Agent>,
    mut controller: Box<dyn Controller>,
) -> Result<RunResult> {
    if let Some(cap) = controller.engine_request_cap() {
        engine.cfg.max_running = cap;
    }

    let mut slots = crate::coordinator::SlotManager::new();
    let total_gen: u64 = agents.iter().map(|a| a.total_gen_tokens()).sum();
    let agents_total = agents.len();
    // Agent ids from the workload generator are dense 0..n — index by id
    // for O(1) access on the hot path.
    let mut fleet: Vec<Agent> = agents;
    fleet.sort_by_key(|a| a.id.0);
    for (i, a) in fleet.iter().enumerate() {
        assert_eq!(a.id.0 as usize, i, "driver requires dense agent ids");
        slots.register(a.id);
    }
    fn agent(fleet: &mut [Agent], id: AgentId) -> &mut Agent {
        &mut fleet[id.0 as usize]
    }
    // Aggregate context of slot-holding agents (the controller's U_t
    // numerator), maintained incrementally — recomputing it per step was
    // ~25% of simulation wall time.
    let mut active_footprint: u64 = 0;

    let mut clock = SimClock::new();
    let mut events: EventQueue<AgentId> = EventQueue::new();
    let mut next_req: u64 = 0;
    let mut result_breakdown_toolwait = Micros::ZERO;

    let mut usage_series = TimeSeries::new("kv_usage");
    let mut hit_series = TimeSeries::new("hit_rate");
    let mut active_series = TimeSeries::new("active_agents");
    let mut window_series = TimeSeries::new("window");
    let mut agent_latency = Histogram::new("agent_e2e_latency");

    let mut finished_agents = 0usize;
    let mut engine_steps = 0u64;
    let mut stagnant = 0u32;

    loop {
        let now = clock.now();

        // 1. Deliver due tool completions; paused agents wait for slots.
        while let Some((_, aid)) = events.pop_due(now) {
            let a = agent(&mut fleet, aid);
            a.on_tool_done();
            if slots.on_step_boundary(aid, controller.window())
                == crate::coordinator::slots::BoundaryDecision::Continue
            {
                let req = a.make_request(RequestId(next_req), now);
                next_req += 1;
                engine.submit(req);
            } else {
                active_footprint -= a.context_len() as u64; // paused
            }
        }

        // 2. Grant freed slots (resume paused LIFO, admit fresh FIFO).
        for aid in slots.grant_up_to(controller.window()) {
            let a = agent(&mut fleet, aid);
            active_footprint += a.context_len() as u64;
            let req = a.make_request(RequestId(next_req), now);
            next_req += 1;
            engine.submit(req);
        }

        // 3. Advance: engine iteration, or jump to the next tool event.
        if engine.has_work() {
            let out = engine.step(now);
            engine_steps += 1;
            let progressed = !out.work.is_empty() || !out.finished.is_empty();
            if progressed {
                stagnant = 0;
            } else {
                stagnant += 1;
                if stagnant > 10_000 {
                    let sig = engine.signals();
                    return Err(ConcurError::engine(format!(
                        "livelock: no progress for 10k iterations \
                         (running={} waiting={} pool_usage={:.3} \
                         working_usage={:.3} free={} evictable={})",
                        sig.running,
                        sig.waiting,
                        sig.pool_usage,
                        sig.kv_usage,
                        engine.pool().free(),
                        engine.tree().evictable_gpu_tokens(),
                    )));
                }
            }
            clock.advance(Micros(out.duration.0.max(1)));
            let after = clock.now();

            for fin in out.finished {
                let a = agent(&mut fleet, fin.agent);
                let before = a.context_len() as u64;
                match a.on_step_finished(&fin.output, after) {
                    Some(tool_latency) => {
                        // Still active: account its context growth.
                        active_footprint += a.context_len() as u64 - before;
                        events.push(after + tool_latency, fin.agent);
                    }
                    None => {
                        active_footprint -= before; // slot released
                        slots.release(fin.agent);
                        finished_agents += 1;
                        let start = a.started_at.unwrap_or(Micros::ZERO);
                        agent_latency.record(after.saturating_sub(start));
                    }
                }
            }

            let sig = engine.signals();
            debug_assert_eq!(
                active_footprint,
                slots
                    .active_ids()
                    .map(|aid| fleet[aid.0 as usize].context_len() as u64)
                    .sum::<u64>(),
                "incremental footprint drifted"
            );
            controller.on_signals(&crate::coordinator::ControlInputs {
                engine: sig,
                active_agents: slots.active_count(),
                active_footprint,
                capacity: engine.pool().capacity(),
            });
            usage_series.record(after, sig.pool_usage);
            hit_series.record(after, sig.hit_rate);
            active_series.record(after, slots.active_count() as f64);
            let w = controller.window();
            window_series.record(
                after,
                if w == usize::MAX { f64::NAN } else { w as f64 },
            );
        } else if let Some(t) = events.peek_time() {
            result_breakdown_toolwait += t.saturating_sub(now);
            clock.advance_to(t);
        } else {
            break; // no engine work, no future events → done
        }
    }

    if finished_agents != agents_total {
        return Err(ConcurError::engine(format!(
            "run ended with {finished_agents}/{agents_total} agents finished"
        )));
    }

    let total_time = clock.now();
    let mut breakdown = std::mem::take(&mut engine.breakdown);
    breakdown.add(Phase::ToolWait, result_breakdown_toolwait);
    let throughput_tps = if total_time.0 > 0 {
        total_gen as f64 / total_time.as_secs_f64()
    } else {
        0.0
    };

    Ok(RunResult {
        scheduler: controller.name(),
        total_time,
        breakdown,
        hit_rate: engine.lifetime_hits.ratio(),
        counters: engine.counters,
        usage_series,
        hit_series,
        active_series,
        window_series,
        agents_total,
        agents_finished: finished_agents,
        total_gen_tokens: total_gen,
        throughput_tps,
        agent_latency,
        engine_steps,
        pauses: slots.pauses,
        resumes: slots.resumes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        AimdParams, EngineConfig, JobConfig, SchedulerKind, WorkloadConfig,
    };
    use crate::config::presets;

    fn small_job(scheduler: SchedulerKind) -> JobConfig {
        JobConfig {
            cluster: presets::qwen3_cluster(8),
            engine: EngineConfig::default(),
            workload: WorkloadConfig {
                n_agents: 8,
                steps_min: 2,
                steps_max: 3,
                ..WorkloadConfig::default()
            },
            scheduler,
        }
    }

    #[test]
    fn uncontrolled_job_completes() {
        let r = run_job(&small_job(SchedulerKind::Uncontrolled)).unwrap();
        assert_eq!(r.agents_finished, 8);
        assert!(r.total_time.0 > 0);
        assert!(r.throughput_tps > 0.0);
        assert!(r.breakdown.total().0 > 0);
    }

    #[test]
    fn concur_job_completes_and_tracks_window() {
        let r = run_job(&small_job(SchedulerKind::Concur(AimdParams::default())))
            .unwrap();
        assert_eq!(r.agents_finished, 8);
        assert!(!r.window_series.is_empty());
        assert!(r.window_series.last().unwrap() >= 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let job = small_job(SchedulerKind::Concur(AimdParams::default()));
        let a = run_job(&job).unwrap();
        let b = run_job(&job).unwrap();
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.counters.decode_tokens, b.counters.decode_tokens);
        assert_eq!(a.hit_rate, b.hit_rate);
    }

    #[test]
    fn agent_cap_limits_active_agents() {
        let r = run_job(&small_job(SchedulerKind::AgentCap(2))).unwrap();
        assert!(r.active_series.max() <= 2.0);
        assert_eq!(r.agents_finished, 8);
    }

    #[test]
    fn request_cap_sets_engine_cap() {
        let r = run_job(&small_job(SchedulerKind::RequestCap(2))).unwrap();
        assert_eq!(r.agents_finished, 8);
    }

    #[test]
    fn parallel_sweep_matches_serial_bitwise() {
        let jobs: Vec<JobConfig> = vec![
            small_job(SchedulerKind::Uncontrolled),
            small_job(SchedulerKind::Concur(AimdParams::default())),
            small_job(SchedulerKind::AgentCap(2)),
            small_job(SchedulerKind::RequestCap(2)),
        ];
        let serial = run_jobs(&jobs);
        let parallel = run_jobs_parallel_with(&jobs, 4);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            let (s, p) = (s.as_ref().unwrap(), p.as_ref().unwrap());
            assert_eq!(s.total_time, p.total_time);
            assert_eq!(s.hit_rate, p.hit_rate);
            assert_eq!(s.counters.decode_tokens, p.counters.decode_tokens);
            assert_eq!(s.counters.evicted_tokens, p.counters.evicted_tokens);
            assert_eq!(s.engine_steps, p.engine_steps);
        }
    }

    #[test]
    fn parallel_sweep_preserves_job_order_and_errors() {
        let mut bad = small_job(SchedulerKind::Uncontrolled);
        bad.workload.n_agents = 0; // fails validation
        let jobs = vec![
            small_job(SchedulerKind::Uncontrolled),
            bad,
            small_job(SchedulerKind::AgentCap(2)),
        ];
        let results = run_jobs_parallel_with(&jobs, 2);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        assert_eq!(results[0].as_ref().unwrap().scheduler, "sglang");
        assert_eq!(results[2].as_ref().unwrap().scheduler, "agent-cap(2)");
    }
}
