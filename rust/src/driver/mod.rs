//! End-to-end batch-job driver: agents × controller × cluster × clock.
//!
//! Runs one offline agentic batch-inference job to completion under a
//! given admission scheduler and collects everything the paper's tables
//! and figures need: end-to-end latency, lifetime hit rate, usage/hit-rate
//! time series, the latency breakdown and controller window trajectory.
//!
//! All agents are submitted at t=0 (offline batch) unless the job's
//! `topology.open_loop` is enabled, in which case the fleet arrives over
//! a seeded Poisson process (see [`crate::agent::open_loop_fleet`]).  The
//! event loop lives in [`crate::cluster::run_sharded`]: a job runs on
//! `job.topology.replicas` data-parallel engine replicas — with the
//! topology's scripted fault plan and per-replica tool-latency skew —
//! and the classic single-engine path is simply its N=1 healthy case
//! (bit-identical to the pre-cluster driver — see
//! `tests/cluster_integration.rs`).

use crate::agent::{open_loop_fleet, workflow_fleet, Agent, WorkloadGenerator};
use crate::cluster::{
    make_router, ClusterCoordinator, FaultStats, OpenLoopStats, PrefixTierStats, TransportStats,
};
use crate::config::{
    FaultPlan, FaultRateConfig, JobConfig, OpenLoopConfig, PrefixTierConfig, RouterKind,
    TransportConfig,
};
use crate::coordinator::{make_controller, Controller};
use crate::core::{AgentId, Micros, Result};
use crate::engine::{EngineCounters, SimEngine};
use crate::metrics::{Breakdown, Histogram, Phase, ProfileSnapshot, TimeSeries};

mod numa;

/// One finished agent's completion record (in finish order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AgentOutcome {
    /// Which agent.
    pub agent: AgentId,
    /// Tokens it generated over its whole trajectory.
    pub gen_tokens: u64,
    /// Simulation time its final step completed.
    pub finished_at: Micros,
}

/// Everything measured over one job run.
pub struct RunResult {
    pub scheduler: String,
    /// End-to-end batch latency (time until the last agent finishes).
    pub total_time: Micros,
    pub breakdown: Breakdown,
    /// Lifetime prefix-cache hit rate (Table 2).
    pub hit_rate: f64,
    pub counters: EngineCounters,
    pub usage_series: TimeSeries,
    pub hit_series: TimeSeries,
    pub active_series: TimeSeries,
    pub window_series: TimeSeries,
    pub agents_total: usize,
    pub agents_finished: usize,
    pub total_gen_tokens: u64,
    /// Generated tokens per second of batch latency.
    pub throughput_tps: f64,
    /// Per-agent end-to-end latency distribution.
    pub agent_latency: Histogram,
    pub engine_steps: u64,
    pub pauses: u64,
    pub resumes: u64,
    /// Data-parallel engine replicas the job ran on.
    pub replicas: usize,
    /// Routing policy name (`"single"` for one-replica runs).
    pub router: String,
    /// Fault/drain/migration telemetry (all zero for healthy runs).
    pub faults: FaultStats,
    /// Admissible (routable) replica count over time: one point at t=0,
    /// plus one per fault-plan transition and drain refill.
    pub alive_series: TimeSeries,
    /// Per-agent completion records, in finish order.
    pub per_agent: Vec<AgentOutcome>,
    /// Shared-prefix broadcast tier telemetry (all zero with the tier
    /// off — the default).
    pub prefix_tier: PrefixTierStats,
    /// Tokens shipped by broadcast installs over time: one point per
    /// tier maintenance pass that moved data (empty with the tier off),
    /// plus — under delayed transport visibility — one per install
    /// commit at its transfer's completion instant.
    pub broadcast_series: TimeSeries,
    /// Asynchronous-transport telemetry (all zero with the transport
    /// off — the default).
    pub transport: TransportStats,
    /// TTFT distribution — arrival to first generation-step completion —
    /// of open-loop sessions, merged across replicas (empty for
    /// closed-batch runs).
    pub ttft: Histogram,
    /// Per-turn latency distribution of open-loop turns after the first
    /// (empty for closed-batch runs).
    pub step_latency: Histogram,
    /// Open-loop traffic telemetry (all zero for closed-batch runs).
    pub open_loop: OpenLoopStats,
    /// Self-profiler section totals covering this run (empty unless the
    /// profiler was enabled — see [`crate::metrics::profiler`]).  Wall-
    /// clock derived, so deliberately excluded from every determinism
    /// comparison and repro JSON dump.
    pub profile: ProfileSnapshot,
}

impl RunResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<24} latency={:>10}  hit={:>5.1}%  recompute={:>6.1}%  tput={:>8.0} tok/s  evictions={}",
            self.scheduler,
            self.total_time.to_string(),
            self.hit_rate * 100.0,
            self.breakdown.fraction(Phase::Recompute) * 100.0,
            self.throughput_tps,
            self.counters.evictions,
        )
    }
}

/// Run a complete job described by `job` on its configured replica fleet
/// (a single replica unless `job.topology` says otherwise).
pub fn run_job(job: &JobConfig) -> Result<RunResult> {
    job.validate()?;
    let (agents, workflow) = if job.topology.open_loop.enabled {
        (open_loop_fleet(&job.workload, &job.topology.open_loop), None)
    } else if job.workload.workflow.enabled {
        let (agents, graph) = workflow_fleet(&job.workload);
        (agents, Some(graph))
    } else {
        (WorkloadGenerator::new(job.workload.clone()).generate(), None)
    };
    let controller = make_controller(&job.scheduler);
    ClusterCoordinator::new(job).run_workflow(agents, workflow, controller)
}

/// Run every job serially, in order.  Reference implementation for
/// [`run_jobs_parallel`]; results are positionally aligned with `jobs`.
pub fn run_jobs(jobs: &[JobConfig]) -> Vec<Result<RunResult>> {
    jobs.iter().map(run_job).collect()
}

/// Fan a batch of independent jobs out across CPU cores.
///
/// Jobs are deterministic functions of their config (every RNG is seeded),
/// so results are **bit-identical** to [`run_jobs`] regardless of thread
/// count or scheduling: workers pull indices from a shared counter and
/// results are scattered back by index.  This is what lets a full paper
/// reproduction (tables × figures × sweeps) saturate a box instead of
/// running one simulation at a time.
///
/// Worker count: `CONCUR_WORKERS` if set (clamped to the machine's
/// available parallelism), else all available cores.
pub fn run_jobs_parallel(jobs: &[JobConfig]) -> Vec<Result<RunResult>> {
    let threads = resolve_workers(
        std::env::var("CONCUR_WORKERS").ok().as_deref(),
        available_parallelism(),
    );
    run_jobs_parallel_with(jobs, threads)
}

fn available_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve the sweep worker count from an optional `CONCUR_WORKERS`-style
/// override and the machine's available parallelism.  Requests above
/// `available` are clamped — a 2-core CI runner must not be oversubscribed
/// by an 8-worker default — and unparsable or zero overrides fall back to
/// `available`.  Every fallback or clamp is reported on stderr so a typo'd
/// override fails loudly instead of silently running on all cores.
pub fn resolve_workers(requested: Option<&str>, available: usize) -> usize {
    let (workers, warning) = resolve_workers_explain(requested, available);
    if let Some(w) = warning {
        eprintln!("warning: {w}");
    }
    workers
}

/// [`resolve_workers`] minus the stderr side effect: returns the resolved
/// count and the warning that would be printed, so tests can pin both.
pub fn resolve_workers_explain(
    requested: Option<&str>,
    available: usize,
) -> (usize, Option<String>) {
    let available = available.max(1);
    let Some(raw) = requested else {
        return (available, None);
    };
    match raw.trim().parse::<usize>() {
        Ok(0) => (
            available,
            Some(format!(
                "CONCUR_WORKERS=0 is not a worker count; \
                 using all {available} available cores"
            )),
        ),
        Ok(n) if n > available => (
            available,
            Some(format!(
                "CONCUR_WORKERS={n} exceeds available parallelism; \
                 clamping to {available}"
            )),
        ),
        Ok(n) => (n, None),
        Err(_) => (
            available,
            Some(format!(
                "CONCUR_WORKERS={raw:?} is not a number; \
                 using all {available} available cores"
            )),
        ),
    }
}

/// [`run_jobs_parallel`] with an explicit worker count (`0`/`1` ⇒ serial).
/// The explicit count is honored verbatim — the determinism proptests
/// deliberately oversubscribe small machines to exercise 4- and 8-worker
/// scheduling; only the `CONCUR_WORKERS` env path clamps.
pub fn run_jobs_parallel_with(
    jobs: &[JobConfig],
    threads: usize,
) -> Vec<Result<RunResult>> {
    use std::sync::atomic::{AtomicUsize, Ordering};

    let threads = threads.min(jobs.len());
    if threads <= 1 {
        return run_jobs(jobs);
    }
    // On multi-socket boxes, pin worker w to NUMA node w % nodes so a
    // simulation's arena stays node-local (see `numa`).  `None` on
    // single-socket machines and under `CONCUR_NUMA=0` — the common case
    // pays nothing.  Pinning is placement only: results are bit-identical
    // either way.
    let numa_plan = numa::plan();
    let next = AtomicUsize::new(0);
    let next = &next;
    let per_worker: Vec<Vec<(usize, Result<RunResult>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                s.spawn(move || {
                    if let Some(nodes) = numa_plan {
                        numa::pin_current_thread(&nodes[w % nodes.len()]);
                    }
                    let mut done = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        done.push((i, run_job(&jobs[i])));
                    }
                    done
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    let mut results: Vec<Option<Result<RunResult>>> =
        (0..jobs.len()).map(|_| None).collect();
    for (i, r) in per_worker.into_iter().flatten() {
        debug_assert!(results[i].is_none(), "job {i} ran twice");
        results[i] = Some(r);
    }
    results
        .into_iter()
        .map(|r| r.expect("every job produces exactly one result"))
        .collect()
}

/// Run with an explicit engine (used by repro harnesses that customize
/// it, e.g. shrunken pools for unit-scale studies).  This is the N=1
/// healthy case of [`crate::cluster::run_sharded`] — no faults, uniform
/// tool latency; the router never fires.
pub fn run_with(
    engine: &mut SimEngine,
    agents: Vec<Agent>,
    controller: Box<dyn Controller>,
) -> Result<RunResult> {
    let mut router = make_router(RouterKind::CacheAffinity);
    crate::cluster::run_sharded(
        std::slice::from_mut(engine),
        router.as_mut(),
        agents,
        None,
        controller,
        &FaultPlan::none(),
        &[],
        &PrefixTierConfig::default(),
        &TransportConfig::default(),
        &OpenLoopConfig::default(),
        &FaultRateConfig::default(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        AimdParams, EngineConfig, JobConfig, RouterKind, SchedulerKind,
        TopologyConfig, WorkloadConfig,
    };
    use crate::config::presets;

    fn small_job(scheduler: SchedulerKind) -> JobConfig {
        JobConfig {
            cluster: presets::qwen3_cluster(8),
            engine: EngineConfig::default(),
            workload: WorkloadConfig {
                n_agents: 8,
                steps_min: 2,
                steps_max: 3,
                ..WorkloadConfig::default()
            },
            scheduler,
            topology: TopologyConfig::default(),
        }
    }

    #[test]
    fn uncontrolled_job_completes() {
        let r = run_job(&small_job(SchedulerKind::Uncontrolled)).unwrap();
        assert_eq!(r.agents_finished, 8);
        assert!(r.total_time.0 > 0);
        assert!(r.throughput_tps > 0.0);
        assert!(r.breakdown.total().0 > 0);
    }

    #[test]
    fn concur_job_completes_and_tracks_window() {
        let r = run_job(&small_job(SchedulerKind::Concur(AimdParams::default())))
            .unwrap();
        assert_eq!(r.agents_finished, 8);
        assert!(!r.window_series.is_empty());
        assert!(r.window_series.last().unwrap() >= 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let job = small_job(SchedulerKind::Concur(AimdParams::default()));
        let a = run_job(&job).unwrap();
        let b = run_job(&job).unwrap();
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.counters.decode_tokens, b.counters.decode_tokens);
        assert_eq!(a.hit_rate, b.hit_rate);
    }

    #[test]
    fn agent_cap_limits_active_agents() {
        let r = run_job(&small_job(SchedulerKind::AgentCap(2))).unwrap();
        assert!(r.active_series.max() <= 2.0);
        assert_eq!(r.agents_finished, 8);
    }

    #[test]
    fn request_cap_sets_engine_cap() {
        let r = run_job(&small_job(SchedulerKind::RequestCap(2))).unwrap();
        assert_eq!(r.agents_finished, 8);
    }

    #[test]
    fn replicated_job_runs_through_the_cluster_path() {
        let mut job = small_job(SchedulerKind::Concur(AimdParams::default()));
        job.topology = TopologyConfig {
            replicas: 2,
            router: RouterKind::CacheAffinity,
            ..TopologyConfig::default()
        };
        let r = run_job(&job).unwrap();
        assert_eq!(r.agents_finished, 8);
        assert_eq!(r.replicas, 2);
        assert_eq!(r.router, "cache-affinity");
    }

    #[test]
    fn per_agent_records_cover_the_fleet() {
        let r = run_job(&small_job(SchedulerKind::Uncontrolled)).unwrap();
        assert_eq!(r.per_agent.len(), 8);
        let mut ids: Vec<u64> = r.per_agent.iter().map(|o| o.agent.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
        // Finish order is chronological and the sum of per-agent tokens
        // is the job total.
        for w in r.per_agent.windows(2) {
            assert!(w[0].finished_at <= w[1].finished_at);
        }
        let total: u64 = r.per_agent.iter().map(|o| o.gen_tokens).sum();
        assert_eq!(total, r.total_gen_tokens);
    }

    #[test]
    fn single_replica_run_reports_single_router() {
        let r = run_job(&small_job(SchedulerKind::Uncontrolled)).unwrap();
        assert_eq!(r.replicas, 1);
        assert_eq!(r.router, "single");
    }

    #[test]
    fn worker_resolution_clamps_and_falls_back() {
        // Unset / garbage / zero → all available cores.
        assert_eq!(resolve_workers(None, 8), 8);
        assert_eq!(resolve_workers(Some("many"), 8), 8);
        assert_eq!(resolve_workers(Some("0"), 8), 8);
        // In-range override respected; oversubscription clamped.
        assert_eq!(resolve_workers(Some("3"), 8), 3);
        assert_eq!(resolve_workers(Some(" 4 "), 8), 4);
        assert_eq!(resolve_workers(Some("8"), 2), 2);
        // Degenerate availability never yields zero workers.
        assert_eq!(resolve_workers(None, 0), 1);
    }

    /// Every bad-override case warns; every clean case stays silent.
    #[test]
    fn worker_resolution_warns_on_every_bad_override() {
        // Unset: silent, all cores.
        assert_eq!(resolve_workers_explain(None, 8), (8, None));
        // Non-numeric: fall back with a warning naming the bad value.
        let (w, msg) = resolve_workers_explain(Some("many"), 8);
        assert_eq!(w, 8);
        assert!(msg.as_deref().unwrap().contains("\"many\""), "{msg:?}");
        assert!(msg.as_deref().unwrap().contains("not a number"), "{msg:?}");
        // Zero: fall back with a warning.
        let (w, msg) = resolve_workers_explain(Some("0"), 8);
        assert_eq!(w, 8);
        assert!(msg.as_deref().unwrap().contains("CONCUR_WORKERS=0"), "{msg:?}");
        // Absurdly large: clamp with a warning naming both numbers.
        let (w, msg) = resolve_workers_explain(Some("9999"), 4);
        assert_eq!(w, 4);
        let msg = msg.unwrap();
        assert!(msg.contains("9999") && msg.contains("clamping to 4"), "{msg}");
        // Whitespace-padded in-range override: honored silently.
        assert_eq!(resolve_workers_explain(Some(" 4 "), 8), (4, None));
        // Negative numbers don't parse as usize: warned fallback.
        let (w, msg) = resolve_workers_explain(Some("-2"), 8);
        assert_eq!(w, 8);
        assert!(msg.is_some());
        // Degenerate availability never yields zero workers.
        assert_eq!(resolve_workers_explain(Some("3"), 0).0, 1);
    }

    #[test]
    fn parallel_sweep_matches_serial_bitwise() {
        let jobs: Vec<JobConfig> = vec![
            small_job(SchedulerKind::Uncontrolled),
            small_job(SchedulerKind::Concur(AimdParams::default())),
            small_job(SchedulerKind::AgentCap(2)),
            small_job(SchedulerKind::RequestCap(2)),
        ];
        let serial = run_jobs(&jobs);
        let parallel = run_jobs_parallel_with(&jobs, 4);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            let (s, p) = (s.as_ref().unwrap(), p.as_ref().unwrap());
            assert_eq!(s.total_time, p.total_time);
            assert_eq!(s.hit_rate, p.hit_rate);
            assert_eq!(s.counters.decode_tokens, p.counters.decode_tokens);
            assert_eq!(s.counters.evicted_tokens, p.counters.evicted_tokens);
            assert_eq!(s.engine_steps, p.engine_steps);
        }
    }

    #[test]
    fn parallel_sweep_preserves_job_order_and_errors() {
        let mut bad = small_job(SchedulerKind::Uncontrolled);
        bad.workload.n_agents = 0; // fails validation
        let jobs = vec![
            small_job(SchedulerKind::Uncontrolled),
            bad,
            small_job(SchedulerKind::AgentCap(2)),
        ];
        let results = run_jobs_parallel_with(&jobs, 2);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
        assert_eq!(results[0].as_ref().unwrap().scheduler, "sglang");
        assert_eq!(results[2].as_ref().unwrap().scheduler, "agent-cap(2)");
    }
}
