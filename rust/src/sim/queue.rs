//! Deterministic time-ordered event queue.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::core::Micros;

struct Entry<E> {
    at: Micros,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first, then FIFO
        // within equal timestamps so the simulation is deterministic.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap of timestamped events with FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn push(&mut self, at: Micros, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, event });
    }

    /// Timestamp of the next event, if any.
    pub fn peek_time(&self) -> Option<Micros> {
        self.heap.peek().map(|e| e.at)
    }

    /// Pop the next event regardless of time.
    pub fn pop(&mut self) -> Option<(Micros, E)> {
        self.heap.pop().map(|e| (e.at, e.event))
    }

    /// Pop the next event only if it is due at or before `now`.
    pub fn pop_due(&mut self, now: Micros) -> Option<(Micros, E)> {
        if self.peek_time()? <= now {
            self.pop()
        } else {
            None
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Micros(30), "c");
        q.push(Micros(10), "a");
        q.push(Micros(20), "b");
        assert_eq!(q.pop(), Some((Micros(10), "a")));
        assert_eq!(q.pop(), Some((Micros(20), "b")));
        assert_eq!(q.pop(), Some((Micros(30), "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_within_same_timestamp() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(Micros(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((Micros(5), i)));
        }
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(Micros(10), "a");
        q.push(Micros(20), "b");
        assert_eq!(q.pop_due(Micros(5)), None);
        assert_eq!(q.pop_due(Micros(10)), Some((Micros(10), "a")));
        assert_eq!(q.pop_due(Micros(15)), None);
        assert_eq!(q.len(), 1);
    }
}
