//! Discrete-event simulation substrate.
//!
//! The driver advances simulated time two ways: engine iterations consume
//! `CostModel::step_time`, and external events (tool completions, request
//! arrivals) are drawn from this queue.  Everything is integral-time and
//! tie-broken by insertion order, so runs are bit-reproducible.

pub mod queue;

pub use queue::EventQueue;

use crate::core::Micros;

/// Simulated wall clock.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: Micros,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock { now: Micros::ZERO }
    }

    pub fn now(&self) -> Micros {
        self.now
    }

    /// Advance by a duration (engine step, stall, ...).
    pub fn advance(&mut self, dt: Micros) {
        self.now += dt;
    }

    /// Jump directly to an absolute time; must be monotone.
    pub fn advance_to(&mut self, t: Micros) {
        debug_assert!(t >= self.now, "clock must be monotone: {t} < {}", self.now);
        if t > self.now {
            self.now = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_monotone() {
        let mut c = SimClock::new();
        c.advance(Micros(10));
        c.advance_to(Micros(50));
        c.advance_to(Micros(50)); // same time is fine
        assert_eq!(c.now(), Micros(50));
    }
}
