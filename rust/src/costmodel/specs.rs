//! Hardware and model specifications used by the analytical cost model.
//!
//! Numbers are calibrated to the paper's testbed (NVIDIA H100 80GB, NVLink
//! within a node) and to the two evaluated models.  Where the paper states a
//! concrete figure we pin to it (e.g. DeepSeek-V3's "6.67 GB cache per
//! request, 4096 tokens" in Fig. 1c → 1.63 MB per token); otherwise we use
//! the public architecture arithmetic (e.g. Qwen3-32B GQA KV geometry).

use crate::core::Bytes;

/// A GPU SKU as seen by the cost model.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Total HBM capacity.
    pub hbm: Bytes,
    /// Fraction of HBM usable by the serving engine (activations,
    /// allocator overheads and CUDA context take the rest).
    pub usable_frac: f64,
    /// Achievable HBM bandwidth (GB/s) under serving access patterns.
    pub hbm_bw_gbps: f64,
    /// *Effective* dense bf16 throughput (TFLOP/s) at serving MFU —
    /// not the datasheet peak (H100 ≈ 989 peak, ~40% MFU sustained).
    pub eff_tflops: f64,
    /// Host link bandwidth per GPU (GB/s) for KV offload (PCIe Gen5 x16
    /// nominal 64 GB/s; ~50 achievable).
    pub pcie_gbps: f64,
}

impl GpuSpec {
    pub fn h100() -> GpuSpec {
        GpuSpec {
            name: "H100-80GB",
            hbm: Bytes::from_gb(80.0),
            usable_frac: 0.90,
            hbm_bw_gbps: 3350.0,
            eff_tflops: 400.0,
            pcie_gbps: 50.0,
        }
    }
}

/// How a model stores KV state — determines bytes/token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// Grouped-query attention: `n_layers * kv_heads * head_dim * 2 (K,V)
    /// * dtype_bytes` per token.
    Gqa { kv_heads: u32, head_dim: u32 },
    /// Calibrated directly from a measured bytes/token figure (used for
    /// DeepSeek-V3, pinned to the paper's Fig. 1c statement).
    Calibrated { bytes_per_token: u64 },
}

/// A served model as seen by the cost model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Weight bytes (whole model, before TP sharding).
    pub weights: Bytes,
    pub n_layers: u32,
    pub d_model: u32,
    /// Total attention query width (n_heads * head_dim) — sets the O(L²)
    /// attention FLOPs term.
    pub q_dim: u32,
    /// Parameters activated per token (≠ total for MoE).
    pub active_params: f64,
    pub kv_layout: KvLayout,
    pub dtype_bytes: u32,
    /// Per-GPU runtime overhead beyond weights: activations, CUDA graphs,
    /// communication buffers — large for MoE models (expert dispatch
    /// buffers, MTP heads).
    pub activation_overhead: Bytes,
    /// Prefill efficiency relative to the GPU's effective dense
    /// throughput.  Dense models ≈ 1.0; MoE prefill is all-to-all bound
    /// (expert dispatch) and runs far below dense MFU — calibrated so the
    /// uncontrolled baseline's recompute share reproduces the paper's
    /// Fig. 3b (~49% of end-to-end latency under thrashing).
    pub prefill_efficiency: f64,
    /// Fraction of the nominal host-link bandwidth KV offload actually
    /// achieves.  GQA caches move in large contiguous pages (~0.5);
    /// MLA caches are tiny per-layer slivers (576 dims x 1 byte) whose
    /// per-page DMA + sync overheads collapse throughput (~0.1) — this is
    /// why the paper's HiCache goes 0.34x on DeepSeek-V3 while *helping*
    /// on Qwen3.
    pub offload_efficiency: f64,
}

impl ModelSpec {
    /// Qwen3-32B: 64 layers, GQA 8 KV heads x 128 head dim, bf16.
    pub fn qwen3_32b() -> ModelSpec {
        ModelSpec {
            name: "Qwen3-32B",
            weights: Bytes::from_gb(65.6), // 32.8B params, bf16
            n_layers: 64,
            d_model: 5120,
            q_dim: 64 * 128,
            active_params: 32.8e9,
            kv_layout: KvLayout::Gqa { kv_heads: 8, head_dim: 128 },
            dtype_bytes: 2,
            activation_overhead: Bytes::from_gb(6.0),
            prefill_efficiency: 1.0,
            offload_efficiency: 0.5,
        }
    }

    /// DeepSeek-V3: 671B total / ~37B active, fp8 weights; KV bytes/token
    /// calibrated to the paper's "6.67 GB per 4096-token request".
    pub fn deepseek_v3() -> ModelSpec {
        ModelSpec {
            name: "DeepSeek-V3",
            weights: Bytes::from_gb(671.0), // fp8
            n_layers: 61,
            d_model: 7168,
            q_dim: 128 * 128,
            active_params: 37.0e9,
            kv_layout: KvLayout::Calibrated {
                bytes_per_token: (6.67e9 / 4096.0) as u64, // ≈ 1.63 MB
            },
            dtype_bytes: 1,
            activation_overhead: Bytes::from_gb(16.0),
            prefill_efficiency: 0.15,
            offload_efficiency: 0.1,
        }
    }

    /// The tiny real model actually executed through PJRT (see
    /// `python/compile/model.py`); used when the simulator and the real
    /// server must agree on geometry.
    pub fn tiny() -> ModelSpec {
        ModelSpec {
            name: "tiny-concur",
            weights: Bytes(853_120 * 4),
            n_layers: 4,
            d_model: 128,
            q_dim: 128,
            active_params: 853_120.0,
            kv_layout: KvLayout::Gqa { kv_heads: 2, head_dim: 64 },
            dtype_bytes: 4,
            activation_overhead: Bytes::ZERO,
            prefill_efficiency: 1.0,
            offload_efficiency: 0.5,
        }
    }

    /// KV cache bytes for one token of context.
    pub fn kv_bytes_per_token(&self) -> u64 {
        match self.kv_layout {
            KvLayout::Gqa { kv_heads, head_dim } => {
                self.n_layers as u64
                    * kv_heads as u64
                    * head_dim as u64
                    * 2 // K and V
                    * self.dtype_bytes as u64
            }
            KvLayout::Calibrated { bytes_per_token } => bytes_per_token,
        }
    }

    /// Dense FLOPs to process one token through the weights (2·N_active).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.active_params
    }

    /// Extra attention FLOPs per (new token, context token) pair — the
    /// O(L²) term that makes recompute-after-eviction so expensive (the
    /// paper's "quadratic penalty").  QK^T + AV = 4·q_dim FLOPs per pair
    /// per layer.
    pub fn attn_flops_per_ctx_token(&self) -> f64 {
        4.0 * self.n_layers as f64 * self.q_dim as f64
    }
}

/// A TP-sharded serving replica (the paper always uses #GPU == TP for one
/// engine instance; data parallel replicas would just multiply throughput).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    pub tp: u32,
    pub n_gpus: u32,
}

impl ClusterSpec {
    pub fn new(gpu: GpuSpec, model: ModelSpec, tp: u32, n_gpus: u32) -> ClusterSpec {
        assert!(n_gpus % tp == 0, "n_gpus must be a multiple of tp");
        ClusterSpec { gpu, model, tp, n_gpus }
    }

    /// Aggregate KV pool bytes across the TP group: per-GPU usable HBM
    /// minus the weight shard, times the group size.
    pub fn kv_pool_bytes(&self) -> Bytes {
        let per_gpu_usable = self.gpu.hbm.0 as f64 * self.gpu.usable_frac;
        let weight_shard = self.model.weights.0 as f64 / self.tp as f64;
        let free = (per_gpu_usable
            - weight_shard
            - self.model.activation_overhead.0 as f64)
            .max(0.0);
        Bytes((free * self.tp as f64) as u64)
    }

    /// KV pool capacity in token slots.
    pub fn kv_pool_tokens(&self) -> u64 {
        self.kv_pool_bytes().0 / self.model.kv_bytes_per_token()
    }

    /// Aggregate effective compute across the TP group (TFLOP/s).
    pub fn agg_tflops(&self) -> f64 {
        self.gpu.eff_tflops * self.tp as f64
    }

    /// Aggregate HBM bandwidth across the TP group (GB/s).
    pub fn agg_hbm_bw(&self) -> f64 {
        self.gpu.hbm_bw_gbps * self.tp as f64
    }

    /// Nodes spanned by the replica (8 GPUs per node).
    pub fn nodes(&self) -> u32 {
        self.n_gpus.div_ceil(8).max(1)
    }

    /// Aggregate host-link bandwidth (GB/s) for offload traffic: per-GPU
    /// PCIe in parallel, capped by the host memory bus each node can
    /// actually absorb for pinned KV transfers (~100 GB/s/node), derated
    /// by the model's KV page-transfer efficiency.
    pub fn agg_pcie_bw(&self) -> f64 {
        (self.gpu.pcie_gbps * self.tp as f64).min(100.0 * self.nodes() as f64)
            * self.model.offload_efficiency
    }

    /// CPU-tier capacity for offloaded KV, in tokens (2 TB host RAM per
    /// node, the typical provisioning of H100 nodes).
    pub fn cpu_tier_tokens(&self) -> u64 {
        (2.0e12 * self.nodes() as f64) as u64 / self.model.kv_bytes_per_token()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen3_kv_geometry() {
        let m = ModelSpec::qwen3_32b();
        // 64 layers * 8 kv heads * 128 dim * 2 (K,V) * 2 bytes = 256 KiB.
        assert_eq!(m.kv_bytes_per_token(), 262_144);
    }

    #[test]
    fn dsv3_kv_matches_paper_calibration() {
        let m = ModelSpec::deepseek_v3();
        let per_4096 = m.kv_bytes_per_token() * 4096;
        let gb = per_4096 as f64 / 1e9;
        assert!((gb - 6.67).abs() < 0.01, "got {gb} GB per 4096 tokens");
    }

    #[test]
    fn qwen3_pool_shrinks_with_tp() {
        let gpu = GpuSpec::h100();
        let pool = |tp| {
            ClusterSpec::new(gpu.clone(), ModelSpec::qwen3_32b(), tp, tp)
                .kv_pool_tokens()
        };
        let (p8, p4, p2) = (pool(8), pool(4), pool(2));
        assert!(p8 > p4 && p4 > p2, "{p8} {p4} {p2}");
        // TP2: 2 * (72 - 32.8 - 6 overhead) GB = ~66GB → ~253k tokens.
        assert!((200_000..300_000).contains(&p2), "p2={p2}");
        // TP8: ~462GB → ~1.76M tokens.
        assert!((1_500_000..2_000_000).contains(&p8), "p8={p8}");
    }

    #[test]
    fn dsv3_pool_brackets_paper_batch_sweep() {
        // The paper sees batch 16 fine and batch 40 thrashing on TP16.
        let c = ClusterSpec::new(
            GpuSpec::h100(),
            ModelSpec::deepseek_v3(),
            16,
            16,
        );
        let pool = c.kv_pool_tokens();
        // ~225 GB / 1.63 MB ≈ 138k token slots: 16 agents at mid-horizon
        // contexts already brush the limit; 40 is far past it.
        assert!(pool > 16 * 6_000, "pool={pool}");
        assert!(pool < 40 * 6_000, "pool={pool}");
    }

    #[test]
    #[should_panic(expected = "multiple of tp")]
    fn cluster_rejects_ragged_tp() {
        ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), 8, 12);
    }
}
