//! Analytical cost model for the serving-engine substrate.
//!
//! The simulator implements the *real* memory-management data structures
//! (radix tree, paged pool, LRU) — only step *time* is modeled, with a
//! standard roofline: each engine iteration is
//! `max(compute_time, memory_time) + fixed overhead`.
//!
//! * prefill tokens pay `2·N_active` dense FLOPs plus the O(L²) attention
//!   term — this is what makes eviction-induced *recompute* ("retransmission"
//!   in the paper's congestion-control analogy) quadratically expensive;
//! * decode tokens are memory-bound: the weights are streamed once per
//!   iteration and each running sequence streams its KV context;
//! * KV offload/reload traffic goes over a contended host link (see
//!   [`pcie`]), reproducing Fig. 1c's crossover.

pub mod pcie;
pub mod specs;
pub mod storage;

pub use pcie::PcieLink;
pub use specs::{ClusterSpec, GpuSpec, KvLayout, ModelSpec};
pub use storage::StorageLink;

use crate::core::Micros;

/// Work submitted to one engine iteration.
#[derive(Debug, Clone, Default)]
pub struct StepWork {
    /// New prompt tokens prefilled this step (cache misses only).
    pub prefill_tokens: u64,
    /// Σ over prefilled tokens of their context length (for the O(L²) term).
    pub prefill_ctx_tokens: u64,
    /// Number of sequences doing a decode step.
    pub decode_seqs: u64,
    /// Σ context length over decoding sequences (KV bytes streamed).
    pub decode_ctx_tokens: u64,
}

impl StepWork {
    pub fn is_empty(&self) -> bool {
        self.prefill_tokens == 0 && self.decode_seqs == 0
    }
}

/// Roofline step-time model for one TP-sharded replica.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub cluster: ClusterSpec,
    /// Fixed per-iteration overhead (scheduler, kernel launches, TP sync).
    pub step_overhead: Micros,
}

impl CostModel {
    pub fn new(cluster: ClusterSpec) -> CostModel {
        CostModel { cluster, step_overhead: Micros(2_000) }
    }

    /// Time for one engine iteration executing `work`.
    ///
    /// Serving engines run an iteration as *prefill chunk, then decode
    /// batch* (SGLang's scheduler), so the two phases add rather than
    /// overlap; the weights are streamed from HBM once per iteration
    /// regardless of phase (for MoE models prefill touches every expert).
    /// This additive structure is what makes eviction-induced recompute
    /// directly inflate decode latency — the thrashing tax.
    pub fn step_time(&self, work: &StepWork) -> Micros {
        if work.is_empty() {
            return Micros::ZERO;
        }
        let m = &self.cluster.model;
        let tflops = self.cluster.agg_tflops() * 1e12;
        let bw = self.cluster.agg_hbm_bw() * 1e9;

        // Weights stream once per iteration.
        let t_weights = m.weights.0 as f64 / bw;

        // Prefill: dense FLOPs + quadratic attention term (compute-bound).
        let prefill_flops = work.prefill_tokens as f64 * m.flops_per_token()
            + work.prefill_ctx_tokens as f64 * m.attn_flops_per_ctx_token();
        let t_prefill = prefill_flops / (tflops * m.prefill_efficiency);

        // Decode: bandwidth-bound KV streaming + (small) dense FLOPs.
        let decode_bytes =
            work.decode_ctx_tokens as f64 * m.kv_bytes_per_token() as f64;
        let decode_flops = work.decode_seqs as f64 * m.flops_per_token();
        let t_decode = (decode_bytes / bw).max(decode_flops / tflops);

        self.step_overhead
            + Micros::from_secs_f64(t_weights + t_prefill + t_decode)
    }

    /// Time to prefill `tokens` of context from scratch (the recompute
    /// penalty paid when an evicted prefix must be rebuilt): used both by
    /// the engine accounting and the Fig. 1c harness.
    pub fn recompute_time(&self, tokens: u64) -> Micros {
        let work = StepWork {
            prefill_tokens: tokens,
            // context grows 0..tokens → sum ≈ tokens²/2
            prefill_ctx_tokens: tokens * tokens / 2,
            ..Default::default()
        };
        self.step_time(&work)
    }

    /// Time to prefill `tokens` new tokens on top of `start_ctx` tokens of
    /// already-materialized context — the compute price the dual-path
    /// policy weighs against a storage reload of the same span.  Context
    /// grows `start_ctx..start_ctx+tokens`, so the attention-term sum is
    /// `(2·start_ctx + tokens)·tokens / 2`.
    pub fn prefill_time(&self, tokens: u64, start_ctx: u64) -> Micros {
        if tokens == 0 {
            return Micros::ZERO;
        }
        let work = StepWork {
            prefill_tokens: tokens,
            prefill_ctx_tokens: (2 * start_ctx + tokens) * tokens / 2,
            ..Default::default()
        };
        self.step_time(&work)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qwen3_tp8() -> CostModel {
        CostModel::new(ClusterSpec::new(
            GpuSpec::h100(),
            ModelSpec::qwen3_32b(),
            8,
            8,
        ))
    }

    #[test]
    fn empty_step_is_free() {
        assert_eq!(qwen3_tp8().step_time(&StepWork::default()), Micros::ZERO);
    }

    #[test]
    fn decode_step_is_memory_bound() {
        let cm = qwen3_tp8();
        // 64 sequences decoding at 4k context: weights (65.6GB) dominate.
        let work = StepWork {
            decode_seqs: 64,
            decode_ctx_tokens: 64 * 4096,
            ..Default::default()
        };
        let t = cm.step_time(&work);
        // weights / (8 * 3.35 TB/s) ≈ 2.45 ms plus KV ≈ 2.6 ms + overhead.
        assert!(t > Micros(3_000) && t < Micros(12_000), "t={t}");
    }

    #[test]
    fn prefill_scales_quadratically_with_context() {
        let cm = qwen3_tp8();
        let t1 = cm.recompute_time(2_000);
        let t2 = cm.recompute_time(8_000);
        // 4x tokens with an O(L²) term → much more than 4x the time once
        // the quadratic term matters, but bounded by 16x.
        let ratio = t2.0 as f64 / t1.0 as f64;
        assert!(ratio > 4.0 && ratio <= 16.0, "ratio={ratio}");
    }

    #[test]
    fn recompute_grows_with_tokens() {
        let cm = qwen3_tp8();
        let mut prev = Micros::ZERO;
        for tokens in [512, 1024, 2048, 4096, 8192] {
            let t = cm.recompute_time(tokens);
            assert!(t > prev, "recompute must be monotone: {t} after {prev}");
            prev = t;
        }
    }

    #[test]
    fn prefill_time_generalizes_recompute_time() {
        let cm = qwen3_tp8();
        for tokens in [512u64, 2_048, 8_192] {
            // From empty context the two formulas coincide (tokens²/2 vs
            // (2·0+tokens)·tokens/2).
            assert_eq!(cm.prefill_time(tokens, 0), cm.recompute_time(tokens));
        }
        // Deeper starting context → strictly more attention work.
        assert!(cm.prefill_time(1_024, 8_192) > cm.prefill_time(1_024, 0));
        assert_eq!(cm.prefill_time(0, 4_096), Micros::ZERO);
    }

    #[test]
    fn fewer_gpus_is_slower() {
        let mk = |tp| {
            CostModel::new(ClusterSpec::new(
                GpuSpec::h100(),
                ModelSpec::qwen3_32b(),
                tp,
                tp,
            ))
        };
        let work = StepWork {
            prefill_tokens: 4096,
            prefill_ctx_tokens: 4096 * 2048,
            decode_seqs: 32,
            decode_ctx_tokens: 32 * 4096,
        };
        assert!(mk(2).step_time(&work) > mk(8).step_time(&work));
    }
}
