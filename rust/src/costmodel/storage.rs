//! Contended storage-link (NVMe) model for the capacity KV tier below the
//! CPU tier.
//!
//! Same queue-depth congestion shape as [`PcieLink`](super::PcieLink) —
//! the Fig. 1c bandwidth pathology only deepens down-stack — but with
//! NVMe-class constants: roughly an order of magnitude less bandwidth
//! than a host bus, a much larger per-operation overhead (submission
//! queue, interrupt, filesystem indirection), and harsher degradation
//! under depth (SSD internal parallelism saturates quickly for the large
//! sequential reads KV extents are).
//!
//! This is what makes *reload vs recompute* a real decision (DualPath,
//! PAPERS.md): reading a long prefix back from storage can lose to simply
//! re-prefilling it once the link is deep in queued reloads.

use crate::core::{Bytes, Micros};

/// Shared, serializing storage link with queue-depth congestion.
#[derive(Debug, Clone)]
pub struct StorageLink {
    /// Aggregate storage read bandwidth in GB/s (NVMe-class).
    pub bandwidth_gbps: f64,
    /// Per-operation overhead (submission, interrupt, FS indirection).
    pub op_overhead: Micros,
    /// Congestion degradation per queued transfer:
    /// `eff_bw = bw / (1 + gamma * depth)`.
    pub gamma: f64,
    busy_until: Micros,
    /// Completion times of recent transfers (for queue-depth estimation).
    inflight: std::collections::VecDeque<Micros>,
    /// Total bytes moved (telemetry).
    pub bytes_moved: u64,
    /// Total transfers (telemetry).
    pub transfers: u64,
}

impl StorageLink {
    pub fn new(bandwidth_gbps: f64) -> StorageLink {
        StorageLink {
            bandwidth_gbps,
            op_overhead: Micros(1_500),
            gamma: 0.5,
            busy_until: Micros::ZERO,
            inflight: std::collections::VecDeque::new(),
            bytes_moved: 0,
            transfers: 0,
        }
    }

    /// Transfers still in flight at `now`.
    pub fn queue_depth(&mut self, now: Micros) -> usize {
        while self.inflight.front().is_some_and(|&t| t <= now) {
            self.inflight.pop_front();
        }
        self.inflight.len()
    }

    /// Raw wire time for `bytes` with no contention.
    pub fn wire_time(&self, bytes: Bytes) -> Micros {
        Micros::from_secs_f64(bytes.0 as f64 / (self.bandwidth_gbps * 1e9))
    }

    /// Schedule a read/write starting no earlier than `now`; returns its
    /// completion time.  Queues behind in-flight transfers and degrades
    /// effective bandwidth with depth, exactly like the host link.
    pub fn transfer(&mut self, now: Micros, bytes: Bytes) -> Micros {
        let depth = self.queue_depth(now);
        let start = if self.busy_until > now { self.busy_until } else { now };
        let eff_bw = self.bandwidth_gbps / (1.0 + self.gamma * depth as f64);
        let wire = Micros::from_secs_f64(bytes.0 as f64 / (eff_bw * 1e9));
        let done = start + wire + self.op_overhead;
        self.busy_until = done;
        self.inflight.push_back(done);
        self.bytes_moved += bytes.0;
        self.transfers += 1;
        done
    }

    /// Latency (not completion time) a transfer issued at `now` would see,
    /// using the same queue-depth-degraded effective bandwidth
    /// [`transfer`](StorageLink::transfer) applies — the dual-path policy
    /// prices reloads with this, so its estimate equals the realized
    /// completion for a transfer issued immediately after.
    pub fn latency_at(&self, now: Micros, bytes: Bytes) -> Micros {
        let queue = self.busy_until.saturating_sub(now);
        // Same depth `transfer` would observe: completions after `now`
        // (read-only — `queue_depth` pops, this must not).
        let depth = self.inflight.iter().filter(|&&t| t > now).count();
        let eff_bw = self.bandwidth_gbps / (1.0 + self.gamma * depth as f64);
        let wire = Micros::from_secs_f64(bytes.0 as f64 / (eff_bw * 1e9));
        queue + wire + self.op_overhead
    }

    pub fn reset(&mut self) {
        self.busy_until = Micros::ZERO;
        self.inflight.clear();
        self.bytes_moved = 0;
        self.transfers = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_matches_bandwidth() {
        let link = StorageLink::new(6.0);
        // 6 GB at 6 GB/s = 1 s.
        let t = link.wire_time(Bytes::from_gb(6.0));
        assert!((t.as_secs_f64() - 1.0).abs() < 1e-3, "t={t}");
    }

    #[test]
    fn transfers_serialize() {
        let mut link = StorageLink::new(6.0);
        let b = Bytes::from_gb(1.0);
        let t1 = link.transfer(Micros::ZERO, b);
        let t2 = link.transfer(Micros::ZERO, b);
        let t3 = link.transfer(Micros::ZERO, b);
        assert!(t2 > t1 && t3 > t2);
        assert!(t3.0 >= 3 * link.wire_time(b).0);
    }

    #[test]
    fn slower_and_costlier_than_host_link() {
        // The whole point of the tier: same bytes, strictly worse than the
        // default host link at every depth.
        let storage = StorageLink::new(6.0);
        let pcie = super::super::PcieLink::new(50.0);
        let b = Bytes::from_gb(1.0);
        assert!(storage.wire_time(b) > pcie.wire_time(b));
        assert!(storage.op_overhead > pcie.sync_overhead);
        assert!(storage.gamma > pcie.gamma);
    }

    #[test]
    fn latency_estimate_matches_realized_completion_when_queued() {
        let mut link = StorageLink::new(6.0);
        let b = Bytes::from_gb(1.0);
        link.transfer(Micros::ZERO, b);
        link.transfer(Micros::ZERO, b);
        let estimate = link.latency_at(Micros::ZERO, b);
        let realized = link.transfer(Micros::ZERO, b);
        assert_eq!(estimate, realized, "estimate must equal realized completion");
    }

    #[test]
    fn latency_monotone_nonincreasing_in_bandwidth() {
        // The dual-path crossover argument rests on this: at fixed queue
        // state, more bandwidth never makes a reload slower.
        let b = Bytes::from_gb(2.0);
        let mut prev = Micros(u64::MAX);
        for bw in [1.0, 2.0, 4.0, 8.0, 16.0] {
            let lat = StorageLink::new(bw).latency_at(Micros::ZERO, b);
            assert!(lat <= prev, "latency must not grow with bandwidth");
            prev = lat;
        }
    }

    #[test]
    fn telemetry_counts() {
        let mut link = StorageLink::new(6.0);
        link.transfer(Micros::ZERO, Bytes(100));
        link.transfer(Micros::ZERO, Bytes(200));
        assert_eq!(link.bytes_moved, 300);
        assert_eq!(link.transfers, 2);
        link.reset();
        assert_eq!(link.bytes_moved, 0);
        assert_eq!(link.transfers, 0);
    }
}
