//! Contended host-link (PCIe) model for the HiCache offload tier.
//!
//! The paper's Fig. 1c shows why cache-centric offloading loses at high
//! concurrency: each transfer is fast in isolation, but simultaneous
//! offload/reload traffic shares one link per GPU, so per-request latency
//! grows roughly linearly with the number of in-flight transfers (plus a
//! fixed synchronization overhead per operation).
//!
//! We model the link as a FIFO-served shared channel: a transfer issued at
//! time `t` with `n` bytes completes at
//! `max(t, busy_until) + bytes / bandwidth + sync_overhead`, i.e. transfers
//! serialize.  This reproduces the paper's shape: offload beats recompute
//! at low concurrency and inverts beyond a crossover.

use crate::core::{Bytes, Micros};

/// Shared, serializing host link with queue-depth congestion.
#[derive(Debug, Clone)]
pub struct PcieLink {
    /// Aggregate bandwidth in GB/s (across the TP group, host-bus capped).
    pub bandwidth_gbps: f64,
    /// Per-operation synchronization overhead (driver, stream sync).
    pub sync_overhead: Micros,
    /// Congestion degradation per queued transfer:
    /// `eff_bw = bw / (1 + gamma * depth)`.  Interleaved DMA, doorbell
    /// storms and bidirectional offload+reload traffic make the effective
    /// link throughput collapse under depth — the Fig. 1c effect.
    pub gamma: f64,
    busy_until: Micros,
    /// Completion times of recent transfers (for queue-depth estimation).
    inflight: std::collections::VecDeque<Micros>,
    /// Total bytes moved (telemetry).
    pub bytes_moved: u64,
    /// Total transfers (telemetry).
    pub transfers: u64,
}

impl PcieLink {
    pub fn new(bandwidth_gbps: f64) -> PcieLink {
        PcieLink {
            bandwidth_gbps,
            sync_overhead: Micros(300),
            gamma: 0.3,
            busy_until: Micros::ZERO,
            inflight: std::collections::VecDeque::new(),
            bytes_moved: 0,
            transfers: 0,
        }
    }

    /// Transfers still in flight at `now`.
    pub fn queue_depth(&mut self, now: Micros) -> usize {
        while self.inflight.front().is_some_and(|&t| t <= now) {
            self.inflight.pop_front();
        }
        self.inflight.len()
    }

    /// Raw wire time for `bytes` with no contention.
    pub fn wire_time(&self, bytes: Bytes) -> Micros {
        Micros::from_secs_f64(bytes.0 as f64 / (self.bandwidth_gbps * 1e9))
    }

    /// Schedule a transfer starting no earlier than `now`; returns its
    /// completion time.  Captures queueing behind in-flight transfers AND
    /// congestion collapse: the deeper the queue, the lower the effective
    /// bandwidth this transfer gets.
    pub fn transfer(&mut self, now: Micros, bytes: Bytes) -> Micros {
        let depth = self.queue_depth(now);
        let start = if self.busy_until > now { self.busy_until } else { now };
        let eff_bw = self.bandwidth_gbps / (1.0 + self.gamma * depth as f64);
        let wire = Micros::from_secs_f64(bytes.0 as f64 / (eff_bw * 1e9));
        let done = start + wire + self.sync_overhead;
        self.busy_until = done;
        self.inflight.push_back(done);
        self.bytes_moved += bytes.0;
        self.transfers += 1;
        done
    }

    /// Latency (not completion time) a transfer issued at `now` would
    /// see, using the same queue-depth-degraded effective bandwidth
    /// [`transfer`](PcieLink::transfer) applies — the estimate and the
    /// realized completion agree exactly for a queued transfer (raw
    /// `wire_time` here would under-estimate congested links).
    pub fn latency_at(&self, now: Micros, bytes: Bytes) -> Micros {
        let queue = self.busy_until.saturating_sub(now);
        // Same depth `transfer` would observe: completions after `now`
        // (read-only — `queue_depth` pops, this must not).
        let depth = self.inflight.iter().filter(|&&t| t > now).count();
        let eff_bw = self.bandwidth_gbps / (1.0 + self.gamma * depth as f64);
        let wire = Micros::from_secs_f64(bytes.0 as f64 / (eff_bw * 1e9));
        queue + wire + self.sync_overhead
    }

    pub fn reset(&mut self) {
        self.busy_until = Micros::ZERO;
        self.inflight.clear();
        self.bytes_moved = 0;
        self.transfers = 0;
    }

    /// Makespan of `n` simultaneous per-request transfers of `bytes` each,
    /// with congestion degradation: interleaved DMA, doorbell/sync storms
    /// and offload+reload bidirectional traffic reduce effective bandwidth
    /// as queue depth grows — `eff_bw(n) = bw / (1 + gamma·(n-1))`.
    ///
    /// `gamma` is calibrated so the offload-vs-recompute crossover lands
    /// where the paper's Fig. 1c puts it (O(10) concurrent requests).
    pub fn contended_makespan(&self, n: u32, bytes: Bytes, gamma: f64) -> Micros {
        if n == 0 {
            return Micros::ZERO;
        }
        let degraded = self.bandwidth_gbps / (1.0 + gamma * (n as f64 - 1.0));
        let wire_each = bytes.0 as f64 / (degraded * 1e9);
        Micros::from_secs_f64(wire_each * n as f64)
            + Micros(self.sync_overhead.0 * n as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_matches_bandwidth() {
        let link = PcieLink::new(50.0);
        // 6.67 GB at 50 GB/s = 133.4 ms.
        let t = link.wire_time(Bytes::from_gb(6.67));
        assert!((t.as_secs_f64() - 0.1334).abs() < 1e-3, "t={t}");
    }

    #[test]
    fn transfers_serialize() {
        let mut link = PcieLink::new(50.0);
        let b = Bytes::from_gb(1.0);
        let t1 = link.transfer(Micros::ZERO, b);
        let t2 = link.transfer(Micros::ZERO, b);
        let t3 = link.transfer(Micros::ZERO, b);
        assert!(t2 > t1 && t3 > t2);
        // Third completes ≈ 3x the single-transfer latency.
        assert!(t3.0 >= 3 * link.wire_time(b).0);
    }

    #[test]
    fn idle_link_has_no_queue() {
        let mut link = PcieLink::new(50.0);
        let b = Bytes::from_gb(1.0);
        let done = link.transfer(Micros(1_000_000), b);
        // Issue far in the future: no queueing behind earlier traffic.
        let lat = link.latency_at(Micros(10_000_000), b);
        assert_eq!(lat, link.wire_time(b) + link.sync_overhead);
        assert!(done < Micros(10_000_000));
    }

    #[test]
    fn latency_grows_with_concurrency_fig1c_shape() {
        // Reproduce the Fig. 1c setup shape: per-request 6.67 GB transfers,
        // rising concurrency → rising per-request latency, while prefill
        // recompute stays constant per request.
        let per_req = Bytes::from_gb(6.67);
        let mut last = Micros::ZERO;
        for conc in [1u32, 4, 16, 64] {
            let mut link = PcieLink::new(50.0);
            let mut worst = Micros::ZERO;
            for _ in 0..conc {
                worst = link.transfer(Micros::ZERO, per_req);
            }
            assert!(worst > last);
            last = worst;
        }
    }

    #[test]
    fn latency_estimate_matches_realized_completion_when_queued() {
        // Regression: `latency_at` used raw `wire_time` while `transfer`
        // applies queue-depth-degraded effective bandwidth, so estimates
        // under-predicted congested links.  Pin estimate == realized for
        // a transfer queued behind two in-flight ones.
        let mut link = PcieLink::new(50.0);
        let b = Bytes::from_gb(1.0);
        link.transfer(Micros::ZERO, b);
        link.transfer(Micros::ZERO, b);
        // The old formula: queue drain + raw wire time + sync.
        let naive = link.busy_until + link.wire_time(b) + link.sync_overhead;
        let estimate = link.latency_at(Micros::ZERO, b);
        // Issued at t=0, so the completion time IS the latency.
        let realized = link.transfer(Micros::ZERO, b);
        assert_eq!(estimate, realized, "estimate must equal realized completion");
        assert!(estimate > naive, "depth-degraded wire time must exceed the raw one");
    }

    #[test]
    fn telemetry_counts() {
        let mut link = PcieLink::new(50.0);
        link.transfer(Micros::ZERO, Bytes(100));
        link.transfer(Micros::ZERO, Bytes(200));
        assert_eq!(link.bytes_moved, 300);
        assert_eq!(link.transfers, 2);
        link.reset();
        assert_eq!(link.bytes_moved, 0);
    }
}
