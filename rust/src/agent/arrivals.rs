//! Open-loop session arrival process.
//!
//! Closed-batch runs start every agent at t=0 and drain the fleet;
//! production traffic does not work that way.  This module turns a
//! generated fleet into an *open-loop* session population: each
//! multi-turn session gets a seeded Poisson arrival instant (with a
//! diurnal rate curve — thinning against the peak rate), a tenant
//! priority class, a patience bound after which a stalled turn makes the
//! session abandon, and an extra lognormal *think time* idled between
//! turns on top of the tool latency.  Sessions return to the admission
//! queue after every think — warm if their KV survived the interim,
//! cold if eviction or a fault took it, exactly as the cache decides.
//!
//! Everything is drawn from forked streams of `OpenLoopConfig::seed`,
//! independent of the workload seed: the same session population can be
//! replayed under different traffic timings, and a fixed seed replays
//! bit-identically.

use crate::config::{OpenLoopConfig, WorkloadConfig};
use crate::core::{Micros, Rng};

use super::{Agent, Priority, WorkloadGenerator};

/// Exponential inter-event gap with the given rate (events per second).
fn exp_gap(rng: &mut Rng, rate_per_s: f64) -> f64 {
    // 1 - u is in (0, 1], so the log is finite and non-positive.
    -(1.0 - rng.next_f64()).ln() / rate_per_s
}

/// Generate the open-loop session population: the workload's fleet with
/// arrival instants, priority classes, patience and think times filled
/// in.  Arrival instants are non-decreasing in agent id, so the fleet
/// doubles as the arrival schedule.
pub fn open_loop_fleet(workload: &WorkloadConfig, ol: &OpenLoopConfig) -> Vec<Agent> {
    assert!(ol.enabled, "open_loop_fleet needs open_loop.enabled");
    let mut agents = WorkloadGenerator::new(workload.clone()).generate();
    let mut root = Rng::new(ol.seed);
    let mut arr = root.fork(1);
    let mut class = root.fork(2);
    let mut think = root.fork(3);

    let lambda = ol.arrival_rate_per_s;
    let amp = ol.diurnal_amplitude;
    let lam_max = lambda * (1.0 + amp);
    let patience = if ol.patience_s > 0.0 {
        Some(Micros::from_secs_f64(ol.patience_s))
    } else {
        None
    };

    let mut t = 0.0f64; // seconds
    for a in agents.iter_mut() {
        // Inhomogeneous Poisson by thinning: draw candidate gaps at the
        // peak rate, accept each candidate with probability
        // rate(t)/λmax where rate(t) = λ·(1 + A·sin(2πt/P)).
        loop {
            t += exp_gap(&mut arr, lam_max);
            if amp == 0.0 {
                break; // homogeneous: every candidate is real
            }
            let phase = (2.0 * std::f64::consts::PI * t) / ol.diurnal_period_s;
            let rate = lambda * (1.0 + amp * phase.sin());
            if arr.next_f64() * lam_max < rate {
                break;
            }
        }
        a.arrival_at = Micros::from_secs_f64(t);
        a.priority = if class.chance(ol.high_priority_share) {
            Priority::High
        } else {
            Priority::Low
        };
        a.patience = patience;
        // Think time between turns: the session idles after each tool
        // observation before issuing its next turn.  The final step has
        // no tool wait (the trajectory ends at its completion).
        for step in a.plan.iter_mut() {
            if !step.tool_tokens.is_empty() {
                let idle = think.lognormal(ol.think_mu, ol.think_sigma);
                step.tool_latency = step.tool_latency + Micros::from_secs_f64(idle);
            }
        }
    }
    agents
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OpenLoopConfig, WorkloadConfig};

    fn small() -> WorkloadConfig {
        WorkloadConfig { n_agents: 40, steps_min: 2, steps_max: 4, ..WorkloadConfig::default() }
    }

    #[test]
    fn fixed_seed_replays_bit_identically() {
        let ol = OpenLoopConfig::on();
        let a = open_loop_fleet(&small(), &ol);
        let b = open_loop_fleet(&small(), &ol);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_at, y.arrival_at);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.patience, y.patience);
            let lx: Vec<_> = x.plan_for_stats().iter().map(|s| s.tool_latency).collect();
            let ly: Vec<_> = y.plan_for_stats().iter().map(|s| s.tool_latency).collect();
            assert_eq!(lx, ly);
        }
        // A different traffic seed moves arrivals without touching the
        // session population itself.
        let c = open_loop_fleet(&small(), &OpenLoopConfig { seed: 99, ..ol });
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival_at != y.arrival_at));
        assert_eq!(a[0].context_len(), c[0].context_len());
    }

    #[test]
    fn arrivals_are_monotone_and_roughly_match_the_rate() {
        let mut ol = OpenLoopConfig::on();
        ol.arrival_rate_per_s = 2.0;
        ol.diurnal_amplitude = 0.0;
        let fleet = open_loop_fleet(&small(), &ol);
        let times: Vec<Micros> = fleet.iter().map(|a| a.arrival_at).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "arrivals must be sorted");
        assert!(times[0] > Micros::ZERO);
        // 40 sessions at λ=2/s should span roughly 20 s (generously
        // bounded: the variance of a 40-sample Poisson horizon is small).
        let span = times.last().unwrap().as_secs_f64();
        assert!((10.0..40.0).contains(&span), "span={span}");
    }

    #[test]
    fn diurnal_modulation_shifts_mass_toward_the_peak() {
        // One full period covering the fleet: more arrivals land in the
        // first half-period (sin > 0, boosted rate) than the second.
        let mut ol = OpenLoopConfig::on();
        ol.arrival_rate_per_s = 4.0;
        ol.diurnal_amplitude = 0.9;
        ol.diurnal_period_s = 20.0;
        let mut w = small();
        w.n_agents = 64;
        let fleet = open_loop_fleet(&w, &ol);
        let (mut peak, mut trough) = (0usize, 0usize);
        for a in &fleet {
            let s = a.arrival_at.as_secs_f64() % ol.diurnal_period_s;
            if s < ol.diurnal_period_s / 2.0 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(peak > trough, "peak={peak} trough={trough}");
    }

    #[test]
    fn classes_patience_and_think_time_are_assigned() {
        let mut ol = OpenLoopConfig::on();
        ol.high_priority_share = 0.5;
        let fleet = open_loop_fleet(&small(), &ol);
        let high = fleet.iter().filter(|a| a.priority == Priority::High).count();
        assert!(high > 0 && high < fleet.len(), "both classes must appear");
        assert!(fleet.iter().all(|a| a.patience == Some(Micros(60_000_000))));
        // Think time strictly inflates every non-final turn's idle gap
        // relative to the closed-batch plan.
        let closed = WorkloadGenerator::new(small()).generate();
        for (o, c) in fleet.iter().zip(&closed) {
            for (so, sc) in o.plan_for_stats().iter().zip(c.plan_for_stats()) {
                if !so.tool_tokens.is_empty() {
                    assert!(so.tool_latency > sc.tool_latency);
                }
            }
        }
        // Patience 0 means infinitely patient.
        ol.patience_s = 0.0;
        let fleet = open_loop_fleet(&small(), &ol);
        assert!(fleet.iter().all(|a| a.patience.is_none()));
    }
}
