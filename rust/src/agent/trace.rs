//! Trace export/import for agent trajectories (JSON lines).
//!
//! `concur trace --out f.jsonl` dumps the deterministic workload so runs
//! can be inspected, diffed across schedulers, or replayed elsewhere.

use std::io::Write as _;

use crate::core::json::Value;
use crate::core::{ConcurError, Micros, Result};
use crate::json_obj;

use super::Agent;

/// One line per agent: ids, step shape and latencies (token *contents* are
/// reproducible from the seed, so only lengths are recorded).
pub fn agent_to_json(a: &Agent) -> Value {
    let steps: Vec<Value> = a
        .plan_for_stats()
        .iter()
        .map(|s| {
            json_obj! {
                "gen_tokens" => s.gen.len(),
                "tool_tokens" => s.tool_tokens.len(),
                "tool_latency_s" => s.tool_latency.as_secs_f64(),
            }
        })
        .collect();
    json_obj! {
        "agent" => a.id.0,
        "initial_context" => a.context_len(),
        "steps" => Value::Array(steps),
    }
}

/// Write a fleet as JSON-lines.
pub fn write_trace(path: &std::path::Path, agents: &[Agent]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    for a in agents {
        writeln!(f, "{}", agent_to_json(a).to_string_compact())?;
    }
    Ok(())
}

/// Summary of a parsed trace (validation / analysis).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSummary {
    pub n_agents: usize,
    pub total_steps: usize,
    pub total_gen_tokens: u64,
    pub mean_tool_latency: Micros,
}

/// Parse a JSON-lines trace back into a summary.
pub fn read_trace_summary(path: &std::path::Path) -> Result<TraceSummary> {
    let text = std::fs::read_to_string(path)?;
    summarize_trace_text(&text)
}

pub fn summarize_trace_text(text: &str) -> Result<TraceSummary> {
    let mut s = TraceSummary::default();
    let mut lat_sum = 0f64;
    let mut lat_n = 0u64;
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let v = Value::parse(line)?;
        s.n_agents += 1;
        let steps = v
            .get("steps")
            .as_array()
            .ok_or_else(|| ConcurError::config("trace line missing steps"))?;
        s.total_steps += steps.len();
        for st in steps {
            s.total_gen_tokens += st.get("gen_tokens").as_u64().unwrap_or(0);
            lat_sum += st.get("tool_latency_s").as_f64().unwrap_or(0.0);
            lat_n += 1;
        }
    }
    if lat_n > 0 {
        s.mean_tool_latency = Micros::from_secs_f64(lat_sum / lat_n as f64);
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::WorkloadGenerator;
    use crate::config::WorkloadConfig;

    #[test]
    fn trace_roundtrip() {
        let cfg = WorkloadConfig { n_agents: 6, ..Default::default() };
        let agents = WorkloadGenerator::new(cfg).generate();
        let dir = std::env::temp_dir().join("concur_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.jsonl");
        write_trace(&path, &agents).unwrap();
        let s = read_trace_summary(&path).unwrap();
        assert_eq!(s.n_agents, 6);
        assert_eq!(
            s.total_gen_tokens,
            agents.iter().map(|a| a.total_gen_tokens()).sum::<u64>()
        );
        assert!(s.mean_tool_latency.0 > 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_trace_rejected() {
        assert!(summarize_trace_text("{not json}").is_err());
        assert!(summarize_trace_text(r#"{"agent": 1}"#).is_err());
    }
}
