//! Synthetic ReAct workload generator.
//!
//! Reproduces the statistical shape of the paper's real-world agent traces:
//!
//! * a shared *system prompt* per task family → the warmup-phase prefix
//!   overlap that yields ~90% hit rates (Fig. 3a, yellow region);
//! * monotone context growth of roughly 1.2k → 10-12k tokens over ~10
//!   ReAct steps (Fig. 1a);
//! * lognormal tool latencies → asynchronous agent progress, the trigger
//!   for recency inversion under LRU;
//! * token *content* is unique per (agent, step) except for shared
//!   prefixes, so radix-tree reuse is exactly agent-history reuse.
//!
//! Generation is seeded and deterministic: the same `WorkloadConfig`
//! produces bit-identical trajectories for every scheduler under test.

use crate::config::WorkloadConfig;
use crate::core::{AgentId, Micros, Rng, Token};

use super::{Agent, StepPlan};

/// Token-id allocator: unique content lives above this base so family
/// system prompts (low ids) never collide with generated/tool tokens.
const UNIQUE_BASE: Token = 1 << 24;

/// Builds deterministic agent fleets from a [`WorkloadConfig`].
pub struct WorkloadGenerator {
    cfg: WorkloadConfig,
    rng: Rng,
    next_unique: Token,
}

/// Aggregate shape statistics (used by the Fig. 1 harness and tests).
#[derive(Debug, Clone, Default)]
pub struct WorkloadStats {
    pub n_agents: usize,
    /// Mean context length (tokens) at the *start* of each step index,
    /// over agents that reach that step.
    pub ctx_at_step: Vec<f64>,
    pub total_gen_tokens: u64,
    pub total_prompt_tokens: u64,
    pub mean_steps: f64,
}

impl WorkloadGenerator {
    pub fn new(cfg: WorkloadConfig) -> WorkloadGenerator {
        let rng = Rng::new(cfg.seed);
        WorkloadGenerator { cfg, rng, next_unique: UNIQUE_BASE }
    }

    fn unique_run(&mut self, n: u32) -> Vec<Token> {
        let start = self.next_unique;
        self.next_unique += n;
        (start..start + n).collect()
    }

    fn range_sample(&mut self, lo: u32, hi: u32) -> u32 {
        if lo >= hi {
            lo
        } else {
            self.rng.gen_range(lo as u64, hi as u64 + 1) as u32
        }
    }

    /// Generate the agent fleet.
    pub fn generate(&mut self) -> Vec<Agent> {
        let cfg = self.cfg.clone();
        // Family system prompts: shared low-id runs.
        let families: Vec<Vec<Token>> = (0..cfg.task_families)
            .map(|f| {
                let base = f * cfg.system_prompt_tokens;
                (base..base + cfg.system_prompt_tokens).collect()
            })
            .collect();

        (0..cfg.n_agents)
            .map(|i| {
                let family = &families[i % families.len()];
                let mut ctx = family.clone();
                let init = self.range_sample(cfg.initial_prompt_min, cfg.initial_prompt_max);
                ctx.extend(self.unique_run(init));

                let steps = self.range_sample(cfg.steps_min, cfg.steps_max);
                let plan: Vec<StepPlan> = (0..steps)
                    .map(|k| {
                        let gen_n =
                            self.range_sample(cfg.gen_tokens_min, cfg.gen_tokens_max);
                        let tool_n =
                            self.range_sample(cfg.tool_tokens_min, cfg.tool_tokens_max);
                        let last = k + 1 == steps;
                        let lat = self
                            .rng
                            .lognormal(cfg.tool_latency_mu, cfg.tool_latency_sigma);
                        StepPlan {
                            gen: self.unique_run(gen_n),
                            tool_tokens: if last {
                                Vec::new()
                            } else {
                                self.unique_run(tool_n)
                            },
                            tool_latency: Micros::from_secs_f64(lat),
                        }
                    })
                    .collect();
                Agent::new(AgentId(i as u64), ctx, plan)
            })
            .collect()
    }

    /// Shape statistics for a fleet (simulating context growth without
    /// running an engine).
    pub fn stats(agents: &[Agent]) -> WorkloadStats {
        let mut stats = WorkloadStats {
            n_agents: agents.len(),
            ..WorkloadStats::default()
        };
        let max_steps = agents.iter().map(|a| a.steps_total()).max().unwrap_or(0);
        let mut sums = vec![0f64; max_steps];
        let mut counts = vec![0u64; max_steps];
        for a in agents {
            // Replay context growth from the plan.
            let mut ctx = a.context_len() as u64;
            stats.total_gen_tokens += a.total_gen_tokens();
            for (k, step) in a.plan_for_stats().iter().enumerate() {
                sums[k] += ctx as f64;
                counts[k] += 1;
                stats.total_prompt_tokens += ctx;
                ctx += step.gen.len() as u64 + step.tool_tokens.len() as u64;
            }
            stats.mean_steps += a.steps_total() as f64;
        }
        stats.mean_steps /= agents.len().max(1) as f64;
        stats.ctx_at_step = (0..max_steps)
            .filter(|&k| counts[k] > 0)
            .map(|k| sums[k] / counts[k] as f64)
            .collect();
        stats
    }
}

impl Agent {
    /// Read-only view of the plan (stats/tracing only).
    pub fn plan_for_stats(&self) -> &[StepPlan] {
        &self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn gen(cfg: WorkloadConfig) -> Vec<Agent> {
        WorkloadGenerator::new(cfg).generate()
    }

    #[test]
    fn deterministic_across_invocations() {
        let a = gen(WorkloadConfig::default());
        let b = gen(WorkloadConfig::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context_len(), y.context_len());
            assert_eq!(x.steps_total(), y.steps_total());
            assert_eq!(x.total_gen_tokens(), y.total_gen_tokens());
        }
    }

    #[test]
    fn different_seed_different_fleet() {
        let a = gen(WorkloadConfig::default());
        let b = gen(WorkloadConfig { seed: 99, ..WorkloadConfig::default() });
        let ta: u64 = a.iter().map(|x| x.total_gen_tokens()).sum();
        let tb: u64 = b.iter().map(|x| x.total_gen_tokens()).sum();
        assert_ne!(ta, tb);
    }

    #[test]
    fn agents_share_family_prefix() {
        let cfg = WorkloadConfig { n_agents: 8, task_families: 2, ..Default::default() };
        let agents = gen(cfg.clone());
        // Agents 0 and 2 are family 0; their first `system_prompt_tokens`
        // match; agent 1 (family 1) differs.
        let sys = cfg.system_prompt_tokens as usize;
        let h0 = agents[0].context();
        let h2 = agents[2].context();
        let h1 = agents[1].context();
        assert_eq!(h0[..sys], h2[..sys]);
        assert_ne!(h0[..sys], h1[..sys]);
        // Beyond the system prompt, content is unique.
        assert_ne!(h0[sys..sys + 10], h2[sys..sys + 10]);
    }

    #[test]
    fn context_growth_matches_fig1a_shape() {
        // Defaults are calibrated to reach ~10k tokens by step 10.
        let agents = gen(WorkloadConfig { n_agents: 64, ..Default::default() });
        let stats = WorkloadGenerator::stats(&agents);
        assert!(stats.ctx_at_step.len() >= 8);
        let first = stats.ctx_at_step[0];
        let at10 = stats.ctx_at_step[9.min(stats.ctx_at_step.len() - 1)];
        assert!((800.0..2000.0).contains(&first), "start={first}");
        assert!((8_000.0..14_000.0).contains(&at10), "step10={at10}");
        // Strictly increasing.
        for w in stats.ctx_at_step.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn unique_tokens_never_collide_with_system_prompts() {
        let agents = gen(WorkloadConfig::default());
        for a in &agents {
            for step in a.plan_for_stats() {
                for &t in &step.gen {
                    assert!(t >= UNIQUE_BASE);
                }
            }
        }
    }

    #[test]
    fn tool_latencies_are_positive_and_varied() {
        let agents = gen(WorkloadConfig::default());
        let lats: Vec<u64> = agents
            .iter()
            .flat_map(|a| a.plan_for_stats().iter().map(|s| s.tool_latency.0))
            .collect();
        assert!(lats.iter().all(|&l| l > 0));
        let uniq: std::collections::HashSet<_> = lats.iter().collect();
        assert!(uniq.len() > lats.len() / 2);
    }
}
