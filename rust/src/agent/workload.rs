//! Synthetic ReAct workload generator.
//!
//! Reproduces the statistical shape of the paper's real-world agent traces:
//!
//! * a shared *system prompt* per task family → the warmup-phase prefix
//!   overlap that yields ~90% hit rates (Fig. 3a, yellow region);
//! * monotone context growth of roughly 1.2k → 10-12k tokens over ~10
//!   ReAct steps (Fig. 1a);
//! * lognormal tool latencies → asynchronous agent progress, the trigger
//!   for recency inversion under LRU;
//! * token *content* is unique per (agent, step) except for shared
//!   prefixes, so radix-tree reuse is exactly agent-history reuse.
//!
//! Generation is seeded and deterministic: the same `WorkloadConfig`
//! produces bit-identical trajectories for every scheduler under test.

use crate::config::WorkloadConfig;
use crate::core::{AgentId, Micros, Rng, Token};

use super::{Agent, StepPlan};

/// Token-id allocator: unique content lives above this base so family
/// system prompts (low ids) never collide with generated/tool tokens.
const UNIQUE_BASE: Token = 1 << 24;

/// Builds deterministic agent fleets from a [`WorkloadConfig`].
pub struct WorkloadGenerator {
    cfg: WorkloadConfig,
    rng: Rng,
    next_unique: Token,
}

/// Aggregate shape statistics (used by the Fig. 1 harness and tests).
#[derive(Debug, Clone, Default)]
pub struct WorkloadStats {
    pub n_agents: usize,
    /// Mean context length (tokens) at the *start* of each step index,
    /// over agents that reach that step.
    pub ctx_at_step: Vec<f64>,
    pub total_gen_tokens: u64,
    pub total_prompt_tokens: u64,
    pub mean_steps: f64,
}

impl WorkloadGenerator {
    pub fn new(cfg: WorkloadConfig) -> WorkloadGenerator {
        let rng = Rng::new(cfg.seed);
        WorkloadGenerator { cfg, rng, next_unique: UNIQUE_BASE }
    }

    fn unique_run(&mut self, n: u32) -> Vec<Token> {
        let start = self.next_unique;
        self.next_unique += n;
        (start..start + n).collect()
    }

    fn range_sample(&mut self, lo: u32, hi: u32) -> u32 {
        if lo >= hi {
            lo
        } else {
            self.rng.gen_range(lo as u64, hi as u64 + 1) as u32
        }
    }

    /// Generate the agent fleet.
    pub fn generate(&mut self) -> Vec<Agent> {
        let cfg = self.cfg.clone();
        // Family system prompts: shared low-id runs.
        let families: Vec<Vec<Token>> = (0..cfg.task_families)
            .map(|f| {
                let base = f * cfg.system_prompt_tokens;
                (base..base + cfg.system_prompt_tokens).collect()
            })
            .collect();

        (0..cfg.n_agents)
            .map(|i| {
                let family = &families[i % families.len()];
                let mut ctx = family.clone();
                let init = self.range_sample(cfg.initial_prompt_min, cfg.initial_prompt_max);
                ctx.extend(self.unique_run(init));

                let steps = self.range_sample(cfg.steps_min, cfg.steps_max);
                let plan: Vec<StepPlan> = (0..steps)
                    .map(|k| {
                        let gen_n =
                            self.range_sample(cfg.gen_tokens_min, cfg.gen_tokens_max);
                        let tool_n =
                            self.range_sample(cfg.tool_tokens_min, cfg.tool_tokens_max);
                        let last = k + 1 == steps;
                        let lat = self
                            .rng
                            .lognormal(cfg.tool_latency_mu, cfg.tool_latency_sigma);
                        StepPlan {
                            gen: self.unique_run(gen_n),
                            tool_tokens: if last {
                                Vec::new()
                            } else {
                                self.unique_run(tool_n)
                            },
                            tool_latency: Micros::from_secs_f64(lat),
                        }
                    })
                    .collect();
                Agent::new(AgentId(i as u64), ctx, plan)
            })
            .collect()
    }

    /// Shape statistics for a fleet (simulating context growth without
    /// running an engine).
    pub fn stats(agents: &[Agent]) -> WorkloadStats {
        let mut stats = WorkloadStats {
            n_agents: agents.len(),
            ..WorkloadStats::default()
        };
        let max_steps = agents.iter().map(|a| a.steps_total()).max().unwrap_or(0);
        let mut sums = vec![0f64; max_steps];
        let mut counts = vec![0u64; max_steps];
        for a in agents {
            // Replay context growth from the plan.
            let mut ctx = a.context_len() as u64;
            stats.total_gen_tokens += a.total_gen_tokens();
            for (k, step) in a.plan_for_stats().iter().enumerate() {
                sums[k] += ctx as f64;
                counts[k] += 1;
                stats.total_prompt_tokens += ctx;
                ctx += step.gen.len() as u64 + step.tool_tokens.len() as u64;
            }
            stats.mean_steps += a.steps_total() as f64;
        }
        stats.mean_steps /= agents.len().max(1) as f64;
        stats.ctx_at_step = (0..max_steps)
            .filter(|&k| counts[k] > 0)
            .map(|k| sums[k] / counts[k] as f64)
            .collect();
        stats
    }
}

impl Agent {
    /// Read-only view of the plan (stats/tracing only).
    pub fn plan_for_stats(&self) -> &[StepPlan] {
        &self.plan
    }
}

/// Dependency DAG of a workflow fleet: which agents are released when a
/// node finishes, and how many unfinished dependencies each node still
/// has.  The cluster owns a mutable copy and drives release through the
/// existing slot path: only indegree-0 nodes are registered at start;
/// [`on_finished`](WorkflowGraph::on_finished) surfaces newly-ready
/// nodes as their last dependency completes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkflowGraph {
    /// `children[i]` = agents whose indegree drops when agent `i`
    /// finishes (indexed by dense `AgentId`).
    children: Vec<Vec<AgentId>>,
    /// Remaining unfinished dependencies per agent.
    indegree: Vec<u32>,
}

impl WorkflowGraph {
    /// An edge-free graph over `n` nodes (every node is a root).  This is
    /// what a non-workflow fleet looks like to release logic.
    pub fn independent(n: usize) -> WorkflowGraph {
        WorkflowGraph { children: vec![Vec::new(); n], indegree: vec![0; n] }
    }

    pub fn len(&self) -> usize {
        self.indegree.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indegree.is_empty()
    }

    /// Is this node free of unfinished dependencies (admissible now)?
    pub fn is_ready(&self, a: AgentId) -> bool {
        self.indegree[a.0 as usize] == 0
    }

    /// Downstream consumers released by this node's completion.
    pub fn children_of(&self, a: AgentId) -> &[AgentId] {
        &self.children[a.0 as usize]
    }

    /// Record a node's completion: decrement each child's indegree and
    /// return the children that just became ready, in child order
    /// (deterministic release order).
    pub fn on_finished(&mut self, a: AgentId) -> Vec<AgentId> {
        let mut ready = Vec::new();
        for &c in &self.children[a.0 as usize] {
            let d = &mut self.indegree[c.0 as usize];
            debug_assert!(*d > 0, "child {c} released twice");
            *d -= 1;
            if *d == 0 {
                ready.push(c);
            }
        }
        ready
    }
}

/// Generate a workflow fleet: `cfg.workflow.graphs` independent DAGs,
/// each a planner whose first step *produces* a shared intermediate
/// context, fan-out workers whose prompts embed that context
/// byte-identically, and — for the map-reduce share — a reducer joining
/// on every worker.  Agent ids are dense and sequential in creation
/// order (planner, workers, reducer per graph), which the cluster's
/// registration loop requires.
///
/// Content layout (W = `align_tokens`, S = the graph's shared context):
///
/// * planner prompt  = `family ++ unique`, and its step-0 generation
///   ends with `pad ++ S` padded so S starts on a W-aligned offset of
///   the planner's accumulated context — S sits *mid-prompt* in every
///   later planner step, visible only to content-hash detection;
/// * worker prompt   = `family ++ pad ++ S ++ unique`, the pad shared
///   per graph, so siblings share `family ++ pad ++ S` as an ordinary
///   radix prefix and S starts W-aligned here too;
/// * reducer prompt  = same layout as a worker.
///
/// Shape draws (fan-out width, map-reduce coin) come from
/// `workflow.seed`; token content comes from the workload seed via the
/// same [`WorkloadGenerator`] machinery as the plain fleet.
pub fn workflow_fleet(cfg: &WorkloadConfig) -> (Vec<Agent>, WorkflowGraph) {
    let wf = cfg.workflow;
    assert!(wf.enabled, "workflow_fleet called with workflow disabled");
    let mut g = WorkloadGenerator::new(cfg.clone());
    let mut shape = Rng::new(wf.seed);
    let w = wf.align_tokens as u64;

    let families: Vec<Vec<Token>> = (0..cfg.task_families)
        .map(|f| {
            let base = f * cfg.system_prompt_tokens;
            (base..base + cfg.system_prompt_tokens).collect()
        })
        .collect();

    let mut agents: Vec<Agent> = Vec::new();
    let mut children: Vec<Vec<AgentId>> = Vec::new();
    let mut indegree: Vec<u32> = Vec::new();

    for gi in 0..wf.graphs {
        let family = &families[gi % families.len()];
        let fanout = if wf.fanout_min >= wf.fanout_max {
            wf.fanout_min
        } else {
            shape.gen_range(wf.fanout_min as u64, wf.fanout_max as u64 + 1) as u32
        };
        let map_reduce = shape.chance(wf.map_reduce_share);
        let shared = g.unique_run(wf.shared_context_tokens);
        // Pad shared per graph: workers prefix-share `family ++ pad ++ S`.
        let worker_pad_len = (w - (family.len() as u64 % w)) % w;
        let worker_pad = g.unique_run(worker_pad_len as u32);

        let planner_id = AgentId(agents.len() as u64);
        // Planner: plain prompt; step 0 generates `pad ++ S` at a
        // W-aligned offset of the accumulated context.
        let init = g.range_sample(cfg.initial_prompt_min, cfg.initial_prompt_max);
        let mut ctx = family.clone();
        ctx.extend(g.unique_run(init));
        let steps = g.range_sample(cfg.steps_min, cfg.steps_max);
        let plan: Vec<StepPlan> = (0..steps)
            .map(|k| {
                let gen_n = g.range_sample(cfg.gen_tokens_min, cfg.gen_tokens_max);
                let tool_n = g.range_sample(cfg.tool_tokens_min, cfg.tool_tokens_max);
                let last = k + 1 == steps;
                let lat = g.rng.lognormal(cfg.tool_latency_mu, cfg.tool_latency_sigma);
                let mut gen = g.unique_run(gen_n);
                if k == 0 {
                    let off = (family.len() + init as usize + gen.len()) as u64;
                    let pad = (w - (off % w)) % w;
                    gen.extend(g.unique_run(pad as u32));
                    gen.extend_from_slice(&shared);
                }
                StepPlan {
                    gen,
                    tool_tokens: if last { Vec::new() } else { g.unique_run(tool_n) },
                    tool_latency: Micros::from_secs_f64(lat),
                }
            })
            .collect();
        agents.push(Agent::new(planner_id, ctx, plan));
        children.push(Vec::new());
        indegree.push(0);

        // Workers (and the reducer) embed the shared context mid-prompt.
        let consumer = |g: &mut WorkloadGenerator| {
            let mut ctx = family.clone();
            ctx.extend_from_slice(&worker_pad);
            ctx.extend_from_slice(&shared);
            let init = g.range_sample(cfg.initial_prompt_min, cfg.initial_prompt_max);
            ctx.extend(g.unique_run(init));
            let steps = g.range_sample(cfg.steps_min, cfg.steps_max);
            let plan: Vec<StepPlan> = (0..steps)
                .map(|k| {
                    let gen_n = g.range_sample(cfg.gen_tokens_min, cfg.gen_tokens_max);
                    let tool_n = g.range_sample(cfg.tool_tokens_min, cfg.tool_tokens_max);
                    let last = k + 1 == steps;
                    let lat =
                        g.rng.lognormal(cfg.tool_latency_mu, cfg.tool_latency_sigma);
                    StepPlan {
                        gen: g.unique_run(gen_n),
                        tool_tokens: if last { Vec::new() } else { g.unique_run(tool_n) },
                        tool_latency: Micros::from_secs_f64(lat),
                    }
                })
                .collect();
            (ctx, plan)
        };

        let mut worker_ids = Vec::with_capacity(fanout as usize);
        for _ in 0..fanout {
            let id = AgentId(agents.len() as u64);
            let (ctx, plan) = consumer(&mut g);
            agents.push(Agent::new(id, ctx, plan));
            children.push(Vec::new());
            indegree.push(1); // released by the planner
            children[planner_id.0 as usize].push(id);
            worker_ids.push(id);
        }
        if map_reduce {
            let id = AgentId(agents.len() as u64);
            let (ctx, plan) = consumer(&mut g);
            agents.push(Agent::new(id, ctx, plan));
            children.push(Vec::new());
            indegree.push(fanout); // released by the last worker
            for &wid in &worker_ids {
                children[wid.0 as usize].push(id);
            }
        }
    }
    (agents, WorkflowGraph { children, indegree })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn gen(cfg: WorkloadConfig) -> Vec<Agent> {
        WorkloadGenerator::new(cfg).generate()
    }

    #[test]
    fn deterministic_across_invocations() {
        let a = gen(WorkloadConfig::default());
        let b = gen(WorkloadConfig::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context_len(), y.context_len());
            assert_eq!(x.steps_total(), y.steps_total());
            assert_eq!(x.total_gen_tokens(), y.total_gen_tokens());
        }
    }

    #[test]
    fn different_seed_different_fleet() {
        let a = gen(WorkloadConfig::default());
        let b = gen(WorkloadConfig { seed: 99, ..WorkloadConfig::default() });
        let ta: u64 = a.iter().map(|x| x.total_gen_tokens()).sum();
        let tb: u64 = b.iter().map(|x| x.total_gen_tokens()).sum();
        assert_ne!(ta, tb);
    }

    #[test]
    fn agents_share_family_prefix() {
        let cfg = WorkloadConfig { n_agents: 8, task_families: 2, ..Default::default() };
        let agents = gen(cfg.clone());
        // Agents 0 and 2 are family 0; their first `system_prompt_tokens`
        // match; agent 1 (family 1) differs.
        let sys = cfg.system_prompt_tokens as usize;
        let h0 = agents[0].context();
        let h2 = agents[2].context();
        let h1 = agents[1].context();
        assert_eq!(h0[..sys], h2[..sys]);
        assert_ne!(h0[..sys], h1[..sys]);
        // Beyond the system prompt, content is unique.
        assert_ne!(h0[sys..sys + 10], h2[sys..sys + 10]);
    }

    #[test]
    fn context_growth_matches_fig1a_shape() {
        // Defaults are calibrated to reach ~10k tokens by step 10.
        let agents = gen(WorkloadConfig { n_agents: 64, ..Default::default() });
        let stats = WorkloadGenerator::stats(&agents);
        assert!(stats.ctx_at_step.len() >= 8);
        let first = stats.ctx_at_step[0];
        let at10 = stats.ctx_at_step[9.min(stats.ctx_at_step.len() - 1)];
        assert!((800.0..2000.0).contains(&first), "start={first}");
        assert!((8_000.0..14_000.0).contains(&at10), "step10={at10}");
        // Strictly increasing.
        for w in stats.ctx_at_step.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn unique_tokens_never_collide_with_system_prompts() {
        let agents = gen(WorkloadConfig::default());
        for a in &agents {
            for step in a.plan_for_stats() {
                for &t in &step.gen {
                    assert!(t >= UNIQUE_BASE);
                }
            }
        }
    }

    fn wf_cfg() -> WorkloadConfig {
        WorkloadConfig {
            workflow: crate::config::WorkflowConfig::on(),
            ..WorkloadConfig::default()
        }
    }

    #[test]
    fn workflow_fleet_is_deterministic_and_seed_sensitive() {
        let (a, ga) = workflow_fleet(&wf_cfg());
        let (b, gb) = workflow_fleet(&wf_cfg());
        assert_eq!(a.len(), b.len());
        assert_eq!(ga, gb);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context(), y.context());
            assert_eq!(x.steps_total(), y.steps_total());
        }
        // Perturbing the workflow seed moves the shape.
        let mut cfg = wf_cfg();
        cfg.workflow.seed += 1;
        let (c, gc) = workflow_fleet(&cfg);
        assert!(
            gc != ga || c.len() != a.len(),
            "workflow seed must influence the fleet"
        );
    }

    #[test]
    fn workflow_graph_has_dense_topo_structure() {
        let (agents, graph) = workflow_fleet(&wf_cfg());
        assert_eq!(agents.len(), graph.len());
        for (i, a) in agents.iter().enumerate() {
            assert_eq!(a.id.0 as usize, i, "ids must be dense and sequential");
        }
        // Every graph: planner root with >= fanout_min children; workers
        // have indegree 1; reducers join on every worker.
        let roots: Vec<_> =
            agents.iter().filter(|a| graph.is_ready(a.id)).map(|a| a.id).collect();
        assert_eq!(roots.len(), wf_cfg().workflow.graphs, "one root per graph");
        for &r in &roots {
            assert!(
                graph.children_of(r).len() >= wf_cfg().workflow.fanout_min as usize,
                "planner must fan out"
            );
        }
        // Releasing a planner readies exactly its workers.
        let mut g = graph.clone();
        let ready = g.on_finished(roots[0]);
        assert_eq!(ready, graph.children_of(roots[0]).to_vec());
    }

    #[test]
    fn workflow_consumers_share_context_byte_identically_and_aligned() {
        let cfg = wf_cfg();
        let (agents, graph) = workflow_fleet(&cfg);
        let s = cfg.workflow.shared_context_tokens as usize;
        let w = cfg.workflow.align_tokens as usize;
        let sys = cfg.system_prompt_tokens as usize;
        let roots: Vec<_> =
            agents.iter().filter(|a| graph.is_ready(a.id)).map(|a| a.id).collect();
        let mut saw_reducer = false;
        for &r in &roots {
            let workers = graph.children_of(r);
            assert!(!workers.is_empty());
            // The planner's step-0 generation ends with the shared run.
            let planner = &agents[r.0 as usize];
            let gen0 = &planner.plan_for_stats()[0].gen;
            let shared = &gen0[gen0.len() - s..];
            // Every consumer embeds the identical run at an aligned,
            // identical mid-prompt offset.
            let pad = (w - sys % w) % w;
            let off = sys + pad;
            assert_eq!(off % w, 0, "shared context must be chunk-aligned");
            for &c in workers {
                let ctx = agents[c.0 as usize].context();
                assert_eq!(&ctx[off..off + s], shared, "worker context differs");
                for &rc in graph.children_of(c) {
                    saw_reducer = true;
                    let rctx = agents[rc.0 as usize].context();
                    assert_eq!(&rctx[off..off + s], shared, "reducer context differs");
                }
            }
            // And it is W-aligned in the planner's accumulated context:
            // ctx after step 0 = prompt ++ gen0, with S its suffix.
            let s_off = planner.context_len() + gen0.len() - s;
            assert_eq!(s_off % w, 0, "planner-side shared context misaligned");
        }
        assert!(saw_reducer, "default map_reduce_share must produce a reducer");
    }

    #[test]
    fn independent_graph_releases_nothing() {
        let mut g = WorkflowGraph::independent(4);
        assert_eq!(g.len(), 4);
        for i in 0..4 {
            assert!(g.is_ready(AgentId(i)));
            assert!(g.on_finished(AgentId(i)).is_empty());
        }
    }

    #[test]
    fn tool_latencies_are_positive_and_varied() {
        let agents = gen(WorkloadConfig::default());
        let lats: Vec<u64> = agents
            .iter()
            .flat_map(|a| a.plan_for_stats().iter().map(|s| s.tool_latency.0))
            .collect();
        assert!(lats.iter().all(|&l| l > 0));
        let uniq: std::collections::HashSet<_> = lats.iter().collect();
        assert!(uniq.len() > lats.len() / 2);
    }
}
