//! ReAct agent execution layer.
//!
//! Agents follow the ReAct loop (reason → act → observe): each step issues
//! a generation request over the *full accumulated context*, appends the
//! generated tokens, then "calls a tool" (a latency + observation tokens)
//! before the next step.  Context therefore grows monotonically (Fig. 1a)
//! and agents progress asynchronously — the two ingredients of middle-phase
//! thrashing.
//!
//! Trajectories are fully predetermined by the workload generator (token
//! content, step count, tool latencies) so that every scheduler is compared
//! on bit-identical work.

pub mod arrivals;
pub mod trace;
pub mod workload;

pub use arrivals::open_loop_fleet;
pub use workload::{workflow_fleet, WorkflowGraph, WorkloadGenerator, WorkloadStats};

use crate::core::{AgentId, Micros, RequestId, Token};
use crate::engine::Request;

/// Tenant priority class of an open-loop session.  Closed-batch agents
/// default to `High`, which is inert: priority only matters under the
/// open-loop admission path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    High,
    Low,
}

/// Where an agent is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentPhase {
    /// Ready to issue its next generation step (awaiting admission).
    Ready,
    /// A generation request is in flight in the engine.
    Generating,
    /// Waiting on an external tool.
    ToolWait,
    /// Trajectory complete.
    Done,
}

/// One predetermined ReAct step.
#[derive(Debug, Clone)]
pub struct StepPlan {
    /// Tokens the model will generate this step.
    pub gen: Vec<Token>,
    /// Tool observation appended to the context afterwards (empty on the
    /// final step).
    pub tool_tokens: Vec<Token>,
    /// Tool execution latency.
    pub tool_latency: Micros,
}

/// A long-horizon agent with a predetermined trajectory.
#[derive(Debug, Clone)]
pub struct Agent {
    pub id: AgentId,
    pub phase: AgentPhase,
    /// Full accumulated context (system prompt + task + history).
    history: Vec<Token>,
    plan: Vec<StepPlan>,
    step: usize,
    /// Context length after the previous generation step (recompute
    /// boundary — see `engine::Request::prev_ctx`).
    prev_ctx: u64,
    /// Completion time (set when Done).
    pub finished_at: Option<Micros>,
    /// First submission time (for end-to-end agent latency).
    pub started_at: Option<Micros>,
    /// Open-loop arrival instant (ZERO for closed-batch agents, which
    /// are all present when the run starts).
    pub arrival_at: Micros,
    /// Tenant priority class (inert `High` for closed-batch agents).
    pub priority: Priority,
    /// Open-loop patience: the session abandons when one of its turns
    /// has waited longer than this without completing (`None` = never).
    pub patience: Option<Micros>,
}

impl Agent {
    pub fn new(id: AgentId, initial_context: Vec<Token>, plan: Vec<StepPlan>) -> Agent {
        assert!(!plan.is_empty(), "agent needs at least one step");
        Agent {
            id,
            phase: AgentPhase::Ready,
            history: initial_context,
            plan,
            step: 0,
            prev_ctx: 0,
            finished_at: None,
            started_at: None,
            arrival_at: Micros::ZERO,
            priority: Priority::High,
            patience: None,
        }
    }

    pub fn context_len(&self) -> usize {
        self.history.len()
    }

    pub fn steps_total(&self) -> usize {
        self.plan.len()
    }

    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Steps left after the current one completes (0 on the last step).
    /// This is the `StepsToExecution` lifetime hint: how much future this
    /// agent's KV still has in front of it.
    pub fn remaining_steps(&self) -> usize {
        self.plan.len().saturating_sub(self.step + 1)
    }

    /// Tool latency the agent will wait after its *current* step — the
    /// `ToolTtl` lifetime hint (`None` on the final step: there is no
    /// tool call, the KV has no return to be pinned for).
    pub fn next_tool_latency(&self) -> Option<Micros> {
        if self.step + 1 < self.plan.len() {
            Some(self.plan[self.step].tool_latency)
        } else {
            None
        }
    }

    /// Build the generation request for the current step.
    pub fn make_request(&mut self, id: RequestId, now: Micros) -> Request {
        assert_eq!(self.phase, AgentPhase::Ready, "agent {} not ready", self.id);
        if self.started_at.is_none() {
            self.started_at = Some(now);
        }
        self.phase = AgentPhase::Generating;
        Request {
            id,
            agent: self.id,
            prompt: self.history.clone(),
            gen: self.plan[self.step].gen.clone(),
            prev_ctx: self.prev_ctx,
            submitted_at: now,
        }
    }

    /// The engine finished this agent's current step.  Returns the tool
    /// latency to wait before the agent is ready again, or `None` when the
    /// trajectory is complete.
    pub fn on_step_finished(&mut self, output: &[Token], now: Micros) -> Option<Micros> {
        assert_eq!(self.phase, AgentPhase::Generating);
        debug_assert_eq!(output, &self.plan[self.step].gen[..]);
        self.history.extend_from_slice(output);
        self.prev_ctx = self.history.len() as u64;
        let plan = &self.plan[self.step];
        let latency = plan.tool_latency;
        let tool_tokens = plan.tool_tokens.clone();
        self.step += 1;
        if self.step >= self.plan.len() {
            self.phase = AgentPhase::Done;
            self.finished_at = Some(now);
            None
        } else {
            self.history.extend_from_slice(&tool_tokens);
            self.phase = AgentPhase::ToolWait;
            Some(latency)
        }
    }

    /// Tool finished; agent may request its next step.
    pub fn on_tool_done(&mut self) {
        assert_eq!(self.phase, AgentPhase::ToolWait);
        self.phase = AgentPhase::Ready;
    }

    /// The replica executing this agent's in-flight step died: the
    /// step's work is lost and the agent returns to `Ready` to reissue
    /// it — same step, same planned tokens, recomputed from scratch on
    /// whichever replica admission lands it next.  History and the
    /// recompute boundary are untouched (the step never completed).
    pub fn on_replica_failed(&mut self) {
        assert_eq!(self.phase, AgentPhase::Generating, "agent {} had no step in flight", self.id);
        self.phase = AgentPhase::Ready;
    }

    pub fn is_done(&self) -> bool {
        self.phase == AgentPhase::Done
    }

    /// Total tokens this agent will ever generate (for progress metrics).
    pub fn total_gen_tokens(&self) -> u64 {
        self.plan.iter().map(|s| s.gen.len() as u64).sum()
    }

    /// Tokens actually generated so far — the open-loop throughput and
    /// goodput accounting for sessions that were shed or abandoned
    /// mid-trajectory (equals [`Self::total_gen_tokens`] once done).
    pub fn gen_tokens_done(&self) -> u64 {
        self.plan[..self.step].iter().map(|s| s.gen.len() as u64).sum()
    }

    /// Read-only view of the accumulated context.  The cluster's drain
    /// handoff snapshots the resident head of this to checkpoint an
    /// agent's warm KV across replicas; tests and tracing read it too.
    pub fn context(&self) -> &[Token] {
        &self.history
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(steps: usize) -> Vec<StepPlan> {
        (0..steps)
            .map(|k| StepPlan {
                gen: (0..10).map(|i| 1000 * (k as u32 + 1) + i).collect(),
                tool_tokens: (0..5).map(|i| 9000 * (k as u32 + 1) + i).collect(),
                tool_latency: Micros(1_000_000),
            })
            .collect()
    }

    #[test]
    fn lifecycle_follows_react_loop() {
        let mut a = Agent::new(AgentId(1), vec![1, 2, 3], plan(2));
        assert_eq!(a.phase, AgentPhase::Ready);
        let req = a.make_request(RequestId(1), Micros(5));
        assert_eq!(req.prompt, vec![1, 2, 3]);
        assert_eq!(req.prev_ctx, 0);
        assert_eq!(a.phase, AgentPhase::Generating);

        let gen = req.gen.clone();
        let lat = a.on_step_finished(&gen, Micros(10));
        assert_eq!(lat, Some(Micros(1_000_000)));
        assert_eq!(a.phase, AgentPhase::ToolWait);
        // History = initial + gen + tool tokens.
        assert_eq!(a.context_len(), 3 + 10 + 5);
        // Recompute boundary excludes the tool tokens.
        assert_eq!(a.prev_ctx, 13);

        a.on_tool_done();
        let req2 = a.make_request(RequestId(2), Micros(20));
        assert_eq!(req2.prompt.len(), 18);
        assert_eq!(req2.prev_ctx, 13);
        let gen2 = req2.gen.clone();
        let lat2 = a.on_step_finished(&gen2, Micros(30));
        assert_eq!(lat2, None);
        assert!(a.is_done());
        assert_eq!(a.finished_at, Some(Micros(30)));
    }

    #[test]
    fn context_grows_monotonically() {
        let mut a = Agent::new(AgentId(1), vec![0; 100], plan(5));
        let mut prev = a.context_len();
        for i in 0..5 {
            let req = a.make_request(RequestId(i), Micros(i));
            let gen = req.gen.clone();
            a.on_step_finished(&gen, Micros(i));
            assert!(a.context_len() > prev);
            prev = a.context_len();
            if !a.is_done() {
                a.on_tool_done();
            }
        }
        assert!(a.is_done());
    }

    #[test]
    #[should_panic(expected = "not ready")]
    fn cannot_request_while_generating() {
        let mut a = Agent::new(AgentId(1), vec![1], plan(2));
        a.make_request(RequestId(1), Micros::ZERO);
        a.make_request(RequestId(2), Micros::ZERO);
    }

    #[test]
    fn replica_failure_reissues_the_same_step() {
        let mut a = Agent::new(AgentId(1), vec![1, 2, 3], plan(2));
        let req = a.make_request(RequestId(1), Micros(5));
        assert_eq!(a.phase, AgentPhase::Generating);
        // The replica dies mid-step: the agent rewinds to Ready with the
        // identical request content (nothing was appended).
        a.on_replica_failed();
        assert_eq!(a.phase, AgentPhase::Ready);
        assert_eq!(a.steps_done(), 0);
        let retry = a.make_request(RequestId(2), Micros(9));
        assert_eq!(retry.prompt, req.prompt);
        assert_eq!(retry.gen, req.gen);
        assert_eq!(retry.prev_ctx, req.prev_ctx);
        // started_at keeps the original first-submission stamp.
        assert_eq!(a.started_at, Some(Micros(5)));
        // The retried step completes normally.
        let gen = retry.gen.clone();
        assert!(a.on_step_finished(&gen, Micros(20)).is_some());
    }

    #[test]
    #[should_panic(expected = "no step in flight")]
    fn replica_failure_requires_an_inflight_step() {
        let mut a = Agent::new(AgentId(1), vec![1], plan(2));
        a.on_replica_failed();
    }
}
