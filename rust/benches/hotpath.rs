//! Hot-path microbenchmarks for the L3 coordinator + engine substrate.
//!
//! `cargo bench --bench hotpath`.  These are the §Perf targets from
//! DESIGN.md: radix match/insert at serving prompt lengths, LRU eviction,
//! the AIMD decision, one engine iteration at paper-scale batch, and a
//! full end-to-end Table-1-scale run.  Alongside the human-readable report
//! it writes `BENCH_hotpath.json` (name → ns/op; override the path with
//! `BENCH_JSON_PATH`) so the perf trajectory is tracked across PRs.

mod bench_util;
use bench_util::Recorder;

use concur::config::{presets, AimdParams, EngineConfig, JobConfig, SchedulerKind, TopologyConfig};
use concur::coordinator::{AimdController, ControlInputs, Controller};
use concur::core::{Micros, Rng, Token};
use concur::costmodel::CostModel;
use concur::driver::{run_job, run_jobs_parallel};
use concur::engine::{EngineSignals, EvictPolicy, RadixTree};

fn agent_prompt(agent: u32, steps: u32, per_step: u32) -> Vec<Token> {
    // shared 512-token system prefix + per-agent unique growth
    let mut p: Vec<Token> = (0..512).collect();
    for s in 0..steps {
        let base = 1 << 24 | agent << 12 | s << 4;
        p.extend((0..per_step).map(|i| base + i));
    }
    p
}

fn main() {
    let mut rec = Recorder::new();

    // --- radix tree -------------------------------------------------------
    let prompts: Vec<Vec<Token>> =
        (0..64).map(|a| agent_prompt(a, 16, 512)).collect();

    rec.report("radix: insert 64 x 8.7k-token prompts", 20, || {
        let mut t = RadixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(p, Micros(i as u64));
        }
    });

    // Finished-request fold: insert prompt+output without concatenation.
    let outputs: Vec<Vec<Token>> = (0..64)
        .map(|a| ((2 << 24 | a << 8)..(2 << 24 | a << 8) + 512).collect())
        .collect();
    rec.report("radix: insert_parts 64 x (8.7k prompt + 512 out)", 20, || {
        let mut t = RadixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(p, Micros(i as u64));
        }
        for (i, (p, o)) in prompts.iter().zip(&outputs).enumerate() {
            t.insert_parts(p, o, Micros(100 + i as u64));
        }
    });

    // Split churn: probes that always diverge mid-edge (arena split is two
    // range adjustments; the old tree copied both halves).
    rec.report("radix: 1k mid-edge splits (partial matches)", 20, || {
        let mut t = RadixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(p, Micros(i as u64));
        }
        let mut stamp = 500u64;
        for k in 0..1_000usize {
            let p = &prompts[k % 64];
            stamp += 1;
            t.match_prefix(&p[..512 + (k % 8_000)], Micros(stamp));
        }
    });

    let mut warm = RadixTree::new();
    for (i, p) in prompts.iter().enumerate() {
        warm.insert(p, Micros(i as u64));
    }
    let mut stamp = 1_000_000u64;
    rec.report_per("radix: match_prefix 8.7k tokens (warm)", 200, 8704, || {
        stamp += 1;
        let m = warm.match_prefix(&prompts[13], Micros(stamp));
        assert!(m.gpu_tokens > 0);
    });

    rec.report("radix: evict half the tree (64 x 8.7k)", 20, || {
        let mut t = RadixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(p, Micros(i as u64));
        }
        let ev = t.evict(t.gpu_tokens() / 2, EvictPolicy::Discard);
        assert!(ev.freed_gpu_tokens > 0);
    });

    rec.report("radix: evictable_gpu_tokens (U_t signal scan)", 200, || {
        let e = warm.evictable_gpu_tokens();
        assert!(e > 0);
    });

    // --- controller -------------------------------------------------------
    let inputs = ControlInputs {
        engine: EngineSignals {
            kv_usage: 0.4,
            pool_usage: 0.9,
            hit_rate: 0.8,
            running: 32,
            waiting: 4,
        },
        active_agents: 32,
        active_footprint: 120_000,
        capacity: 300_000,
    };
    let mut ctl = AimdController::new(AimdParams { control_interval: 1, ..Default::default() });
    rec.report_per("aimd: 10k control decisions", 50, 10_000, || {
        for _ in 0..10_000 {
            ctl.on_signals(&inputs);
        }
    });

    // --- engine iteration at paper scale -----------------------------------
    rec.report("engine: one iteration, 256 running decode seqs", 50, || {
        let cost = CostModel::new(presets::qwen3_cluster(8));
        let mut engine = concur::engine::SimEngine::new(
            EngineConfig::default(),
            cost,
        );
        let mut rng = Rng::new(1);
        for a in 0..256u64 {
            let base = (a as u32 + 1) << 14;
            engine.submit(concur::engine::Request {
                id: concur::core::RequestId(a),
                agent: concur::core::AgentId(a),
                prompt: (base..base + 1024).collect(),
                gen: (0..64).map(|i| 900_000_000 + a as u32 * 100 + i).collect(),
                prev_ctx: 0,
                submitted_at: Micros::ZERO,
            });
        }
        let mut now = Micros::ZERO;
        for _ in 0..20 {
            let out = engine.step(now);
            now = now + out.duration + Micros(1);
        }
        let _ = rng.next_u64();
    });

    // --- end-to-end simulation ---------------------------------------------
    let table1_job = || JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: presets::qwen3_workload(64),
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig::default(),
    };
    rec.report("driver: full job, 64 agents, Qwen3 TP2, CONCUR", 5, || {
        let r = run_job(&table1_job()).unwrap();
        assert_eq!(r.agents_finished, 64);
    });

    // Parallel sweep harness: 8 independent jobs across all cores (the
    // repro-harness fan-out pattern).
    let sweep: Vec<JobConfig> = (0..8)
        .map(|i| {
            let mut j = table1_job();
            j.workload.seed = 7 + i as u64;
            j
        })
        .collect();
    rec.report("driver: 8-job sweep via run_jobs_parallel", 3, || {
        let rs = run_jobs_parallel(&sweep);
        assert!(rs.iter().all(|r| r.is_ok()));
    });

    let json_path = std::env::var("BENCH_JSON_PATH")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let json_path = std::path::PathBuf::from(json_path);
    rec.write_json(&json_path).expect("write bench json");
    println!("\n(machine-readable results written to {})", json_path.display());
}
