//! Hot-path microbenchmarks for the L3 coordinator + engine substrate.
//!
//! `cargo bench --bench hotpath` (append `-- --quick` for the PR-smoke
//! grid: same metrics, smaller scales, seconds instead of minutes).
//! These are the §Perf targets from DESIGN.md: radix match/insert at
//! serving prompt lengths, LRU eviction, the AIMD decision, one engine
//! iteration at paper-scale batch, and a full end-to-end Table-1-scale
//! run.  Alongside the human-readable report it writes
//! `BENCH_hotpath.json` (override the path with `BENCH_JSON_PATH`) keyed
//! by **stable machine names** (`radix/insert_prompts_ns`, ...) — the
//! same names `ci/perf_thresholds.json` gates on, so renaming a metric
//! here without touching the thresholds fails the gate instead of
//! silently dropping coverage.

mod bench_util;
use bench_util::Recorder;

use concur::config::{presets, AimdParams, EngineConfig, JobConfig, SchedulerKind, TopologyConfig};
use concur::coordinator::{AimdController, ControlInputs, Controller};
use concur::core::{Micros, Rng, Token};
use concur::costmodel::CostModel;
use concur::driver::{run_job, run_jobs_parallel};
use concur::engine::{EngineSignals, EvictPolicy, RadixTree};

fn agent_prompt(agent: u32, steps: u32, per_step: u32) -> Vec<Token> {
    // shared 512-token system prefix + per-agent unique growth
    let mut p: Vec<Token> = (0..512).collect();
    for s in 0..steps {
        let base = 1 << 24 | agent << 12 | s << 4;
        p.extend((0..per_step).map(|i| base + i));
    }
    p
}

/// Scale knobs: the full grid for nightly trend tracking, the `--quick`
/// grid for PR smoke (same metric names, ~seconds of wall clock).
struct Grid {
    prompts: u32,
    samples: usize,
    match_samples: usize,
    job_agents: usize,
    job_samples: usize,
    sweep_jobs: usize,
    step_probe: usize,
}

const FULL: Grid = Grid {
    prompts: 64,
    samples: 20,
    match_samples: 200,
    job_agents: 64,
    job_samples: 5,
    sweep_jobs: 8,
    step_probe: 200,
};

const QUICK: Grid = Grid {
    prompts: 16,
    samples: 5,
    match_samples: 30,
    job_agents: 16,
    job_samples: 2,
    sweep_jobs: 4,
    step_probe: 60,
};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let g = if quick { QUICK } else { FULL };
    println!("hotpath bench · {} grid\n", if quick { "--quick" } else { "full" });
    let mut rec = Recorder::new();

    // --- radix tree -------------------------------------------------------
    let prompts: Vec<Vec<Token>> =
        (0..g.prompts).map(|a| agent_prompt(a, 16, 512)).collect();
    let prompt_len = prompts[0].len() as u64;

    rec.report("radix/insert_prompts_ns", g.samples, || {
        let mut t = RadixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(p, Micros(i as u64));
        }
    });

    // Finished-request fold: insert prompt+output without concatenation.
    let outputs: Vec<Vec<Token>> = (0..g.prompts)
        .map(|a| ((2 << 24 | a << 8)..(2 << 24 | a << 8) + 512).collect())
        .collect();
    rec.report("radix/insert_parts_ns", g.samples, || {
        let mut t = RadixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(p, Micros(i as u64));
        }
        for (i, (p, o)) in prompts.iter().zip(&outputs).enumerate() {
            t.insert_parts(p, o, Micros(100 + i as u64));
        }
    });

    // Split churn: probes that always diverge mid-edge (arena split is two
    // range adjustments; the old tree copied both halves).
    rec.report("radix/mid_edge_splits_ns", g.samples, || {
        let mut t = RadixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(p, Micros(i as u64));
        }
        let mut stamp = 500u64;
        for k in 0..1_000usize {
            let p = &prompts[k % prompts.len()];
            stamp += 1;
            t.match_prefix(&p[..512 + (k % 8_000)], Micros(stamp));
        }
    });

    let mut warm = RadixTree::new();
    for (i, p) in prompts.iter().enumerate() {
        warm.insert(p, Micros(i as u64));
    }
    let mut stamp = 1_000_000u64;
    rec.report_per("radix/match_prefix_ns_per_token", g.match_samples, prompt_len, || {
        stamp += 1;
        let m = warm.match_prefix(&prompts[13 % prompts.len()], Micros(stamp));
        assert!(m.gpu_tokens > 0);
    });

    rec.report("radix/evict_half_tree_ns", g.samples, || {
        let mut t = RadixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(p, Micros(i as u64));
        }
        let ev = t.evict(t.gpu_tokens() / 2, EvictPolicy::Discard);
        assert!(ev.freed_gpu_tokens > 0);
    });

    rec.report("radix/evictable_scan_ns", g.match_samples, || {
        let e = warm.evictable_gpu_tokens();
        assert!(e > 0);
    });

    // --- controller -------------------------------------------------------
    let inputs = ControlInputs {
        engine: EngineSignals {
            kv_usage: 0.4,
            pool_usage: 0.9,
            hit_rate: 0.8,
            running: 32,
            waiting: 4,
        },
        active_agents: 32,
        active_footprint: 120_000,
        capacity: 300_000,
    };
    let mut ctl = AimdController::new(AimdParams { control_interval: 1, ..Default::default() });
    rec.report_per("aimd/decision_ns", 50, 10_000, || {
        for _ in 0..10_000 {
            ctl.on_signals(&inputs);
        }
    });

    // --- engine iteration at paper scale -----------------------------------
    let loaded_engine = || {
        let cost = CostModel::new(presets::qwen3_cluster(8));
        let mut engine = concur::engine::SimEngine::new(EngineConfig::default(), cost);
        for a in 0..256u64 {
            let base = (a as u32 + 1) << 14;
            engine.submit(concur::engine::Request {
                id: concur::core::RequestId(a),
                agent: concur::core::AgentId(a),
                prompt: (base..base + 1024).collect(),
                gen: (0..64).map(|i| 900_000_000 + a as u32 * 100 + i).collect(),
                prev_ctx: 0,
                submitted_at: Micros::ZERO,
            });
        }
        engine
    };
    rec.report("engine/iteration_ns", g.samples, || {
        let mut engine = loaded_engine();
        let mut rng = Rng::new(1);
        let mut now = Micros::ZERO;
        for _ in 0..20 {
            let out = engine.step(now);
            now = now + out.duration + Micros(1);
        }
        let _ = rng.next_u64();
    });

    // Tail latency of a single engine step under a long mixed
    // prefill/decode run — the p99 is what a congested replica's clock
    // advance actually waits on, and it regresses independently of the
    // 20-step median above (e.g. an eviction storm at pool pressure).
    {
        let mut engine = loaded_engine();
        let mut now = Micros::ZERO;
        let mut step_ns: Vec<u128> = Vec::with_capacity(g.step_probe);
        for _ in 0..g.step_probe {
            let t = std::time::Instant::now();
            let out = engine.step(now);
            step_ns.push(t.elapsed().as_nanos());
            now = now + out.duration + Micros(1);
            if !engine.has_work() {
                break;
            }
        }
        step_ns.sort_unstable();
        let p99 = step_ns[(step_ns.len().saturating_sub(1)) * 99 / 100];
        rec.record("engine/step_p99_ns", p99 as f64);
    }

    // --- end-to-end simulation ---------------------------------------------
    let table1_job = || JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: presets::qwen3_workload(g.job_agents),
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig::default(),
    };
    rec.report("driver/full_job_ns", g.job_samples, || {
        let r = run_job(&table1_job()).unwrap();
        assert_eq!(r.agents_finished, g.job_agents);
    });

    // Wall-clock simulation throughput (generated tokens per real second)
    // of that same job — the floor metric: any hot-path regression shows
    // up here even if no single microbench moved past its ceiling.
    {
        let t = std::time::Instant::now();
        let r = run_job(&table1_job()).unwrap();
        let secs = t.elapsed().as_secs_f64().max(1e-9);
        rec.record("driver/full_job_tokens_per_s", r.total_gen_tokens as f64 / secs);
    }

    // Parallel sweep harness: independent jobs across all cores (the
    // repro-harness fan-out pattern).
    let sweep: Vec<JobConfig> = (0..g.sweep_jobs)
        .map(|i| {
            let mut j = table1_job();
            j.workload.seed = 7 + i as u64;
            j
        })
        .collect();
    rec.report("driver/sweep_parallel_ns", 3, || {
        let rs = run_jobs_parallel(&sweep);
        assert!(rs.iter().all(|r| r.is_ok()));
    });

    // --- self-profiler derived metrics -------------------------------------
    // Dedicated profiled runs: the profiler is process-global and
    // wall-clock, so these run alone (nothing in parallel), bracketed by
    // reset/snapshot.  The three derived metrics are the ones the perf
    // gate tracks: radix match throughput (floor), admission latency and
    // clock-stop cost (ceilings).
    {
        use concur::metrics::profiler::{self, Section};
        profiler::reset();
        profiler::set_enabled(true);
        let r = run_job(&table1_job()).unwrap();
        assert_eq!(r.agents_finished, g.job_agents);
        // A 4-replica run of the same job so the cluster clock-advance
        // section sees real boundary/heap churn, not the 1-replica
        // degenerate case.
        let mut cj = table1_job();
        cj.topology.replicas = 4;
        run_job(&cj).unwrap();
        profiler::set_enabled(false);
        let snap = profiler::snapshot();
        rec.record("radix/match_tokens_per_s", snap.get(Section::RadixMatch).units_per_s());
        rec.record("engine/admit_ns", snap.get(Section::Admit).ns_per_call());
        rec.record("cluster/clock_stop_ns", snap.get(Section::ClockAdvance).ns_per_call());
    }

    let json_path = std::env::var("BENCH_JSON_PATH")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let json_path = std::path::PathBuf::from(json_path);
    rec.write_json(&json_path).expect("write bench json");
    println!("\n(machine-readable results written to {})", json_path.display());
}
