//! Bench target regenerating the paper's FIGURES end-to-end.
//!
//! `cargo bench --bench paper_figures` prints fig1(a/b/c), fig3, fig5 and
//! fig6 with wall-time per harness.

fn main() {
    for name in ["fig1", "fig3", "fig5", "fig6"] {
        let t0 = std::time::Instant::now();
        match concur::repro::run(name) {
            Ok(outputs) => {
                for o in &outputs {
                    println!("{}", o.render());
                }
                println!("[{name} regenerated in {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("{name} failed: {e}");
                std::process::exit(1);
            }
        }
    }
}
