//! Bench target regenerating the paper's TABLES end-to-end.
//!
//! `cargo bench --bench paper_tables` prints every table with wall-time
//! per harness.  (Tables are deterministic; timing shows simulation cost.)

fn main() {
    for name in ["table1", "table2", "table3"] {
        let t0 = std::time::Instant::now();
        match concur::repro::run(name) {
            Ok(outputs) => {
                for o in &outputs {
                    println!("{}", o.render());
                }
                println!("[{name} regenerated in {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("{name} failed: {e}");
                std::process::exit(1);
            }
        }
    }
}
