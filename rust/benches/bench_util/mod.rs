//! Minimal timing harness shared by the bench targets.
//!
//! (criterion is not in the vendored crate set; this provides the same
//! warmup + multi-sample + median reporting for our purposes.)

use std::time::{Duration, Instant};

/// Run `f` with warmup and return (median, min, max) over `samples` runs.
pub fn time_it<F: FnMut()>(samples: usize, mut f: F) -> (Duration, Duration, Duration) {
    f(); // warmup
    let mut times: Vec<Duration> = (0..samples.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect();
    times.sort();
    (times[times.len() / 2], times[0], times[times.len() - 1])
}

pub fn report(name: &str, samples: usize, f: impl FnMut()) {
    let (med, min, max) = time_it(samples, f);
    println!(
        "{name:<52} median {:>12.3?}  (min {:>12.3?}, max {:>12.3?})",
        med, min, max
    );
}

/// Report with a custom per-iteration unit count (e.g. ops per call).
#[allow(dead_code)]
pub fn report_per(name: &str, samples: usize, units: u64, f: impl FnMut()) {
    let (med, _, _) = time_it(samples, f);
    let per = med.as_nanos() as f64 / units.max(1) as f64;
    println!(
        "{name:<52} median {:>12.3?}  ({per:>10.1} ns/op over {units} ops)",
        med
    );
}
