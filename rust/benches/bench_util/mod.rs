//! Minimal timing harness shared by the bench targets.
//!
//! (criterion is not in the vendored crate set; this provides the same
//! warmup + multi-sample + median reporting for our purposes, plus a
//! machine-readable JSON dump so the perf trajectory is tracked across
//! PRs — see DESIGN.md §Perf.)

use std::time::{Duration, Instant};

/// Run `f` with warmup and return (median, min, max) over `samples` runs.
pub fn time_it<F: FnMut()>(samples: usize, mut f: F) -> (Duration, Duration, Duration) {
    f(); // warmup
    let mut times: Vec<Duration> = (0..samples.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect();
    times.sort();
    (times[times.len() / 2], times[0], times[times.len() - 1])
}

/// Collects every measurement of a bench run and can dump them as
/// `{"bench name": ns_per_op, ...}` JSON next to the human-readable report.
pub struct Recorder {
    /// (name, median ns per unit op).
    entries: Vec<(String, f64)>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder { entries: Vec::new() }
    }

    /// Time `f`, print the human-readable line, record median ns/op
    /// (units = 1, i.e. per call).
    pub fn report(&mut self, name: &str, samples: usize, f: impl FnMut()) {
        let (med, min, max) = time_it(samples, f);
        println!(
            "{name:<52} median {:>12.3?}  (min {:>12.3?}, max {:>12.3?})",
            med, min, max
        );
        self.entries.push((name.to_string(), med.as_nanos() as f64));
    }

    /// Like [`Recorder::report`], with `units` inner operations per call.
    pub fn report_per(&mut self, name: &str, samples: usize, units: u64, f: impl FnMut()) {
        let (med, _, _) = time_it(samples, f);
        let per = med.as_nanos() as f64 / units.max(1) as f64;
        println!(
            "{name:<52} median {:>12.3?}  ({per:>10.1} ns/op over {units} ops)",
            med
        );
        self.entries.push((name.to_string(), per));
    }

    /// Record a value measured outside the timing harness (throughputs,
    /// percentiles) so it lands in the JSON dump — and the perf gate —
    /// alongside the timed entries.
    pub fn record(&mut self, name: &str, value: f64) {
        println!("{name:<52} value  {value:>12.1}");
        self.entries.push((name.to_string(), value));
    }

    /// Write `{name -> ns_per_op}` through the crate's own JSON codec.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        use concur::core::json::Value;
        use std::collections::BTreeMap;

        let mut map: BTreeMap<String, Value> = BTreeMap::new();
        for (name, per) in &self.entries {
            map.insert(name.clone(), Value::Number(*per));
        }
        std::fs::write(path, format!("{}\n", Value::Object(map).to_string_pretty()))
    }
}
